"""DELTA-Failsafe chaos suite: fault-injected fleet robustness metrics.

Three measurements, all seeded and generation-bounded so the emitted
quality metrics are deterministic and gate-able by
benchmarks/check_regression.py:

  * scripted fabric faults on a two-tenant fleet -- per-event repair
    latency plus the chosen option and the masked-makespan inflation the
    repair accepted (``chaos/repair/<event>``);
  * a pool of seeded `FaultInjector` traces driven through fresh planners
    -- ledger conservation is checked after every event and the row
    records the violation count, which must stay at zero
    (``chaos/traces``);
  * journal-based crash recovery -- snapshot + tail replay wall clock and
    whether the recovered planner's decision history is bit-identical
    (``chaos/recovery``);
  * the solver fallback chain under a zero MILP budget -- the stage that
    produced the plan and its makespan (``chaos/fallback``).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import Row, save_json
from repro.core.ga import GAOptions
from repro.core.milp import solve_resilient
from repro.core.schedule import build_comm_dag
from repro.core.traffic import JobSpec
from repro.fleet import (FaultInjector, FleetPlanner, FleetSpec, JobArrival,
                         LinkFailure, LinkRecovery, PlaneFailure,
                         PlaneRecovery, PlanCache, fault_events_from_trace)
from repro.obs import FleetJournal


def _ga_opts(full: bool, smoke: bool) -> GAOptions:
    gens = 40 if full else (10 if smoke else 20)
    return GAOptions(seed=0, pop_size=32 if full else 16,
                     max_generations=gens, patience=10**9, time_limit=1e9)


def _job(name: str, pp: int = 4, mb: int = 4) -> JobSpec:
    return JobSpec(name=name, tp=2, pp=pp, dp=2, num_microbatches=mb,
                   micro_tokens=4096, d_model=4096,
                   stage_params=(1.75e9,) * pp, gpus_per_pod_per_replica=4)


def _planner(opts: GAOptions, cache: PlanCache, seed: int = 0,
             **kw) -> FleetPlanner:
    fleet = FleetSpec(num_pods=6, ports_per_pod=16, nic_gbps=100.0)
    return FleetPlanner(fleet, ga_options=opts, cache=cache, seed=seed, **kw)


def _admit(pl: FleetPlanner) -> None:
    pl.handle(JobArrival(name="a", job=_job("ja")))
    pl.handle(JobArrival(name="b", job=_job("jb", pp=2), port_min=True))


def _repair_rows(opts: GAOptions, cache: PlanCache) -> list[Row]:
    """Scripted faults; each row is one `handle()` call on a live fleet."""
    pl = _planner(opts, cache)
    _admit(pl)
    ms_healthy = pl.tenants["a"].plan.makespan
    events = [
        ("link50", LinkFailure(pair=(0, 1), fraction=0.5)),
        ("plane_down", PlaneFailure(plane=0)),
        ("recovery", LinkRecovery(pair=(0, 1))),
        ("all_clear", PlaneRecovery(plane=0)),
    ]
    rows: list[Row] = []
    for label, ev in events:
        t0 = time.time()
        record = pl.handle(ev)
        dt = time.time() - t0
        repairs = record.get("repairs", [])
        dec = next((r for r in repairs if r["tenant"] == "a"), None)
        ms = dec["makespan"] if dec else pl.tenants["a"].plan.makespan
        infl = ms / ms_healthy if np.isfinite(ms) and ms_healthy > 0 else 0.0
        rows.append(Row(
            f"chaos/repair/{label}", dt * 1e6,
            f"option={dec['option'] if dec else 'none'};"
            f"makespan={ms:.6f};inflation={infl:.4f};"
            f"repairs={len(repairs)}"))
    pl.ledger.check()
    return rows


def _trace_rows(opts: GAOptions, cache: PlanCache, full: bool,
                smoke: bool) -> list[Row]:
    """Seeded fault traces through fresh planners; the ledger must balance
    after every event and no event may raise."""
    num_traces = 40 if full else 20
    trace_len = 8 if full else (5 if smoke else 8)
    violations = 0
    events = repairs = replans = 0
    t0 = time.time()
    for seed in range(num_traces):
        pl = _planner(opts, cache, seed=seed)
        _admit(pl)
        inj = FaultInjector(num_pods=pl.fleet.num_pods, seed=seed,
                            max_fraction=0.9)
        for ev in fault_events_from_trace(inj.trace(trace_len)):
            try:
                record = pl.handle(ev)   # runs ledger.check() internally
            except Exception:            # noqa: BLE001
                violations += 1
                continue
            events += 1
            repairs += len(record.get("repairs", []))
            replans += len(record.get("replans", []))
        for name in pl.tenants:
            acct = pl.ledger.account(name)
            if (acct.allocated > acct.limits).any():
                violations += 1
    dt = time.time() - t0
    return [Row(
        "chaos/traces", dt * 1e6,
        f"traces={num_traces};events={events};violations={violations};"
        f"repairs={repairs};replans={replans}")]


def _recovery_rows(opts: GAOptions, cache: PlanCache) -> list[Row]:
    """Crash-recovery drill: snapshot + journal-tail replay must land on a
    bit-identical decision history."""
    import tempfile
    events = [
        LinkFailure(pair=(0, 1), fraction=0.5),
        PlaneFailure(plane=0),
        LinkRecovery(pair=(0, 1)),
        PlaneRecovery(plane=0),
    ]
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "journal.jsonl")
        pl = _planner(opts, cache, snapshot_every=3,
                      journal=FleetJournal(path))
        _admit(pl)
        for ev in events:
            pl.handle(ev)
        pl.journal.close()
        t0 = time.time()
        pl2 = FleetPlanner.recover(path, pl.fleet, ga_options=opts,
                                   cache=PlanCache(), snapshot_every=3)
        dt = time.time() - t0
        same = json.dumps(pl.history, default=str) == \
            json.dumps(pl2.history, default=str)
    return [Row(
        "chaos/recovery", dt * 1e6,
        f"identical={int(same)};events={len(events) + 2};"
        f"snapshots={pl._events_handled // 3}")]


def _fallback_rows(opts: GAOptions) -> list[Row]:
    """Solver fallback chain with a zero MILP budget: the chain must skip
    straight past the MILP and still return a validate-clean plan."""
    dag = build_comm_dag(_job("fb", pp=2, mb=2))
    t0 = time.time()
    res = solve_resilient(dag, budget_s=0.0, ga_options=opts)
    dt = time.time() - t0
    stage = getattr(res, "fallback_stage", None) or "milp"
    return [Row(
        "chaos/fallback", dt * 1e6,
        f"stage={stage};degraded={int(bool(getattr(res, 'degraded', 0)))};"
        f"makespan={res.makespan:.6f};feasible={int(res.feasible)}")]


def run(full: bool = False) -> list[Row]:
    from repro.core.des_jax import des_cache_stats
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    opts = _ga_opts(full, smoke)
    # one shared plan cache: arrivals repeat across traces, so after the
    # first planner the admission path is cache-hits and the suite
    # measures fault handling, not GA planning
    cache = PlanCache()
    rows: list[Row] = []
    t_suite = time.time()
    cache0 = des_cache_stats()
    rows += _repair_rows(opts, cache)
    rows += _trace_rows(opts, cache, full, smoke)
    rows += _recovery_rows(opts, cache)
    rows += _fallback_rows(opts)
    cache1 = des_cache_stats()
    wall = time.time() - t_suite
    compiles = cache1["misses"] - cache0["misses"]
    rows.append(Row(
        "chaos/suite_wall", wall * 1e6,
        f"seconds={wall:.2f};des_compiles={compiles};"
        f"des_cache_reuses={cache1['hits'] - cache0['hits']}"))
    save_json("chaos_bench", {
        "rows": [{"name": r.name, "us_per_call": r.us_per_call,
                  "derived": r.derived} for r in rows],
        "seconds": wall, "des_compiles": compiles})
    return rows
