"""CI benchmark-regression gate.

Compares a fresh smoke run's ``BENCH_<suite>.json`` (under
``experiments/bench/``) against the committed repo-root baselines and fails
on regressions, so a PR cannot silently lose the perf wins the baselines
record (e.g. the vectorized GA speedup or the robust-plan regret):

  * quality metrics (``makespan=...`` / ``worst_regret=...`` inside a row's
    ``derived`` string): fresh > baseline * (1 + metric_tol) fails
    (default +20%; these are deterministic seeded quantities);
  * wall clock (``us_per_call``): fresh > baseline * wall_ratio fails
    (default 2x, with per-suite overrides because shared CI runners are
    noisy); rows faster than ``--wall-floor-us`` are skipped entirely;
  * a fresh suite carrying an ``error`` or missing a baseline row fails.

Usage (exactly what CI runs after the benchmark smoke step):

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline-dir /tmp/bench-baselines --suites des,ga,tab1,robust

ORDERING CAVEAT: ``benchmarks.run`` mirrors every fresh ``BENCH_*.json``
over the repo-root copies as it finishes, so the committed baselines must
be snapshotted (or read via ``git show HEAD:BENCH_<suite>.json``) BEFORE
the smoke run -- gating the repo root after a smoke run compares the
fresh payload to itself.  CI snapshots to /tmp/bench-baselines first.

Exit status 0 = no regression, 1 = regression (with a per-row diff table).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# violations gates the chaos suite's ledger-conservation count: with a
# committed baseline of 0, any fresh violation fails (0 * (1+tol) < 1)
METRIC_KEYS = ("makespan", "worst_regret", "violations")
DEFAULT_METRIC_TOL = 0.20      # >20% quality regression fails
DEFAULT_WALL_RATIO = 2.0       # >2x wall-clock regression fails
DEFAULT_WALL_FLOOR_US = 10_000.0   # ignore wall noise on sub-10ms rows

# per-suite tolerance overrides: tab1 rows time DAG *builds* (millisecond
# scale, jittery on shared runners); ga/des/robust time GA/XLA paths whose
# compile times vary across runner generations.  The committed baselines
# are produced on the PR author's machine, so the wall gate is a blowup
# detector, not a precision benchmark: quality metrics (deterministic,
# seeded) carry the tight 20% bound, wall clock gets generous ratios plus
# the REPRO_GATE_WALL_SCALE escape hatch for known-slow runners.
SUITE_TOL: dict[str, dict[str, float]] = {
    "tab1": {"wall": 5.0},
    "des": {"wall": 4.0},
    "ga": {"wall": 4.0},
    "robust": {"wall": 4.0},
    "chaos": {"wall": 4.0},
    "steering": {"wall": 4.0},
    "planes": {"wall": 4.0},
}

# rows that MUST exist in both the committed baseline and the fresh run:
# the robust suite-total wall clock pins the fused-DES engine wins
# (bucketed jit cache, kernel-backed fair-share loop) -- losing the row
# (e.g. a refactor silently dropping it) must fail the gate, not skip it
REQUIRED_ROWS: dict[str, tuple[str, ...]] = {
    "robust": ("robust/suite_wall",),
    # chaos/traces pins the zero-ledger-violation invariant: losing the
    # row (or the suite) must fail the gate, not silently skip it
    "chaos": ("chaos/suite_wall", "chaos/traces"),
    # steering/policy pins controller-beats-both-trivial-policies (its
    # violations metric gates at the committed zero baseline)
    "steering": ("steering/suite_wall", "steering/policy"),
    # planes/transition pins the exact-oracle step certification and
    # planes/midfault pins never-stranded; both gate violations at the
    # committed zero baseline
    "planes": ("planes/suite_wall", "planes/transition", "planes/midfault"),
}


def parse_derived(derived: str) -> dict[str, float]:
    """``k1=v1;k2=v2`` -> {k: float(v)} keeping only float-parsable values."""
    out: dict[str, float] = {}
    for part in str(derived).split(";"):
        if "=" not in part:
            continue
        key, _, val = part.partition("=")
        try:
            out[key.strip()] = float(val)
        except ValueError:
            continue
    return out


def load_suite(path: str) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def compare_suite(suite: str, base: dict, fresh: dict, metric_tol: float,
                  wall_ratio: float, wall_floor_us: float
                  ) -> tuple[list[str], list[str]]:
    """Returns (problems, report_lines) for one suite."""
    tol = SUITE_TOL.get(suite, {})
    metric_tol = tol.get("metric", metric_tol)
    wall_scale = float(os.environ.get("REPRO_GATE_WALL_SCALE", "1.0"))
    wall_ratio = tol.get("wall", wall_ratio) * wall_scale
    problems: list[str] = []
    lines: list[str] = []

    if fresh.get("error"):
        problems.append(f"{suite}: fresh run errored: {fresh['error']}")
        return problems, lines
    fresh_rows = {r["name"]: r for r in fresh.get("rows", [])}
    base_names = {r["name"] for r in base.get("rows", [])}
    for required in REQUIRED_ROWS.get(suite, ()):
        for side, present in (("baseline", required in base_names),
                              ("fresh run", required in fresh_rows)):
            if not present:
                problems.append(f"{suite}: required row {required!r} "
                                f"missing from the {side}")
    for brow in base.get("rows", []):
        name = brow["name"]
        frow = fresh_rows.get(name)
        if frow is None:
            problems.append(f"{suite}: baseline row {name!r} missing "
                            f"from the fresh run")
            continue
        # wall clock -- the floor must consider BOTH sides: a sub-floor
        # baseline row that blows up to seconds is exactly the regression
        # the gate exists to catch
        b_us, f_us = float(brow["us_per_call"]), float(frow["us_per_call"])
        if max(b_us, f_us) >= wall_floor_us:
            ratio = f_us / max(b_us, 1e-9)
            ok = ratio <= wall_ratio
            lines.append(f"{name:<44} wall_us {b_us:>12.0f} {f_us:>12.0f} "
                         f"x{ratio:>5.2f}  {'ok' if ok else 'FAIL'}")
            if not ok:
                problems.append(
                    f"{suite}: {name} wall clock {f_us:.0f}us vs baseline "
                    f"{b_us:.0f}us (x{ratio:.2f} > x{wall_ratio:.2f})")
        # span summaries (from `benchmarks.run --trace`): carried into the
        # report so baseline diffs can attribute a wall-clock move to jit
        # churn vs simulate vs solver time, but NOT gated on -- tracing is
        # optional and the summaries depend on whether a side ran traced
        for side, row in (("base", brow), ("fresh", frow)):
            spans = row.get("spans")
            if spans:
                parts = ", ".join(
                    f"{k}:{v['total_s']:.3f}s/{v['count']}"
                    for k, v in sorted(spans.items()))
                lines.append(f"{name:<44} spans({side}) {parts}")
        # quality metrics
        bm = parse_derived(brow.get("derived", ""))
        fm = parse_derived(frow.get("derived", ""))
        for key in METRIC_KEYS:
            if key not in bm:
                continue
            if key not in fm:
                problems.append(f"{suite}: {name} lost metric {key!r}")
                continue
            bv, fv = bm[key], fm[key]
            ok = fv <= bv * (1 + metric_tol) + 1e-12
            lines.append(f"{name:<44} {key:<8} {bv:>12.6f} {fv:>12.6f} "
                         f"{'ok' if ok else 'FAIL'}")
            if not ok:
                problems.append(
                    f"{suite}: {name} {key} {fv:.6f} vs baseline "
                    f"{bv:.6f} (+{(fv / max(bv, 1e-12) - 1) * 100:.1f}% > "
                    f"+{metric_tol * 100:.0f}%)")
    return problems, lines


def main(argv: list[str] | None = None) -> int:
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", default=repo_root,
                    help="committed BENCH_*.json baselines (repo root)")
    ap.add_argument("--fresh-dir",
                    default=os.environ.get("REPRO_BENCH_OUT",
                                           "experiments/bench"),
                    help="fresh smoke-run output directory")
    ap.add_argument("--suites", default="des,ga,tab1,robust",
                    help="comma-separated suites to gate")
    ap.add_argument("--metric-tol", type=float, default=DEFAULT_METRIC_TOL)
    ap.add_argument("--wall-ratio", type=float, default=DEFAULT_WALL_RATIO)
    ap.add_argument("--wall-floor-us", type=float,
                    default=DEFAULT_WALL_FLOOR_US)
    args = ap.parse_args(argv)

    problems: list[str] = []
    for suite in [s.strip() for s in args.suites.split(",") if s.strip()]:
        fname = f"BENCH_{suite}.json"
        base = load_suite(os.path.join(args.baseline_dir, fname))
        fresh = load_suite(os.path.join(args.fresh_dir, fname))
        if base is None:
            if REQUIRED_ROWS.get(suite):
                # a suite with pinned rows must not lose its gate by
                # losing the baseline file itself
                problems.append(
                    f"{suite}: committed baseline {fname} is missing but "
                    f"the suite has required rows "
                    f"{list(REQUIRED_ROWS[suite])}; restore the baseline")
            else:
                print(f"# {suite}: no committed baseline ({fname}); "
                      f"skipping")
            continue
        if fresh is None:
            problems.append(f"{suite}: fresh run produced no {fname} "
                            f"under {args.fresh_dir}")
            continue
        suite_problems, lines = compare_suite(
            suite, base, fresh, args.metric_tol, args.wall_ratio,
            args.wall_floor_us)
        print(f"# suite {suite}: {len(base.get('rows', []))} baseline rows, "
              f"{len(suite_problems)} regression(s)")
        for line in lines:
            print("  " + line)
        problems.extend(suite_problems)

    if problems:
        print("\nBENCHMARK REGRESSIONS:")
        for p in problems:
            print("  - " + p)
        return 1
    print("\nno benchmark regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
