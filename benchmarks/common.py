"""Shared helpers for the benchmark modules.

Every benchmark emits rows `name,us_per_call,derived`; `us_per_call` is the
wall time of the measured operation in microseconds and `derived` the
figure's metric (NCT, port ratio, solve time, ...).

Default scale: the paper's workloads with reduced microbatch counts so the
whole `python -m benchmarks.run` completes in minutes on CPU; pass --full
for paper-scale (# of MBS = 8 x PP, 600 s solver budgets).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

from repro.configs import PAPER_WORKLOADS, make_job
from repro.core.api import optimize
from repro.core.ga import GAOptions
from repro.core.milp import MILPOptions
from repro.core.schedule import build_comm_dag

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")

WORKLOADS = ("gpt-7b", "megatron-177b", "mixtral-8x22b", "megatron-462b",
             "deepseek-671b")
# MILP variants run on the tractable subset by default.  mixtral-8x22b used
# to be here, but that was an artifact of the bug this repo fixed: its DAG
# silently dropped the expert-parallel all-to-all and carried only 16 DP
# tasks.  The corrected MoE DAG (272 tasks at reduced scale) needs
# Gurobi-class budgets, so only gpt-7b stays HiGHS-tractable by default;
# delta-fast covers the MoE workloads.
MILP_WORKLOADS = ("gpt-7b",)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def emit(self) -> str:
        line = f"{self.name},{self.us_per_call:.1f},{self.derived}"
        print(line, flush=True)
        return line


def save_json(name: str, payload, out_dir: str = OUT_DIR) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def bench_dag(workload: str, bandwidth: float = 400.0, seq_len: int = 4096,
              full: bool = False, mb: int | None = None,
              reverse: bool = False):
    arch = PAPER_WORKLOADS[workload]
    if mb is None:
        # reduced default: pp microbatches keeps the MILP variants tractable
        # under HiGHS (paper scale via --full: 8 x pp and Gurobi-level time)
        mb = arch.plan.num_microbatches if full else \
            max(arch.plan.pp, 4 if workload == "gpt-7b" else 8)
    job = make_job(arch, seq_len=seq_len, microbatches=mb)
    return build_comm_dag(job, inter_pod_gbps=bandwidth,
                          reverse_stages=reverse)


def ga_opts(full: bool) -> GAOptions:
    return GAOptions(seed=0, time_limit=120.0 if full else 25.0,
                     patience=60 if full else 25)


def milp_opts(full: bool, **kw) -> MILPOptions:
    return MILPOptions(time_limit=600.0 if full else 120.0,
                       mip_rel_gap=1e-4 if full else 2e-3, **kw)


def run_method(dag, method: str, full: bool, port_min: bool = False):
    t0 = time.time()
    res = optimize(dag, method, port_min=port_min,
                   ga_options=ga_opts(full),
                   milp_options=milp_opts(full, port_min=port_min))
    return res, time.time() - t0


def nct_str(res) -> str:
    return f"nct={res.nct:.4f};ports={res.total_ports}" if res.feasible \
        else "infeasible"
