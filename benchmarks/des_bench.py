"""DES engine throughput: numpy event loop vs batched JAX vmap fitness
(the TPU-native ParallelEvalDES adaptation), plus the bucketed compile
cache (a fresh `JaxDES` on a warm bucket must not re-jit)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, bench_dag
from repro.core.des import DESProblem, simulate
from repro.core.des_jax import JaxDES, des_cache_stats
from repro.core.ga import TopologySpace


def run(full: bool = False) -> list[Row]:
    rows = []
    w = "megatron-462b" if full else "mixtral-8x22b"
    dag = bench_dag(w, full=False)
    prob = DESProblem(dag)
    space = TopologySpace(dag)
    rng = np.random.default_rng(0)
    xs = np.stack([space.to_matrix(space.feasible_random_init(rng))
                   for _ in range(32)])

    t0 = time.time()
    for x in xs[:8]:
        simulate(prob, x)
    us_np = (time.time() - t0) / 8 * 1e6
    rows.append(Row(f"des/numpy/{w}", us_np,
                    f"tasks={dag.num_real_tasks};"
                    f"events_per_s={prob.n*2/us_np*1e6:.0f}"))

    jd = JaxDES(prob)
    jd.batch_makespan(xs)  # compile
    t0 = time.time()
    ms, feas = jd.batch_makespan(xs)
    us_jax = (time.time() - t0) / len(xs) * 1e6
    # agreement check on the batch
    ok = all(abs(float(ms[i]) - simulate(prob, xs[i]).makespan)
             / max(simulate(prob, xs[i]).makespan, 1e-9) < 1e-4
             for i in range(4) if feas[i])
    rows.append(Row(f"des/jax_vmap32/{w}", us_jax,
                    f"speedup_vs_numpy={us_np/us_jax:.1f}x;match={ok}"))

    # fused genome->topology scatter + vmap DES (the GA generation step)
    G = np.stack([space.genome_of(x) for x in xs])
    jd.batch_genome_makespan(G, space.edge_u, space.edge_v)  # compile
    t0 = time.time()
    ms_g, feas_g = jd.batch_genome_makespan(G, space.edge_u, space.edge_v)
    us_gen = (time.time() - t0) / len(G) * 1e6
    agree = bool((feas_g == feas).all()) and bool(
        np.allclose(ms_g[feas_g], ms[feas], rtol=1e-6))
    rows.append(Row(f"des/jax_genome32/{w}", us_gen,
                    f"speedup_vs_numpy={us_np/us_gen:.1f}x;match={agree}"))

    # jit churn: constructing a FRESH JaxDES on the (now warm) bucket and
    # evaluating must reuse the cached executables instead of recompiling
    # (pre-bucketing this cost a full XLA compile, seconds per instance)
    stats0 = des_cache_stats()
    t0 = time.time()
    jd2 = JaxDES(DESProblem(dag))
    ms2, _ = jd2.batch_genome_makespan(G, space.edge_u, space.edge_v)
    us_fresh = (time.time() - t0) * 1e6
    stats1 = des_cache_stats()
    rows.append(Row(
        f"des/jit_cache_reuse/{w}", us_fresh,
        f"recompiles={stats1['misses'] - stats0['misses']};"
        f"cache_hits={stats1['hits'] - stats0['hits']};"
        f"match={bool(np.allclose(ms2, ms_g, equal_nan=True))}"))
    return rows
