"""DES engine throughput: numpy event loop vs batched JAX vmap fitness
(the TPU-native ParallelEvalDES adaptation)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, bench_dag
from repro.core.des import DESProblem, simulate
from repro.core.des_jax import JaxDES
from repro.core.ga import TopologySpace


def run(full: bool = False) -> list[Row]:
    rows = []
    w = "megatron-462b" if full else "mixtral-8x22b"
    dag = bench_dag(w, full=False)
    prob = DESProblem(dag)
    space = TopologySpace(dag)
    rng = np.random.default_rng(0)
    xs = np.stack([space.to_matrix(space.feasible_random_init(rng))
                   for _ in range(32)])

    t0 = time.time()
    for x in xs[:8]:
        simulate(prob, x)
    us_np = (time.time() - t0) / 8 * 1e6
    rows.append(Row(f"des/numpy/{w}", us_np,
                    f"tasks={dag.num_real_tasks};"
                    f"events_per_s={prob.n*2/us_np*1e6:.0f}"))

    jd = JaxDES(prob)
    jd.batch_makespan(xs)  # compile
    t0 = time.time()
    ms, feas = jd.batch_makespan(xs)
    us_jax = (time.time() - t0) / len(xs) * 1e6
    # agreement check on the batch
    ok = all(abs(float(ms[i]) - simulate(prob, xs[i]).makespan)
             / max(simulate(prob, xs[i]).makespan, 1e-9) < 1e-4
             for i in range(4) if feas[i])
    rows.append(Row(f"des/jax_vmap32/{w}", us_jax,
                    f"speedup_vs_numpy={us_np/us_jax:.1f}x;match={ok}"))

    # fused genome->topology scatter + vmap DES (the GA generation step)
    G = np.stack([space.genome_of(x) for x in xs])
    jd.batch_genome_makespan(G, space.edge_u, space.edge_v)  # compile
    t0 = time.time()
    ms_g, feas_g = jd.batch_genome_makespan(G, space.edge_u, space.edge_v)
    us_gen = (time.time() - t0) / len(G) * 1e6
    agree = bool((feas_g == feas).all()) and bool(
        np.allclose(ms_g[feas_g], ms[feas], rtol=1e-6))
    rows.append(Row(f"des/jax_genome32/{w}", us_gen,
                    f"speedup_vs_numpy={us_np/us_gen:.1f}x;match={agree}"))
    return rows
