"""Fig. 10: reduced NCTs of bandwidth-bottlenecked workloads by
reallocating surplus ports (Model^T = reversed stage-to-pod mapping).

Runs end-to-end through the fleet subsystem: a port-minimized donor and its
reversed-placement co-tenant are admitted onto the same pods, the donor's
trimmed ports are donated to the pool, and the replanning loop waterfills
them into the co-tenant, whose boosted topology is chosen by one batched
`JaxDES` evaluation (`repro.fleet.realloc`).
"""
from __future__ import annotations

import time

from benchmarks.common import Row, ga_opts, save_json
from repro.configs import PAPER_WORKLOADS, make_job
from repro.fleet import FleetPlanner, FleetSpec, JobArrival


def run(full: bool = False) -> list[Row]:
    rows = []
    payload = {}
    for w in ("gpt-7b", "mixtral-8x22b"):
        arch = PAPER_WORKLOADS[w]
        mb = arch.plan.num_microbatches if full else 2 * arch.plan.pp
        job = make_job(arch, microbatches=mb)
        placement = job.placement()
        fleet = FleetSpec(num_pods=placement.num_pods,
                          ports_per_pod=2 * max(placement.port_limits()),
                          nic_gbps=100.0)
        planner = FleetPlanner(fleet, ga_options=ga_opts(full), seed=0)

        t0 = time.time()
        donor = planner.handle(JobArrival(
            "model", job, port_min=True))       # frees + donates ports
        dt0 = time.time() - t0
        t0 = time.time()
        cot = planner.handle(JobArrival(
            "model_t", job, reverse_stages=True))   # bottlenecked co-tenant
        dt1 = time.time() - t0

        nct_before = cot["nct"]
        nct_after = planner.tenants["model_t"].plan.nct
        surplus = donor["donated_ports"]
        derived = (f"nct_before={nct_before:.4f};nct_after={nct_after:.4f};"
                   f"surplus_ports={surplus}")
        rows.append(Row(f"fig10/{w}", (dt0 + dt1) * 1e6, derived))
        payload[w] = {"before": nct_before, "after": nct_after,
                      "surplus": surplus}
    save_json("fig10_realloc", payload)
    return rows
