"""Fig. 10: reduced NCTs of bandwidth-bottlenecked workloads by
reallocating surplus ports (Model^T = reversed stage-to-pod mapping)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, bench_dag, ga_opts, run_method, save_json
from repro.configs import PAPER_WORKLOADS, make_job
from repro.core.ga import delta_fast, trim_ports
from repro.core.schedule import build_comm_dag


def run(full: bool = False) -> list[Row]:
    rows = []
    payload = {}
    for w in ("gpt-7b", "mixtral-8x22b"):
        # donor job: port-minimized topology frees ports
        mb = None if full else 2 * PAPER_WORKLOADS[w].plan.pp
        dag = bench_dag(w, bandwidth=100.0, full=full, mb=mb)
        ga = delta_fast(dag, ga_opts(full))
        x_saved = trim_ports(dag, ga.x)
        U = np.asarray(dag.cluster.port_limits)
        surplus = U - x_saved.sum(axis=1)
        # bottlenecked co-tenant: same workload, reversed placement
        dag_t = bench_dag(w, bandwidth=100.0, full=full, mb=mb,
                          reverse=True)
        r0, dt0 = run_method(dag_t, "delta-fast", full)
        arch = PAPER_WORKLOADS[w]
        job = make_job(arch, microbatches=mb or
                       arch.plan.num_microbatches)
        boosted = dag_t.cluster.with_port_limits(U + surplus)
        dag_boost = build_comm_dag(job, inter_pod_gbps=100.0,
                                   reverse_stages=True, cluster=boosted)
        r1, dt1 = run_method(dag_boost, "delta-fast", full)
        derived = (f"nct_before={r0.nct:.4f};nct_after={r1.nct:.4f};"
                   f"surplus_ports={int(surplus.sum())}")
        rows.append(Row(f"fig10/{w}", (dt0 + dt1) * 1e6, derived))
        payload[w] = {"before": r0.nct, "after": r1.nct,
                      "surplus": int(surplus.sum())}
    save_json("fig10_realloc", payload)
    return rows
