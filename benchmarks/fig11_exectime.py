"""Fig. 11: execution time of the DELTA algorithms vs # of microbatches,
including the hot-start speedup."""
from __future__ import annotations

import time

from benchmarks.common import Row, bench_dag, ga_opts, milp_opts, save_json
from repro.core.ga import delta_fast
from repro.core.milp import solve_delta_milp


def run(full: bool = False) -> list[Row]:
    rows = []
    payload = {}
    # delta-fast scales on the MoE workload (now carrying the full EP
    # all-to-all); the MILP timing series runs on gpt-7b, the remaining
    # HiGHS-tractable workload (see benchmarks.common.MILP_WORKLOADS)
    w = "mixtral-8x22b"
    w_milp = "gpt-7b"
    mbs = (16, 32, 64, 128) if full else (8, 16)
    milp_mbs = mbs if full else (8, 16)
    for mb in mbs:
        dag = bench_dag(w, full=full, mb=mb)
        t0 = time.time()
        ga = delta_fast(dag, ga_opts(full))
        dt = time.time() - t0
        rows.append(Row(f"fig11/{w}/mb{mb}/delta-fast", dt * 1e6,
                        f"seconds={dt:.1f};gens={ga.generations};"
                        f"evals={ga.evaluations}"))
        payload[f"fast|{mb}"] = dt
        if mb not in milp_mbs:
            continue
        dag = bench_dag(w_milp, full=full, mb=mb)
        ga = delta_fast(dag, ga_opts(full))
        for name, opts in (
                ("delta-topo", milp_opts(full, fairness=True)),
                ("delta-joint", milp_opts(full, fairness=False,
                                          hot_start=False)),
                ("delta-joint-hotstart",
                 milp_opts(full, fairness=False, hot_start=True,
                           upper_bound=ga.makespan * (1 + 1e-9),
                           seed_x=ga.x))):
            t0 = time.time()
            res = solve_delta_milp(dag, opts)
            dt = time.time() - t0
            rows.append(Row(f"fig11/{w_milp}/mb{mb}/{name}", dt * 1e6,
                            f"seconds={dt:.1f};status={res.status};"
                            f"nvars={res.stats.get('nvars')}"))
            payload[f"{name}|{mb}"] = dt
    save_json("fig11_exectime", payload)
    return rows
