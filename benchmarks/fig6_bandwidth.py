"""Fig. 6: NCT of DAG-driven vs traffic-matrix-driven topology optimization
under varying inter-pod bandwidths."""
from __future__ import annotations

from benchmarks.common import (MILP_WORKLOADS, Row, WORKLOADS, bench_dag,
                               nct_str, run_method, save_json)

BANDWIDTHS = (200.0, 400.0, 800.0, 1600.0)
BASE_METHODS = ("prop-alloc", "sqrt-alloc", "iter-halve", "delta-fast")
MILP_METHODS = ("delta-topo", "delta-joint")


def run(full: bool = False) -> list[Row]:
    rows = []
    payload = {}
    workloads = WORKLOADS if full else WORKLOADS[:3]
    for w in workloads:
        for bw in BANDWIDTHS:
            dag = bench_dag(w, bandwidth=bw, full=full)
            methods = BASE_METHODS + (
                MILP_METHODS if w in MILP_WORKLOADS else ())
            for m in methods:
                res, dt = run_method(dag, m, full)
                rows.append(Row(f"fig6/{w}/bw{int(bw)}/{m}", dt * 1e6,
                                nct_str(res)))
                payload[f"{w}|{bw}|{m}"] = {
                    "nct": res.nct, "ports": res.total_ports,
                    "makespan": res.makespan, "seconds": dt}
    save_json("fig6_bandwidth", payload)
    return rows
