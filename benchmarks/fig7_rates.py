"""Fig. 7: flow-rate control of DP communication -- joint optimization keeps
the critical flow at its physical bound while fair sharing degrades it."""
from __future__ import annotations


from benchmarks.common import Row, bench_dag, milp_opts, save_json
from repro.core.des import DESProblem, simulate
from repro.core.milp import solve_delta_milp


def run(full: bool = False) -> list[Row]:
    dag = bench_dag("gpt-7b", bandwidth=400.0, full=False,
                    mb=8 if not full else 16)
    res = solve_delta_milp(dag, milp_opts(full, fairness=False))
    rows = []
    if not res.feasible:
        return [Row("fig7/joint", 0.0, "infeasible")]
    # per-interval joint rates of the DP tasks
    dp_tasks = [t.tid for t in dag.real_tasks() if t.kind == "dp"]
    t = res.t
    joint_rates = {}
    for (m, k), vol in res.w.items():
        if m in dp_tasks:
            dt = max(t[k] - t[k - 1], 1e-12)
            joint_rates.setdefault(m, []).append((t[k - 1], t[k], vol / dt))
    # fair-share rates on the same topology
    prob = DESProblem(dag)
    des = simulate(prob, res.x, record_rates=True)
    B = dag.cluster.nic_bandwidth
    peak_joint = max(r for trace in joint_rates.values()
                     for (_, _, r) in trace)
    peak_fair = max(float(rates[dp_tasks].max())
                    for _, _, rates in des.rate_trace) if des.rate_trace \
        else 0.0
    cap = max(dag.flows()[m] for m in dp_tasks) * B
    save_json("fig7_rates", {
        "joint": {str(m): v for m, v in joint_rates.items()},
        "fair_peak": peak_fair, "joint_peak": peak_joint, "cap": cap})
    rows.append(Row("fig7/dp_peak_rate", res.solve_time * 1e6,
                    f"joint={peak_joint/1e9:.1f}GBps;"
                    f"fair={peak_fair/1e9:.1f}GBps;"
                    f"bound={cap/1e9:.1f}GBps;"
                    f"joint_frac={peak_joint/cap:.3f}"))
    rows.append(Row("fig7/makespan", res.solve_time * 1e6,
                    f"joint={res.makespan*1e3:.2f}ms;"
                    f"fair={des.makespan*1e3:.2f}ms"))
    return rows
