"""Fig. 8: NCT under varying sequence lengths."""
from __future__ import annotations

from benchmarks.common import (MILP_WORKLOADS, Row, WORKLOADS, bench_dag,
                               nct_str, run_method, save_json)

SEQ_LENS = (2048, 4096, 8192, 16384)
BASE_METHODS = ("prop-alloc", "sqrt-alloc", "iter-halve", "delta-fast")


def run(full: bool = False) -> list[Row]:
    rows = []
    payload = {}
    workloads = WORKLOADS if full else ("gpt-7b", "mixtral-8x22b")
    for w in workloads:
        for seq in SEQ_LENS:
            dag = bench_dag(w, seq_len=seq, full=full)
            methods = BASE_METHODS + (
                ("delta-joint",) if w in MILP_WORKLOADS else ())
            for m in methods:
                res, dt = run_method(dag, m, full)
                rows.append(Row(f"fig8/{w}/seq{seq}/{m}", dt * 1e6,
                                nct_str(res)))
                payload[f"{w}|{seq}|{m}"] = {"nct": res.nct,
                                             "seconds": dt}
    save_json("fig8_seqlen", payload)
    return rows
