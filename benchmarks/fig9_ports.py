"""Fig. 9: allocated-port ratio compressed by the DELTA variants without
prolonging iteration time (lexicographic Eq. 4 / greedy trim for Fast)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (MILP_WORKLOADS, Row, bench_dag, ga_opts,
                               nct_str, run_method, save_json)
from repro.core.des import DESProblem, simulate
from repro.core.ga import delta_fast, trim_ports


def run(full: bool = False) -> list[Row]:
    rows = []
    payload = {}
    for w in ("gpt-7b", "mixtral-8x22b", "megatron-177b"):
        dag = bench_dag(w, full=full)
        U = np.asarray(dag.cluster.port_limits).sum()
        # DELTA-Fast + greedy trim (beyond-paper counterpart of Eq. 4)
        t0 = time.time()
        ga = delta_fast(dag, ga_opts(full))
        x_trim = trim_ports(dag, ga.x)
        dt = time.time() - t0
        ms0 = simulate(DESProblem(dag), ga.x).makespan
        ms1 = simulate(DESProblem(dag), x_trim).makespan
        ratio = x_trim.sum() / U
        rows.append(Row(f"fig9/{w}/delta-fast-trim", dt * 1e6,
                        f"port_ratio={ratio:.3f};makespan_delta="
                        f"{(ms1/ms0-1)*100:.3f}%"))
        payload[f"{w}|fast"] = {"ratio": float(ratio), "before":
                                int(ga.x.sum()), "after": int(x_trim.sum())}
        if w in MILP_WORKLOADS:
            for m in ("delta-topo", "delta-joint"):
                res, dt = run_method(dag, m, full, port_min=True)
                if res.feasible:
                    ratio = res.total_ports / U
                    rows.append(Row(f"fig9/{w}/{m}", dt * 1e6,
                                    f"port_ratio={ratio:.3f};"
                                    + nct_str(res)))
                    payload[f"{w}|{m}"] = {"ratio": float(ratio)}
    save_json("fig9_ports", payload)
    return rows
