"""Fleet planner scaling: 2-8 concurrent tenants on shared pods.

Admits alternating donor (port-minimized) / bottlenecked (reversed
placement) tenants of the same workload into one fleet and measures the
whole event stream: admission + planning walltime per tenant, plan-cache
hit rate (repeated workloads should only solve twice), surplus-pass batched
DES evaluations, and the mean NCT improvement the reallocation bought.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, save_json
from repro.configs import PAPER_WORKLOADS, make_job
from repro.core.ga import GAOptions
from repro.fleet import FleetPlanner, FleetSpec, JobArrival


def _bench_ga(full: bool) -> GAOptions:
    return GAOptions(seed=0, pop_size=32 if full else 16,
                     time_limit=25.0 if full else 8.0,
                     patience=30 if full else 12)


def run(full: bool = False) -> list[Row]:
    arch = PAPER_WORKLOADS["gpt-7b"]
    mb = arch.plan.num_microbatches if full else arch.plan.pp
    job = make_job(arch, microbatches=mb)
    placement = job.placement()
    span = placement.num_pods
    ent = max(placement.port_limits())

    rows = []
    payload = {}
    for tenants in (2, 4, 6, 8):
        # pairs of tenants co-locate on one pod window
        windows = (tenants + 1) // 2
        fleet = FleetSpec(num_pods=span * windows, ports_per_pod=2 * ent,
                          nic_gbps=100.0)
        planner = FleetPlanner(fleet, ga_options=_bench_ga(full), seed=0)
        events = []
        for i in range(tenants):
            if i % 2 == 0:
                events.append(JobArrival(f"donor{i}", job, port_min=True))
            else:
                events.append(JobArrival(f"needy{i}", job,
                                         reverse_stages=True))
        t0 = time.time()
        planner.process(events)
        elapsed = time.time() - t0

        report = planner.report()
        gains = []
        for t in planner.tenants.values():
            if t.base_plan is not None and np.isfinite(t.base_plan.nct):
                gains.append(t.base_plan.nct - t.plan.nct)
        mean_gain = float(np.mean(gains)) if gains else 0.0
        cache = report["cache"]
        derived = (f"tenants={tenants};cache_hits={cache['hits']};"
                   f"misses={cache['misses']};"
                   f"realloc_batches={report['realloc']['batches']};"
                   f"mean_nct_gain={mean_gain:.4f}")
        rows.append(Row(f"fleet/T={tenants}", elapsed / tenants * 1e6,
                        derived))
        payload[tenants] = {"elapsed_s": elapsed, "cache": cache,
                            "realloc": report["realloc"],
                            "mean_nct_gain": mean_gain,
                            "ncts": {n: t.plan.nct
                                     for n, t in planner.tenants.items()}}
        planner.ledger.check()
    save_json("fleet_bench", payload)
    return rows
