"""GA engine throughput: the population-array-resident DELTA-Fast hot loop
vs the legacy per-genome implementation, at identical seed and generation
budget, plus batched vs serial `trim_ports`.

Emits the measured speedup and the relative makespan delta (the acceptance
bar: >= 3x wall clock at unchanged-or-better makespan on the medium
workload, identical trim_ports port count and makespan).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import Row, bench_dag, save_json
from repro.core import _ga_legacy as legacy
from repro.core.des import DESProblem, simulate
from repro.core.ga import GAOptions, TopologySpace, delta_fast, trim_ports

WORKLOAD = "megatron-177b"      # medium: 24 pods, 5 active pairs


def _opts(gens: int) -> dict:
    return dict(seed=0, max_generations=gens, patience=10**9,
                time_limit=1e9)


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    mb = 16 if full else 8
    gens = 30 if full else (6 if smoke else 12)
    dag = bench_dag(WORKLOAD, full=False, mb=mb)

    t0 = time.time()
    new = delta_fast(dag, GAOptions(**_opts(gens)))
    t_new = time.time() - t0
    rows.append(Row(f"ga/vectorized/{WORKLOAD}/mb{mb}", t_new * 1e6,
                    f"seconds={t_new:.2f};gens={new.generations};"
                    f"evals={new.evaluations};makespan={new.makespan:.6f}"))

    t0 = time.time()
    old = legacy.delta_fast(dag, legacy.GAOptions(**_opts(gens)))
    t_old = time.time() - t0
    rows.append(Row(f"ga/legacy/{WORKLOAD}/mb{mb}", t_old * 1e6,
                    f"seconds={t_old:.2f};gens={old.generations};"
                    f"evals={old.evaluations};makespan={old.makespan:.6f}"))

    speedup = t_old / max(t_new, 1e-9)
    rel = (new.makespan - old.makespan) / max(old.makespan, 1e-12)
    rows.append(Row(f"ga/speedup/{WORKLOAD}/mb{mb}", t_new * 1e6,
                    f"speedup={speedup:.2f}x;rel_makespan={rel:+.2e}"))

    # trim_ports: batched candidate rounds vs serial one-drop-at-a-time,
    # identical result required.  Trim a port-saturated feasible topology
    # (X̄ pushed through Alg. 6 repair) so the sweep has real work to do.
    problem = DESProblem(dag)
    space = TopologySpace(dag)
    g_fat, _ = space.repair(space.xbar.copy(), np.random.default_rng(0))
    x_fat = space.to_matrix(g_fat)
    t0 = time.time()
    xt_new = trim_ports(dag, x_fat)            # auto backend (cost-gated)
    t_tnew = time.time() - t0
    t0 = time.time()
    xt_jax = trim_ports(dag, x_fat, backend="jax")   # forced batched path
    t_tjax = time.time() - t0
    t0 = time.time()
    xt_old = legacy.trim_ports(dag, x_fat)
    t_told = time.time() - t0
    same = bool((xt_new == xt_old).all()) and bool((xt_jax == xt_old).all())
    ms_new = simulate(problem, xt_new).makespan
    ms_old = simulate(problem, xt_old).makespan
    rows.append(Row(
        f"ga/trim_ports/{WORKLOAD}/mb{mb}", t_tnew * 1e6,
        f"seconds={t_tnew:.2f};jax_seconds={t_tjax:.2f};"
        f"legacy_seconds={t_told:.2f};identical={same};"
        f"ports={int(xt_new.sum())};legacy_ports={int(xt_old.sum())};"
        f"rel_makespan={(ms_new - ms_old) / max(ms_old, 1e-12):+.2e}"))

    save_json("ga_bench", {
        "workload": WORKLOAD, "mb": mb, "generations": gens,
        "vectorized_seconds": t_new, "legacy_seconds": t_old,
        "speedup": speedup, "vectorized_makespan": new.makespan,
        "legacy_makespan": old.makespan,
        "trim_identical": same, "trim_auto_seconds": t_tnew,
        "trim_jax_seconds": t_tjax, "trim_legacy_seconds": t_told})
    return rows
