"""Per-kernel microbenchmarks: jitted reference backend wall time on CPU
(the production CPU path) + one interpret-mode Pallas correctness pass.
On TPU the pallas backend is selected automatically by repro.kernels.ops."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.kernels import ops
from repro.kernels.ref import NEG_INF


def _time(fn, *args, reps: int = 5) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run(full: bool = False) -> list[Row]:
    rng = np.random.default_rng(0)
    rows = []
    n = 1024 if full else 512
    a = jnp.asarray(rng.random((n, n)) < 0.01)
    us = _time(lambda x: ops.tclosure_step(x, backend="ref"), a)
    got = np.asarray(ops.tclosure_step(np.asarray(a)[:128, :128],
                                       backend="pallas", interpret=True))
    want = np.asarray(ops.tclosure_step(np.asarray(a)[:128, :128],
                                        backend="ref"))
    rows.append(Row(f"kernels/tclosure_step/n{n}", us,
                    f"gflops={2*n**3/us/1e3:.1f};pallas_match="
                    f"{bool((got == want).all())}"))

    m = jnp.asarray(np.where(rng.random((n, n)) < 0.05,
                             rng.random((n, n)), NEG_INF), dtype=jnp.float32)
    us = _time(lambda x: ops.maxplus(x, x, backend="ref"), m)
    got = np.asarray(ops.maxplus(np.asarray(m)[:64, :64],
                                 np.asarray(m)[:64, :64],
                                 backend="pallas", interpret=True))
    want = np.asarray(ops.maxplus(np.asarray(m)[:64, :64],
                                  np.asarray(m)[:64, :64], backend="ref"))
    rows.append(Row(f"kernels/maxplus/n{n}", us,
                    f"gops={n**3/us/1e3:.1f};pallas_match="
                    f"{bool(np.allclose(got, want, rtol=1e-5))}"))

    C, N = (2048, 4096) if full else (512, 1024)
    w = jnp.asarray(rng.random((C, N)).astype(np.float32))
    rhs = jnp.asarray(rng.random((N, 2)).astype(np.float32))
    us = _time(lambda *x: ops.fill_matvec(*x, backend="ref"), w, rhs)
    got = np.asarray(ops.fill_matvec(np.asarray(w)[:100],
                                     np.asarray(rhs), backend="pallas",
                                     interpret=True))
    want = np.asarray(ops.fill_matvec(np.asarray(w)[:100], np.asarray(rhs),
                                      backend="ref"))
    rows.append(Row(f"kernels/fill_matvec/{C}x{N}", us,
                    f"gb_per_s={(C*N*4)/us/1e3:.2f};pallas_match="
                    f"{bool(np.allclose(got, want, rtol=1e-4, atol=1e-4))}"))
    return rows
