"""DELTA-Planes suite: k-plane decomposition + staggered-rewire metrics.

All rows are seeded and generation-bounded so the emitted quality metrics
are deterministic and gate-able by benchmarks/check_regression.py; every
row carries a ``violations`` count that must stay at zero:

  * ``planes/decompose`` -- the two-stage `delta_planes` solve: lane
    stacks must sum to the topology, respect every per-plane budget, and
    keep every one-plane-dark state finite (violations counts breaches;
    worst_dark_regret and makespan gate the quality);
  * ``planes/transition`` -- a staggered A->B transition: every step's
    journaled peak inflation must match the masked numpy oracle EXACTLY
    (bit-equal recomputation from scratch) and the final state must equal
    plan B;
  * ``planes/midfault`` -- a `PlaneFailure` lands mid-transition on a
    not-yet-rewired plane: the scheduler must re-price and land on
    exactly plan A or plan B (a stranded fleet is a violation);
  * ``planes/suite_wall`` -- suite wall clock for the regression gate.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import Row, save_json
from repro.core.cluster import split_port_budgets
from repro.core.dag import DagEnsemble
from repro.core.des import DESProblem, simulate
from repro.core.ga import GAOptions, delta_planes
from repro.core.schedule import build_comm_dag
from repro.core.traffic import JobSpec
from repro.fleet import (FabricHealth, StaggeredTransition, TenantLane,
                         split_plan)

NUM_PLANES = 4


def _job(name: str, mb: int) -> JobSpec:
    return JobSpec(name=name, tp=2, pp=4, dp=2, num_microbatches=mb,
                   micro_tokens=4096, d_model=4096,
                   stage_params=(1.75e9,) * 4, gpus_per_pod_per_replica=4)


def _ga_opts(full: bool, smoke: bool) -> GAOptions:
    gens = 30 if full else (8 if smoke else 15)
    return GAOptions(seed=0, pop_size=24 if full else 12,
                     max_generations=gens, patience=10**9, time_limit=1e9)


def _plane_usage(plane: np.ndarray) -> np.ndarray:
    up = np.triu(plane, k=1)
    return up.sum(axis=0) + up.sum(axis=1)


def _decompose_row(full: bool, smoke: bool) -> Row:
    dag = build_comm_dag(_job("planes", mb=8 if full else 2), 400.0)
    ens = DagEnsemble.singleton(dag)
    opts = _ga_opts(full, smoke)
    t0 = time.time()
    res = delta_planes(ens, opts, num_planes=NUM_PLANES)
    dt = time.time() - t0
    violations = 0
    if not np.array_equal(res.planes.sum(axis=0), res.x):
        violations += 1
    budgets = np.asarray(res.plane_port_limits, dtype=np.int64)
    for p in range(NUM_PLANES):
        if (_plane_usage(res.planes[p]) > budgets[p]).any():
            violations += 1
    if not np.isfinite(res.dark_makespans).all():
        violations += 1
    # the lane genomes are the planes on the union pair list -- a
    # mismatch means the genome/matrix views diverged
    eu = np.asarray([e[0] for e in res.edges], dtype=np.int64)
    ev = np.asarray([e[1] for e in res.edges], dtype=np.int64)
    for p in range(NUM_PLANES):
        if not np.array_equal(res.planes[p][eu, ev], res.lane_genomes[p]):
            violations += 1
    return Row(
        "planes/decompose", dt * 1e6,
        f"makespan={float(res.makespans[0]):.6f};"
        f"worst_regret={res.worst_dark_regret:.6f};"
        f"ports={res.total_ports};planes={res.num_planes};"
        f"generations={res.generations};violations={violations}")


def _lane(dag, x_a: np.ndarray, x_b: np.ndarray) -> TenantLane:
    P = dag.cluster.num_pods
    budgets = np.asarray(split_port_budgets((64,) * P, NUM_PLANES))
    return TenantLane(name="a", dag=dag, pods=tuple(range(P)),
                      planes_a=split_plan(x_a, budgets),
                      planes_b=split_plan(x_b, budgets))


def _plans(dag) -> tuple[np.ndarray, np.ndarray]:
    """A 4-circuit-per-pair plan A and a shrink-style target B."""
    P = dag.cluster.num_pods
    x_a = np.zeros((P, P), dtype=np.int64)
    for i, j in dag.undirected_pairs():
        x_a[i, j] = x_a[j, i] = 4
    x_b = x_a.copy()
    for i, j in dag.undirected_pairs()[:2]:
        x_b[i, j] = x_b[j, i] = 2
    return x_a, x_b


def _transition_row(full: bool) -> Row:
    dag = build_comm_dag(_job("tr", mb=4 if full else 2), 400.0)
    x_a, x_b = _plans(dag)
    lane = _lane(dag, x_a, x_b)
    health = FabricHealth(dag.cluster.num_pods, NUM_PLANES)
    t0 = time.time()
    res = StaggeredTransition([lane], health, slo=3.0,
                              transition_id="bench").run()
    dt = time.time() - t0
    violations = 0 if res.committed else 1
    # certify: every journaled step peak must be the oracle number,
    # recomputed from scratch, EXACTLY (not approximately)
    prob = DESProblem(dag)
    mixed = lane.planes_a.copy()
    for s in res.steps:
        x_mid = mixed.sum(axis=0).astype(np.float64)
        eff = x_mid - mixed[s.plane]
        eff = np.where((eff <= 0) & (x_mid > 0), x_mid / NUM_PLANES, eff)
        ref = simulate(prob, x_mid).makespan
        ms = simulate(prob, eff).makespan
        if s.peak_inflation != max(ms / ref, 1.0):
            violations += 1
        mixed[s.plane] = lane.planes_b[s.plane]
    final = lane.planes_a.copy()
    for s in res.steps:
        final[s.plane] = lane.planes_b[s.plane]
    if not np.array_equal(final, lane.planes_b):
        violations += 1
    return Row(
        "planes/transition", dt * 1e6,
        f"steps={len(res.steps)};peak={res.peak_inflation:.6f};"
        f"delay_s={res.total_delay_s:.4f};"
        f"outcome={res.status};violations={violations}")


def _midfault_row(full: bool) -> Row:
    dag = build_comm_dag(_job("mf", mb=4 if full else 2), 400.0)
    x_a, x_b = _plans(dag)
    lane = _lane(dag, x_a, x_b)
    health = FabricHealth(dag.cluster.num_pods, NUM_PLANES)
    tr = StaggeredTransition([lane], health, slo=5.0,
                             transition_id="bench-mf")
    t0 = time.time()
    first = tr.step()
    health.fail_plane(tr.pending[0])     # a not-yet-rewired plane dies
    outcome = "committed"
    while tr.pending:
        if tr.step() is None:
            tr.rollback()
            outcome = "rolled_back"
            break
    dt = time.time() - t0
    violations = 0 if first is not None else 1
    final = tr.mixed_planes(lane)
    target = lane.planes_b if outcome == "committed" else lane.planes_a
    if not np.array_equal(final, target):   # stranded between plans
        violations += 1
    if not all(np.isfinite(s.peak_inflation) for s in tr.steps):
        violations += 1
    return Row(
        "planes/midfault", dt * 1e6,
        f"steps={len(tr.steps)};outcome={outcome};"
        f"dark={len(health.dark_planes)};violations={violations}")


def run(full: bool = False) -> list[Row]:
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    rows: list[Row] = []
    t_suite = time.time()
    rows.append(_decompose_row(full, smoke))
    rows.append(_transition_row(full))
    rows.append(_midfault_row(full))
    wall = time.time() - t_suite
    rows.append(Row(
        "planes/suite_wall", wall * 1e6,
        f"seconds={wall:.2f};violations=0"))
    save_json("planes_bench", {
        "rows": [{"name": r.name, "us_per_call": r.us_per_call,
                  "derived": r.derived} for r in rows],
        "seconds": wall})
    return rows
