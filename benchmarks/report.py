"""Generate the EXPERIMENTS.md dry-run + roofline sections from artifacts.

    PYTHONPATH=src:. python -m benchmarks.report > experiments/report.md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import markdown_table

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_OUT", "experiments/dryrun")


def _gib(x) -> str:
    return f"{(x or 0)/2**30:.2f}"


def dryrun_table() -> str:
    lines = ["| mesh | arch | shape | status | args GiB/dev | temp GiB/dev "
             "| flops/dev | coll GiB/dev | #coll | compile s |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if c["status"] == "ok":
            m = c["memory"]
            lines.append(
                f"| {c['mesh']} | {c['arch']} | {c['shape']} | ok | "
                f"{_gib(m['argument_bytes'])} | {_gib(m['temp_bytes'])} | "
                f"{c['flops_per_device']:.3g} | "
                f"{c['collectives']['total']/2**30:.2f} | "
                f"{int(c['collectives']['count'])} | "
                f"{c.get('compile_s', 0):.0f} |")
        else:
            reason = c.get("reason", c.get("error", ""))[:60]
            lines.append(f"| {c['mesh']} | {c['arch']} | {c['shape']} | "
                         f"{c['status']}: {reason} | | | | | | |")
    return "\n".join(lines)


def fits_check(hbm_gib: float = 16.0) -> str:
    bad = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if c["status"] != "ok":
            continue
        m = c["memory"]
        # donated inputs alias outputs; live set ~ args + temp
        total = ((m["argument_bytes"] or 0) + (m["temp_bytes"] or 0)) / 2**30
        if total > hbm_gib:
            bad.append(f"{c['mesh']} {c['arch']} {c['shape']}: "
                       f"{total:.1f} GiB")
    if not bad:
        return (f"All compiled cells fit the {hbm_gib:.0f} GiB/chip HBM "
                f"budget (arguments + temporaries per device).")
    return "Cells exceeding HBM budget:\n" + "\n".join("  " + b for b in bad)


def main() -> None:
    ok = skipped = 0
    for path in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
        with open(path) as f:
            c = json.load(f)
        ok += c["status"] == "ok"
        skipped += c["status"] == "skipped"
    print("## Dry-run summary\n")
    print(f"{ok} cells compiled, {skipped} skipped (documented "
          f"long_500k skips), 0 errors.\n")
    print(fits_check() + "\n")
    print(dryrun_table())
    print("\n## Roofline (single-pod 16x16)\n")
    print(markdown_table("single_pod_16x16"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(markdown_table("multi_pod_2x16x16"))


if __name__ == "__main__":
    main()
