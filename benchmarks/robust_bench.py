"""DELTA-Robust: one static topology for a Table-I workload mix.

Each mix is a `DagEnsemble` of two phases of a Table-I workload on the same
cluster (sequence-length change, PP-dominant vs DP-dominant phase,
microbatch-count change).  For every mix we plan each member alone
(delta-fast), cross-evaluate the single plans on the *other* member, then
plan the whole ensemble under both robust objectives -- the headline metric
is the worst-member regret (makespan / that member's best single-DAG plan):
a robust plan should stay near 1.0 where either single plan degrades.

All GA runs are generation-bounded with fixed seeds (no wall-clock cutoff),
so the emitted worst_regret / makespan values are deterministic and gate-able
by benchmarks/check_regression.py.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import Row, bench_dag, save_json
from repro.core.cluster import GBPS, ClusterSpec
from repro.core.dag import DagEnsemble
from repro.core.des import DESProblem, simulate
from repro.core.ga import GAOptions, delta_fast, delta_robust
from repro.core.schedule import build_comm_dag
from repro.core.traffic import JobSpec


def _ga_opts(full: bool, smoke: bool) -> GAOptions:
    gens = 60 if full else (15 if smoke else 30)
    return GAOptions(seed=0, pop_size=48 if full else 24,
                     max_generations=gens, patience=10**9, time_limit=1e9)


def _gpt7b(mb: int, **kw) -> JobSpec:
    defaults = dict(name="gpt7b", tp=2, pp=4, dp=2, num_microbatches=mb,
                    micro_tokens=4096, d_model=4096,
                    stage_params=(1.75e9,) * 4,
                    gpus_per_pod_per_replica=4)
    defaults.update(kw)
    return JobSpec(**defaults)


def _mixes(full: bool, smoke: bool) -> list[tuple[str, list, list[str]]]:
    """(mix name, member DAGs, member names); members share a cluster."""
    mixes = []
    # 1) gpt-7b at two sequence lengths (traffic-change scenario)
    mixes.append(("gpt7b-seqlen",
                  [bench_dag("gpt-7b", seq_len=4096),
                   bench_dag("gpt-7b", seq_len=16384)],
                  ["seq4k", "seq16k"]))
    # 2) contended PP-dominant vs DP-dominant phases on a half-budget
    # cluster (co-tenant entitlements): the single plans want opposite
    # port splits, so this is where max-regret visibly beats them
    cl = ClusterSpec(num_pods=4, port_limits=(5, 5, 5, 5),
                     nic_bandwidth=400 * GBPS)
    job_pp = _gpt7b(4, tp=4, gpus_per_pod_per_replica=8, micro_tokens=65536,
                    stage_params=(0.05e9,) * 4)
    job_dp = _gpt7b(2, tp=4, gpus_per_pod_per_replica=8, micro_tokens=2048,
                    stage_params=(8e9,) * 4)
    mixes.append(("gpt7b-phase",
                  [build_comm_dag(job_pp, cluster=cl),
                   build_comm_dag(job_dp, cluster=cl)],
                  ["pp-phase", "dp-phase"]))
    if not smoke:
        # 3) megatron-177b at two microbatch counts (PP/DP ratio shift)
        mixes.append(("megatron177b-mb",
                      [bench_dag("megatron-177b", mb=8),
                       bench_dag("megatron-177b", mb=16)],
                      ["mb8", "mb16"]))
    if full:
        # 4) megatron-462b microbatch phases (paper-scale fabric)
        mixes.append(("megatron462b-mb",
                      [bench_dag("megatron-462b", mb=16),
                       bench_dag("megatron-462b", mb=32)],
                      ["mb16", "mb32"]))
    return mixes


def run(full: bool = False) -> list[Row]:
    from repro.core.des_jax import des_cache_stats
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    opts = _ga_opts(full, smoke)
    rows: list[Row] = []
    payload: dict = {}
    t_suite = time.time()
    cache0 = des_cache_stats()
    for mix_name, dags, names in _mixes(full, smoke):
        problems = [DESProblem(d) for d in dags]
        singles, t_single = [], []
        for dag in dags:
            t0 = time.time()
            singles.append(delta_fast(dag, opts))
            t_single.append(time.time() - t0)
        refs = np.array([s.makespan for s in singles])

        # cross-evaluation: each single plan on every member
        cross = np.array([[simulate(p, s.x).makespan for p in problems]
                          for s in singles])
        single_worst = (cross / refs).max(axis=1)
        for name, s, wr, dt in zip(names, singles, single_worst, t_single):
            rows.append(Row(
                f"robust/{mix_name}/single/{name}", dt * 1e6,
                f"makespan={s.makespan:.6f};ports={s.total_ports};"
                f"worst_regret={wr:.4f}"))

        ensemble = DagEnsemble(list(dags), names=list(names))
        mix_payload = {
            "members": names,
            "refs": refs.tolist(),
            "cross_regret": (cross / refs).tolist(),
            "single_ports": [s.total_ports for s in singles],
        }
        for objective in ("max-regret", "weighted"):
            t0 = time.time()
            rob = delta_robust(ensemble, opts, objective=objective,
                               refs=refs)
            dt = time.time() - t0
            improve = float(single_worst.min() - rob.worst_regret)
            rows.append(Row(
                f"robust/{mix_name}/{objective}", dt * 1e6,
                f"worst_regret={rob.worst_regret:.4f};"
                f"weighted_makespan={rob.weighted_makespan:.6f};"
                f"ports={rob.total_ports};"
                f"improve_vs_best_single={improve:+.4f}"))
            mix_payload[objective] = {
                "worst_regret": rob.worst_regret,
                "regrets": rob.regrets.tolist(),
                "makespans": rob.makespans.tolist(),
                "ports": rob.total_ports,
                "generations": rob.generations,
                "evaluations": rob.evaluations,
                "seconds": dt,
            }
        payload[mix_name] = mix_payload
    # suite-total wall clock: the regression gate pins this row, so a lost
    # DES-engine optimization (jit-cache churn, kernel backend) fails CI
    # even when no single mix crosses the per-row floor
    cache1 = des_cache_stats()
    wall = time.time() - t_suite
    compiles = cache1["misses"] - cache0["misses"]
    reuses = cache1["hits"] - cache0["hits"]
    rows.append(Row(
        "robust/suite_wall", wall * 1e6,
        f"seconds={wall:.2f};des_compiles={compiles};"
        f"des_cache_reuses={reuses}"))
    payload["suite"] = {"seconds": wall, "des_compiles": compiles,
                        "des_cache_reuses": reuses,
                        "des_cache": cache1}
    save_json("robust_bench", payload)
    return rows
