"""Roofline analysis from the dry-run artifacts (experiments/dryrun/*.json).

Per (arch x shape x mesh) cell, with TPU v5e targets:
    compute term    = FLOPs_dev / 197e12            [s]
    memory term     = bytes_dev / 819e9             [s]
    collective term = collective_bytes_dev / 50e9   [s]
(dry-run numbers are per-device, so dividing by per-chip peaks matches the
assignment's global/chips formulation).  MODEL_FLOPS = 6*N*D for training
(N = active params for MoE), 2*N*D for inference cells.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import OUT_DIR, Row

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_OUT", "experiments/dryrun")


def model_flops(cell: dict) -> float:
    n = cell["params_active"]
    d = cell["tokens"]
    per_tok = 6.0 if cell["kind"] == "train" else 2.0
    return per_tok * n * d


def analyze_cell(cell: dict) -> dict:
    devices = cell["devices"]
    compute = cell["flops_per_device"] / PEAK_FLOPS
    memory = cell["bytes_per_device"] / HBM_BW
    coll = cell["collectives"]["total"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cell)
    hlo_global = cell["flops_per_device"] * devices
    bound = max(terms.values())
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "kind": cell["kind"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_fraction": compute / bound if bound > 0 else 0.0,
        "hbm_args_gib": (cell["memory"]["argument_bytes"] or 0) / 2**30,
        "hbm_temp_gib": (cell["memory"]["temp_bytes"] or 0) / 2**30,
    }


HINTS = {
    "memory": "fuse attention score chain (Pallas flash kernel) / "
              "sequence-parallel activations to cut HBM traffic",
    "collective": "reshard GQA KV (replicate small KV heads instead of "
                  "splitting head_dim) and reduce-scatter gradients",
    "compute": "compute-bound: increase arithmetic intensity only via "
               "larger per-device batch or faster kernels",
}


def load_cells(mesh: str | None = "single_pod_16x16") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        if cell.get("status") != "ok":
            continue
        if mesh and cell["mesh"] != mesh:
            continue
        cells.append(cell)
    return cells


def run(full: bool = False) -> list[Row]:
    rows = []
    table = []
    for cell in load_cells(mesh=None):
        if cell["mesh"] != "single_pod_16x16":
            continue  # roofline table is single-pod per the assignment
        a = analyze_cell(cell)
        table.append(a)
        derived = (f"compute={a['compute_s']:.4f}s;"
                   f"memory={a['memory_s']:.4f}s;"
                   f"collective={a['collective_s']:.4f}s;"
                   f"dominant={a['dominant']};"
                   f"useful={a['useful_ratio']:.3f};"
                   f"roofline_frac={a['roofline_fraction']:.3f}")
        rows.append(Row(f"roofline/{a['arch']}/{a['shape']}",
                        cell.get("compile_s", 0) * 1e6, derived))
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "roofline.json"), "w") as f:
        json.dump(table, f, indent=1)
    return rows


def markdown_table(mesh: str = "single_pod_16x16") -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO | roofline frac | next lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for cell in load_cells(mesh=mesh):
        a = analyze_cell(cell)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']:.4f} | "
            f"{a['memory_s']:.4f} | {a['collective_s']:.4f} | "
            f"{a['dominant']} | {a['useful_ratio']:.3f} | "
            f"{a['roofline_fraction']:.3f} | {HINTS[a['dominant']]} |")
    return "\n".join(lines)
