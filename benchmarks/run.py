"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run           # reduced scale
    PYTHONPATH=src python -m benchmarks.run --full    # paper scale
    PYTHONPATH=src python -m benchmarks.run --only fig6,roofline
    PYTHONPATH=src python -m benchmarks.run --trace   # + span summaries

Prints ``name,us_per_call,derived`` CSV (also written to
experiments/bench/results.csv) and, per suite, a machine-readable
``BENCH_<suite>.json`` -- written both under experiments/bench/ and at the
repo root, where the cross-PR perf-trajectory tooling reads it (the
smoke-sized des/ga/tab1 files are committed with each PR; CI runs the same
smoke command and uploads the results as artifacts).

With ``--trace`` (or ``$REPRO_BENCH_TRACE=1``) the repro.obs tracer runs
for the whole suite and every row carries a ``spans`` dict -- the per-row
delta of the span summary (count / total seconds per span name), i.e. the
jit-vs-simulate-vs-solver decomposition of that row's wall clock.  The
regression gate carries these fields but does not gate on them; the CI
smoke runs WITHOUT --trace so the wall-clock gate measures the default
(disabled, near-zero-cost) configuration.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

sys.path.insert(0, "src")
sys.path.insert(0, ".")

SUITES = ("tab1", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11",
          "fleet", "kernels", "des", "ga", "robust", "chaos", "steering",
          "planes", "roofline")


def _span_delta(before: dict, after: dict) -> dict:
    """Per-row span summary: what the tracer accumulated since the last
    yielded row, as {span name: {count, total_s}} (max_s is a running
    maximum, not a delta, so it is dropped here)."""
    out = {}
    for name, row in after.items():
        prev = before.get(name, {"count": 0, "total_s": 0.0})
        count = row["count"] - prev["count"]
        if count > 0:
            out[name] = {"count": int(count),
                         "total_s": round(row["total_s"] - prev["total_s"],
                                          6)}
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale microbatches and solver budgets")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(SUITES))
    ap.add_argument("--trace", action="store_true",
                    default=os.environ.get("REPRO_BENCH_TRACE", "0")
                    not in ("0", ""),
                    help="enable repro.obs tracing; attach per-row span "
                         "summaries (jit vs simulate vs solver time) to "
                         "the BENCH_*.json payloads")
    args = ap.parse_args()
    picked = [s.strip() for s in args.only.split(",") if s.strip()] or \
        list(SUITES)

    from benchmarks import (chaos_bench, des_bench, fig6_bandwidth,
                            fig7_rates, fig8_seqlen, fig9_ports,
                            fig10_realloc, fig11_exectime, fleet_bench,
                            ga_bench, kernels_bench, planes_bench,
                            robust_bench, roofline, steering_bench,
                            tab1_workloads)
    from benchmarks.common import OUT_DIR, save_json
    from repro.obs import TRACER

    if args.trace:
        TRACER.enable()

    modules = {"tab1": tab1_workloads, "fig6": fig6_bandwidth,
               "fig7": fig7_rates, "fig8": fig8_seqlen,
               "fig9": fig9_ports, "fig10": fig10_realloc,
               "fig11": fig11_exectime, "fleet": fleet_bench,
               "kernels": kernels_bench, "des": des_bench,
               "ga": ga_bench, "robust": robust_bench,
               "chaos": chaos_bench, "steering": steering_bench,
               "planes": planes_bench, "roofline": roofline}

    print("name,us_per_call,derived")
    lines = ["name,us_per_call,derived"]
    t_start = time.time()
    failures = []
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    os.makedirs(OUT_DIR, exist_ok=True)
    for s in picked:
        mod = modules[s]
        t0 = time.time()
        rows = []
        row_spans = []
        error = None
        TRACER.clear()
        prev_summary: dict = {}
        try:
            for row in mod.run(full=args.full):
                rows.append(row)
                lines.append(row.emit())
                if args.trace:
                    cur = TRACER.summary()
                    row_spans.append(_span_delta(prev_summary, cur))
                    prev_summary = cur
        except Exception as exc:   # noqa: BLE001
            failures.append(s)
            error = f"{type(exc).__name__}: {exc}"
            print(f"{s}/ERROR,0,{type(exc).__name__}:{exc}", flush=True)
            traceback.print_exc(file=sys.stderr)
        dt = time.time() - t0
        print(f"# {s} done in {dt:.1f}s", flush=True)
        payload = {
            "suite": s, "full": args.full, "seconds": dt, "error": error,
            "rows": [{"name": r.name, "us_per_call": r.us_per_call,
                      "derived": r.derived} for r in rows]}
        if args.trace:
            for rdict, spans in zip(payload["rows"], row_spans):
                if spans:
                    rdict["spans"] = spans
            payload["spans"] = TRACER.summary()
        save_json(f"BENCH_{s}", payload)
        # mirror to the repo root: the growth loop's perf trajectory reads
        # BENCH_*.json from there, not from experiments/bench/
        save_json(f"BENCH_{s}", payload, out_dir=repo_root)
    with open(os.path.join(OUT_DIR, "results.csv"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"# total {time.time()-t_start:.1f}s -> {OUT_DIR}/results.csv",
          flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
