"""DELTA-Pilot steering suite: the telemetry-driven controller against the
two trivial policies it must dominate.

One tenant runs a phase-shifting workload (PP-heavy pretrain `A`, DP-heavy
finetune `B`) on a 4-pod fleet: a long stretch of `A`, a short `B` flap
that reverts before any sane controller should react, then a real switch
to `B`.  Three steering policies pay for that timeline in *extra seconds*
against an oracle that always holds the perfect topology for free:

  never       keep the admission-time topology forever -- zero rewiring
              delay, but every second of `B` runs at the incumbent's
              makespan inflation (``dwell x inflation``);
  always      replan on every phase marker with zero detection latency --
              zero inflation, but the flap alone costs two full rewires
              and the real switch a third (``sum of reconfig delays``);
  controller  the real `ControlPlane` on the synthesized telemetry stream:
              hysteresis swallows the flap, the real switch is confirmed,
              priced with the *measured* dwell and replanned only because
              it clears the FastReChain break-even.

``steering/policy`` pins the ordering as a gateable quality metric:
``violations`` is 0 only if the controller beats BOTH trivial policies
and every replan it issued cleared ``dwell x inflation > delay`` (the
regression gate fails on any fresh violation against the committed zero
baseline).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import Row, save_json
from repro.core.des import DESProblem, simulate
from repro.core.ga import GAOptions
from repro.core.schedule import build_comm_dag
from repro.core.traffic import JobSpec
from repro.fleet import (ControllerConfig, ControlPlane, FleetPlanner,
                         FleetSpec, JobArrival, PlanCache, TrafficChange,
                         synthesize_telemetry)

NIC = 100.0
RECONFIG_S = 0.5               # per-circuit rewiring delay (OCS-scale)
FLAP_T0, FLAP_ITERS = 100.0, 2
SWITCH_T0 = 300.0


def _ga_opts(full: bool, smoke: bool) -> GAOptions:
    gens = 40 if full else (10 if smoke else 20)
    return GAOptions(seed=0, pop_size=32 if full else 16,
                     max_generations=gens, patience=10**9, time_limit=1e9)


def _phase_job(mb: int, d_model: int, params: float) -> JobSpec:
    """Same placement footprint, different traffic shape -- the legal
    domain of a TrafficChange."""
    return JobSpec(name="t", tp=2, pp=4, dp=2, num_microbatches=mb,
                   micro_tokens=4096, d_model=d_model,
                   stage_params=(params,) * 4, gpus_per_pod_per_replica=4)


JOB_A = _phase_job(8, 4096, 0.2e9)      # pretrain: PP-heavy
JOB_B = _phase_job(2, 1024, 3e9)        # finetune: DP-heavy


def _planner(opts: GAOptions, cache: PlanCache) -> FleetPlanner:
    fleet = FleetSpec(num_pods=4, ports_per_pod=8, nic_gbps=NIC)
    return FleetPlanner(fleet, ga_options=opts, cache=cache, seed=0,
                        reconfig_s_per_circuit=RECONFIG_S)


def _controller_session(opts: GAOptions, cache: PlanCache,
                        iters_b: int) -> dict:
    """Drive the real ControlPlane through the scenario; returns the
    applied steer decisions plus the timeline facts every policy's
    accounting shares (inflation, segment durations, stream end)."""
    pl = _planner(opts, cache)
    pl.handle(JobArrival(name="t", job=JOB_A))
    x0 = pl.tenants["t"].plan.x.copy()
    dag_a = build_comm_dag(JOB_A, NIC)
    dag_b = build_comm_dag(JOB_B, NIC)
    cp = ControlPlane(pl, ControllerConfig(
        cadence_s=2.0, confirm_ticks=2, cooldown_s=0.0,
        drift_threshold=0.05, drift_tau_s=5.0),
        phase_book={"t": {"A": JOB_A, "B": JOB_B}})

    def drive(dag, phase, t0, iterations):
        events = synthesize_telemetry(dag, x0, tenant="t", phase=phase,
                                      t0=t0, iterations=iterations)
        for ev in events:
            cp.observe(ev)
        return max(float(e.t) + float(getattr(e, "dt", 0.0))
                   for e in events)

    drive(dag_a, "A", 0.0, 20)                       # on-plan stretch
    flap_end = drive(dag_b, "B", FLAP_T0, FLAP_ITERS)  # flap...
    drive(dag_a, "A", flap_end, 20)                  # ...reverts
    t_end = drive(dag_b, "B", SWITCH_T0, iters_b)    # the real switch
    applied = [d for d in cp.decisions if "decision" in d]
    # exact-DES ground truth for the incumbent on phase B (= ms_keep)
    ms_keep = simulate(DESProblem(dag_b), x0.astype(np.float64)).makespan
    return {"planner": pl, "cp": cp, "applied": applied, "x0": x0,
            "flap_s": flap_end - FLAP_T0, "t_end": t_end,
            "ms_keep": ms_keep}


def _always_extra(opts: GAOptions, cache: PlanCache) -> tuple[float, int]:
    """Prescient always-replan: rewire on every phase marker (flap in,
    flap out, real switch) with zero detection latency and zero
    inflation; its cost is purely the sum of rewiring delays."""
    pl = _planner(opts, cache)
    pl.handle(JobArrival(name="t", job=JOB_A))
    extra, replans = 0.0, 0
    for job in (JOB_B, JOB_A, JOB_B):
        # force the break-even to always choose replan: infinite dwell
        # makes any nonzero inflation dominate the rewiring delay
        pl.set_dwell_estimate("t", 1e12)
        rec = pl.handle(TrafficChange(name="t", job=job, steered=True))
        dec = rec["decision"]
        if dec["option"] == "replan":
            extra += dec["delay_s"]
            replans += 1
    return extra, replans


def run(full: bool = False) -> list[Row]:
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    opts = _ga_opts(full, smoke)
    iters_b = 30 if smoke else 60
    cache = PlanCache()          # shared: all policies price the same plans
    rows: list[Row] = []
    t_suite = time.time()

    t0 = time.time()
    sess = _controller_session(opts, cache, iters_b)
    ctl_wall = time.time() - t0
    applied = sess["applied"]
    steers = len(applied)
    dec = applied[0]["decision"] if applied else {}
    infl = float(dec.get("inflation", 0.0))
    if not infl:                 # controller never steered: reconstruct
        ms_new = sess["planner"].tenants["t"].plan.makespan
        infl = max(sess["ms_keep"] / ms_new - 1.0, 0.0)
    detect_s = (applied[0]["t"] - SWITCH_T0) if applied else \
        (sess["t_end"] - SWITCH_T0)
    b_real_s = sess["t_end"] - SWITCH_T0

    # extra seconds vs the free-perfect-topology oracle, per policy
    never_extra = infl * (sess["flap_s"] + b_real_s)
    t0 = time.time()
    always_extra, always_replans = _always_extra(opts, cache)
    always_wall = time.time() - t0
    ctl_extra = infl * (sess["flap_s"] + detect_s) + \
        float(dec.get("delay_s", 0.0))

    # gate-able invariants: the controller must beat both trivial
    # policies, steer exactly once (the flap never reaches the planner),
    # and every replan must clear the break-even it was priced with
    violations = 0
    violations += int(steers != 1)
    violations += int(not (ctl_extra < never_extra))
    violations += int(not (ctl_extra < always_extra))
    for d in applied:
        dd = d["decision"]
        if dd["option"] == "replan" and not (
                dd["dwell_s"] * dd["inflation"] > dd["delay_s"]):
            violations += 1

    rows.append(Row(
        "steering/controller", ctl_wall * 1e6,
        f"makespan={ctl_extra:.6f};steers={steers};"
        f"detect_s={detect_s:.2f};delay_s={dec.get('delay_s', 0.0):.4f};"
        f"dwell_s={dec.get('dwell_s', 0.0):.1f};inflation={infl:.6f}"))
    rows.append(Row(
        "steering/never", 0.0,
        f"makespan={never_extra:.6f};inflation={infl:.6f};"
        f"b_seconds={sess['flap_s'] + b_real_s:.1f}"))
    rows.append(Row(
        "steering/always", always_wall * 1e6,
        f"makespan={always_extra:.6f};replans={always_replans}"))
    rows.append(Row(
        "steering/policy", 0.0,
        f"violations={violations};controller={ctl_extra:.4f};"
        f"never={never_extra:.4f};always={always_extra:.4f}"))
    wall = time.time() - t_suite
    rows.append(Row("steering/suite_wall", wall * 1e6,
                    f"seconds={wall:.2f};iters_b={iters_b}"))
    save_json("steering_bench", {
        "rows": [{"name": r.name, "us_per_call": r.us_per_call,
                  "derived": r.derived} for r in rows],
        "seconds": wall, "violations": violations})
    return rows
