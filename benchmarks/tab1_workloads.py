"""Table I: evaluation workload configurations + derived DAG statistics."""
from __future__ import annotations

import time

from benchmarks.common import Row, WORKLOADS, bench_dag, save_json
from repro.configs import PAPER_WORKLOADS
from repro.core.des import DESProblem
from repro.core.pruning import profile_anchors


def run(full: bool = False) -> list[Row]:
    rows = []
    payload = {}
    for w in WORKLOADS:
        plan = PAPER_WORKLOADS[w].plan
        t0 = time.time()
        dag = bench_dag(w, full=full)
        build_us = (time.time() - t0) * 1e6
        _, _, K = profile_anchors(DESProblem(dag))
        s = dag.summary()
        # MoE-vs-dense traffic split: EP all-to-all bytes vs PP/DP/xattn
        ep_gb = sum(v for k, v in s["volume_by_kind_gb"].items()
                    if k.startswith("ep_a2a"))
        dense_gb = s["total_volume_gb"] - ep_gb
        derived = (f"tp={plan.tp};pp={plan.pp};dp={plan.dp};ep={plan.ep};"
                   f"gpus={plan.num_gpus};tasks={s['num_tasks']};"
                   f"deps={s['num_deps']};pods={s['num_pods']};K={K};"
                   f"gb_per_iter={s['total_volume_gb']:.1f};"
                   f"ep_gb={ep_gb:.1f};dense_gb={dense_gb:.1f};"
                   f"ep_frac={s['ep_volume_fraction']:.3f}")
        payload[w] = {**s, "K": K, "ep_gb": ep_gb, "dense_gb": dense_gb,
                      "ep_spans": [list(g) for g in dag.cluster.ep_spans]}
        rows.append(Row(f"tab1/{w}", build_us, derived))
    save_json("tab1_workloads", payload)
    return rows
