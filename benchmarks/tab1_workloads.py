"""Table I: evaluation workload configurations + derived DAG statistics."""
from __future__ import annotations

import time

from benchmarks.common import Row, WORKLOADS, bench_dag, save_json
from repro.configs import PAPER_WORKLOADS
from repro.core.des import DESProblem
from repro.core.pruning import profile_anchors


def run(full: bool = False) -> list[Row]:
    rows = []
    payload = {}
    for w in WORKLOADS:
        plan = PAPER_WORKLOADS[w].plan
        t0 = time.time()
        dag = bench_dag(w, full=full)
        build_us = (time.time() - t0) * 1e6
        _, _, K = profile_anchors(DESProblem(dag))
        s = dag.summary()
        derived = (f"tp={plan.tp};pp={plan.pp};dp={plan.dp};"
                   f"gpus={plan.num_gpus};tasks={s['num_tasks']};"
                   f"deps={s['num_deps']};pods={s['num_pods']};K={K};"
                   f"gb_per_iter={s['total_volume_gb']:.1f}")
        payload[w] = {**s, "K": K}
        rows.append(Row(f"tab1/{w}", build_us, derived))
    save_json("tab1_workloads", payload)
    return rows
