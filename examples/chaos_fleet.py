"""Fault-injected fleet demo: failures, priced repairs, crash recovery.

    PYTHONPATH=src python examples/chaos_fleet.py

Admits two tenants, then drives a scripted failure trace through the
planner: a half-capacity link, a dark OCS plane, a port failure that
strands a tenant, and the matching recoveries.  Every event prints the
repair decision the planner priced (keep / rewire / replan) and the
ledger is conservation-checked after each one.  The journal is then
replayed from the last snapshot into a second planner, which must land on
a bit-identical decision history.

Exits non-zero if any invariant is violated (ledger imbalance, committed
pricing disagreeing with the masked DES oracle, or a non-identical
recovery), so CI can run it as a smoke gate.
"""
import json
import os
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np                                             # noqa: E402

from repro.core.des import DESProblem, simulate                # noqa: E402
from repro.core.ga import GAOptions                            # noqa: E402
from repro.core.traffic import JobSpec                         # noqa: E402
from repro.fleet import (FleetPlanner, FleetSpec, JobArrival,  # noqa: E402
                         LinkFailure, LinkRecovery, PlanCache,
                         PlaneFailure, PlaneRecovery, PortFailure,
                         PortRecovery)
from repro.obs import FleetJournal                             # noqa: E402
from repro.obs.journal import _json_default                    # noqa: E402

FAILURES = 0


def check(ok: bool, what: str) -> None:
    global FAILURES
    print(f"  [{'ok' if ok else 'VIOLATION'}] {what}")
    if not ok:
        FAILURES += 1


def job(name: str, pp: int = 4) -> JobSpec:
    return JobSpec(name=name, tp=2, pp=pp, dp=2, num_microbatches=4,
                   micro_tokens=4096, d_model=4096,
                   stage_params=(1.75e9,) * pp, gpus_per_pod_per_replica=4)


def verify_pricing(pl: FleetPlanner) -> None:
    """Every committed plan's makespan must equal the masked DES oracle."""
    for name, t in pl.tenants.items():
        mask = pl.health.local_mask(t.pods)
        got = t.plan.makespan
        want = simulate(DESProblem(t.dag),
                        t.plan.x.astype(np.float64) * mask).makespan
        same = (got == want) or (not np.isfinite(got)
                                 and not np.isfinite(want)) \
            or abs(got - want) <= 1e-9 * max(abs(want), 1.0)
        check(same, f"{name}: committed makespan {got:.6f} == masked "
                    f"oracle {want:.6f}")


def main() -> int:
    ga = GAOptions(seed=0, pop_size=16, max_generations=10,
                   patience=10**9, time_limit=1e9)
    fleet = FleetSpec(num_pods=6, ports_per_pod=16, nic_gbps=100.0)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "journal.jsonl")
        pl = FleetPlanner(fleet, ga_options=ga, seed=0, snapshot_every=3,
                          journal=FleetJournal(path))
        print(f"fleet: {fleet.num_pods} pods x {fleet.ports_per_pod} ports, "
              f"{pl.health.num_planes} OCS planes, snapshot every "
              f"3 events\n")

        events = [
            JobArrival(name="a", job=job("ja")),
            JobArrival(name="b", job=job("jb", pp=2), port_min=True),
            LinkFailure(pair=(0, 1), fraction=0.5),
            PlaneFailure(plane=0),
            PortFailure(pod=0, count=10),
            PortRecovery(pod=0, count=10),
            LinkRecovery(pair=(0, 1)),
            PlaneRecovery(plane=0),
        ]
        for ev in events:
            record = pl.handle(ev)   # raises on ledger imbalance
            kind = type(ev).__name__
            blob = json.dumps(record, default=_json_default)
            print(f"[{kind}] {blob[:120]}...")
            for dec in record.get("repairs", []):
                print(f"  repair {dec['tenant']}: chose {dec['option']!r} "
                      f"cost={dec['cost_s']:.2f}s "
                      f"(makespan {dec['ms_healthy']:.4f} -> "
                      f"{dec['makespan']:.4f}, "
                      f"{dec['changed_circuits']} circuit changes)")
            for rec in record.get("replans", []):
                print(f"  replan {rec['tenant']}: path={rec['path']}")
            try:
                pl.ledger.check()
                check(True, "ledger conservation")
            except Exception as exc:   # noqa: BLE001
                check(False, f"ledger conservation: {exc}")
            verify_pricing(pl)
        pl.journal.close()

        print("\n[recovery] replaying snapshot + journal tail ...")
        pl2 = FleetPlanner.recover(path, fleet, ga_options=ga,
                                   cache=PlanCache(), snapshot_every=3)
        h1 = json.dumps(pl.history, default=_json_default)
        h2 = json.dumps(pl2.history, default=_json_default)
        check(h1 == h2, "recovered decision history is bit-identical")
        check(pl.rng.bit_generator.state == pl2.rng.bit_generator.state,
              "recovered rng stream matches")
        for name, t in pl.tenants.items():
            t2 = pl2.tenants[name]
            check(bool((t.plan.x == t2.plan.x).all())
                  and t.plan.makespan == t2.plan.makespan,
                  f"recovered plan for {name!r} matches")

    print(f"\n{FAILURES} invariant violation(s)")
    return 1 if FAILURES else 0


if __name__ == "__main__":
    sys.exit(main())
