"""Telemetry-driven control plane demo: monitor -> decide -> apply.

    PYTHONPATH=src python examples/control_plane.py

Admits one tenant on a PP-heavy training phase, then feeds the controller
the telemetry its workload would emit (synthesized from the exact DES
rate trace): a stretch of on-plan iterations, a short phase flap the
hysteresis must swallow, and a real switch to a DP-heavy phase that the
controller confirms, prices with the *measured* dwell, and steers through
the planner's break-even machinery.  The journaled session is finally
replayed into a fresh planner, which must land on identical decisions.

Exits non-zero if any invariant is violated (flap reaching the planner,
steer not clearing the break-even, pricing disagreeing with the exact DES
oracle, or a non-identical replay), so CI can run it as a smoke gate.
"""
import json
import os
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np                                             # noqa: E402

from repro.core.des import DESProblem, simulate                # noqa: E402
from repro.core.ga import GAOptions                            # noqa: E402
from repro.core.schedule import build_comm_dag                 # noqa: E402
from repro.core.traffic import JobSpec                         # noqa: E402
from repro.fleet import (ControllerConfig, ControlPlane,       # noqa: E402
                         FleetPlanner, FleetSpec, JobArrival,
                         synthesize_telemetry)
from repro.obs import FleetJournal                             # noqa: E402

FAILURES = 0
NIC = 100.0


def check(ok: bool, what: str) -> None:
    global FAILURES
    print(f"  [{'ok' if ok else 'VIOLATION'}] {what}")
    if not ok:
        FAILURES += 1


def phase_job(mb: int, d_model: int, params: float) -> JobSpec:
    """Same placement footprint, different traffic shape (PP- vs
    DP-heavy) -- the legal domain of a TrafficChange."""
    return JobSpec(name="t", tp=2, pp=4, dp=2, num_microbatches=mb,
                   micro_tokens=4096, d_model=d_model,
                   stage_params=(params,) * 4, gpus_per_pod_per_replica=4)


JOB_A = phase_job(8, 4096, 0.2e9)      # pretrain: PP-heavy
JOB_B = phase_job(2, 1024, 3e9)        # finetune: DP-heavy
CFG = ControllerConfig(cadence_s=2.0, confirm_ticks=2, cooldown_s=0.0,
                       drift_threshold=0.05, drift_tau_s=5.0)


def make_planner(path: str | None = None) -> FleetPlanner:
    ga = GAOptions(seed=0, pop_size=16, max_generations=10,
                   patience=10**9, time_limit=1e9)
    return FleetPlanner(FleetSpec(num_pods=4, ports_per_pod=8,
                                  nic_gbps=NIC),
                        ga_options=ga, seed=0, reconfig_s_per_circuit=0.05,
                        journal=FleetJournal(path))


def drive(cp: ControlPlane, dag, x, **kw) -> None:
    for ev in synthesize_telemetry(dag, x, tenant="t", **kw):
        cp.observe(ev)


def main() -> int:
    dag_a = build_comm_dag(JOB_A, NIC)
    dag_b = build_comm_dag(JOB_B, NIC)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "session.jsonl")
        pl = make_planner(path)
        pl.handle(JobArrival(name="t", job=JOB_A))
        x0 = pl.tenants["t"].plan.x.copy()
        print(f"admitted on phase A: makespan="
              f"{pl.tenants['t'].plan.makespan * 1e3:.1f}ms, "
              f"dwell prior={pl.dwell_for('t'):.0f}s\n")

        cp = ControlPlane(pl, CFG, phase_book={"t": {"A": JOB_A,
                                                     "B": JOB_B}})
        print("phase A: 20 on-plan iterations")
        drive(cp, dag_a, x0, phase="A", t0=0.0, iterations=20)
        check(all("decision" not in d for d in cp.decisions),
              "on-plan traffic issued no steered change")

        print("flap: 2 iterations of B, back to A before confirm")
        drive(cp, dag_b, x0, phase="B", t0=100.0, iterations=2)
        drive(cp, dag_a, x0, phase="A", t0=104.0, iterations=20)
        check(all("decision" not in d for d in cp.decisions),
              "flap shorter than the confirm window never reached the "
              "planner")

        print("switch: phase B for real (measured dwell ~300s)")
        drive(cp, dag_b, x0, phase="B", t0=300.0, iterations=60)
        applied = [d for d in cp.decisions if "decision" in d]
        check(len(applied) == 1, "exactly one steered change was issued")
        if applied:
            d = applied[0]["decision"]
            print(f"  steer: {d['option']} dwell={d['dwell_s']:.0f}s "
                  f"inflation={d['inflation']:.3f} "
                  f"cost_keep={d['cost_keep_s']:.2f}s "
                  f"cost_replan={d['cost_replan_s']:.2f}s")
            check(d["dwell_s"] != 600.0,
                  "pricing used the measured dwell, not the prior")
            cheap, dear = ((d["cost_replan_s"], d["cost_keep_s"])
                           if d["option"] == "replan" else
                           (d["cost_keep_s"], d["cost_replan_s"]))
            check(cheap <= dear, "the chosen option is the cheaper one")
            if d["option"] == "replan":
                check(d["dwell_s"] * d["inflation"] > d["delay_s"],
                      "replan cleared the dwell x inflation > delay "
                      "break-even")
            t = pl.tenants["t"]
            want = simulate(DESProblem(t.dag),
                            t.plan.x.astype(np.float64)).makespan
            check(abs(t.plan.makespan - want)
                  <= 1e-9 * max(abs(want), 1.0),
                  f"committed makespan {t.plan.makespan:.6f} == exact DES "
                  f"oracle {want:.6f}")
        report = cp.report()
        print(f"\ncontroller report: {json.dumps(report['actions'])}, "
              f"dwell estimate "
              f"{report['tenants']['t']['dwell_estimate_s']:.0f}s")

        print("replay: journal -> fresh planner")
        fresh = make_planner()
        cp2 = ControlPlane.replay(path, fresh, config=CFG,
                                  phase_book={"t": {"A": JOB_A,
                                                    "B": JOB_B}})
        def strip(ds):
            return [{k: v for k, v in d.items() if k != "decision"}
                    for d in ds]
        check(strip(cp2.decisions) == strip(cp.decisions),
              "replayed decision history is identical")
        check(np.array_equal(fresh.tenants["t"].plan.x,
                             pl.tenants["t"].plan.x),
              "replayed topology is bit-identical")

    print(f"\n{'OK' if FAILURES == 0 else f'{FAILURES} VIOLATION(S)'}")
    return 1 if FAILURES else 0


if __name__ == "__main__":
    raise SystemExit(main())
