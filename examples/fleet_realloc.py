"""Fleet demo: donate a port-minimized tenant's savings to a bottlenecked
co-tenant (paper Sec. VI / Fig. 10, as a multi-tenant service).

    PYTHONPATH=src python examples/fleet_realloc.py

Admits the GPT-7B workload twice onto the same four pods: once normally
with port minimization (the donor), once with reversed stage placement (the
bandwidth-bottlenecked Model^T co-tenant).  The fleet planner's port ledger
tracks the donor's freed ports, waterfills them into the co-tenant, and
re-optimizes its topology with one batched JAX DES evaluation.
"""
import sys

sys.path.insert(0, "src")

from repro.configs import PAPER_WORKLOADS, make_job            # noqa: E402
from repro.core.ga import GAOptions                            # noqa: E402
from repro.fleet import (FleetPlanner, FleetSpec, JobArrival,  # noqa: E402
                         JobDeparture)


def main(fast: bool = True) -> None:
    arch = PAPER_WORKLOADS["gpt-7b"]
    job = make_job(arch, microbatches=8 if fast else
                   arch.plan.num_microbatches)
    placement = job.placement()
    fleet = FleetSpec(num_pods=placement.num_pods,
                      ports_per_pod=2 * max(placement.port_limits()),
                      nic_gbps=100.0)
    print(f"fleet: {fleet.num_pods} pods x {fleet.ports_per_pod} OCS ports, "
          f"{fleet.nic_gbps:.0f} Gb/s per port")

    ga = GAOptions(seed=0, time_limit=10 if fast else 60,
                   patience=15 if fast else 60)
    planner = FleetPlanner(fleet, ga_options=ga, seed=0)

    donor = planner.handle(JobArrival("model", job, port_min=True))
    print(f"\n[arrival] model        nct={donor['nct']:.4f} "
          f"ports={donor['ports']} donated={donor['donated_ports']}")

    cot = planner.handle(JobArrival("model_t", job, reverse_stages=True))
    print(f"[arrival] model_t      nct={cot['nct']:.4f} "
          f"ports={cot['ports']} (bottlenecked co-tenant)")
    for o in cot["realloc"]:
        print(f"[realloc] {o['tenant']:<12s} granted={o['granted']} "
              f"kept={o['kept']} nct {o['nct_before']:.4f} -> "
              f"{o['nct_after']:.4f} "
              f"({o['candidates']} candidates, 1 batched DES call)")

    report = planner.report()
    print(f"\nledger pool: {report['ledger']['pool']}")
    for name, t in report["tenants"].items():
        print(f"  {name:<12s} pods={t['pods']} nct={t['nct']:.4f} "
              f"ports={t['ports']}")
    print(f"plan cache: {report['cache']}")

    dep = planner.handle(JobDeparture("model"))
    print("\n[departure] model leaves; surplus pass re-runs:")
    for o in dep["realloc"]:
        print(f"[realloc] {o['tenant']:<12s} granted={o['granted']} "
              f"kept={o['kept']} nct {o['nct_before']:.4f} -> "
              f"{o['nct_after']:.4f}")
    planner.ledger.check()
    print("ledger conservation: OK")


if __name__ == "__main__":
    main(fast="--full" not in sys.argv)
