"""Plan OCS topologies for the paper's large workloads and reproduce the
port-saving + reallocation story (Figs. 9/10 direction) at reduced scale.

    PYTHONPATH=src python examples/plan_topology.py [--full]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np                                             # noqa: E402

from repro.configs import PAPER_WORKLOADS, make_job            # noqa: E402
from repro.core.api import optimize                            # noqa: E402
from repro.core.ga import GAOptions                            # noqa: E402
from repro.core.milp import MILPOptions                        # noqa: E402
from repro.core.schedule import build_comm_dag                 # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale microbatch counts (slow)")
    ap.add_argument("--arch", default="mixtral-8x22b")
    args = ap.parse_args()
    arch = PAPER_WORKLOADS[args.arch]
    mb = arch.plan.num_microbatches if args.full else 2 * arch.plan.pp
    job = make_job(arch, microbatches=mb)
    dag = build_comm_dag(job, inter_pod_gbps=400.0)
    print(f"{args.arch}: {dag.num_real_tasks} tasks, "
          f"{dag.cluster.num_pods} pods")

    fast = optimize(dag, "delta-fast",
                    ga_options=GAOptions(seed=0, time_limit=60))
    print(f"delta-fast : NCT={fast.nct:.4f} ports={fast.total_ports}")
    saved = optimize(dag, "delta-joint", port_min=True,
                     milp_options=MILPOptions(time_limit=240))
    if saved.feasible:
        U = np.asarray(dag.cluster.port_limits)
        used = saved.x.sum(axis=1)
        print(f"delta-joint+port-min: NCT={saved.nct:.4f} "
              f"ports={saved.total_ports} "
              f"(ratio {saved.total_ports/U.sum():.2f})")
        # reallocate surplus to the reversed-placement co-tenant
        dag_t = build_comm_dag(job, inter_pod_gbps=400.0,
                               reverse_stages=True)
        boosted = dag_t.cluster.with_port_limits(U + (U - used))
        dag_b = build_comm_dag(job, inter_pod_gbps=400.0,
                               reverse_stages=True, cluster=boosted)
        r0 = optimize(dag_t, "delta-fast",
                      ga_options=GAOptions(seed=0, time_limit=60))
        r1 = optimize(dag_b, "delta-fast",
                      ga_options=GAOptions(seed=0, time_limit=60))
        print(f"co-tenant Model^T: NCT {r0.nct:.4f} -> {r1.nct:.4f} "
              f"after port reallocation")


if __name__ == "__main__":
    main()
