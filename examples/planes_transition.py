"""Staggered k-plane transition demo: zero-downtime rewires under fire.

    PYTHONPATH=src python examples/planes_transition.py

Admits a tenant on a 4-plane fabric, replans it (a `TrafficChange`), and
shows the fleet applying the change as a staggered plane-by-plane
transition -- each step's certified peak inflation, then the journaled
plane events replayed into a second planner that must land on a
bit-identical plane book.

Then the hard case: a standalone `StaggeredTransition` takes a
`PlaneFailure` mid-transition on a plane it has NOT yet rewired.  The
scheduler re-prices the remaining steps against the doubly-degraded
fabric and either finishes or rolls back -- but the fleet must land on
exactly plan A or plan B, never between them.  A sub-1.0 SLO forces the
rollback path, and the transition timeline is schema-validated.

Exits non-zero if any invariant is violated (a step's journaled inflation
disagreeing with the masked numpy-DES oracle, a stranded fleet, a
non-identical replay, or an invalid timeline), so CI runs it as a gate.
"""
import json
import sys

sys.path.insert(0, "src")

import numpy as np                                             # noqa: E402

from repro.core.cluster import split_port_budgets              # noqa: E402
from repro.core.des import DESProblem, simulate                # noqa: E402
from repro.core.ga import GAOptions                            # noqa: E402
from repro.core.schedule import build_comm_dag                 # noqa: E402
from repro.core.traffic import JobSpec                         # noqa: E402
from repro.fleet import (FabricHealth, FleetPlanner,           # noqa: E402
                         FleetSpec, JobArrival, PlanCache,
                         StaggeredTransition, TenantLane,
                         TrafficChange, effective_topology, split_plan)
from repro.obs import (FleetJournal, plane_rewire_timeline,    # noqa: E402
                       validate_trace)
from repro.obs.journal import _json_default                    # noqa: E402

FAILURES = 0
NUM_PLANES = 4


def check(ok: bool, what: str) -> None:
    global FAILURES
    print(f"  [{'ok' if ok else 'VIOLATION'}] {what}")
    if not ok:
        FAILURES += 1


def job(name: str, mb: int = 4, tokens: int = 4096) -> JobSpec:
    return JobSpec(name=name, tp=2, pp=4, dp=2, num_microbatches=mb,
                   micro_tokens=tokens, d_model=4096,
                   stage_params=(1.75e9,) * 4, gpus_per_pod_per_replica=4)


GA = GAOptions(seed=0, pop_size=12, max_generations=25, patience=8,
               time_limit=5.0)


# ------------------------------------------------- fleet-driven transition
print("== fleet replan applies as a staggered transition ==")
journal = FleetJournal()
pl = FleetPlanner(FleetSpec(num_pods=4, ports_per_pod=8, nic_gbps=100.0),
                  ga_options=GA, seed=0, journal=journal, cache=PlanCache())
pl.handle(JobArrival(name="a", job=job("j")))
check(np.array_equal(pl.planes.total("a"), pl.tenants["a"].plan.x),
      "arrival decomposed across the plane book")
rec = pl.handle(TrafficChange(name="a", job=job("j", mb=8, tokens=8192)))
tr = rec.get("transition")
check(tr is not None and tr["status"] == "committed",
      "traffic change committed through the staggered scheduler")
if tr is not None:
    print(f"  transition {tr['transition']}: {tr['steps']} steps, "
          f"peak inflation {tr['peak_inflation']:.4f}, "
          f"plane order {tr['planes']}")
check(np.array_equal(pl.planes.total("a"), pl.tenants["a"].plan.x),
      "plane book sums to the committed topology")

plane_records = [e for e in journal.entries
                 if e.get("kind") == "plane_event"]
check(bool(plane_records) and all(e["event"]["v"] == 3
                                  for e in plane_records),
      f"{len(plane_records)} plane events journaled at schema v3")

pl2 = FleetPlanner.recover(journal.entries, pl.fleet, ga_options=GA,
                           seed=0, cache=PlanCache())
check(pl2.planes.snapshot() == pl.planes.snapshot(),
      "journal replay lands on a bit-identical plane book")
check(json.dumps(pl2.transitions, default=_json_default)
      == json.dumps(pl.transitions, default=_json_default),
      "replayed transitions match the recorded ones exactly")


# ------------------------------------------- mid-transition plane failure
print("== PlaneFailure mid-transition on a not-yet-rewired plane ==")
dag = build_comm_dag(job("solo", mb=2), 400.0)
P = dag.cluster.num_pods
x_a = np.zeros((P, P), dtype=np.int64)
for i, j in dag.undirected_pairs():
    x_a[i, j] = x_a[j, i] = 4
x_b = x_a.copy()
for i, j in dag.undirected_pairs()[:2]:
    x_b[i, j] = x_b[j, i] = 2
budgets = np.asarray(split_port_budgets((64,) * P, NUM_PLANES))
lane = TenantLane(name="solo", dag=dag, pods=tuple(range(P)),
                  planes_a=split_plan(x_a, budgets),
                  planes_b=split_plan(x_b, budgets))
health = FabricHealth(P, NUM_PLANES)
tr2 = StaggeredTransition([lane], health, slo=5.0, transition_id="demo")

first = tr2.step()
check(first is not None, "first rewire step performed")
victim = tr2.pending[0]
health.fail_plane(victim)
print(f"  !! plane {victim} fails while still carrying plan-A circuits")
outcome = "committed"
while tr2.pending:
    if tr2.step() is None:
        tr2.rollback()
        outcome = "rolled_back"
        break
print(f"  outcome: {outcome} after {len(tr2.steps)} steps "
      f"(fabric still dark on plane {victim})")

final = tr2.mixed_planes(lane)
target = lane.planes_b if outcome == "committed" else lane.planes_a
check(np.array_equal(final, target),
      f"fleet landed on exactly plan {'B' if outcome == 'committed' else 'A'}"
      " -- never stranded between plans")

# re-certify every journaled step against the masked numpy oracle
prob = DESProblem(dag)
done: list[int] = []
exact = 0
for s in tr2.steps:
    mixed = lane.planes_a.copy()
    for p in done:
        mixed[p] = lane.planes_b[p]
    dark = {victim} if s.seq > first.seq else set()
    ref = simulate(prob, effective_topology(mixed, dark)).makespan
    ms = simulate(prob, effective_topology(mixed, dark | {s.plane})).makespan
    peak = max(ms / ref, 1.0) if np.isfinite(ms) else float("inf")
    if s.peak_inflation == peak:
        exact += 1
    if s.direction == "forward":
        done.append(s.plane)
    else:
        done.remove(s.plane)
check(exact == len(tr2.steps),
      f"{exact}/{len(tr2.steps)} step inflations match the oracle EXACTLY")

trace = plane_rewire_timeline(tr2.steps, tr2._result(outcome).summary)
check(validate_trace(trace) == [], "transition timeline is schema-valid")


# --------------------------------------------------------- forced rollback
print("== sub-1.0 SLO forces the rollback path ==")
health2 = FabricHealth(P, NUM_PLANES)
tr3 = StaggeredTransition([lane], health2, slo=0.5, transition_id="tight")
res3 = tr3.run()
check(res3.status == "rolled_back"
      and np.array_equal(tr3.mixed_planes(lane), lane.planes_a),
      "impossible SLO rolls back to plan A exactly")

print(f"{'PASS' if FAILURES == 0 else 'FAIL'}: {FAILURES} violation(s)")
sys.exit(1 if FAILURES else 0)
