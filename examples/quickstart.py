"""Quickstart: plan an OCS logical topology for a small LLM training job.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's GPT-7B profiling workload (Fig. 1), derives its reduced
inter-pod communication DAG, and compares DELTA-Fast against the
traffic-matrix baselines.
"""
import sys

sys.path.insert(0, "src")

from repro.configs import PAPER_WORKLOADS, make_job            # noqa: E402
from repro.core.api import compare                             # noqa: E402
from repro.core.ga import GAOptions                            # noqa: E402
from repro.core.schedule import build_comm_dag                 # noqa: E402


def main(fast: bool = False) -> None:
    arch = PAPER_WORKLOADS["gpt-7b"]
    job = make_job(arch, seq_len=4096,
                   microbatches=4 if fast else arch.plan.num_microbatches)
    dag = build_comm_dag(job, inter_pod_gbps=400.0)
    s = dag.summary()
    print(f"job {job.name}: tp={job.tp} pp={job.pp} dp={job.dp} "
          f"mb={job.num_microbatches}")
    print(f"inter-pod DAG: {s['num_tasks']} tasks, {s['num_deps']} deps, "
          f"{s['num_pods']} pods, {s['total_volume_gb']:.1f} GB/iteration")

    ga = GAOptions(seed=0, time_limit=10 if fast else 60,
                   patience=15 if fast else 60)
    plans = compare(dag, methods=("prop-alloc", "sqrt-alloc", "iter-halve",
                                  "delta-fast"), ga_options=ga)
    print(f"\n{'method':<14s} {'NCT':>8s} {'makespan':>12s} {'ports':>6s}")
    for name, r in plans.items():
        print(f"{name:<14s} {r.nct:8.4f} {r.makespan*1e3:10.2f}ms "
              f"{r.total_ports:6d}")
    best = min(plans.values(), key=lambda r: r.nct)
    print(f"\nbest: {best.method} (NCT {best.nct:.4f})")
    print("planned circuits x_ij (row i -> col j):")
    print(best.x)


if __name__ == "__main__":
    main()
