"""Serve a small model with batched requests (prefill + decode loop).

    PYTHONPATH=src python examples/serve_decode.py
"""
import subprocess
import sys


def main() -> None:
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", "qwen3-0.6b", "--reduce",
           "--batch", "4", "--prompt-len", "64", "--decode-steps", "32"]
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env={"PYTHONPATH": "src",
                                               "PATH": "/usr/bin:/bin",
                                               "HOME": "/root"}))


if __name__ == "__main__":
    main()
