"""Plan one Table-I workload and emit its schedule timeline + span trace.

    PYTHONPATH=src python examples/trace_plan.py [--out DIR] [--full]

Produces, under --out (default experiments/trace):

  schedule_gpt-7b.json   Chrome-trace JSON of the DES schedule -- open in
                         https://ui.perfetto.dev (one track per inter-pod
                         link, critical-path tasks in red, per-link
                         utilization counter tracks)
  spans_gpt-7b.json      Chrome-trace JSON of the planner's own spans
                         (ga.evolve > ga.generation > ga.fitness_batch >
                         des.simulate / des.jit)

and prints the critical-path / per-task-slack report plus the span
summary.  Exits non-zero if the emitted trace fails schema validation or
the slack report disagrees with the DES makespan -- CI runs this as a
smoke check of the whole repro.obs layer.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, "src")

import numpy as np                                             # noqa: E402

from repro.configs import PAPER_WORKLOADS, make_job            # noqa: E402
from repro.core.des import DESProblem, simulate                # noqa: E402
from repro.core.ga import GAOptions, delta_fast                # noqa: E402
from repro.core.schedule import build_comm_dag                 # noqa: E402
from repro.obs import (TRACER, schedule_timeline,              # noqa: E402
                       slack_report, validate_trace, write_trace)

WORKLOAD = "gpt-7b"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="experiments/trace")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale microbatches and GA budget")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    arch = PAPER_WORKLOADS[WORKLOAD]
    mb = arch.plan.num_microbatches if args.full else max(arch.plan.pp, 4)
    job = make_job(arch, microbatches=mb)
    dag = build_comm_dag(job, inter_pod_gbps=100.0)
    print(f"{WORKLOAD}: {dag.num_tasks} comm tasks over "
          f"{dag.cluster.num_pods} pods ({mb} microbatches)")

    # ---- plan with tracing on: the span trace shows where the GA's wall
    # clock went (generations, fused DES fitness batches, jit compiles)
    TRACER.enable()
    ga = GAOptions(seed=0, time_limit=60.0 if args.full else 15.0,
                   patience=60 if args.full else 20)
    res = delta_fast(dag, ga)
    print(f"DELTA-Fast: makespan {res.makespan:.6f}s, "
          f"{res.total_ports} ports, {res.generations} generations, "
          f"{res.evaluations} evaluations in {res.elapsed:.1f}s")

    # ---- simulate the chosen plan with per-interval rates and export the
    # schedule timeline + the critical-path / slack report
    problem = DESProblem(dag)
    sim = simulate(problem, res.x, record_rates=True)
    rep = slack_report(dag, sim)
    trace = schedule_timeline(dag, res.x, sim)

    # the report must agree with the DES: the zero-slack chain IS the
    # makespan (paper: critical path pins the schedule; everything else
    # carries exploitable temporal slack)
    finish = np.asarray(sim.finish)
    realized = float(finish[np.isfinite(finish)].max())
    if abs(realized - rep["makespan"]) > 1e-9 * max(1.0, rep["makespan"]):
        print(f"FAIL: slack report makespan {rep['makespan']} != realized "
              f"{realized}")
        return 1
    if not rep["zero_slack_tasks"]:
        print("FAIL: no zero-slack task (critical path must have slack 0)")
        return 1

    print(f"\nslack report: makespan {rep['makespan']:.6f}s, "
          f"comm {rep['comm_time']:.6f}s, "
          f"{len(rep['zero_slack_tasks'])}/{rep['num_tasks']} tasks on the "
          f"critical (zero-slack) set, "
          f"mean slack {rep['mean_slack']:.6f}s")

    sched_path = os.path.join(args.out, f"schedule_{WORKLOAD}.json")
    write_trace(trace, sched_path)       # raises if schema-invalid
    print(f"wrote {sched_path} ({len(trace['traceEvents'])} events) -- "
          f"open in https://ui.perfetto.dev")

    span_trace = TRACER.to_chrome_trace(process_name=f"plan {WORKLOAD}")
    errors = validate_trace(span_trace)
    if errors:
        print(f"FAIL: span trace invalid: {errors[:3]}")
        return 1
    span_path = os.path.join(args.out, f"spans_{WORKLOAD}.json")
    with open(span_path, "w") as f:
        json.dump(span_trace, f)
    print(f"wrote {span_path} ({len(span_trace['traceEvents'])} events)")

    print("\nspan summary (where the planning time went):")
    for name, row in sorted(TRACER.summary().items(),
                            key=lambda kv: -kv[1]["total_s"]):
        print(f"  {name:<24} x{row['count']:<6} total {row['total_s']:8.3f}s"
              f"  max {row['max_s']:.3f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
