"""End-to-end driver: plan the fabric with DELTA, then train a ~100M-class
model for a few hundred steps on synthetic data with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py            # ~5 min on CPU
    PYTHONPATH=src python examples/train_lm.py --quick    # CI-sized
"""
import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    steps = "60" if args.quick else "300"
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-0.6b", "--reduce",
           "--steps", steps, "--batch", "8", "--seq", "128",
           "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
           "--plan-topology",
           "--simulate-failure", "75" if not args.quick else "-1",
           "--log-every", "20"]
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env={"PYTHONPATH": "src",
                                               "PATH": "/usr/bin:/bin",
                                               "HOME": "/root"}))


if __name__ == "__main__":
    main()
