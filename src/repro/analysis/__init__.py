"""DELTA-Sentinel: repo-specific static analysis (stdlib-only, AST-based).

Every correctness bug this repo shipped and later fixed was a *class*, not
a one-off: `JobSpec.ep` plumbed but never read (PR 3), the jitted DES
silently downcasting float64 caps (PR 2), `optimize()` mutating the
caller's `MILPOptions` (PR 1), `solve` extracting garbage from a
`time_limit` status with no incumbent (PR 7).  Sentinel turns each fixed
bug class into a machine-checked rule (`RPR###` codes) so it cannot
regress, the way the benchmark gate made perf regressions unshippable.

Usage:

    PYTHONPATH=src python -m repro.analysis src tests benchmarks

Per-line suppression:   ``# sentinel: ignore[RPR001]`` (trailing comment on
the reported line; several codes separated by commas, bare
``# sentinel: ignore`` suppresses every rule on the line).

Grandfathered findings live in ``sentinel_baseline.json`` (see
`repro.analysis.baseline`); `repro.analysis.check_baseline` is the CI
guard that keeps the baseline from growing silently.

This package intentionally imports nothing outside the standard library,
so the CI sentinel job runs on a bare Python install.
"""
from repro.analysis.engine import (FileContext, Finding, Rule, RULES,
                                   analyze_paths, collect_contexts,
                                   iter_python_files)
from repro.analysis.baseline import Baseline
from repro.analysis.report import render_json, render_text

__all__ = [
    "Baseline",
    "FileContext",
    "Finding",
    "RULES",
    "Rule",
    "analyze_paths",
    "collect_contexts",
    "iter_python_files",
    "render_json",
    "render_text",
]
