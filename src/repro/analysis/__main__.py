"""Sentinel CLI.

    PYTHONPATH=src python -m repro.analysis src tests benchmarks

Exit status 0 = no non-baselined findings, 1 = findings (or stale
baseline), 2 = usage error.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.baseline import DEFAULT_BASELINE, Baseline
from repro.analysis.engine import RULES, analyze_paths
from repro.analysis.report import (render_json, render_rule_catalog,
                                   render_text)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="DELTA-Sentinel repo-specific static analysis")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files/directories to analyze")
    ap.add_argument("--select", default="",
                    help="comma-separated rule codes (default: all)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit JSON instead of text")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline file (default {DEFAULT_BASELINE}; "
                         f"ignored when absent)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, baselined or not")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings to --baseline and "
                         "exit 0 (grandfathering; guarded in CI by "
                         "repro.analysis.check_baseline)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        from repro.analysis import rules as _rules  # noqa: F401
        print(render_rule_catalog())
        return 0
    if not args.paths:
        ap.error("no paths given (try: src tests benchmarks)")

    select = [s.strip() for s in args.select.split(",") if s.strip()] or None
    if select:
        from repro.analysis import rules as _rules  # noqa: F401
        unknown = [s for s in select if s not in RULES and s != "RPR000"]
        if unknown:
            ap.error(f"unknown rule code(s) {unknown}; "
                     f"known: {sorted(RULES)}")

    findings = analyze_paths(args.paths, select=select)
    nfiles = len(list(_count_files(args.paths)))

    if args.write_baseline:
        Baseline.from_findings(findings).save(args.baseline)
        print(f"wrote {len(findings)} entr{'y' if len(findings) == 1 else 'ies'} "
              f"to {args.baseline}")
        return 0

    baselined: list = []
    stale: list = []
    if not args.no_baseline and os.path.exists(args.baseline):
        bl = Baseline.load(args.baseline)
        findings, baselined, stale = bl.split(findings)

    render = render_json if args.as_json else render_text
    out = render(findings, baselined, nfiles)
    if out:
        print(out, end="" if out.endswith("\n") else "\n")
    for e in stale:
        print(f"# stale baseline entry (no longer matches anything -- "
              f"remove it): {e['rule']} {e['path']} {e['key']}")
    return 1 if findings or stale else 0


def _count_files(paths):
    from repro.analysis.engine import iter_python_files
    return iter_python_files(paths)


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:   # e.g. `... --list-rules | head`
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        sys.exit(0)
