"""Sentinel baseline: grandfathered findings, committed for review.

``sentinel_baseline.json`` holds findings that predate a rule (or are
accepted with justification) as ``{rule, path, key, note}`` entries -- no
line numbers, so entries survive unrelated edits.  The CLI subtracts
baselined findings before deciding the exit status; `check_baseline` (the
CI guard) fails when the file grows beyond the pinned entry count or
carries entries that no longer match any finding, so grandfathering is
always visible in review and the baseline can only shrink silently.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

from repro.analysis.engine import Finding

DEFAULT_BASELINE = "sentinel_baseline.json"


@dataclass
class Baseline:
    entries: list[dict] = field(default_factory=list)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path, encoding="utf-8") as f:
            payload = json.load(f)
        entries = payload.get("findings", [])
        for e in entries:
            missing = {"rule", "path", "key"} - set(e)
            if missing:
                raise ValueError(
                    f"baseline entry {e!r} is missing {sorted(missing)}")
        return cls(entries=entries)

    @classmethod
    def from_findings(cls, findings: list[Finding]) -> "Baseline":
        return cls(entries=[
            {"rule": f.rule, "path": f.path, "key": f.key,
             "note": "grandfathered; fix and remove"}
            for f in findings])

    def save(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump({"version": 1, "findings": self.entries}, f, indent=2,
                      sort_keys=True)
            f.write("\n")

    def ids(self) -> set[tuple[str, str, str]]:
        return {(e["rule"], e["path"], e["key"]) for e in self.entries}

    def split(self, findings: list[Finding]
              ) -> tuple[list[Finding], list[Finding], list[dict]]:
        """(new, baselined, stale-entries) for a fresh run's findings."""
        ids = self.ids()
        new = [f for f in findings if f.baseline_id not in ids]
        old = [f for f in findings if f.baseline_id in ids]
        matched = {f.baseline_id for f in old}
        stale = [e for e in self.entries
                 if (e["rule"], e["path"], e["key"]) not in matched]
        return new, old, stale
