"""CI guard on the Sentinel baseline (check_regression.py-style).

Grandfathering must be visible in review: the number of baselined findings
is pinned HERE, in code, so adding a baseline entry requires touching this
file in the same PR.  The guard fails when

  * the baseline holds more than ``MAX_BASELINE_ENTRIES`` entries,
  * the baseline holds duplicate entries,
  * (with ``--paths``) an entry matches no current finding -- stale
    entries must be deleted, so the baseline can only shrink over time.

Usage (what CI runs):

    PYTHONPATH=src python -m repro.analysis.check_baseline \
        --paths src tests benchmarks

Exit status 0 = baseline healthy, 1 = guard tripped.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.baseline import DEFAULT_BASELINE, Baseline
from repro.analysis.engine import analyze_paths

# The one number a PR must edit to grow the baseline.  The shipped tree
# carries zero grandfathered findings: every rule is either clean or
# suppressed inline with a justification comment at the offending line.
MAX_BASELINE_ENTRIES = 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--paths", nargs="*", default=[],
                    help="when given, also fail on stale entries")
    ap.add_argument("--max-entries", type=int, default=MAX_BASELINE_ENTRIES,
                    help="override the pinned entry budget (tests only)")
    args = ap.parse_args(argv)

    problems: list[str] = []
    if not os.path.exists(args.baseline):
        print(f"# no baseline file ({args.baseline}); nothing to guard")
        return 0

    bl = Baseline.load(args.baseline)
    n = len(bl.entries)
    print(f"# baseline {args.baseline}: {n} entr{'y' if n == 1 else 'ies'} "
          f"(budget {args.max_entries})")
    if n > args.max_entries:
        problems.append(
            f"baseline grew to {n} entries > pinned budget "
            f"{args.max_entries}: fix the finding instead, or raise "
            f"MAX_BASELINE_ENTRIES in repro/analysis/check_baseline.py in "
            f"the same PR so the grandfathering is visible in review")
    if len(bl.ids()) != n:
        problems.append("baseline holds duplicate entries")

    if args.paths:
        findings = analyze_paths(args.paths)
        _, _, stale = bl.split(findings)
        for e in stale:
            problems.append(
                f"stale baseline entry (matches no current finding; "
                f"delete it): {e['rule']} {e['path']} {e['key']}")

    if problems:
        print("\nSENTINEL BASELINE GUARD:")
        for p in problems:
            print("  - " + p)
        return 1
    print("baseline healthy")
    return 0


if __name__ == "__main__":
    sys.exit(main())
