"""Sentinel engine: file contexts, rule registry, suppressions, runner.

A rule is a function ``(ctxs: list[FileContext]) -> Iterable[Finding]``
registered with `@rule(...)`.  Every rule sees the whole analyzed corpus
(several rules are package-wide by nature: "field never read anywhere",
"function reachable from a jit call site"); purely local rules just loop
over the contexts.

Findings carry a ``key`` -- a line-number-free fingerprint (rule, path,
symbol/context) -- so baseline entries survive unrelated edits to the same
file.  Suppression is a trailing ``# sentinel: ignore[RPR###]`` comment on
the reported line.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator

# directories never analyzed: VCS/cache noise plus the seeded-violation
# fixtures (tests/test_sentinel.py analyzes those explicitly)
EXCLUDED_DIRS = {".git", "__pycache__", ".ruff_cache", "sentinel_fixtures",
                 ".pytest_cache", "node_modules"}

_SUPPRESS_RE = re.compile(
    r"#\s*sentinel:\s*ignore(?:\[(?P<codes>[A-Z0-9,\s]+)\])?")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str          # e.g. "RPR001"
    path: str          # repo-relative posix path
    line: int          # 1-based
    message: str
    key: str           # stable fingerprint (no line numbers) for baselines

    @property
    def baseline_id(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.key)

    def sort_key(self) -> tuple:
        return (self.path, self.line, self.rule, self.key)


@dataclass(frozen=True)
class Rule:
    """Registered rule: code + metadata + the check callable."""

    code: str
    name: str
    summary: str                 # one-line description (rule catalog)
    bug: str                     # the historical bug class it encodes
    check: Callable[[list["FileContext"]], Iterable[Finding]]


RULES: dict[str, Rule] = {}


def rule(code: str, name: str, summary: str, bug: str):
    """Decorator registering a corpus-level check under an RPR### code."""

    def deco(fn: Callable[[list["FileContext"]], Iterable[Finding]]):
        if code in RULES:
            raise ValueError(f"duplicate sentinel rule code {code}")
        RULES[code] = Rule(code=code, name=name, summary=summary, bug=bug,
                           check=fn)
        return fn

    return deco


@dataclass
class FileContext:
    """One parsed source file."""

    path: str                    # normalized relative posix path
    tree: ast.Module
    lines: list[str] = field(default_factory=list)
    # line -> set of suppressed codes (empty set == suppress everything)
    suppressions: dict[int, set[str]] = field(default_factory=dict)
    module: str = ""             # dotted module name when under a package

    @classmethod
    def parse(cls, path: str, display_path: str,
              source: str | None = None) -> "FileContext":
        if source is None:
            with open(path, encoding="utf-8") as f:
                source = f.read()
        tree = ast.parse(source, filename=display_path)
        ctx = cls(path=display_path, tree=tree,
                  lines=source.splitlines(),
                  module=_module_name(display_path))
        ctx.suppressions = _parse_suppressions(ctx.lines)
        return ctx

    def is_suppressed(self, finding: Finding) -> bool:
        codes = self.suppressions.get(finding.line)
        if codes is None:
            return False
        return not codes or finding.rule in codes


def _module_name(path: str) -> str:
    """Best-effort dotted module name ('' when not under src/)."""
    p = path.replace(os.sep, "/")
    if p.endswith(".py"):
        p = p[:-3]
    if "src/" in p:
        p = p.split("src/", 1)[1]
    elif p.startswith("src/"):
        p = p[4:]
    parts = [q for q in p.split("/") if q]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _parse_suppressions(lines: list[str]) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, line in enumerate(lines, start=1):
        if "sentinel" not in line:
            continue
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        codes = m.group("codes")
        out[i] = {c.strip() for c in codes.split(",") if c.strip()} \
            if codes else set()
    return out


def iter_python_files(paths: Iterable[str],
                      root: str | None = None) -> Iterator[tuple[str, str]]:
    """Yield (abspath, display_path) for every .py file under `paths`.

    `display_path` is relative to `root` (default: cwd) with forward
    slashes, so findings and baselines are machine-independent.
    """
    root = os.path.abspath(root or os.getcwd())

    def display(p: str) -> str:
        rel = os.path.relpath(os.path.abspath(p), root)
        return rel.replace(os.sep, "/")

    for path in paths:
        if os.path.isfile(path):
            if path.endswith(".py"):
                yield os.path.abspath(path), display(path)
            continue
        for dirpath, dirnames, filenames in os.walk(path):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in EXCLUDED_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    full = os.path.join(dirpath, fn)
                    yield os.path.abspath(full), display(full)


def collect_contexts(paths: Iterable[str],
                     root: str | None = None
                     ) -> tuple[list[FileContext], list[Finding]]:
    """Parse every file; unparsable files become RPR000 findings."""
    ctxs: list[FileContext] = []
    errors: list[Finding] = []
    for abspath, display_path in iter_python_files(paths, root):
        try:
            ctxs.append(FileContext.parse(abspath, display_path))
        except (SyntaxError, UnicodeDecodeError) as exc:
            errors.append(Finding(
                rule="RPR000", path=display_path,
                line=getattr(exc, "lineno", 1) or 1,
                message=f"file does not parse: {exc.msg}"
                if isinstance(exc, SyntaxError) else f"cannot read: {exc}",
                key="parse-error"))
    return ctxs, errors


def analyze_paths(paths: Iterable[str], select: Iterable[str] | None = None,
                  root: str | None = None) -> list[Finding]:
    """Run the (selected) rules over `paths`; suppressions applied."""
    # rule modules register themselves on import
    from repro.analysis import rules as _rules  # noqa: F401

    ctxs, findings = collect_contexts(paths, root)
    by_path = {c.path: c for c in ctxs}
    selected = set(select) if select else None
    for code in sorted(RULES):
        if selected is not None and code not in selected:
            continue
        findings.extend(RULES[code].check(ctxs))
    out = []
    for f in findings:
        ctx = by_path.get(f.path)
        if ctx is not None and ctx.is_suppressed(f):
            continue
        out.append(f)
    return sorted(out, key=Finding.sort_key)


# ---------------------------------------------------------------- AST utils
def call_name(node: ast.AST) -> str:
    """Dotted name of a call target: 'jnp.asarray', 'md.solve', 'float'."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def names_in(node: ast.AST) -> set[str]:
    """All Name identifiers loaded anywhere inside `node`."""
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


def iter_functions(tree: ast.AST
                   ) -> Iterator[ast.FunctionDef | ast.AsyncFunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def decorator_names(fn: ast.FunctionDef | ast.AsyncFunctionDef
                    ) -> list[str]:
    out = []
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call):
            out.append(call_name(dec.func))
        else:
            out.append(call_name(dec))
    return out


def annotation_text(node: ast.AST | None) -> str:
    if node is None:
        return ""
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - unparse is total on parsed ASTs
        return ""


def is_dataclass_def(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        name = call_name(dec.func) if isinstance(dec, ast.Call) \
            else call_name(dec)
        if name in ("dataclass", "dataclasses.dataclass"):
            return True
    return False


def is_namedtuple_def(cls: ast.ClassDef) -> bool:
    return any(call_name(base) in ("NamedTuple", "typing.NamedTuple")
               for base in cls.bases)


def class_fields(cls: ast.ClassDef) -> list[tuple[str, ast.AnnAssign]]:
    """(name, node) for annotated class-level fields (dataclass/NamedTuple
    style), skipping ClassVar and underscore-private names."""
    out: list[tuple[str, ast.AnnAssign]] = []
    for stmt in cls.body:
        if not isinstance(stmt, ast.AnnAssign) or \
                not isinstance(stmt.target, ast.Name):
            continue
        name = stmt.target.id
        if name.startswith("_"):
            continue
        if "ClassVar" in annotation_text(stmt.annotation):
            continue
        out.append((name, stmt))
    return out
