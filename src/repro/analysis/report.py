"""Sentinel reporters: text (CI log) and JSON (tooling)."""
from __future__ import annotations

import json

from repro.analysis.engine import RULES, Finding


def render_text(findings: list[Finding], baselined: list[Finding],
                files_analyzed: int) -> str:
    lines: list[str] = []
    for f in findings:
        lines.append(f"{f.path}:{f.line}: {f.rule} {f.message}")
    if baselined:
        lines.append(f"# {len(baselined)} baselined finding(s) suppressed "
                     f"(see sentinel_baseline.json)")
    counts: dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    summary = ", ".join(f"{k}: {v}" for k, v in sorted(counts.items()))
    lines.append(f"# {files_analyzed} file(s), {len(findings)} finding(s)"
                 + (f" [{summary}]" if summary else ""))
    return "\n".join(lines)


def render_json(findings: list[Finding], baselined: list[Finding],
                files_analyzed: int) -> str:
    return json.dumps({
        "version": 1,
        "files_analyzed": files_analyzed,
        "findings": [
            {"rule": f.rule, "path": f.path, "line": f.line,
             "message": f.message, "key": f.key}
            for f in findings],
        "baselined": [
            {"rule": f.rule, "path": f.path, "line": f.line, "key": f.key}
            for f in baselined],
    }, indent=2) + "\n"


def render_rule_catalog() -> str:
    lines = ["Sentinel rule catalog:"]
    for code in sorted(RULES):
        r = RULES[code]
        lines.append(f"  {r.code}  {r.name}")
        lines.append(f"         {r.summary}")
        lines.append(f"         history: {r.bug}")
    return "\n".join(lines)
