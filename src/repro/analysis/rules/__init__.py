"""Sentinel rules: importing this package registers every rule.

Catalog (one rule per documented historical bug class):

  RPR001  unread-field              the PR-3 `JobSpec.ep` bug
  RPR002  caller-options-mutation   the PR-1 `MILPOptions` bug
  RPR003  jit-float64-downcast      the PR-2 DES cap-dtype bug
  RPR004  bare-host-array-hot-path  the PR-2 bug's host-side twin
  RPR005  solver-status-gate        the PR-7 time_limit/no-incumbent bug
  RPR006  jit-host-sync             live hazard on the PR-5 jit seams
  RPR007  jit-impurity              live hazard since PR-6 obs tracing
  RPR008  cache-key-hygiene         PR-5 CompiledDES bucket keys
  RPR009  deprecated-facade-call    the PR-9 plan() API unification
"""
from repro.analysis.rules import (cachekey, dtype, facade, fields, jit,
                                  mutation, solver)

__all__ = ["cachekey", "dtype", "facade", "fields", "jit", "mutation",
           "solver"]
