"""RPR008: compile-cache keys must be hashable statics.

History: the PR-5 `CompiledDES` bucket cache keys a jit executable by
``(cfg, pad.d, pad.e)`` where ``cfg`` is a NamedTuple of scalars -- the
whole point is that every element is a *hashable static*.  The failure
modes this rule guards:

* keying a cache on a list/dict/set (TypeError at first insert -- found in
  review twice),
* keying on a non-frozen dataclass instance (``eq=True`` without
  ``frozen=True`` sets ``__hash__ = None``: unhashable),
* keying on a frozen-but-array-carrying container (NamedTuple / frozen
  dataclass holding ``np.ndarray`` fields: the tuple hash recurses into
  the unhashable array),
* ``functools.lru_cache`` over parameters of those same types.

Only names that look like caches (``*_CACHE``, ``cache``, ...) are
checked, so ordinary dict writes stay out of scope.
"""
from __future__ import annotations

import ast
import re
from typing import Iterable

from repro.analysis.engine import (FileContext, Finding, annotation_text,
                                   call_name, class_fields, is_dataclass_def,
                                   is_namedtuple_def, iter_functions, rule)

_CACHE_NAME_RE = re.compile(r"(?i)(^|_)cache(s|_|$)|^memo")

_UNHASHABLE_ANN_TOKENS = ("list", "List", "dict", "Dict", "set", "Set",
                          "ndarray", "Array", "bytearray", "DataFrame")


def _ann_unhashable(ann: str) -> bool:
    if not ann:
        return False
    return any(re.search(rf"\b{re.escape(tok)}\b", ann)
               for tok in _UNHASHABLE_ANN_TOKENS)


def _class_info(ctxs: list[FileContext]) -> tuple[set[str], set[str]]:
    """(unhashable class names, array-carrying hashable containers)."""
    unhashable: set[str] = set()
    array_carrying: set[str] = set()
    for ctx in ctxs:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if is_dataclass_def(node):
                if not _dataclass_frozen(node):
                    unhashable.add(node.name)
                elif _has_unhashable_fields(node):
                    array_carrying.add(node.name)
            elif is_namedtuple_def(node) and _has_unhashable_fields(node):
                array_carrying.add(node.name)
    return unhashable, array_carrying


def _dataclass_frozen(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        if isinstance(dec, ast.Call) and call_name(dec.func) in (
                "dataclass", "dataclasses.dataclass"):
            for kw in dec.keywords:
                if kw.arg == "frozen" and \
                        isinstance(kw.value, ast.Constant):
                    return bool(kw.value.value)
    return False


def _has_unhashable_fields(cls: ast.ClassDef) -> bool:
    return any(_ann_unhashable(annotation_text(f.annotation))
               for _, f in class_fields(cls))


def _is_cache_name(expr: ast.AST) -> bool:
    name = call_name(expr)
    return bool(name and _CACHE_NAME_RE.search(name.split(".")[-1]))


def _key_elements(key: ast.expr) -> list[ast.expr]:
    if isinstance(key, ast.Tuple):
        return list(key.elts)
    return [key]


def _scope_env(fn) -> tuple[dict[str, str], dict[str, str]]:
    """(local name -> ctor class name, param name -> annotation text)."""
    ctors: dict[str, str] = {}
    for node in _walk_shallow(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                isinstance(node.value, ast.Call):
            cname = call_name(node.value.func).split(".")[-1]
            if cname and cname[0].isupper():
                ctors[node.targets[0].id] = cname
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                isinstance(node.value, (ast.List, ast.Dict, ast.Set,
                                        ast.ListComp, ast.DictComp,
                                        ast.SetComp)):
            ctors[node.targets[0].id] = "@literal"
    params: dict[str, str] = {}
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        for a in (list(fn.args.posonlyargs) + list(fn.args.args) +
                  list(fn.args.kwonlyargs)):
            params[a.arg] = annotation_text(a.annotation)
    return ctors, params


def _element_problem(el: ast.expr, ctors: dict[str, str],
                     params: dict[str, str], unhashable: set[str],
                     array_carrying: set[str]) -> str | None:
    if isinstance(el, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                       ast.DictComp, ast.SetComp)):
        return "a list/dict/set literal is unhashable"
    if isinstance(el, ast.Call):
        cname = call_name(el.func)
        tail = cname.split(".")[-1]
        if tail in ("list", "dict", "set", "bytearray"):
            return f"`{tail}(...)` is unhashable"
        if tail in unhashable:
            return f"`{tail}` is a non-frozen dataclass (unhashable)"
        if tail in array_carrying:
            return f"`{tail}` carries ndarray fields (hash recurses into " \
                   f"the unhashable array)"
        return None
    if isinstance(el, ast.Name):
        src = ctors.get(el.id)
        if src == "@literal":
            return f"`{el.id}` is a list/dict/set"
        if src in unhashable:
            return f"`{el.id}` is a non-frozen `{src}` (unhashable)"
        if src in array_carrying:
            return f"`{el.id}` is a `{src}` carrying ndarray fields"
        ann = params.get(el.id, "")
        if _ann_unhashable(ann):
            return f"`{el.id}: {ann}` is unhashable"
        if ann.split(".")[-1] in unhashable:
            return f"`{el.id}: {ann}` is a non-frozen dataclass (unhashable)"
    return None


@rule(
    code="RPR008",
    name="cache-key-hygiene",
    summary="compile/lookup cache keyed (or lru_cache parameterized) on an "
            "unhashable or array-carrying value",
    bug="PR 5: CompiledDES bucket keys must be hashable scalars/NamedTuples; "
        "an ndarray or non-frozen dataclass in the key dies at first insert",
)
def check(ctxs: list[FileContext]) -> Iterable[Finding]:
    unhashable, array_carrying = _class_info(ctxs)
    for ctx in ctxs:
        scopes = [("<module>", ctx.tree)] + \
            [(f.name, f) for f in iter_functions(ctx.tree)]
        for scope_name, scope in scopes:
            ctors, params = _scope_env(scope)
            for node in _walk_shallow(scope):
                key_expr = _cache_key_expr(node)
                if key_expr is None:
                    continue
                for i, el in enumerate(_key_elements(key_expr)):
                    why = _element_problem(el, ctors, params, unhashable,
                                           array_carrying)
                    if why is None:
                        continue
                    yield Finding(
                        rule="RPR008", path=ctx.path, line=node.lineno,
                        message=f"cache key element {i} in `{scope_name}` "
                                f"is not a hashable static: {why}; cache "
                                f"keys must be scalars / frozen scalar "
                                f"containers (the CompiledDES bucket-key "
                                f"contract)",
                        key=f"{scope_name}:key[{i}]")
        yield from _check_lru_cache(ctx)


def _walk_shallow(scope) -> Iterable[ast.AST]:
    """Walk one scope without descending into nested function/class defs
    (each def is its own scope in the outer loop)."""
    stack = list(scope.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _cache_key_expr(node: ast.AST) -> ast.expr | None:
    """Key expression of a cache write/lookup, else None."""
    if isinstance(node, ast.Subscript) and _is_cache_name(node.value):
        return node.slice
    if isinstance(node, ast.Call) and \
            isinstance(node.func, ast.Attribute) and \
            node.func.attr in ("get", "setdefault", "pop") and \
            _is_cache_name(node.func.value) and node.args:
        return node.args[0]
    return None


def _check_lru_cache(ctx: FileContext) -> Iterable[Finding]:
    for fn in iter_functions(ctx.tree):
        decorated = False
        for dec in fn.decorator_list:
            name = call_name(dec.func) if isinstance(dec, ast.Call) \
                else call_name(dec)
            if name in ("functools.lru_cache", "lru_cache",
                        "functools.cache", "cache"):
                decorated = True
        if not decorated:
            continue
        for a in (list(fn.args.posonlyargs) + list(fn.args.args) +
                  list(fn.args.kwonlyargs)):
            ann = annotation_text(a.annotation)
            if _ann_unhashable(ann):
                yield Finding(
                    rule="RPR008", path=ctx.path, line=fn.lineno,
                    message=f"@lru_cache on `{fn.name}` with unhashable "
                            f"parameter `{a.arg}: {ann}`: every call "
                            f"raises TypeError; key on hashable statics "
                            f"(shape tuples, frozen configs) instead",
                    key=f"{fn.name}.{a.arg}")
