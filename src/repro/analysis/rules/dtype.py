"""RPR003/RPR004: dtype hazards on the host/device seam of hot paths.

History: PR 2 fixed the DES capacity buffers being built as float64 on the
host and silently downcast at the jit boundary (JAX runs with x64
*disabled*), which made long-horizon makespans drift by whole timesteps.
Two rules encode the lesson, both scoped to the hot modules (`des_jax`,
`kernels`) where a dtype seam is a correctness bug rather than a style
nit:

* RPR003 -- an explicit ``dtype=jnp.float64`` (or ``"float64"`` /
  ``np.float64``) passed to a ``jnp.*`` constructor.  With x64 disabled
  this is a silent no-op downcast to float32: the author *believes* they
  requested double precision and nobody gets it.

* RPR004 -- a bare host-side ``np.*`` array construction whose default
  dtype is float64 (``np.zeros``/``ones``/``full``/``empty``/
  ``linspace``, or ``np.array``/``asarray`` over float payloads) with no
  explicit ``dtype=``.  The array crosses to the device as float32 while
  host-side consumers keep float64 -- the exact PR-2 seam.  Chained
  ``.astype(...)`` makes the intent explicit and is accepted.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import FileContext, Finding, call_name, rule

# modules where the host/device dtype seam is load-bearing
_HOT_MARKERS = ("des_jax", "kernels")

_F64_DEFAULT_CTORS = {"zeros", "ones", "full", "empty", "linspace",
                      "zeros_like", "ones_like", "full_like", "empty_like",
                      "eye", "identity"}
_ARRAY_CTORS = {"array", "asarray", "ascontiguousarray"}


def _is_hot(ctx: FileContext) -> bool:
    return any(m in ctx.path for m in _HOT_MARKERS)


def _dtype_kw(node: ast.Call) -> ast.expr | None:
    for kw in node.keywords:
        if kw.arg == "dtype":
            return kw.value
    return None


def _is_float64(expr: ast.expr) -> bool:
    if isinstance(expr, ast.Constant) and expr.value in ("float64", "f8"):
        return True
    name = call_name(expr)
    return name in ("jnp.float64", "np.float64", "numpy.float64",
                    "jax.numpy.float64", "float64")


def _astype_wrapped(tree: ast.Module) -> set[ast.Call]:
    """Calls that are immediately chained into `.astype(...)`."""
    wrapped: set[ast.Call] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "astype" and \
                isinstance(node.value, ast.Call):
            wrapped.add(node.value)
    return wrapped


def _has_float_payload(node: ast.Call) -> bool:
    """True when an np.array/asarray argument visibly carries floats."""
    for arg in node.args[:1]:
        for sub in ast.walk(arg):
            if isinstance(sub, ast.Constant) and isinstance(sub.value, float):
                return True
            if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Div):
                return True
    return False


@rule(
    code="RPR003",
    name="jit-float64-downcast",
    summary="explicit dtype=float64 on a jnp constructor in a hot module "
            "(x64 is disabled: this silently produces float32)",
    bug="PR 2: DES capacity buffers requested float64 under jnp; with x64 "
        "disabled the request is a silent downcast and makespans drifted",
)
def check_rpr003(ctxs: list[FileContext]) -> Iterable[Finding]:
    for ctx in ctxs:
        if not _is_hot(ctx):
            continue
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node.func)
            if not (name.startswith("jnp.") or name.startswith("jax.numpy.")):
                continue
            dt = _dtype_kw(node)
            if dt is not None and _is_float64(dt):
                yield Finding(
                    rule="RPR003", path=ctx.path, line=node.lineno,
                    message=f"`{name}(..., dtype=float64)` in a hot module: "
                            f"JAX x64 is disabled here, so this silently "
                            f"yields float32 (the PR-2 downcast bug); use "
                            f"float32 explicitly or route through "
                            f"jax.config if double precision is required",
                    key=f"{name}:{_nearest_scope(ctx.tree, node)}")


@rule(
    code="RPR004",
    name="bare-host-array-hot-path",
    summary="np.* array construction with float64 default dtype and no "
            "explicit dtype= in a hot module (host/device dtype seam)",
    bug="PR 2: host-side float64 staging arrays crossed the jit boundary "
        "as float32 while host consumers stayed float64",
)
def check_rpr004(ctxs: list[FileContext]) -> Iterable[Finding]:
    for ctx in ctxs:
        if not _is_hot(ctx):
            continue
        wrapped = _astype_wrapped(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) or node in wrapped:
                continue
            name = call_name(node.func)
            if not (name.startswith("np.") or name.startswith("numpy.")):
                continue
            tail = name.split(".")[-1]
            if _dtype_kw(node) is not None:
                continue
            if tail in _F64_DEFAULT_CTORS or \
                    (tail in _ARRAY_CTORS and _has_float_payload(node)):
                yield Finding(
                    rule="RPR004", path=ctx.path, line=node.lineno,
                    message=f"`{name}(...)` defaults to float64 on the "
                            f"host but the device side of this module runs "
                            f"float32 (the PR-2 seam); pass an explicit "
                            f"dtype= or chain .astype(...)",
                    key=f"{name}:{_nearest_scope(ctx.tree, node)}")


def _nearest_scope(tree: ast.Module, target: ast.AST) -> str:
    """Enclosing function/class name for a stable, line-free key."""
    best = "<module>"
    tline = getattr(target, "lineno", 0)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            end = getattr(node, "end_lineno", node.lineno)
            if node.lineno <= tline <= end:
                best = node.name
    return best
