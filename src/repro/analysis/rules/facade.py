"""RPR009: in-tree calls to the deprecated planner facades.

History: PR 9 collapsed the six-way facade sprawl (`optimize`,
`optimize_ensemble`, `optimize_failsafe`, `optimize_resilient`,
`fleet_optimize`) into the single typed entry point
``plan(PlanRequest(...))`` in ``repro.core.api``.  The old names remain
as bit-identical shims so downstream callers keep working, but *in-tree*
code growing new calls to them re-forks the API surface the redesign
just unified -- every new mode would again need five signatures kept in
sync.

The rule flags calls to the facade names inside ``repro.*`` modules
(``repro.core.api`` itself excepted: it hosts the shims) whenever the
name is traceable to ``repro.core.api`` -- a ``from repro.core.api
import optimize`` binding, or an attribute call through an alias of the
module (``from repro.core import api; api.optimize(...)``).  Local
functions that merely share a facade's name are not flagged.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import (FileContext, Finding, call_name,
                                   iter_functions, rule)

FACADES = {"optimize", "optimize_ensemble", "optimize_failsafe",
           "optimize_resilient", "fleet_optimize"}
API_MODULE = "repro.core.api"


def _scopes(ctx: FileContext):
    yield "<module>", ctx.tree
    for fn in iter_functions(ctx.tree):
        yield fn.name, fn


def _walk_scope(scope) -> Iterable[ast.AST]:
    """Walk a function/module without descending into nested defs."""
    stack = list(scope.body) if hasattr(scope, "body") else [scope]
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _facade_bindings(tree: ast.Module) -> tuple[dict[str, str], set[str]]:
    """(local name -> facade it binds, aliases naming repro.core.api)."""
    direct: dict[str, str] = {}
    mod_aliases: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            if node.module == API_MODULE:
                for a in node.names:
                    if a.name in FACADES:
                        direct[a.asname or a.name] = a.name
            elif node.module == "repro.core":
                for a in node.names:
                    if a.name == "api":
                        mod_aliases.add(a.asname or "api")
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == API_MODULE:
                    mod_aliases.add(a.asname or API_MODULE)
    return direct, mod_aliases


@rule(
    code="RPR009",
    name="deprecated-facade-call",
    summary="in-tree call to a deprecated planner facade instead of "
            "plan(PlanRequest(...))",
    bug="PR 9: the five optimize_*/fleet_optimize facades were collapsed "
        "into plan(); new in-tree callers of the shims re-fork the API "
        "surface the redesign unified",
)
def check(ctxs: list[FileContext]) -> Iterable[Finding]:
    for ctx in ctxs:
        if not ctx.module.startswith("repro.") or ctx.module == API_MODULE:
            continue
        direct, mod_aliases = _facade_bindings(ctx.tree)
        if not direct and not mod_aliases:
            continue
        for scope_name, scope in _scopes(ctx):
            for node in _walk_scope(scope):
                if not isinstance(node, ast.Call):
                    continue
                facade = _called_facade(node, direct, mod_aliases)
                if facade is None:
                    continue
                yield Finding(
                    rule="RPR009", path=ctx.path, line=node.lineno,
                    message=f"call to deprecated facade `{facade}`; build "
                            f"a PlanRequest and call "
                            f"`repro.core.api.plan` instead",
                    key=f"{scope_name}:{facade}")


def _called_facade(node: ast.Call, direct: dict[str, str],
                   mod_aliases: set[str]) -> str | None:
    if isinstance(node.func, ast.Name):
        return direct.get(node.func.id)
    name = call_name(node.func)
    if "." not in name:
        return None
    prefix, attr = name.rsplit(".", 1)
    if attr in FACADES and prefix in mod_aliases:
        return attr
    return None
