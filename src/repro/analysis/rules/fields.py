"""RPR001: dataclass/NamedTuple fields written or plumbed but never read.

History: `JobSpec.ep` (PR 3) was added, plumbed through `make_job` and the
placement constructors, and then never *read* -- every Table-I MoE
workload silently built a DP-only DAG, losing 24-42% of its traffic and
invalidating the headline comparison.  A field nobody reads is either dead
weight or, much worse, a feature that silently fell off the data path.

Detection is package-wide and name-based: a field of a dataclass /
NamedTuple defined under ``repro`` counts as *read* when any analyzed file
loads an attribute of that name (``obj.field``), names it in a literal
``getattr(obj, "field")``, or the defining class maps it dynamically via a
``getattr(x, f) for f in ...`` sweep over its own fields.  Constructor
keywords, ``dataclasses.replace(...)`` keywords and assignments are writes
("plumbing"), not reads.  Name-matching is deliberately generous -- a
shared name anywhere counts -- so every finding is high-signal.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import (FileContext, Finding, class_fields,
                                   call_name, is_dataclass_def,
                                   is_namedtuple_def, rule)


def _defining_contexts(ctxs: list[FileContext]) -> list[FileContext]:
    """Field definitions are only collected from package modules (module
    name derived from an `src/` layout): a helper dataclass in a test or
    benchmark is not production API."""
    return [c for c in ctxs if c.module.startswith("repro.")]


def _read_names(ctxs: list[FileContext]) -> set[str]:
    """Every attribute name the corpus loads, plus literal getattr names."""
    reads: set[str] = set()
    for ctx in ctxs:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.ctx, ast.Load):
                reads.add(node.attr)
            elif isinstance(node, ast.Call) and \
                    call_name(node.func) in ("getattr", "hasattr") and \
                    len(node.args) >= 2 and \
                    isinstance(node.args[1], ast.Constant) and \
                    isinstance(node.args[1].value, str):
                reads.add(node.args[1].value)
    return reads


def _dynamic_sweep_classes(ctxs: list[FileContext]) -> set[str]:
    """Class names whose fields are consumed via `_fields`/`asdict`-style
    dynamic sweeps anywhere (e.g. `getattr(self.arrays, f) for f in
    _ARRAY_FIELDS`): their fields cannot be tracked by name, skip them."""
    dynamic: set[str] = set()
    for ctx in ctxs:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute) and node.attr in (
                    "_fields", "__dataclass_fields__"):
                base = call_name(node.value)
                if base:
                    dynamic.add(base.split(".")[-1])
            elif isinstance(node, ast.Call) and call_name(node.func) in (
                    "dataclasses.asdict", "asdict", "dataclasses.astuple",
                    "astuple", "vars"):
                for arg in node.args:
                    base = call_name(arg)
                    if base:
                        dynamic.add(base.split(".")[-1])
    return dynamic


@rule(
    code="RPR001",
    name="unread-field",
    summary="dataclass/NamedTuple field is never read anywhere in the "
            "analyzed tree (attribute load or literal getattr)",
    bug="PR 3: JobSpec.ep was plumbed but never read, so Table-I MoE "
        "workloads silently lost their 24-42% EP traffic",
)
def check(ctxs: list[FileContext]) -> Iterable[Finding]:
    reads = _read_names(ctxs)
    dynamic = _dynamic_sweep_classes(ctxs)
    for ctx in _defining_contexts(ctxs):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not (is_dataclass_def(node) or is_namedtuple_def(node)):
                continue
            # `getattr(instance, f) for f in CLASS._fields` sweeps make
            # name-tracking blind; `cls(**mapping)` round-trips do not
            # (those are writes)
            if node.name in dynamic:
                continue
            for fname, fnode in class_fields(node):
                if fname in reads:
                    continue
                yield Finding(
                    rule="RPR001", path=ctx.path, line=fnode.lineno,
                    message=f"field `{node.name}.{fname}` is never read "
                            f"anywhere in the analyzed tree -- plumbed-but-"
                            f"unread fields silently drop features (the "
                            f"JobSpec.ep bug); read it, remove it, or "
                            f"suppress with a justification",
                    key=f"{node.name}.{fname}")
