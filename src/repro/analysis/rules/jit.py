"""RPR006/RPR007: host syncs and impurity inside jit-traced code.

History: the PR-5 kernel-fused DES moved the event loop under `jax.jit` /
`lax.while_loop`, and PR-6 added `repro.obs` tracing spans.  Both changes
created a standing hazard class: code that is *reachable from a trace
context* silently misbehaves when it branches on traced values (trace-time
constant folding), forces host syncs (`.item()`, `float(...)` -- a device
round-trip per call inside the hot loop), or calls impure host APIs
(`time.*`, `random.*`, `repro.obs` spans -- these run ONCE at trace time
and never again, so the metric/span is a lie).

The rules build a conservative call graph:

* seeds -- functions passed to ``jax.jit``/``vmap``/``pmap``,
  ``jax.lax.while_loop``/``scan``/``cond``/``fori_loop``,
  ``pl.pallas_call`` (including through ``functools.partial``), and
  functions decorated ``@jax.jit`` / ``@partial(jax.jit, ...)``;
* edges -- calls to module-level functions, ``self.`` methods, and
  attributes of corpus-module import aliases.

Inside reachable functions, a value is treated as *traced* when it is a
local assigned from a ``jnp.*``/``jax.*`` expression -- ``.shape`` /
``.dtype`` / ``.ndim`` / ``.size`` derivations are static under trace and
excluded, as are plain parameters (static arguments like a mode string
would otherwise drown the signal).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Iterable

from repro.analysis.engine import FileContext, Finding, call_name, rule

_STATIC_ATTRS = {"shape", "dtype", "ndim", "size"}
_IMPURE_PREFIXES = ("time.", "random.", "np.random.", "numpy.random.",
                    "datetime.", "secrets.")
_OBS_MODULE = "repro.obs"


# ------------------------------------------------------------- call graph
@dataclass
class _Fn:
    key: tuple[str, str]            # (path, qualname)
    node: ast.FunctionDef | ast.AsyncFunctionDef
    ctx: FileContext
    cls: str | None


def _collect_functions(ctxs: list[FileContext]) -> dict[tuple, _Fn]:
    fns: dict[tuple, _Fn] = {}
    for ctx in ctxs:
        for node in ctx.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                key = (ctx.path, node.name)
                fns[key] = _Fn(key, node, ctx, None)
            elif isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        key = (ctx.path, f"{node.name}.{sub.name}")
                        fns[key] = _Fn(key, sub, ctx, node.name)
    return fns


def _import_map(ctx: FileContext) -> tuple[dict[str, str],
                                           dict[str, tuple[str, str]]]:
    """(module aliases, from-imports): `import x.y as z` -> {z: 'x.y'};
    `from x import f` -> {f: ('x', 'f')}."""
    aliases: dict[str, str] = {}
    froms: dict[str, tuple[str, str]] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                froms[a.asname or a.name] = (node.module, a.name)
    return aliases, froms


def _resolve_ref(expr: ast.AST, ctx: FileContext,
                 fns: dict[tuple, _Fn],
                 module_fns: dict[tuple[str, str], tuple],
                 aliases: dict[str, str],
                 froms: dict[str, tuple[str, str]]) -> tuple | None:
    """Map a function reference expression to a _Fn key, if in-corpus."""
    if isinstance(expr, ast.Call) and call_name(expr.func) in (
            "functools.partial", "partial"):
        if expr.args:
            return _resolve_ref(expr.args[0], ctx, fns, module_fns,
                                aliases, froms)
        return None
    if isinstance(expr, ast.Name):
        key = (ctx.path, expr.id)
        if key in fns:
            return key
        if expr.id in froms:
            mod, orig = froms[expr.id]
            return module_fns.get((mod, orig))
        return None
    if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name):
        base = expr.value.id
        if base == "self":
            for key, fn in fns.items():
                if key[0] == ctx.path and fn.cls and \
                        key[1].endswith("." + expr.attr):
                    return key
            return None
        mod = aliases.get(base)
        if mod is None and base in froms:
            parent, orig = froms[base]
            mod = f"{parent}.{orig}"
        if mod is not None:
            return module_fns.get((mod, expr.attr))
    return None


_SEED_CALLS = {
    "jax.jit": [0], "jit": [0], "jax.vmap": [0], "vmap": [0],
    "jax.pmap": [0],
    "jax.lax.while_loop": [0, 1], "lax.while_loop": [0, 1],
    "jax.lax.scan": [0], "lax.scan": [0],
    "jax.lax.cond": [1, 2], "lax.cond": [1, 2],
    "jax.lax.fori_loop": [2], "lax.fori_loop": [2],
    "jax.lax.map": [0], "lax.map": [0],
    "pl.pallas_call": [0], "pallas_call": [0],
    "jax.checkpoint": [0], "jax.remat": [0],
}


def _jit_decorated(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    for dec in fn.decorator_list:
        name = call_name(dec.func) if isinstance(dec, ast.Call) \
            else call_name(dec)
        if name in ("jax.jit", "jit", "jax.vmap", "vmap", "jax.pmap"):
            return True
        if name in ("functools.partial", "partial") and \
                isinstance(dec, ast.Call) and dec.args and \
                call_name(dec.args[0]) in ("jax.jit", "jit", "jax.vmap",
                                           "vmap"):
            return True
    return False


def _reachable(ctxs: list[FileContext]) -> dict[tuple, _Fn]:
    fns = _collect_functions(ctxs)
    module_fns: dict[tuple[str, str], tuple] = {}
    for key, fn in fns.items():
        if fn.cls is None and fn.ctx.module:
            module_fns[(fn.ctx.module, key[1])] = key

    seeds: set[tuple] = set()
    imports = {ctx.path: _import_map(ctx) for ctx in ctxs}
    for key, fn in fns.items():
        if _jit_decorated(fn.node):
            seeds.add(key)
    for ctx in ctxs:
        aliases, froms = imports[ctx.path]
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node.func)
            idxs = _SEED_CALLS.get(name)
            if idxs is None:
                continue
            for i in idxs:
                if i < len(node.args):
                    key = _resolve_ref(node.args[i], ctx, fns, module_fns,
                                       aliases, froms)
                    if key is not None:
                        seeds.add(key)

    # transitive closure over in-corpus call edges
    reached: dict[tuple, _Fn] = {}
    frontier = list(seeds)
    while frontier:
        key = frontier.pop()
        if key in reached or key not in fns:
            continue
        fn = fns[key]
        reached[key] = fn
        aliases, froms = imports[fn.ctx.path]
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Call):
                tgt = _resolve_ref(node.func, fn.ctx, fns, module_fns,
                                   aliases, froms)
                if tgt is not None and tgt not in reached:
                    frontier.append(tgt)
    return reached


# ------------------------------------------------------ traced-value model
def _is_jnp_expr(expr: ast.AST) -> bool:
    """Expression contains a jnp./jax. call (device-producing)."""
    return any(
        isinstance(node, ast.Call) and call_name(node.func).startswith(
            ("jnp.", "jax.numpy.", "jax.lax.", "lax."))
        for node in ast.walk(expr))


def _is_static_derivation(expr: ast.AST) -> bool:
    """`x.shape`, `x.dtype`, `x.shape[0]`, `len(...)` -- static at trace."""
    node = expr
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
        return True
    if isinstance(node, ast.Call) and call_name(node.func) == "len":
        return True
    return False


def _traced_locals(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    traced: set[str] = set()
    for _ in range(2):  # one re-pass picks up traced-from-traced chains
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, ast.AugAssign):
                value, targets = node.value, [node.target]
            else:
                continue
            derived = _is_jnp_expr(value) or any(
                isinstance(n, ast.Name) and n.id in traced and
                isinstance(n.ctx, ast.Load) for n in ast.walk(value))
            if not derived or _is_static_derivation(value):
                continue
            for tgt in targets:
                elts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) \
                    else [tgt]
                for e in elts:
                    if isinstance(e, ast.Name):
                        traced.add(e.id)
    return traced


def _traced_usage(expr: ast.AST, traced: set[str]) -> bool:
    """A traced name (or jnp call) used in `expr` NOT under a static
    `.shape`/`.dtype`/... derivation."""
    if _is_static_derivation(expr):
        return False
    if isinstance(expr, ast.Attribute):
        if expr.attr in _STATIC_ATTRS:
            return False
        return _traced_usage(expr.value, traced)
    if isinstance(expr, ast.Name):
        return expr.id in traced
    if isinstance(expr, ast.Call):
        name = call_name(expr.func)
        if name.startswith(("jnp.", "jax.numpy.", "jax.lax.", "lax.")):
            return True
        return any(_traced_usage(a, traced) for a in expr.args)
    for child in ast.iter_child_nodes(expr):
        if _traced_usage(child, traced):
            return True
    return False


# ------------------------------------------------------------------ rules
@rule(
    code="RPR006",
    name="jit-host-sync",
    summary="host sync or Python control flow on traced values inside a "
            "jit-reachable function",
    bug="PR 5 moved the DES event loop under jit: .item()/float() force a "
        "device round-trip per call; `if` on a traced value is folded at "
        "trace time and never re-evaluated",
)
def check_rpr006(ctxs: list[FileContext]) -> Iterable[Finding]:
    for key, fn in _reachable(ctxs).items():
        traced = _traced_locals(fn.node)
        qual = key[1]
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Call):
                name = call_name(node.func)
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and not node.args:
                    yield Finding(
                        rule="RPR006", path=fn.ctx.path, line=node.lineno,
                        message=f"`.item()` inside jit-reachable "
                                f"`{qual}`: forces a device->host sync per "
                                f"call (and fails under trace); keep the "
                                f"value on-device or hoist the sync out "
                                f"of the jitted body",
                        key=f"{qual}:item")
                elif name in ("float", "int", "bool") and len(node.args) \
                        == 1 and _traced_usage(node.args[0], traced):
                    yield Finding(
                        rule="RPR006", path=fn.ctx.path, line=node.lineno,
                        message=f"`{name}(...)` on a traced value inside "
                                f"jit-reachable `{qual}`: host sync / "
                                f"ConcretizationTypeError under trace; "
                                f"use jnp casts (.astype) instead",
                        key=f"{qual}:{name}")
            elif isinstance(node, (ast.If, ast.While)):
                if _traced_usage(node.test, traced):
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield Finding(
                        rule="RPR006", path=fn.ctx.path, line=node.lineno,
                        message=f"Python `{kind}` on a traced value inside "
                                f"jit-reachable `{qual}`: the branch is "
                                f"folded once at trace time and never "
                                f"re-evaluated; use jnp.where / "
                                f"lax.cond / lax.while_loop",
                        key=f"{qual}:{kind}")
            elif isinstance(node, ast.Assert) and \
                    _traced_usage(node.test, traced):
                yield Finding(
                    rule="RPR006", path=fn.ctx.path, line=node.lineno,
                    message=f"`assert` on a traced value inside "
                            f"jit-reachable `{qual}`: evaluated once at "
                            f"trace time only; use "
                            f"jax.debug or checkify for runtime checks",
                    key=f"{qual}:assert")


@rule(
    code="RPR007",
    name="jit-impurity",
    summary="impure host API (time/random/obs spans) or host numpy on "
            "traced operands inside a jit-reachable function",
    bug="PR 6 added repro.obs spans: a span or time.time() inside a jitted "
        "body runs once at trace time, so the recorded metric is a lie",
)
def check_rpr007(ctxs: list[FileContext]) -> Iterable[Finding]:
    for key, fn in _reachable(ctxs).items():
        traced = _traced_locals(fn.node)
        qual = key[1]
        aliases, froms = _import_map(fn.ctx)
        obs_names = {local for local, (mod, _) in froms.items()
                     if mod == _OBS_MODULE or mod.startswith(_OBS_MODULE + ".")}
        obs_aliases = {a for a, mod in aliases.items()
                       if mod == _OBS_MODULE or
                       mod.startswith(_OBS_MODULE + ".")}
        for node in ast.walk(fn.node):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node.func)
            if name.startswith(_IMPURE_PREFIXES):
                yield Finding(
                    rule="RPR007", path=fn.ctx.path, line=node.lineno,
                    message=f"`{name}(...)` inside jit-reachable "
                            f"`{qual}`: runs ONCE at trace time, then the "
                            f"traced constant is reused forever; hoist it "
                            f"out of the jitted body (or thread a PRNG "
                            f"key for randomness)",
                    key=f"{qual}:{name}")
            elif name.split(".")[0] in obs_names or \
                    name.split(".")[0] in obs_aliases:
                yield Finding(
                    rule="RPR007", path=fn.ctx.path, line=node.lineno,
                    message=f"repro.obs call `{name}(...)` inside "
                            f"jit-reachable `{qual}`: spans/metrics fire "
                            f"once at trace time, so the recorded timing "
                            f"is a lie; instrument the host-side caller "
                            f"instead",
                    key=f"{qual}:{name}")
            elif name.startswith(("np.", "numpy.")) and \
                    not name.startswith(("np.random.", "numpy.random.")) \
                    and any(_traced_usage(a, traced) for a in node.args):
                yield Finding(
                    rule="RPR007", path=fn.ctx.path, line=node.lineno,
                    message=f"host numpy `{name}(...)` on a traced "
                            f"operand inside jit-reachable `{qual}`: "
                            f"forces a sync and detaches the value "
                            f"from the trace; use the jnp equivalent",
                    key=f"{qual}:{name}")
