"""RPR002: public functions mutating caller-passed option/dataclass args.

History: PR 1 fixed `optimize()` silently mutating the caller's
`MILPOptions` (the options object is shared across calls; a mutated
time_limit leaked into every later solve).  The repo convention since is
`dataclasses.replace(opts, ...)` for per-call overrides.

The rule flags, inside any public function or method, an attribute
assignment (or augmented assignment, or `setattr`) on a bare parameter
when the parameter is annotated with a package dataclass type or named
like an options object.  Rebinding the parameter first via
`dataclasses.replace(...)`, `copy.deepcopy(...)`, `.copy()` or a fresh
constructor makes later mutations local and is accepted;
``opts = opts or Default()`` is NOT accepted (the caller's object is still
the one being mutated whenever the caller passed one).
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import (FileContext, Finding, annotation_text,
                                   call_name, is_dataclass_def, rule)

# parameter names treated as caller-owned option objects even without a
# resolvable annotation
_OPTIONS_NAMES = {"opts", "options", "config", "cfg"}

_SAFE_REBIND_CALLS = ("replace", "dataclasses.replace", "copy.deepcopy",
                      "deepcopy", "copy.copy")


# class-name suffixes marking a dataclass as an options/config object
# (entity dataclasses like Tenant are mutable state by design; the PR-1
# bug class is specifically about *shared configuration* objects)
_OPTIONS_SUFFIXES = ("Options", "Opts", "Config", "Params", "Settings")


def _package_dataclasses(ctxs: list[FileContext]) -> set[str]:
    out: set[str] = set()
    for ctx in ctxs:
        if not ctx.module.startswith("repro."):
            continue
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef) and is_dataclass_def(node) \
                    and node.name.endswith(_OPTIONS_SUFFIXES):
                out.add(node.name)
    return out


def _tracked_params(fn: ast.FunctionDef | ast.AsyncFunctionDef,
                    dataclasses_: set[str]) -> dict[str, str]:
    """param name -> why it is tracked ('annotation X' / 'name')."""
    out: dict[str, str] = {}
    args = list(fn.args.posonlyargs) + list(fn.args.args) \
        + list(fn.args.kwonlyargs)
    for a in args:
        if a.arg in ("self", "cls"):
            continue
        ann = annotation_text(a.annotation)
        ann_names = {p.strip() for p in ann.replace("|", " ")
                     .replace("[", " ").replace("]", " ")
                     .replace(",", " ").split()}
        hit = ann_names & dataclasses_
        if hit:
            out[a.arg] = f"annotated {sorted(hit)[0]}"
        elif a.arg in _OPTIONS_NAMES:
            out[a.arg] = "an options-style parameter"
    return out


def _is_safe_rebind(value: ast.AST) -> bool:
    """`x = dataclasses.replace(x, ...)` / deepcopy / fresh constructor."""
    if isinstance(value, ast.Call):
        name = call_name(value.func)
        if name in _SAFE_REBIND_CALLS or name.endswith(".copy"):
            return True
        # a fresh constructor call (Type(...)) with no argument sharing the
        # old object is a new instance; approximated by "a Call that is not
        # a BoolOp fallback" -- `opts or Default()` is handled below
        if name and name[0].isupper():
            return True
    return False


@rule(
    code="RPR002",
    name="caller-options-mutation",
    summary="public function mutates a caller-passed options/dataclass "
            "argument instead of dataclasses.replace()",
    bug="PR 1: optimize() mutated the caller's MILPOptions, leaking a "
        "per-call time_limit into every later solve",
)
def check(ctxs: list[FileContext]) -> Iterable[Finding]:
    dataclasses_ = _package_dataclasses(ctxs)
    for ctx in ctxs:
        for cls_or_mod, fn in _public_functions(ctx.tree):
            tracked = _tracked_params(fn, dataclasses_)
            if not tracked:
                continue
            # parameters rebound to a fresh object before a given line
            rebound_at: dict[str, int] = {}
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    tgt = node.targets[0].id
                    if tgt in tracked and _is_safe_rebind(node.value):
                        rebound_at.setdefault(tgt, node.lineno)
            for node in ast.walk(fn):
                pname, line = _mutation_of(node, tracked)
                if pname is None:
                    continue
                if pname in rebound_at and rebound_at[pname] < line:
                    continue
                qual = f"{cls_or_mod}.{fn.name}" if cls_or_mod else fn.name
                yield Finding(
                    rule="RPR002", path=ctx.path, line=line,
                    message=f"public function `{qual}` mutates caller-"
                            f"passed `{pname}` ({tracked[pname]}); use "
                            f"dataclasses.replace() on a local copy -- "
                            f"mutating shared options leaks state across "
                            f"calls (the MILPOptions bug)",
                    key=f"{qual}.{pname}")


def _public_functions(tree: ast.Module):
    """Yield (enclosing-class-name-or-'', fn) for public defs."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_"):
                yield "", node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if not sub.name.startswith("_"):
                        yield node.name, sub


def _mutation_of(node: ast.AST, tracked: dict[str, str]
                 ) -> tuple[str | None, int]:
    """Return (param, line) when `node` writes an attribute of a tracked
    bare parameter."""
    target = None
    if isinstance(node, ast.Assign):
        for t in node.targets:
            if isinstance(t, ast.Attribute):
                target = t
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)) and \
            isinstance(node.target, ast.Attribute):
        target = node.target
    elif isinstance(node, ast.Call) and call_name(node.func) == "setattr" \
            and node.args and isinstance(node.args[0], ast.Name) and \
            node.args[0].id in tracked:
        return node.args[0].id, node.lineno
    if target is not None and isinstance(target.value, ast.Name) and \
            target.value.id in tracked:
        return target.value.id, node.lineno
    return None, 0
