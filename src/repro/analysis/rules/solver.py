"""RPR005: solver results consumed without gating on the full status set.

History: PR 7 fixed the fleet loop treating ``time_limit`` as "has a
solution": under load the MILP can hit its deadline with *no incumbent*,
returning ``status == "time_limit"`` and ``x is None``, and the extraction
crashed (or, worse, scheduled from a stale vector).  The repo convention:

* tuple-unpack form -- ``status, x, info = model.solve(...)`` must branch
  on ``x is None`` (an incumbent can be absent for *any* non-optimal
  status) before touching ``x``;
* result-object form -- ``res = solve_delta_milp(...)`` must consult
  ``res.feasible`` or ``res.status`` before reading ``res.x`` /
  ``res.schedule`` / ``res.makespan``.

The rule flags extraction sites missing those gates, in any analyzed file
(benchmarks included: a demo that crashes on a timeout is still a crash).
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import (FileContext, Finding, call_name,
                                   iter_functions, rule)

# corpus functions returning a MILPResult-style object
_RESULT_FNS = {"solve_delta_milp", "solve_robust_milp", "solve_resilient"}
_RESULT_PAYLOAD = {"x", "schedule", "makespan", "assignment"}
_RESULT_GATES = {"feasible", "status", "degraded"}


def _scopes(ctx: FileContext):
    yield "<module>", ctx.tree
    for fn in iter_functions(ctx.tree):
        yield fn.name, fn


def _is_none_check(node: ast.AST, var: str) -> bool:
    """`var is None` / `var is not None` anywhere inside `node`."""
    if isinstance(node, ast.Compare) and isinstance(node.left, ast.Name) \
            and node.left.id == var and len(node.ops) == 1 \
            and isinstance(node.ops[0], (ast.Is, ast.IsNot)) \
            and isinstance(node.comparators[0], ast.Constant) \
            and node.comparators[0].value is None:
        return True
    return False


@rule(
    code="RPR005",
    name="solver-status-gate",
    summary="solver result payload read without branching on the full "
            "status set (None incumbent / feasible / status)",
    bug="PR 7: time_limit was treated as 'has a solution'; a deadline hit "
        "with no incumbent returned x=None and the extraction crashed",
)
def check(ctxs: list[FileContext]) -> Iterable[Finding]:
    for ctx in ctxs:
        for scope_name, scope in _scopes(ctx):
            yield from _check_tuple_unpack(ctx, scope_name, scope)
            yield from _check_result_objects(ctx, scope_name, scope)


def _walk_scope(scope) -> Iterable[ast.AST]:
    """Walk a function/module without descending into nested defs (each
    scope is checked on its own)."""
    stack = list(scope.body) if hasattr(scope, "body") else [scope]
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _check_tuple_unpack(ctx: FileContext, scope_name: str,
                        scope) -> Iterable[Finding]:
    """`status, x, info = md.solve(...)` -> x needs an `is None` gate."""
    payload_vars: dict[str, int] = {}
    for node in _walk_scope(scope):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = node.targets[0]
        if not isinstance(tgt, ast.Tuple) or len(tgt.elts) < 2:
            continue
        if not isinstance(node.value, ast.Call):
            continue
        fname = call_name(node.value.func)
        if not (fname == "solve" or fname.endswith(".solve")):
            continue
        second = tgt.elts[1]
        if isinstance(second, ast.Name) and second.id != "_":
            payload_vars[second.id] = node.lineno
    if not payload_vars:
        return
    guarded: set[str] = set()
    for node in _walk_scope(scope):
        for var in payload_vars:
            if _is_none_check(node, var):
                guarded.add(var)
    for var, assign_line in payload_vars.items():
        if var in guarded:
            continue
        use_line = _first_use(scope, var, after=assign_line)
        if use_line is None:
            continue
        yield Finding(
            rule="RPR005", path=ctx.path, line=use_line,
            message=f"`{var}` unpacked from a .solve() call is used "
                    f"without an `is None` gate: any non-optimal status "
                    f"(time_limit included) can carry no incumbent (the "
                    f"PR-7 bug); branch on `{var} is None` first",
            key=f"{scope_name}.{var}")


def _check_result_objects(ctx: FileContext, scope_name: str,
                          scope) -> Iterable[Finding]:
    """`res = solve_delta_milp(...)` -> res.x needs feasible/status gate."""
    result_vars: dict[str, int] = {}
    for node in _walk_scope(scope):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = node.targets[0]
        if not isinstance(tgt, ast.Name) or not isinstance(node.value,
                                                           ast.Call):
            continue
        fname = call_name(node.value.func).split(".")[-1]
        if fname in _RESULT_FNS:
            result_vars[tgt.id] = node.lineno
    if not result_vars:
        return
    gated: set[str] = set()
    payload_use: dict[str, int] = {}
    for node in _walk_scope(scope):
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and \
                node.value.id in result_vars:
            if node.attr in _RESULT_GATES:
                gated.add(node.value.id)
            elif node.attr in _RESULT_PAYLOAD and \
                    isinstance(node.ctx, ast.Load):
                payload_use.setdefault(node.value.id, node.lineno)
                payload_use[node.value.id] = min(
                    payload_use[node.value.id], node.lineno)
    for var, line in sorted(payload_use.items()):
        if var in gated:
            continue
        yield Finding(
            rule="RPR005", path=ctx.path, line=line,
            message=f"`{var}.x`-style payload read without consulting "
                    f"`{var}.feasible` or `{var}.status`: a time-limited "
                    f"solve can return an infeasible result object (the "
                    f"PR-7 bug)",
            key=f"{scope_name}.{var}")


def _first_use(scope, var: str, after: int) -> int | None:
    best: int | None = None
    for node in _walk_scope(scope):
        if isinstance(node, ast.Name) and node.id == var and \
                isinstance(node.ctx, ast.Load) and node.lineno > after \
                and (best is None or node.lineno < best):
            best = node.lineno
    return best
