"""Architecture registry.

REGISTRY       -- the 10 assigned architectures (dry-run / roofline matrix)
PAPER_WORKLOADS -- the paper's Table-I workloads + GPT-7B (DELTA benchmarks)
"""
from repro.configs.base import (ArchSpec, ModelConfig, ParallelismPlan,
                                SHAPES, ShapeSpec, make_job,
                                shape_applicable)
from repro.configs import (granite_moe_1b_a400m, grok_1_314b,
                           jamba_1_5_large_398b, llama_3_2_vision_11b,
                           mamba2_130m, phi3_mini_3_8b, qwen2_5_14b,
                           qwen3_0_6b, whisper_large_v3, yi_6b)
from repro.configs.paper_workloads import PAPER_WORKLOADS

REGISTRY: dict[str, ArchSpec] = {
    "jamba-1.5-large-398b": jamba_1_5_large_398b.ARCH,
    "yi-6b": yi_6b.ARCH,
    "qwen2.5-14b": qwen2_5_14b.ARCH,
    "phi3-mini-3.8b": phi3_mini_3_8b.ARCH,
    "qwen3-0.6b": qwen3_0_6b.ARCH,
    "mamba2-130m": mamba2_130m.ARCH,
    "llama-3.2-vision-11b": llama_3_2_vision_11b.ARCH,
    "whisper-large-v3": whisper_large_v3.ARCH,
    "grok-1-314b": grok_1_314b.ARCH,
    "granite-moe-1b-a400m": granite_moe_1b_a400m.ARCH,
}

ALL_ARCHS = {**REGISTRY, **PAPER_WORKLOADS}

__all__ = ["REGISTRY", "PAPER_WORKLOADS", "ALL_ARCHS", "ArchSpec",
           "ModelConfig", "ParallelismPlan", "SHAPES", "ShapeSpec",
           "make_job", "shape_applicable"]
