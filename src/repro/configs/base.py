"""Architecture / shape / parallelism-plan schema for the framework.

Each assigned architecture file (repro/configs/<id>.py) defines
    CONFIG: ModelConfig   -- exact published dimensions
    PLAN:   ParallelismPlan -- training parallelization + pod placement used
                               by DELTA's traffic generator
and registers itself in the registry (repro.configs.REGISTRY).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | encdec
    layers: int
    d_model: int
    heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 -> d_model // heads
    # --- MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1        # MoE FFN every k-th layer (jamba: 2)
    moe_capacity: float = 1.25  # capacity factor (tokens may drop beyond)
    # --- SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    attn_every: int = 0       # hybrid: 1 attention layer per this many
    # --- modality frontends (stubs provide precomputed embeddings)
    cross_attn_every: int = 0  # vlm: cross-attn layer per this many
    num_image_tokens: int = 0
    encoder_layers: int = 0    # encdec decoder cross-attends to these
    enc_tokens: int = 0        # whisper: 1500 frames after conv frontend
    # --- flags
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.heads)

    @property
    def group_size(self) -> int:
        """Layer-pattern period (scan groups stack identical periods)."""
        g = 1
        for v in (self.attn_every, self.moe_every, self.cross_attn_every):
            if v and v > 1:
                g = math.lcm(g, v)
        return g

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid" and self.attn_every:
            return (i % self.attn_every) == self.attn_every - 1
        return True

    def is_moe_layer(self, i: int) -> bool:
        if self.moe_experts <= 0:
            return False
        return (i % self.moe_every) == self.moe_every - 1

    def is_xattn_layer(self, i: int) -> bool:
        if not self.cross_attn_every:
            return False
        return (i % self.cross_attn_every) == self.cross_attn_every - 1

    # ------------------------------------------------------- param counting
    def layer_params(self, i: int) -> int:
        d, hd = self.d_model, self.hd
        n = 0
        if self.is_attn_layer(i):
            q = d * self.heads * hd
            kv = 2 * d * self.kv_heads * hd
            o = self.heads * hd * d
            n += q + kv + o
            if self.qkv_bias:
                n += (self.heads + 2 * self.kv_heads) * hd
        else:  # mamba2 block
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            n += d * (2 * d_in + 2 * self.ssm_state + nheads)  # in_proj
            n += self.ssm_conv * (d_in + 2 * self.ssm_state)   # conv
            n += d_in * d                                       # out_proj
            n += 2 * nheads                                     # A_log, dt_b
        if self.is_moe_layer(i):
            n += d * self.moe_experts                           # router
            n += self.moe_experts * 3 * d * self.d_ff
        elif self.d_ff > 0:
            n += 3 * d * self.d_ff                              # swiglu
        if self.is_xattn_layer(i):
            n += 2 * d * self.heads * hd + 2 * d * self.kv_heads * hd
        n += 2 * d                                              # 2 rmsnorms
        return n

    def layer_active_params(self, i: int) -> int:
        n = self.layer_params(i)
        if self.is_moe_layer(i):
            n -= self.moe_experts * 3 * self.d_model * self.d_ff
            n += self.moe_top_k * 3 * self.d_model * self.d_ff
        return n

    def embed_params(self) -> int:
        return self.vocab * self.d_model

    def head_params(self) -> int:
        return 0 if self.tie_embeddings else self.vocab * self.d_model

    def encoder_params(self) -> int:
        if not self.encoder_layers:
            return 0
        d, hd = self.d_model, self.hd
        per = (self.heads * hd * d * 2 + 2 * d * self.kv_heads * hd
               + 3 * d * self.d_ff + 2 * d)
        return self.encoder_layers * per

    def total_params(self) -> int:
        n = self.embed_params() + self.head_params() + self.encoder_params()
        n += sum(self.layer_params(i) for i in range(self.layers))
        return n

    def total_active_params(self) -> int:
        n = self.embed_params() + self.head_params() + self.encoder_params()
        n += sum(self.layer_active_params(i) for i in range(self.layers))
        return n

    # ------------------------------------------------------------- reduction
    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        g = self.group_size
        layers = max(g, 2 if g == 1 else g)
        enc = min(self.encoder_layers, 2)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            layers=layers,
            d_model=128,
            heads=4,
            kv_heads=min(self.kv_heads, 2) if self.kv_heads < self.heads
            else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            moe_experts=min(self.moe_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            moe_capacity=float(max(self.moe_experts, 1)),  # drop-free smoke
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            num_image_tokens=min(self.num_image_tokens, 16),
            encoder_layers=enc,
            enc_tokens=min(self.enc_tokens, 32),
        )


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Skip rules per the assignment (recorded in the dry-run table)."""
    if shape.name == "long_500k" and cfg.family not in \
            SUBQUADRATIC_FAMILIES:
        return False, "long_500k skipped: pure full-attention architecture"
    return True, ""


@dataclass(frozen=True)
class ParallelismPlan:
    """Training parallelization feeding DELTA's inter-pod DAG."""
    tp: int
    pp: int
    dp: int
    ep: int = 1
    gpus_per_pod_per_replica: int = 16
    microbatches: int = 0          # 0 -> 8 * pp (paper Sec. V-A1)
    micro_batch_size: int = 1      # sequences per microbatch
    gpu_flops: float = 140e12      # effective bf16/GPU incl. MFU

    @property
    def num_gpus(self) -> int:
        return self.tp * self.pp * self.dp

    @property
    def num_microbatches(self) -> int:
        return self.microbatches or 8 * self.pp


@dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    plan: ParallelismPlan
    # provenance strings for humans reading the spec tables, not the code
    source: str = ""  # sentinel: ignore[RPR001]
    notes: str = ""  # sentinel: ignore[RPR001]


def make_job(arch: ArchSpec, seq_len: int = 4096,
             microbatches: int | None = None, act_bytes: int = 2,
             grad_bytes: int = 2):
    """ArchSpec -> repro.core.traffic.JobSpec (DELTA's input)."""
    from repro.core.traffic import JobSpec
    cfg, plan = arch.config, arch.plan
    pp = plan.pp
    dec_layers = cfg.layers
    enc_layers = cfg.encoder_layers
    total_layers = dec_layers + enc_layers
    if total_layers % pp:
        raise ValueError(f"{cfg.name}: {total_layers} layers not divisible "
                         f"by pp={pp}")
    per_stage = total_layers // pp
    stage_params: list[float] = []
    stage_active: list[float] = []
    stage_moe: list[int] = []
    enc_stages = enc_layers // per_stage if enc_layers else 0
    d = cfg.d_model
    enc_layer_p = (cfg.encoder_params() / max(enc_layers, 1)) \
        if enc_layers else 0.0
    for s in range(pp):
        lo, hi = s * per_stage, (s + 1) * per_stage
        p = a = 0.0
        n_moe = 0
        for li in range(lo, hi):
            if li < enc_layers:
                p += enc_layer_p
                a += enc_layer_p
            else:
                i = li - enc_layers
                p += cfg.layer_params(i)
                a += cfg.layer_active_params(i)
                n_moe += int(cfg.is_moe_layer(i))
        if s == 0:
            p += cfg.embed_params()
            a += cfg.embed_params() / max(seq_len, 1)  # sparse lookup
        if s == pp - 1:
            p += cfg.head_params()
            a += cfg.head_params()
        stage_params.append(p)
        stage_active.append(a)
        stage_moe.append(n_moe)
    mb = microbatches or plan.num_microbatches
    return JobSpec(
        name=cfg.name,
        tp=plan.tp, pp=pp, dp=plan.dp, ep=plan.ep,
        num_microbatches=mb,
        micro_tokens=plan.micro_batch_size * seq_len,
        d_model=d,
        stage_params=tuple(stage_params),
        active_stage_params=tuple(stage_active),
        moe_experts=cfg.moe_experts, moe_top_k=cfg.moe_top_k,
        moe_every=cfg.moe_every,
        moe_stage_layers=tuple(stage_moe) if cfg.moe_experts else (),
        gpus_per_pod_per_replica=plan.gpus_per_pod_per_replica,
        act_bytes=act_bytes, grad_bytes=grad_bytes,
        gpu_flops=plan.gpu_flops,
        enc_stages=enc_stages,
        enc_tokens=plan.micro_batch_size * cfg.enc_tokens,
        seq_len=seq_len,
    )
