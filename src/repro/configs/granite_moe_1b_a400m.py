"""granite-moe-1b-a400m [moe]: 24L d1024 16H (GQA kv=8) dff512,
MoE 32e top-8, vocab 49155 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.configs.base import ArchSpec, ModelConfig, ParallelismPlan

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    layers=24, d_model=1024, heads=16, kv_heads=8, d_ff=512,
    vocab=49155, head_dim=64, moe_experts=32, moe_top_k=8, moe_every=1,
    rope_theta=1e4)
PLAN = ParallelismPlan(tp=1, pp=4, dp=8, ep=8,
                       gpus_per_pod_per_replica=2)
ARCH = ArchSpec(CONFIG, PLAN, source="hf:ibm-granite/granite-3.0-1b-a400m",
                notes="32 experts top-8")
