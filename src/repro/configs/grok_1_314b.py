"""grok-1-314b [moe]: 64L d6144 48H (GQA kv=8) dff32768 vocab 131072,
MoE 8e top-2 [hf:xai-org/grok-1; unverified]."""
from repro.configs.base import ArchSpec, ModelConfig, ParallelismPlan

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    layers=64, d_model=6144, heads=48, kv_heads=8, d_ff=32768,
    vocab=131072, head_dim=128, moe_experts=8, moe_top_k=2, moe_every=1,
    rope_theta=1e4)
PLAN = ParallelismPlan(tp=8, pp=8, dp=8, ep=8,
                       gpus_per_pod_per_replica=32)
ARCH = ArchSpec(CONFIG, PLAN, source="hf:xai-org/grok-1",
                notes="8 experts top-2, every layer MoE")
