"""jamba-1.5-large-398b [hybrid]: 72L d8192 64H (GQA kv=8) dff24576
vocab 65536, MoE 16e top-2, Mamba+attention 1:7 interleave
[arXiv:2403.19887; hf]."""
from repro.configs.base import ArchSpec, ModelConfig, ParallelismPlan

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    layers=72, d_model=8192, heads=64, kv_heads=8, d_ff=24576,
    vocab=65536, head_dim=128,
    moe_experts=16, moe_top_k=2, moe_every=2,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    attn_every=8, rope_theta=1e6)
PLAN = ParallelismPlan(tp=8, pp=9, dp=8, ep=16,
                       gpus_per_pod_per_replica=32)
ARCH = ArchSpec(CONFIG, PLAN, source="arXiv:2403.19887",
                notes="Mamba/attn 1:7, MoE every 2nd layer")
