"""llama-3.2-vision-11b [vlm]: 40L d4096 32H (GQA kv=8) dff14336
vocab 128256, cross-attn image layers every 5
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from repro.configs.base import ArchSpec, ModelConfig, ParallelismPlan

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    layers=40, d_model=4096, heads=32, kv_heads=8, d_ff=14336,
    vocab=128256, head_dim=128, rope_theta=5e5,
    cross_attn_every=5, num_image_tokens=1601)
PLAN = ParallelismPlan(tp=4, pp=5, dp=4, gpus_per_pod_per_replica=4)
ARCH = ArchSpec(CONFIG, PLAN, source="hf:meta-llama/Llama-3.2-11B-Vision",
                notes="vision frontend stubbed: input_specs provides "
                      "precomputed patch embeddings")
