"""mamba2-130m [ssm]: 24L d768 attn-free, ssm_state=128, SSD
[arXiv:2405.21060; unverified]."""
from repro.configs.base import ArchSpec, ModelConfig, ParallelismPlan

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    layers=24, d_model=768, heads=12, kv_heads=12, d_ff=0,
    vocab=50280, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    tie_embeddings=True)
PLAN = ParallelismPlan(tp=1, pp=4, dp=8, gpus_per_pod_per_replica=2)
ARCH = ArchSpec(CONFIG, PLAN, source="arXiv:2405.21060",
                notes="SSD state-space duality; no attention, no FFN")
