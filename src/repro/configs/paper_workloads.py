"""The paper's four evaluation workloads (Table I) + the GPT-7B profiling
example of Fig. 1/3.  Parallelism configs match Table I exactly; model
dimensions are representative published configs with matching totals (the
DELTA benchmarks only consume parallelism + parameter/activation volumes).
"""
from repro.configs.base import ArchSpec, ModelConfig, ParallelismPlan

GPT_7B = ArchSpec(
    ModelConfig(name="gpt-7b", family="dense", layers=32, d_model=4096,
                heads=32, kv_heads=32, d_ff=11008, vocab=50257),
    ParallelismPlan(tp=2, pp=4, dp=2, gpus_per_pod_per_replica=4,
                    microbatches=8),
    source="paper Fig. 1", notes="profiling example; 4 pods")

MEGATRON_177B = ArchSpec(
    ModelConfig(name="megatron-177b", family="dense", layers=96,
                d_model=12288, heads=96, kv_heads=96, d_ff=32768,
                vocab=51200),
    ParallelismPlan(tp=8, pp=6, dp=8, gpus_per_pod_per_replica=16,
                    microbatches=48),
    source="paper Table I / Megatron benchmarks [59-61]")

MIXTRAL_8X22B = ArchSpec(
    ModelConfig(name="mixtral-8x22b", family="moe", layers=56,
                d_model=6144, heads=48, kv_heads=8, d_ff=16384,
                vocab=32768, moe_experts=8, moe_top_k=2, moe_every=1),
    ParallelismPlan(tp=2, pp=8, dp=8, ep=8, gpus_per_pod_per_replica=16,
                    microbatches=64),
    source="paper Table I [arXiv:2401.04088]")

MEGATRON_462B = ArchSpec(
    ModelConfig(name="megatron-462b", family="dense", layers=128,
                d_model=17408, heads=136, kv_heads=136, d_ff=46080,
                vocab=51200),
    ParallelismPlan(tp=8, pp=16, dp=8, gpus_per_pod_per_replica=32,
                    microbatches=128),
    source="paper Table I / Megatron benchmarks [59-61]")

DEEPSEEK_671B = ArchSpec(
    ModelConfig(name="deepseek-671b", family="moe", layers=64,
                d_model=7168, heads=56, kv_heads=8, d_ff=1888,
                vocab=129280, moe_experts=256, moe_top_k=8, moe_every=1),
    ParallelismPlan(tp=2, pp=16, dp=8, ep=8, gpus_per_pod_per_replica=32,
                    microbatches=128),
    source="paper Table I [DeepSeek-V3]")

PAPER_WORKLOADS = {
    "gpt-7b": GPT_7B,
    "megatron-177b": MEGATRON_177B,
    "mixtral-8x22b": MIXTRAL_8X22B,
    "megatron-462b": MEGATRON_462B,
    "deepseek-671b": DEEPSEEK_671B,
}
