"""phi3-mini-3.8b [dense]: 32L d3072 32H (GQA kv=32) dff8192 vocab 32064,
RoPE SwiGLU [arXiv:2404.14219; unverified]."""
from repro.configs.base import ArchSpec, ModelConfig, ParallelismPlan

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    layers=32, d_model=3072, heads=32, kv_heads=32, d_ff=8192,
    vocab=32064, head_dim=96, rope_theta=1e4)
PLAN = ParallelismPlan(tp=2, pp=4, dp=4, gpus_per_pod_per_replica=4)
ARCH = ArchSpec(CONFIG, PLAN, source="arXiv:2404.14219",
                notes="MHA (kv=heads), RoPE + SwiGLU")
