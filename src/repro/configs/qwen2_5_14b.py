"""qwen2.5-14b [dense]: 48L d5120 40H (GQA kv=8) dff13824 vocab 152064,
QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
from repro.configs.base import ArchSpec, ModelConfig, ParallelismPlan

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    layers=48, d_model=5120, heads=40, kv_heads=8, d_ff=13824,
    vocab=152064, head_dim=128, qkv_bias=True, rope_theta=1e6)
PLAN = ParallelismPlan(tp=4, pp=4, dp=4, gpus_per_pod_per_replica=8)
ARCH = ArchSpec(CONFIG, PLAN, source="hf:Qwen/Qwen2.5-0.5B",
                notes="GQA with QKV bias")
