"""qwen3-0.6b [dense]: 28L d1024 16H (GQA kv=8) dff3072 vocab 151936,
qk_norm [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ArchSpec, ModelConfig, ParallelismPlan

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    layers=28, d_model=1024, heads=16, kv_heads=8, d_ff=3072,
    vocab=151936, head_dim=64, qk_norm=True, rope_theta=1e6)
PLAN = ParallelismPlan(tp=1, pp=4, dp=8, gpus_per_pod_per_replica=2)
ARCH = ArchSpec(CONFIG, PLAN, source="hf:Qwen/Qwen3-8B",
                notes="qk_norm, GQA")
