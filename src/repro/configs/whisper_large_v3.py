"""whisper-large-v3 [audio/encdec]: 32L(+32 enc) d1280 20H dff5120
vocab 51866, conv frontend stubbed [arXiv:2212.04356; unverified]."""
from repro.configs.base import ArchSpec, ModelConfig, ParallelismPlan

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    layers=32, d_model=1280, heads=20, kv_heads=20, d_ff=5120,
    vocab=51866, head_dim=64, rope_theta=1e4,
    cross_attn_every=1, encoder_layers=32, enc_tokens=1500)
PLAN = ParallelismPlan(tp=1, pp=8, dp=8, gpus_per_pod_per_replica=2)
ARCH = ArchSpec(CONFIG, PLAN, source="arXiv:2212.04356",
                notes="conv frontend stub: input_specs provides "
                      "precomputed frame embeddings (1500 x d_model)")
