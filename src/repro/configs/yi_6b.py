"""yi-6b [dense]: 32L d4096 32H (GQA kv=4) dff11008 vocab 64000
[arXiv:2403.04652; hf]."""
from repro.configs.base import ArchSpec, ModelConfig, ParallelismPlan

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    layers=32, d_model=4096, heads=32, kv_heads=4, d_ff=11008,
    vocab=64000, head_dim=128, rope_theta=5e6)
PLAN = ParallelismPlan(tp=2, pp=4, dp=4, gpus_per_pod_per_replica=4)
ARCH = ArchSpec(CONFIG, PLAN, source="arXiv:2403.04652",
                notes="llama-arch GQA")
