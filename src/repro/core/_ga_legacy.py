"""Legacy (pre-vectorization) DELTA-Fast engine -- reference only.

This is the per-genome Python-loop implementation of Algs. 3/5/6 that
`repro.core.ga` replaced with population-array ops.  It is kept verbatim so

  * `benchmarks/ga_bench.py` can measure the vectorized engine's speedup
    against the exact pre-refactor hot loop at a fixed seed, and
  * `tests/test_ga_vectorized.py` can assert the new engine's makespans and
    `trim_ports` outputs are no worse than / identical to the old ones.

Do not import this from production code paths; use `repro.core.ga`.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.dag import CommDAG
from repro.core.des import DESProblem, simulate
from repro.core.xbound import x_upper_bound

INF = float("inf")


@dataclass
class GAOptions:
    pop_size: int = 48
    max_generations: int = 400
    patience: int = 60            # stop after N gens without improvement
    elite_frac: float = 0.15
    tournament: int = 3
    crossover_rate: float = 0.9
    mutation_rate: float = 0.25   # per-gene probability of a +/-1 step
    seed: int = 0
    backend: str = "auto"         # numpy | jax | auto
    jax_task_limit: int = 1200
    time_limit: float = 120.0
    port_weight: float = 1e-9     # lexicographic secondary objective


@dataclass
class GAResult:
    x: np.ndarray
    makespan: float
    generations: int
    evaluations: int
    elapsed: float
    history: list[float] = field(default_factory=list)
    feasible: bool = True

    @property
    def total_ports(self) -> int:
        return int(self.x.sum())


class TopologySpace:
    """Genome <-> symmetric topology matrix mapping + Algs. 5/6."""

    def __init__(self, dag: CommDAG, xbar: np.ndarray | None = None):
        self.dag = dag
        self.P = dag.cluster.num_pods
        self.U = np.asarray(dag.cluster.port_limits, dtype=np.int64)
        self.edges = dag.undirected_pairs()
        self.E = len(self.edges)
        xbar = xbar if xbar is not None else x_upper_bound(dag)
        self.xbar = np.array(
            [max(1, min(int(xbar[i, j]), int(self.U[i]), int(self.U[j])))
             for i, j in self.edges], dtype=np.int64)
        self.pod_edges: list[list[int]] = [[] for _ in range(self.P)]
        for e, (i, j) in enumerate(self.edges):
            self.pod_edges[i].append(e)
            self.pod_edges[j].append(e)
        # quick feasibility: connectivity needs one port per incident edge
        for p in range(self.P):
            if len(self.pod_edges[p]) > self.U[p]:
                raise ValueError(
                    f"pod {p} has {len(self.pod_edges[p])} active pairs but "
                    f"only {self.U[p]} ports; placement is infeasible")

    def to_matrix(self, genome: np.ndarray) -> np.ndarray:
        x = np.zeros((self.P, self.P), dtype=np.int64)
        for e, (i, j) in enumerate(self.edges):
            x[i, j] = x[j, i] = int(genome[e])
        return x

    def port_usage(self, genome: np.ndarray) -> np.ndarray:
        used = np.zeros(self.P, dtype=np.int64)
        for e, (i, j) in enumerate(self.edges):
            used[i] += genome[e]
            used[j] += genome[e]
        return used

    def is_feasible(self, genome: np.ndarray) -> bool:
        return bool((genome >= 1).all() and (genome <= self.xbar).all()
                    and (self.port_usage(genome) <= self.U).all())

    # ---------------------------------------------------------------- Alg. 5
    def feasible_random_init(self, rng: np.random.Generator) -> np.ndarray:
        genome = np.zeros(self.E, dtype=np.int64)
        used = np.zeros(self.P, dtype=np.int64)
        deg = np.array([len(self.pod_edges[p]) for p in range(self.P)])
        for e, (u, v) in enumerate(self.edges):
            deg[u] -= 1
            deg[v] -= 1
            ru = self.U[u] - used[u] - deg[u]   # reserve future connectivity
            rv = self.U[v] - used[v] - deg[v]
            limit = max(1, min(ru, rv, self.xbar[e]))
            genome[e] = rng.integers(1, limit + 1)
            used[u] += genome[e]
            used[v] += genome[e]
        return genome

    # ---------------------------------------------------------------- Alg. 6
    def repair(self, genome: np.ndarray, rng: np.random.Generator
               ) -> tuple[np.ndarray, bool]:
        g = np.clip(genome, 1, self.xbar)
        used = self.port_usage(g)
        guard = int(g.sum()) + self.P + 1
        for _ in range(guard):
            over = np.nonzero(used > self.U)[0]
            if len(over) == 0:
                return g, True
            p = int(rng.choice(over))
            reducible = [e for e in self.pod_edges[p] if g[e] > 1]
            if not reducible:
                return g, False
            e = int(rng.choice(reducible))
            g[e] -= 1
            i, j = self.edges[e]
            used[i] -= 1
            used[j] -= 1
        return g, bool((self.port_usage(g) <= self.U).all())


class _Fitness:
    def __init__(self, dag: CommDAG, space: TopologySpace, opts: GAOptions):
        self.problem = DESProblem(dag)
        self.space = space
        self.opts = opts
        self.cache: dict[tuple, float] = {}
        self.evaluations = 0
        use_jax = opts.backend == "jax" or (
            opts.backend == "auto"
            and self.problem.n <= opts.jax_task_limit)
        self._jd = None
        if use_jax:
            try:
                from repro.core.des_jax import JaxDES
                self._jd = JaxDES(self.problem)
            except Exception:   # pragma: no cover - jax always available here
                self._jd = None

    def __call__(self, genomes: list[np.ndarray]) -> np.ndarray:
        out = np.empty(len(genomes))
        todo: list[int] = []
        for i, g in enumerate(genomes):
            key = tuple(int(v) for v in g)
            if key in self.cache:
                out[i] = self.cache[key]
            else:
                todo.append(i)
        if todo:
            self.evaluations += len(todo)
            if self._jd is not None:
                xs = np.stack([self.space.to_matrix(genomes[i])
                               for i in todo])
                ms, feas = self._jd.batch_makespan(xs)
                vals = np.where(feas, ms, INF)
            else:
                vals = np.array([
                    simulate(self.problem,
                             self.space.to_matrix(genomes[i])).makespan
                    for i in todo])
            for i, v in zip(todo, vals):
                key = tuple(int(x) for x in genomes[i])
                score = float(v)
                if np.isfinite(score):
                    score += self.opts.port_weight * float(genomes[i].sum())
                self.cache[key] = score
                out[i] = score
        return out


def delta_fast(dag: CommDAG, opts: GAOptions | None = None,
               xbar: np.ndarray | None = None,
               seeds: list[np.ndarray] | None = None) -> GAResult:
    """Alg. 3: SimBasedDomainAdaptedGA."""
    opts = opts or GAOptions()
    rng = np.random.default_rng(opts.seed)
    space = TopologySpace(dag, xbar)
    fit = _Fitness(dag, space, opts)
    t0 = time.time()

    pop = [space.feasible_random_init(rng) for _ in range(opts.pop_size)]
    # seed candidates (e.g. baselines) -- repaired into the population
    for s in (seeds or []):
        g = np.array([s[i, j] for (i, j) in space.edges], dtype=np.int64)
        g, ok = space.repair(g, rng)
        if ok:
            pop[rng.integers(len(pop))] = g
    fitness = fit(pop)
    best_i = int(np.argmin(fitness))
    best_g, best_f = pop[best_i].copy(), float(fitness[best_i])
    history = [best_f]
    n_elite = max(1, int(opts.elite_frac * opts.pop_size))
    stall = 0
    gen = 0

    while gen < opts.max_generations:
        gen += 1
        if time.time() - t0 > opts.time_limit or stall >= opts.patience:
            break
        order = np.argsort(fitness)
        new_pop = [pop[i].copy() for i in order[:n_elite]]
        while len(new_pop) < opts.pop_size:
            a = _tournament(pop, fitness, rng, opts.tournament)
            b = _tournament(pop, fitness, rng, opts.tournament)
            child = _crossover(a, b, rng) if \
                rng.random() < opts.crossover_rate else a.copy()
            child = _mutate(child, space, rng, opts.mutation_rate)
            child, ok = space.repair(child, rng)
            if not ok:
                child = space.feasible_random_init(rng)
            new_pop.append(child)
        pop = new_pop
        fitness = fit(pop)
        i = int(np.argmin(fitness))
        if fitness[i] < best_f - 1e-15:
            best_f, best_g = float(fitness[i]), pop[i].copy()
            stall = 0
        else:
            stall += 1
        history.append(best_f)

    # re-rank the best distinct candidates with the exact numpy DES (the
    # batched jax fitness may run in float32; ~1e-5 ranking noise)
    ranked = sorted(fit.cache.items(), key=lambda kv: kv[1])[:8]
    best_x, best_ms = space.to_matrix(best_g), INF
    for key, fval in ranked:
        if not np.isfinite(fval):
            continue
        x = space.to_matrix(np.asarray(key, dtype=np.int64))
        ms = simulate(fit.problem, x).makespan
        port_pen = opts.port_weight * float(np.asarray(key).sum())
        if ms + port_pen < best_ms:
            best_ms, best_x = ms + port_pen, x
    ms = simulate(fit.problem, best_x).makespan
    return GAResult(x=best_x, makespan=float(ms), generations=gen,
                    evaluations=fit.evaluations, elapsed=time.time() - t0,
                    history=history, feasible=np.isfinite(ms))


def _tournament(pop, fitness, rng, k) -> np.ndarray:
    idx = rng.integers(0, len(pop), size=k)
    return pop[idx[np.argmin(fitness[idx])]]


def _crossover(a: np.ndarray, b: np.ndarray, rng) -> np.ndarray:
    mask = rng.random(len(a)) < 0.5
    return np.where(mask, a, b)


def _mutate(g: np.ndarray, space: TopologySpace, rng, rate: float
            ) -> np.ndarray:
    out = g.copy()
    for e in range(len(out)):
        if rng.random() < rate:
            out[e] += rng.choice((-1, 1))
    return np.clip(out, 1, space.xbar)


def trim_ports(dag: CommDAG, x: np.ndarray, rel_tol: float = 1e-6
               ) -> np.ndarray:
    """Greedy port minimization for heuristic topologies (beyond-paper
    DELTA-Fast counterpart of Eq. 4): repeatedly drop the circuit whose
    removal leaves the DES makespan unchanged, exploiting the temporal
    slack of non-critical tasks."""
    problem = DESProblem(dag)
    base = simulate(problem, x).makespan
    if not np.isfinite(base):
        return x
    x = x.copy()
    budget = base * (1 + rel_tol)
    improved = True
    while improved:
        improved = False
        for i, j in dag.undirected_pairs():
            if x[i, j] <= 1:
                continue
            x[i, j] -= 1
            x[j, i] -= 1
            if simulate(problem, x).makespan <= budget:
                improved = True
            else:
                x[i, j] += 1
                x[j, i] += 1
    return x


def exhaustive_search(dag: CommDAG, limit: int = 200000
                      ) -> tuple[np.ndarray, float, int]:
    """Exact topology search by enumeration (tests / tiny instances)."""
    space = TopologySpace(dag)
    problem = DESProblem(dag)
    ranges = [range(1, int(b) + 1) for b in space.xbar]
    total = int(np.prod([len(r) for r in ranges]))
    if total > limit:
        raise ValueError(f"{total} combinations exceed limit {limit}")
    best = (INF, None)
    count = 0
    for combo in itertools.product(*ranges):
        g = np.asarray(combo, dtype=np.int64)
        if not space.is_feasible(g):
            continue
        count += 1
        ms = simulate(problem, space.to_matrix(g)).makespan
        if ms < best[0]:
            best = (ms, g)
    if best[1] is None:
        raise RuntimeError("no feasible topology")
    return space.to_matrix(best[1]), float(best[0]), count
