"""DELTA facade: one typed entry point for every planning mode.

    result = plan(PlanRequest(dag=dag, method="delta-joint", port_min=True))
    robust = plan(PlanRequest(ensemble=DagEnsemble([dagA, dagB]),
                              objective="max-regret"))
    fleet = plan(PlanRequest(fleet_requests=[("a", job_a), ("b", job_b)]))
    report = compare(dag)      # all six, ready for the Fig. 6/8 benchmarks

`PlanRequest` carries the what (dag | ensemble | fleet_requests, exactly
one) and the how (method/objective, `FailureModel`, `FleetOptions`,
nested `GAOptions`/`MILPOptions`/`DESOptions`); `plan` dispatches on
`request.kind`.  The historical facades (`optimize`,
`optimize_ensemble`, `optimize_failsafe`, `optimize_resilient`,
`fleet_optimize`) remain as thin shims that build the equivalent
`PlanRequest` -- bit-identical results, see README "Migrating to plan()".

Methods:
  prop-alloc | sqrt-alloc | iter-halve    traffic-matrix baselines
  delta-fast                              GA (Alg. 3) on the DES
  delta-topo                              MILP + fairness (Eq. 17)
  delta-joint                             MILP, joint topology + rates
  delta-joint-hotstart                    delta-joint seeded by delta-fast
  delta-robust                            GA over a DagEnsemble (one static
                                          topology for a set of DAGs; on a
                                          single CommDAG it reduces to the
                                          delta-fast path)
  delta-robust-milp                       shared-x multi-member MILP
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.baselines import BASELINES
from repro.core.dag import VIRTUAL, CommDAG, DagEnsemble
from repro.core.des import DESProblem, DESResult, simulate
from repro.core.ga import (GAOptions, GAResult, delta_failsafe, delta_fast,
                           delta_robust, ROBUST_OBJECTIVES)
from repro.core.milp import (MILPOptions, MILPResult, solve_delta_milp,
                             solve_resilient, solve_robust_milp)

# DES engine knobs + jit-churn accounting, re-exported so callers tuning
# the evaluation engine (kernel backend, compile buckets) need only the
# facade: optimize(dag, ga_options=GAOptions(des_options=DESOptions(...))).
# Lazy (PEP 562): the rest of the facade works without importing jax, and
# every other des_jax consumer in the codebase imports it inside functions.
_DES_JAX_EXPORTS = ("DESOptions", "des_cache_stats")


def __getattr__(name: str):
    if name in _DES_JAX_EXPORTS:
        from repro.core import des_jax
        return getattr(des_jax, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

INF = float("inf")

METHODS = ("prop-alloc", "sqrt-alloc", "iter-halve",
           "delta-fast", "delta-topo", "delta-joint",
           "delta-joint-hotstart", "delta-robust")
ROBUST_METHODS = ("delta-robust", "delta-robust-milp")


@dataclass
class PlanResult:
    method: str
    x: np.ndarray
    makespan: float            # under the method's own rate semantics
    comm_time: float           # inter-pod comm time on the critical path
    nct: float
    total_ports: int
    elapsed: float
    feasible: bool = True
    details: dict = field(default_factory=dict)


def _ideal(problem: DESProblem) -> DESResult:
    P = problem.dag.cluster.num_pods
    return simulate(problem, np.zeros((P, P)), ideal=True)


def milp_critical_delta(dag: CommDAG, res: MILPResult) -> float:
    """Sum of rigid deltas along the binding chain of a MILP schedule."""
    finish = res.finish
    start = res.start
    preds: dict[int, list] = {}
    for d in dag.deps:
        preds.setdefault(d.succ, []).append(d)
    cur = int(np.argmax(finish))
    delta_sum = 0.0
    guard = 0
    while cur != VIRTUAL and guard <= dag.num_tasks + 1:
        guard += 1
        plist = preds.get(cur, [])
        if not plist:
            break
        best = max(plist, key=lambda d: (0.0 if d.pre == VIRTUAL
                                         else finish[d.pre]) + d.delta)
        delta_sum += best.delta
        cur = best.pre
    del start
    return delta_sum


def _plan_dag(dag: CommDAG, method: str = "delta-fast",
              port_min: bool = False,
              ga_options: GAOptions | None = None,
              milp_options: MILPOptions | None = None,
              ideal_result: DESResult | None = None) -> PlanResult:
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; pick from {METHODS}")
    problem = DESProblem(dag)
    ideal = ideal_result or _ideal(problem)
    t0 = time.time()

    if method == "delta-robust":
        # singleton ensemble: the weighted objective degenerates to the
        # plain makespan, so this IS the delta-fast path (same RNG stream)
        eres = _plan_ensemble(DagEnsemble.singleton(dag),
                              method="delta-robust", objective="weighted",
                              refs=np.array([max(ideal.makespan, 1e-12)]),
                              ga_options=ga_options)
        elapsed = time.time() - t0
        out = _from_des(dag, problem, method, eres.x, elapsed, ideal)
        out.details.update(eres.details)
        return out

    if method in BASELINES:
        x = BASELINES[method](dag)
        elapsed = time.time() - t0
        return _from_des(dag, problem, method, x, elapsed, ideal)

    if method == "delta-fast":
        res: GAResult = delta_fast(dag, ga_options)
        elapsed = time.time() - t0
        out = _from_des(dag, problem, method, res.x, elapsed, ideal)
        out.details.update(generations=res.generations,
                           evaluations=res.evaluations,
                           history_len=len(res.history))
        return out

    # shallow-copy: optimize() tweaks port_min/fairness per method and must
    # not leak those into the caller's (possibly shared) options object
    opts = dataclasses.replace(milp_options) if milp_options \
        else MILPOptions()
    opts.port_min = port_min or opts.port_min
    if method == "delta-topo":
        opts.fairness = True
        mres = solve_delta_milp(dag, opts)
        elapsed = time.time() - t0
        out = _from_des(dag, problem, method, mres.x, elapsed, ideal)
        out.details.update(milp_status=mres.status,
                           milp_makespan=mres.makespan,
                           solve_time=mres.solve_time,
                           port_min_applied=mres.port_min_applied,
                           stats=mres.stats)
        return out

    # delta-joint variants: makespan/comm time come from the MILP schedule
    opts.fairness = False
    if method == "delta-joint-hotstart":
        ga = delta_fast(dag, ga_options)
        if np.isfinite(ga.makespan):
            ub = ga.makespan * (1 + 1e-9)
            opts.upper_bound = min(opts.upper_bound, ub) \
                if opts.upper_bound else ub
            # route the GA incumbent into the MILP hot start: its DES trace
            # seeds the anchors and the polish pre-pass (see MILPOptions)
            opts.seed_x = ga.x
        opts.hot_start = True
    mres = solve_delta_milp(dag, opts)
    elapsed = time.time() - t0
    if not mres.feasible or not np.isfinite(mres.makespan):
        return PlanResult(method=method, x=mres.x, makespan=INF,
                          comm_time=INF, nct=INF, total_ports=0,
                          elapsed=elapsed, feasible=False,
                          details={"milp_status": mres.status})
    crit_delta = milp_critical_delta(dag, mres)
    comm = mres.makespan - crit_delta
    # a time-limited incumbent schedule can carry slack; the topology is
    # still at least as good as its fair-share execution (joint rate
    # control can only improve on fair sharing), so report the better of
    # the two measurements
    des = simulate(problem, mres.x)
    makespan = mres.makespan
    source = "milp_schedule"
    if des.feasible and (not np.isfinite(comm) or des.comm_time < comm):
        comm, makespan, source = des.comm_time, des.makespan, "des_fairshare"
    nct = comm / ideal.comm_time if ideal.comm_time > 0 else INF
    return PlanResult(method=method, x=mres.x, makespan=makespan,
                      comm_time=comm, nct=nct,
                      total_ports=int(mres.x.sum()), elapsed=elapsed,
                      details={"milp_status": mres.status,
                               "solve_time": mres.solve_time,
                               "port_min_applied": mres.port_min_applied,
                               "comm_time_source": source,
                               "stats": mres.stats})


def _from_des(dag: CommDAG, problem: DESProblem, method: str, x: np.ndarray,
              elapsed: float, ideal: DESResult) -> PlanResult:
    res = simulate(problem, x)
    if not res.feasible:
        return PlanResult(method=method, x=x, makespan=INF, comm_time=INF,
                          nct=INF, total_ports=int(x.sum()), elapsed=elapsed,
                          feasible=False)
    nct = res.comm_time / ideal.comm_time if ideal.comm_time > 0 else INF
    return PlanResult(method=method, x=x, makespan=res.makespan,
                      comm_time=res.comm_time, nct=nct,
                      total_ports=int(x.sum()), elapsed=elapsed)


def compare(dag: CommDAG, methods=METHODS[:6], **kw) -> dict[str, PlanResult]:
    problem = DESProblem(dag)
    ideal = _ideal(problem)
    return {m: _plan_dag(dag, m, ideal_result=ideal, **kw) for m in methods}


# ------------------------------------------------------------- DELTA-Robust
@dataclass
class EnsemblePlanResult:
    """One static topology scored against every member of a DagEnsemble."""

    method: str
    objective: str
    x: np.ndarray
    member_names: list[str]
    weights: np.ndarray
    makespans: np.ndarray          # (M,) exact fair-share DES makespans
    refs: np.ndarray               # (M,) reference makespans (regret = 1)
    regrets: np.ndarray            # (M,) makespans / refs
    elapsed: float
    feasible: bool = True
    details: dict = field(default_factory=dict)

    @property
    def worst_regret(self) -> float:
        return float(self.regrets.max()) if len(self.regrets) else INF

    @property
    def weighted_makespan(self) -> float:
        return float(self.makespans @ self.weights)

    @property
    def total_ports(self) -> int:
        return int(self.x.sum())


def evaluate_on_ensemble(ensemble: DagEnsemble, x: np.ndarray) -> np.ndarray:
    """Exact fair-share DES makespan of topology `x` on every member (INF
    where infeasible) -- the cross-evaluation used for regret reporting."""
    return np.array([simulate(DESProblem(m), np.asarray(x)).makespan
                     for m in ensemble.members])


def _plan_ensemble(ensemble: DagEnsemble, method: str = "delta-robust",
                   objective: str = "max-regret",
                   refs: np.ndarray | None = None,
                   ga_options: GAOptions | None = None,
                   milp_options: MILPOptions | None = None
                   ) -> EnsemblePlanResult:
    """DELTA-Robust entry point: one port allocation for a set of DAGs.

    `refs` define regret (makespan / ref per member); when omitted they
    are the members' best single-DAG `delta-fast` plans computed here with
    the same `ga_options` (their plan makespans are also the natural
    baseline to report robust regret against).
    """
    if method not in ROBUST_METHODS:
        raise ValueError(
            f"unknown method {method!r}; pick from {ROBUST_METHODS}")
    if objective not in ROBUST_OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"pick from {ROBUST_OBJECTIVES}")
    t0 = time.time()
    details: dict = {}
    if refs is None:
        singles = [delta_fast(m, ga_options) for m in ensemble.members]
        refs = np.array([s.makespan for s in singles])
        details["single_plan_ports"] = [s.total_ports for s in singles]
        details["single_plan_x"] = [s.x for s in singles]
    refs = np.asarray(refs, dtype=np.float64)

    if method == "delta-robust":
        res = delta_robust(ensemble, ga_options, objective=objective,
                           refs=refs)
        x, makespans, feasible = res.x, res.makespans, res.feasible
        details.update(generations=res.generations,
                       evaluations=res.evaluations,
                       objective_value=res.objective_value)
    else:
        # honour the caller's fairness choice: MILPOptions(fairness=True)
        # yields the Eq. 17 fair-share robust variant (the delta-topo
        # analog), the default the joint-rate one (the delta-joint analog)
        opts = dataclasses.replace(milp_options) if milp_options \
            else MILPOptions()
        res = solve_robust_milp(ensemble, opts, objective=objective,
                                refs=refs)
        # a time-limited schedule can carry slack; the shared topology is
        # at least as good as its fair-share execution (cf. `optimize`)
        des_ms = evaluate_on_ensemble(ensemble, res.x)
        makespans = np.minimum(res.makespans, des_ms) if res.feasible \
            else des_ms
        x, feasible = res.x, bool(np.isfinite(makespans).all())
        details.update(milp_status=res.status, solve_time=res.solve_time,
                       objective_value=res.objective_value,
                       stats=res.stats)
    with np.errstate(invalid="ignore"):
        regrets = makespans / refs
    return EnsemblePlanResult(
        method=method, objective=objective, x=x,
        member_names=list(ensemble.names),
        weights=np.asarray(ensemble.weights), makespans=makespans,
        refs=refs, regrets=regrets, elapsed=time.time() - t0,
        feasible=feasible, details=details)


def _plan_failsafe(dag: CommDAG,
                   scenarios: list[np.ndarray] | None = None,
                   num_planes: int = 4, k: int = 1,
                   objective: str = "worst",
                   ga_options: GAOptions | None = None,
                   ideal_result: DESResult | None = None) -> PlanResult:
    """DELTA-Failsafe entry point: one topology whose makespan holds up
    across fabric-degradation scenarios (capacity masks; default: every
    k-of-num_planes plane loss per pod pair).  Reported under healthy
    fair-share DES semantics; per-scenario exact makespans ride in
    `details`."""
    problem = DESProblem(dag)
    ideal = ideal_result or _ideal(problem)
    t0 = time.time()
    res = delta_failsafe(dag, ga_options, scenarios=scenarios,
                         num_planes=num_planes, k=k, objective=objective)
    elapsed = time.time() - t0
    out = _from_des(dag, problem, "delta-failsafe", res.x, elapsed, ideal)
    out.feasible = out.feasible and res.feasible
    out.details.update(objective=objective,
                       scenario_makespans=res.makespans.tolist(),
                       worst_scenario_makespan=float(res.makespans.max()),
                       generations=res.generations,
                       evaluations=res.evaluations)
    return out


def _plan_resilient(dag: CommDAG, *, budget_s: float | None = None,
                    retries: int = 1,
                    ga_options: GAOptions | None = None,
                    milp_options: MILPOptions | None = None,
                    current_x: np.ndarray | None = None,
                    mask: np.ndarray | None = None,
                    ideal_result: DESResult | None = None) -> PlanResult:
    """Budgeted MILP solve with the full fallback chain (MILP -> GA ->
    masked current plan): always returns a plan, with `degraded` and the
    producing `fallback_stage` in `details` when the MILP did not make
    the budget."""
    problem = DESProblem(dag)
    ideal = ideal_result or _ideal(problem)
    t0 = time.time()
    mres = solve_resilient(dag, milp_options, budget_s=budget_s,
                           retries=retries, ga_options=ga_options,
                           current_x=current_x, mask=mask)
    elapsed = time.time() - t0
    out = _from_des(dag, problem, "delta-resilient", mres.x, elapsed, ideal)
    out.feasible = out.feasible and mres.feasible
    out.details.update(milp_status=mres.status,
                       milp_makespan=mres.makespan,
                       degraded=bool(getattr(mres, "degraded", False)),
                       fallback_stage=getattr(mres, "fallback_stage", None),
                       stats=mres.stats)
    return out


def _plan_fleet(requests, num_pods: int | None = None,
                ports_per_pod: int | None = None,
                nic_gbps: float = 400.0,
                ga_options=None, nct_threshold: float = 1.005,
                seed: int = 0):
    """Multi-tenant entry point (paper Sec. VI): admit every request into a
    shared-pod fleet, donate port-minimized savings, waterfill the surplus
    across bottlenecked tenants, and return the FleetPlanner for inspection.

    `requests` is an iterable of `repro.fleet.JobArrival` events or
    `(name, JobSpec[, kwargs])` tuples.  The fleet defaults to the smallest
    cluster that can host all requests back to back: the max pod span among
    requests, with each pod sized for the sum of co-located entitlements.

    Returns `(planner, report)`; `report` is `planner.report()` after all
    arrivals and surplus passes.
    """
    from repro.fleet import FleetPlanner, FleetSpec, arrivals

    events = arrivals(*requests)
    if not events:
        raise ValueError("fleet_optimize needs at least one job request")

    if num_pods is None or ports_per_pod is None:
        spans, per_pod = [], []
        for ev in events:
            pl = ev.job.placement()
            spans.append(pl.num_pods)
            per_pod.append(max(pl.port_limits()))
        num_pods = num_pods or max(spans)
        # stack all co-located entitlements: every request fits, worst case
        ports_per_pod = ports_per_pod or sum(per_pod)

    planner = FleetPlanner(
        FleetSpec(num_pods=num_pods, ports_per_pod=ports_per_pod,
                  nic_gbps=nic_gbps),
        ga_options=ga_options, nct_threshold=nct_threshold, seed=seed)
    planner.process(events)
    return planner, planner.report()


# -------------------------------------------------------- unified entry
@dataclass
class FailureModel:
    """How `plan` should handle fabric failures.

    Default (``resilient=False``): DELTA-Failsafe -- optimize one topology
    against degradation `scenarios` (capacity masks; when None, every
    `k`-of-`num_planes` plane loss per pod pair), aggregated by
    `objective` ("worst" | "mean").

    ``resilient=True``: budgeted MILP with the full fallback chain
    (MILP -> GA -> masked `current_x`); `budget_s`/`retries` bound the
    solve, `mask` degrades capacities during it.
    """

    scenarios: list[np.ndarray] | None = None
    num_planes: int = 4
    k: int = 1
    objective: str = "worst"
    resilient: bool = False
    budget_s: float | None = None
    retries: int = 1
    current_x: np.ndarray | None = None
    mask: np.ndarray | None = None


@dataclass
class FleetOptions:
    """Fleet sizing + admission knobs for `plan(kind="fleet")`."""

    num_pods: int | None = None
    ports_per_pod: int | None = None
    nic_gbps: float = 400.0
    nct_threshold: float = 1.005
    seed: int = 0


@dataclass
class FleetPlanResult:
    """`plan` result for a fleet request: the live planner + its report."""

    planner: object
    report: dict

    def __iter__(self):
        # unpacks like the historical (planner, report) tuple
        return iter((self.planner, self.report))


@dataclass
class PlanRequest:
    """One typed request for every planning mode.

    Exactly one of `dag` / `ensemble` / `fleet_requests` must be set;
    `kind` is derived from which one is.  A `dag` request with a
    `FailureModel` routes to the failsafe path (or the resilient one when
    ``failure.resilient``).  `method` / `objective` default per kind
    ("delta-fast" for a dag, "delta-robust" / "max-regret" for an
    ensemble).  `des_options` is a convenience overlay: when set it is
    copied into ``ga_options.des_options`` (without mutating the caller's
    options object).
    """

    dag: CommDAG | None = None
    ensemble: DagEnsemble | None = None
    fleet_requests: list | tuple | None = None
    method: str | None = None
    objective: str | None = None
    port_min: bool = False
    refs: np.ndarray | None = None
    failure: FailureModel | None = None
    fleet: FleetOptions | None = None
    ga_options: GAOptions | None = None
    milp_options: MILPOptions | None = None
    des_options: object | None = None
    ideal_result: DESResult | None = None

    @property
    def kind(self) -> str:
        given = [k for k, v in (("dag", self.dag),
                                ("ensemble", self.ensemble),
                                ("fleet", self.fleet_requests))
                 if v is not None]
        if len(given) != 1:
            raise ValueError(
                "PlanRequest needs exactly one of dag | ensemble | "
                f"fleet_requests, got {given or 'none'}")
        if given[0] == "dag" and self.failure is not None:
            return "resilient" if self.failure.resilient else "failsafe"
        return given[0]


def plan(request: PlanRequest):
    """THE planner entry point: dispatch a `PlanRequest` by `kind`.

    Returns `PlanResult` (dag / failsafe / resilient),
    `EnsemblePlanResult` (ensemble) or `FleetPlanResult` (fleet) -- the
    same objects, bit-identical, that the legacy facades produced.
    """
    kind = request.kind
    ga = request.ga_options
    if request.des_options is not None:
        ga = dataclasses.replace(ga or GAOptions(),
                                 des_options=request.des_options)
    if kind == "dag":
        return _plan_dag(request.dag, method=request.method or "delta-fast",
                         port_min=request.port_min, ga_options=ga,
                         milp_options=request.milp_options,
                         ideal_result=request.ideal_result)
    if kind == "ensemble":
        return _plan_ensemble(request.ensemble,
                              method=request.method or "delta-robust",
                              objective=request.objective or "max-regret",
                              refs=request.refs, ga_options=ga,
                              milp_options=request.milp_options)
    if kind == "failsafe":
        f = request.failure
        return _plan_failsafe(request.dag, scenarios=f.scenarios,
                              num_planes=f.num_planes, k=f.k,
                              objective=f.objective, ga_options=ga,
                              ideal_result=request.ideal_result)
    if kind == "resilient":
        f = request.failure
        return _plan_resilient(request.dag, budget_s=f.budget_s,
                               retries=f.retries, ga_options=ga,
                               milp_options=request.milp_options,
                               current_x=f.current_x, mask=f.mask,
                               ideal_result=request.ideal_result)
    # kind == "fleet"
    fo = request.fleet or FleetOptions()
    planner, report = _plan_fleet(
        request.fleet_requests, num_pods=fo.num_pods,
        ports_per_pod=fo.ports_per_pod, nic_gbps=fo.nic_gbps,
        ga_options=ga, nct_threshold=fo.nct_threshold, seed=fo.seed)
    return FleetPlanResult(planner=planner, report=report)


# ------------------------------------------------- deprecated facades
# Thin shims over `plan` (bit-identical; regression-tested).  New code
# should build a `PlanRequest` -- the sentinel rule RPR009 flags in-tree
# calls to these names.
def optimize(dag: CommDAG, method: str = "delta-fast",
             port_min: bool = False,
             ga_options: GAOptions | None = None,
             milp_options: MILPOptions | None = None,
             ideal_result: DESResult | None = None) -> PlanResult:
    """Deprecated: use ``plan(PlanRequest(dag=..., method=...))``."""
    return plan(PlanRequest(dag=dag, method=method, port_min=port_min,
                            ga_options=ga_options, milp_options=milp_options,
                            ideal_result=ideal_result))


def optimize_ensemble(ensemble: DagEnsemble, method: str = "delta-robust",
                      objective: str = "max-regret",
                      refs: np.ndarray | None = None,
                      ga_options: GAOptions | None = None,
                      milp_options: MILPOptions | None = None
                      ) -> EnsemblePlanResult:
    """Deprecated: use ``plan(PlanRequest(ensemble=..., objective=...))``."""
    return plan(PlanRequest(ensemble=ensemble, method=method,
                            objective=objective, refs=refs,
                            ga_options=ga_options,
                            milp_options=milp_options))


def optimize_failsafe(dag: CommDAG,
                      scenarios: list[np.ndarray] | None = None,
                      num_planes: int = 4, k: int = 1,
                      objective: str = "worst",
                      ga_options: GAOptions | None = None,
                      ideal_result: DESResult | None = None) -> PlanResult:
    """Deprecated: use ``plan(PlanRequest(dag=..., failure=FailureModel(...)))``."""
    return plan(PlanRequest(
        dag=dag, ga_options=ga_options, ideal_result=ideal_result,
        failure=FailureModel(scenarios=scenarios, num_planes=num_planes,
                             k=k, objective=objective)))


def optimize_resilient(dag: CommDAG, *, budget_s: float | None = None,
                       retries: int = 1,
                       ga_options: GAOptions | None = None,
                       milp_options: MILPOptions | None = None,
                       current_x: np.ndarray | None = None,
                       mask: np.ndarray | None = None,
                       ideal_result: DESResult | None = None) -> PlanResult:
    """Deprecated: use ``plan(PlanRequest(dag=...,
    failure=FailureModel(resilient=True, ...)))``."""
    return plan(PlanRequest(
        dag=dag, ga_options=ga_options, milp_options=milp_options,
        ideal_result=ideal_result,
        failure=FailureModel(resilient=True, budget_s=budget_s,
                             retries=retries, current_x=current_x,
                             mask=mask)))


def fleet_optimize(requests, num_pods: int | None = None,
                   ports_per_pod: int | None = None,
                   nic_gbps: float = 400.0,
                   ga_options=None, nct_threshold: float = 1.005,
                   seed: int = 0):
    """Deprecated: use ``plan(PlanRequest(fleet_requests=...,
    fleet=FleetOptions(...)))``."""
    res = plan(PlanRequest(
        fleet_requests=list(requests), ga_options=ga_options,
        fleet=FleetOptions(num_pods=num_pods, ports_per_pod=ports_per_pod,
                           nic_gbps=nic_gbps, nct_threshold=nct_threshold,
                           seed=seed)))
    return res.planner, res.report
