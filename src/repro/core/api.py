"""DELTA facade: one entry point for the six algorithms of Sec. V-A2.

    plan = optimize(dag, method="delta-joint", port_min=True)
    report = compare(dag)      # all six, ready for the Fig. 6/8 benchmarks

Methods:
  prop-alloc | sqrt-alloc | iter-halve    traffic-matrix baselines
  delta-fast                              GA (Alg. 3) on the DES
  delta-topo                              MILP + fairness (Eq. 17)
  delta-joint                             MILP, joint topology + rates
  delta-joint-hotstart                    delta-joint seeded by delta-fast
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.baselines import BASELINES
from repro.core.dag import VIRTUAL, CommDAG
from repro.core.des import DESProblem, DESResult, simulate
from repro.core.ga import GAOptions, GAResult, delta_fast
from repro.core.milp import MILPOptions, MILPResult, solve_delta_milp

INF = float("inf")

METHODS = ("prop-alloc", "sqrt-alloc", "iter-halve",
           "delta-fast", "delta-topo", "delta-joint",
           "delta-joint-hotstart")


@dataclass
class PlanResult:
    method: str
    x: np.ndarray
    makespan: float            # under the method's own rate semantics
    comm_time: float           # inter-pod comm time on the critical path
    nct: float
    total_ports: int
    elapsed: float
    feasible: bool = True
    details: dict = field(default_factory=dict)


def _ideal(problem: DESProblem) -> DESResult:
    P = problem.dag.cluster.num_pods
    return simulate(problem, np.zeros((P, P)), ideal=True)


def milp_critical_delta(dag: CommDAG, res: MILPResult) -> float:
    """Sum of rigid deltas along the binding chain of a MILP schedule."""
    finish = res.finish
    start = res.start
    preds: dict[int, list] = {}
    for d in dag.deps:
        preds.setdefault(d.succ, []).append(d)
    cur = int(np.argmax(finish))
    delta_sum = 0.0
    guard = 0
    while cur != VIRTUAL and guard <= dag.num_tasks + 1:
        guard += 1
        plist = preds.get(cur, [])
        if not plist:
            break
        best = max(plist, key=lambda d: (0.0 if d.pre == VIRTUAL
                                         else finish[d.pre]) + d.delta)
        delta_sum += best.delta
        cur = best.pre
    del start
    return delta_sum


def optimize(dag: CommDAG, method: str = "delta-fast",
             port_min: bool = False,
             ga_options: GAOptions | None = None,
             milp_options: MILPOptions | None = None,
             ideal_result: DESResult | None = None) -> PlanResult:
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; pick from {METHODS}")
    problem = DESProblem(dag)
    ideal = ideal_result or _ideal(problem)
    t0 = time.time()

    if method in BASELINES:
        x = BASELINES[method](dag)
        elapsed = time.time() - t0
        return _from_des(dag, problem, method, x, elapsed, ideal)

    if method == "delta-fast":
        res: GAResult = delta_fast(dag, ga_options)
        elapsed = time.time() - t0
        out = _from_des(dag, problem, method, res.x, elapsed, ideal)
        out.details.update(generations=res.generations,
                           evaluations=res.evaluations,
                           history_len=len(res.history))
        return out

    # shallow-copy: optimize() tweaks port_min/fairness per method and must
    # not leak those into the caller's (possibly shared) options object
    opts = dataclasses.replace(milp_options) if milp_options \
        else MILPOptions()
    opts.port_min = port_min or opts.port_min
    if method == "delta-topo":
        opts.fairness = True
        mres = solve_delta_milp(dag, opts)
        elapsed = time.time() - t0
        out = _from_des(dag, problem, method, mres.x, elapsed, ideal)
        out.details.update(milp_status=mres.status,
                           milp_makespan=mres.makespan,
                           solve_time=mres.solve_time,
                           port_min_applied=mres.port_min_applied,
                           stats=mres.stats)
        return out

    # delta-joint variants: makespan/comm time come from the MILP schedule
    opts.fairness = False
    if method == "delta-joint-hotstart":
        ga = delta_fast(dag, ga_options)
        if np.isfinite(ga.makespan):
            ub = ga.makespan * (1 + 1e-9)
            opts.upper_bound = min(opts.upper_bound, ub) \
                if opts.upper_bound else ub
            # route the GA incumbent into the MILP hot start: its DES trace
            # seeds the anchors and the polish pre-pass (see MILPOptions)
            opts.seed_x = ga.x
        opts.hot_start = True
    mres = solve_delta_milp(dag, opts)
    elapsed = time.time() - t0
    if not mres.feasible or not np.isfinite(mres.makespan):
        return PlanResult(method=method, x=mres.x, makespan=INF,
                          comm_time=INF, nct=INF, total_ports=0,
                          elapsed=elapsed, feasible=False,
                          details={"milp_status": mres.status})
    crit_delta = milp_critical_delta(dag, mres)
    comm = mres.makespan - crit_delta
    # a time-limited incumbent schedule can carry slack; the topology is
    # still at least as good as its fair-share execution (joint rate
    # control can only improve on fair sharing), so report the better of
    # the two measurements
    des = simulate(problem, mres.x)
    makespan = mres.makespan
    source = "milp_schedule"
    if des.feasible and (not np.isfinite(comm) or des.comm_time < comm):
        comm, makespan, source = des.comm_time, des.makespan, "des_fairshare"
    nct = comm / ideal.comm_time if ideal.comm_time > 0 else INF
    return PlanResult(method=method, x=mres.x, makespan=makespan,
                      comm_time=comm, nct=nct,
                      total_ports=int(mres.x.sum()), elapsed=elapsed,
                      details={"milp_status": mres.status,
                               "solve_time": mres.solve_time,
                               "port_min_applied": mres.port_min_applied,
                               "comm_time_source": source,
                               "stats": mres.stats})


def _from_des(dag: CommDAG, problem: DESProblem, method: str, x: np.ndarray,
              elapsed: float, ideal: DESResult) -> PlanResult:
    res = simulate(problem, x)
    if not res.feasible:
        return PlanResult(method=method, x=x, makespan=INF, comm_time=INF,
                          nct=INF, total_ports=int(x.sum()), elapsed=elapsed,
                          feasible=False)
    nct = res.comm_time / ideal.comm_time if ideal.comm_time > 0 else INF
    return PlanResult(method=method, x=x, makespan=res.makespan,
                      comm_time=res.comm_time, nct=nct,
                      total_ports=int(x.sum()), elapsed=elapsed)


def compare(dag: CommDAG, methods=METHODS[:6], **kw) -> dict[str, PlanResult]:
    problem = DESProblem(dag)
    ideal = _ideal(problem)
    return {m: optimize(dag, m, ideal_result=ideal, **kw) for m in methods}


def fleet_optimize(requests, num_pods: int | None = None,
                   ports_per_pod: int | None = None,
                   nic_gbps: float = 400.0,
                   ga_options=None, nct_threshold: float = 1.005,
                   seed: int = 0):
    """Multi-tenant entry point (paper Sec. VI): admit every request into a
    shared-pod fleet, donate port-minimized savings, waterfill the surplus
    across bottlenecked tenants, and return the FleetPlanner for inspection.

    `requests` is an iterable of `repro.fleet.JobArrival` events or
    `(name, JobSpec[, kwargs])` tuples.  The fleet defaults to the smallest
    cluster that can host all requests back to back: the max pod span among
    requests, with each pod sized for the sum of co-located entitlements.

    Returns `(planner, report)`; `report` is `planner.report()` after all
    arrivals and surplus passes.
    """
    from repro.fleet import FleetPlanner, FleetSpec, arrivals

    events = arrivals(*requests)
    if not events:
        raise ValueError("fleet_optimize needs at least one job request")

    if num_pods is None or ports_per_pod is None:
        spans, per_pod = [], []
        for ev in events:
            pl = ev.job.placement()
            spans.append(pl.num_pods)
            per_pod.append(max(pl.port_limits()))
        num_pods = num_pods or max(spans)
        # stack all co-located entitlements: every request fits, worst case
        ports_per_pod = ports_per_pod or sum(per_pod)

    planner = FleetPlanner(
        FleetSpec(num_pods=num_pods, ports_per_pod=ports_per_pod,
                  nic_gbps=nic_gbps),
        ga_options=ga_options, nct_threshold=nct_threshold, seed=seed)
    planner.process(events)
    return planner, planner.report()
