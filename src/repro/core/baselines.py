"""Traffic-matrix-based logical-topology baselines (paper Sec. V-A2).

All three baselines see only the aggregated traffic matrix -- exactly the
information loss the paper criticizes -- and allocate symmetric circuits
subject to per-pod port budgets U_p:

  * Prop-Alloc (derived from SiP-ML [44]): circuits proportional to traffic
    volume.  Integer apportionment via the D'Hondt / Jefferson highest-
    quotient method (argmax w_ij / (x_ij + 1)), which is the integral
    counterpart of proportional allocation.
  * Sqrt-Alloc (paper's modification): proportional to sqrt(volume),
    modelling strictly sequential demands from a common source.
  * Iter-Halve (derived from TopoOpt [17]): repeatedly grant one circuit to
    the heaviest pair, then halve that pair's weight.

Every baseline first guarantees one circuit per active pair (connectivity),
then spends the remaining port budget per its rule.
"""
from __future__ import annotations

import numpy as np

from repro.core.dag import CommDAG


def _undirected_weights(dag: CommDAG, transform=lambda v: v) -> np.ndarray:
    tm = dag.traffic_matrix()
    w = tm + tm.T
    w = np.triu(transform(np.where(w > 0, w, 0.0)), k=1)
    return w


def _greedy_fill(dag: CommDAG, weights: np.ndarray,
                 quotient: str, max_total: int | None = None) -> np.ndarray:
    """Symmetric integral allocation under port budgets.

    quotient='dhondt'  : pick argmax w/(x+1), keep w fixed  (Prop/Sqrt-Alloc)
    quotient='halving' : pick argmax w, then halve w        (Iter-Halve)
    """
    P = dag.cluster.num_pods
    U = np.array(dag.cluster.port_limits, dtype=np.int64)
    x = np.zeros((P, P), dtype=np.int64)
    used = np.zeros(P, dtype=np.int64)
    pairs = dag.undirected_pairs()
    w = weights.copy()

    def addable(i, j):
        return used[i] < U[i] and used[j] < U[j]

    # connectivity first
    for i, j in pairs:
        if addable(i, j):
            x[i, j] += 1
            x[j, i] += 1
            used[i] += 1
            used[j] += 1

    total = int(x.sum() // 2)
    while max_total is None or total < max_total:
        best, best_q = None, 0.0
        for i, j in pairs:
            if not addable(i, j) or w[i, j] <= 0:
                continue
            q = w[i, j] / (x[i, j] + 1) if quotient == "dhondt" else w[i, j]
            if q > best_q:
                best_q, best = q, (i, j)
        if best is None:
            break
        i, j = best
        x[i, j] += 1
        x[j, i] += 1
        used[i] += 1
        used[j] += 1
        total += 1
        if quotient == "halving":
            w[i, j] /= 2.0
    return x


def prop_alloc(dag: CommDAG) -> np.ndarray:
    """SiP-ML-style proportional-to-volume allocation."""
    return _greedy_fill(dag, _undirected_weights(dag), "dhondt")


def sqrt_alloc(dag: CommDAG) -> np.ndarray:
    """Proportional to sqrt(volume) (sequential-demand assumption)."""
    return _greedy_fill(dag, _undirected_weights(dag, np.sqrt), "dhondt")


def iter_halve(dag: CommDAG) -> np.ndarray:
    """TopoOpt-style iterative weight-halving allocation."""
    return _greedy_fill(dag, _undirected_weights(dag), "halving")


BASELINES = {
    "prop-alloc": prop_alloc,
    "sqrt-alloc": sqrt_alloc,
    "iter-halve": iter_halve,
}
