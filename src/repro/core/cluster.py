"""Cluster and placement model for OCS-AIDC topology optimization.

Pods are interconnected by optical circuit switches (OCS); within a pod the
electrical network is treated as non-blocking (intra-pod tasks are collapsed
into the rigid deltas of the reduced DAG, per paper Sec. III-A).

Units used throughout repro.core:
    time        -> seconds
    data volume -> bytes
    bandwidth   -> bytes / second
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Sequence

GBPS = 1e9 / 8.0  # 1 Gb/s in bytes/s


def split_port_budgets(port_limits: Sequence[int],
                       num_planes: int) -> tuple[tuple[int, ...], ...]:
    """Split per-pod port budgets across `num_planes` parallel OCS planes.

    Each pod's U_p ports are divided as evenly as possible: every plane
    gets ``U_p // k`` and the first ``U_p % k`` planes one extra, so the
    per-plane budgets sum to U_p exactly and differ by at most one.  The
    deterministic remainder placement (low plane ids first) matters: the
    fleet's plane book must be bit-identically reconstructible from a
    journal replay.
    """
    k = int(num_planes)
    if k < 1:
        raise ValueError(f"num_planes must be >= 1, got {num_planes}")
    limits = [int(u) for u in port_limits]
    if any(u < 0 for u in limits):
        raise ValueError(f"port budgets must be non-negative: {limits}")
    return tuple(
        tuple(u // k + (1 if p < u % k else 0) for u in limits)
        for p in range(k))


@dataclass(frozen=True)
class ClusterSpec:
    """A set of pods with OCS port budgets and per-NIC injection bandwidth.

    Attributes:
      num_pods:     number of pods |P| hosting the job.
      port_limits:  U_p -- max OCS ports available to this job per pod.  The
                    paper constrains U_p to the number of GPUs the job owns in
                    pod p (fairness); callers can pass larger budgets to model
                    surplus-port reallocation (Fig. 10).
      nic_bandwidth: B -- injection bandwidth of a single NIC == capacity of a
                    single OCS port (bytes/s).
      intra_pod_bandwidth: per-GPU intra-pod electrical bandwidth used only to
                    derive durations of intra-pod communication before DAG
                    reduction (bytes/s).
      ep_spans:     one tuple of pod ids per expert-parallel group, listing
                    the pods the group's GPUs span (empty when the job has
                    no cross-replica EP traffic).  Purely descriptive:
                    recorded in the tab1 benchmark payload so consumers of
                    the workload JSON can reason about concurrent EP
                    all-to-all demand without re-deriving the placement.
    """

    num_pods: int
    port_limits: tuple[int, ...]
    nic_bandwidth: float
    intra_pod_bandwidth: float = 900e9
    ep_spans: tuple[tuple[int, ...], ...] = ()

    def __post_init__(self) -> None:
        if len(self.port_limits) != self.num_pods:
            raise ValueError(
                f"port_limits has {len(self.port_limits)} entries, expected "
                f"{self.num_pods}")
        if self.nic_bandwidth <= 0:
            raise ValueError("nic_bandwidth must be positive")

    @classmethod
    def uniform(cls, num_pods: int, ports_per_pod: int,
                nic_bandwidth: float, **kw) -> "ClusterSpec":
        return cls(num_pods=num_pods,
                   port_limits=(ports_per_pod,) * num_pods,
                   nic_bandwidth=nic_bandwidth, **kw)

    def with_port_limits(self, port_limits: Sequence[int]) -> "ClusterSpec":
        return dataclasses.replace(self, port_limits=tuple(port_limits))

    def plane_port_limits(self, num_planes: int
                          ) -> tuple[tuple[int, ...], ...]:
        """Per-plane port budgets for a k-plane fabric (see
        `split_port_budgets`): k tuples of per-pod budgets summing to
        `port_limits` elementwise."""
        return split_port_budgets(self.port_limits, num_planes)


@dataclass(frozen=True)
class Placement:
    """Maps (replica, stage, tp_rank) -> (pod, global gpu id).

    Fragmented multi-tenant placement (paper Sec. V-A1): each DP replica owns
    `gpus_per_pod_per_replica` GPUs in each pod it touches, so a replica with
    tp*pp GPUs spans ceil(tp*pp / gppr) pods, stages packed contiguously.
    `reverse_stages=True` gives the Model^T deployment of Fig. 10 (reversed
    stage-to-pod mapping over the same pods).

    `ep` is the expert-parallel degree: EP groups stride across DP replicas
    within a stage, so group g covers replicas [g*span, (g+1)*span) with
    span = min(ep, dp).  A replica's stage-s expert shard exchanges
    all-to-all traffic with the stage-s shards of its group peers, which
    live in the peers' pods.
    """

    tp: int
    pp: int
    dp: int
    gpus_per_pod_per_replica: int
    ep: int = 1
    reverse_stages: bool = False

    def __post_init__(self) -> None:
        gppr = self.gpus_per_pod_per_replica
        if gppr % self.tp != 0:
            raise ValueError(
                f"gpus_per_pod_per_replica={gppr} must be a multiple of tp="
                f"{self.tp} so stages do not straddle pods")
        if self.ep > 1:
            if self.ep <= self.dp and self.dp % self.ep:
                raise ValueError(f"ep={self.ep} must divide dp={self.dp}")
            if self.ep > self.dp and self.ep % self.dp:
                raise ValueError(f"ep={self.ep} > dp={self.dp} needs dp | ep")

    @property
    def gpus_per_replica(self) -> int:
        return self.tp * self.pp

    @property
    def pods_per_replica(self) -> int:
        return math.ceil(self.gpus_per_replica /
                         self.gpus_per_pod_per_replica)

    @property
    def stages_per_pod(self) -> int:
        return max(1, self.gpus_per_pod_per_replica // self.tp)

    @property
    def num_pods(self) -> int:
        return self.pods_per_replica * self.dp

    @property
    def num_gpus(self) -> int:
        return self.gpus_per_replica * self.dp

    def stage_pod_offset(self, stage: int) -> int:
        s = (self.pp - 1 - stage) if self.reverse_stages else stage
        return min(s // self.stages_per_pod, self.pods_per_replica - 1)

    def pod_of(self, replica: int, stage: int) -> int:
        return replica * self.pods_per_replica + self.stage_pod_offset(stage)

    def gpu_ids(self, replica: int, stage: int) -> tuple[int, ...]:
        base = replica * self.gpus_per_replica + stage * self.tp
        return tuple(range(base, base + self.tp))

    def gpus_in_pod(self, pod: int) -> int:
        count = 0
        for r in range(self.dp):
            for s in range(self.pp):
                if self.pod_of(r, s) == pod:
                    count += self.tp
        return count

    def port_limits(self) -> tuple[int, ...]:
        """Default U_p = number of job GPUs in each pod (paper fairness rule)."""
        return tuple(self.gpus_in_pod(p) for p in range(self.num_pods))

    # ------------------------------------------------------------ EP groups
    @property
    def ep_span(self) -> int:
        """DP replicas spanned by one EP group (1 -> no cross-replica EP)."""
        return min(self.ep, self.dp) if self.ep > 1 else 1

    def ep_groups(self) -> list[tuple[int, ...]]:
        """Replica ids per EP group; empty when EP stays within a replica."""
        span = self.ep_span
        if span < 2:
            return []
        return [tuple(range(g * span, (g + 1) * span))
                for g in range(self.dp // span)]

    def ep_group_pods(self, group: Sequence[int]) -> tuple[int, ...]:
        """Pods spanned by one EP group's GPUs (all stages)."""
        return tuple(sorted({self.pod_of(r, s)
                             for r in group for s in range(self.pp)}))

    def ep_spans(self) -> tuple[tuple[int, ...], ...]:
        return tuple(self.ep_group_pods(g) for g in self.ep_groups())

    def cluster(self, nic_bandwidth: float, **kw) -> ClusterSpec:
        kw.setdefault("ep_spans", self.ep_spans())
        return ClusterSpec(num_pods=self.num_pods,
                           port_limits=self.port_limits(),
                           nic_bandwidth=nic_bandwidth, **kw)

    def reversed(self) -> "Placement":
        return dataclasses.replace(self, reverse_stages=not self.reverse_stages)
