"""Reduced inter-pod communication DAG data structures (paper Sec. III-A).

A `CommTask` is the paper's 6-tuple m = (i_m, j_m, F_m, V_m, G_src, G_dst);
a `Dep` is an element (m_pre, m, delta) of the temporal-dependency set D.
Task 0 is always the virtual source task occurring at t=0 that carries the
rigid delays of intra-pod work preceding the first inter-pod communication.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Iterable, Iterator

import numpy as np

from repro.core.cluster import ClusterSpec

VIRTUAL = 0  # tid of the virtual source task


@dataclass(frozen=True)
class CommTask:
    tid: int
    src_pod: int
    dst_pod: int
    flows: int            # F_m: concurrent GPU-pair flows aggregated in m
    volume: float         # V_m: bytes
    src_gpus: tuple[int, ...]
    dst_gpus: tuple[int, ...]
    kind: str = "comm"    # pp_fwd | pp_bwd | dp | xattn | ep_a2a_fwd |
                          # ep_a2a_bwd | virtual
    tag: tuple = ()       # free-form (replica, stage, microbatch, ...) labels

    @property
    def is_virtual(self) -> bool:
        return self.kind == "virtual"

    @property
    def pair(self) -> tuple[int, int]:
        return (self.src_pod, self.dst_pod)


@dataclass(frozen=True)
class Dep:
    pre: int
    succ: int
    delta: float  # rigid interval (seconds) after pre completes


def make_virtual() -> CommTask:
    return CommTask(tid=VIRTUAL, src_pod=-1, dst_pod=-1, flows=0, volume=0.0,
                    src_gpus=(), dst_gpus=(), kind="virtual")


@dataclass
class CommDAG:
    """Reduced inter-pod communication DAG for one training iteration."""

    tasks: list[CommTask]
    deps: list[Dep]
    cluster: ClusterSpec
    meta: dict = field(default_factory=dict)

    # ------------------------------------------------------------------ basic
    def __post_init__(self) -> None:
        self._validate()

    @property
    def num_tasks(self) -> int:
        return len(self.tasks)

    @property
    def num_real_tasks(self) -> int:
        return sum(1 for t in self.tasks if not t.is_virtual)

    def real_tasks(self) -> Iterator[CommTask]:
        return (t for t in self.tasks if not t.is_virtual)

    def _validate(self) -> None:
        if not self.tasks or self.tasks[VIRTUAL].kind != "virtual":
            raise ValueError("task 0 must be the virtual source task")
        n = len(self.tasks)
        for i, t in enumerate(self.tasks):
            if t.tid != i:
                raise ValueError(f"task {i} has tid {t.tid}")
            if not t.is_virtual:
                if t.volume <= 0 or t.flows <= 0:
                    raise ValueError(f"task {i}: non-positive volume/flows")
                if not (0 <= t.src_pod < self.cluster.num_pods):
                    raise ValueError(f"task {i}: bad src_pod {t.src_pod}")
                if not (0 <= t.dst_pod < self.cluster.num_pods):
                    raise ValueError(f"task {i}: bad dst_pod {t.dst_pod}")
                if t.src_pod == t.dst_pod:
                    raise ValueError(f"task {i}: intra-pod task in reduced DAG")
        for d in self.deps:
            if not (0 <= d.pre < n and 0 <= d.succ < n):
                raise ValueError(f"dep {d} out of range")
            if d.delta < 0:
                raise ValueError(f"dep {d} has negative delta")
        order = self.topo_order()  # raises on cycles
        pos = {t: i for i, t in enumerate(order)}
        for d in self.deps:
            if pos[d.pre] >= pos[d.succ]:  # pragma: no cover - defensive
                raise ValueError("topological order violated")

    # ------------------------------------------------------------ graph views
    def preds(self) -> dict[int, list[Dep]]:
        out: dict[int, list[Dep]] = collections.defaultdict(list)
        for d in self.deps:
            out[d.succ].append(d)
        return dict(out)

    def succs(self) -> dict[int, list[Dep]]:
        out: dict[int, list[Dep]] = collections.defaultdict(list)
        for d in self.deps:
            out[d.pre].append(d)
        return dict(out)

    def topo_order(self) -> list[int]:
        indeg = [0] * len(self.tasks)
        succs = collections.defaultdict(list)
        for d in self.deps:
            indeg[d.succ] += 1
            succs[d.pre].append(d.succ)
        queue = collections.deque(i for i, v in enumerate(indeg) if v == 0)
        order: list[int] = []
        while queue:
            u = queue.popleft()
            order.append(u)
            for v in succs[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    queue.append(v)
        if len(order) != len(self.tasks):
            raise ValueError("dependency graph has a cycle")
        return order

    # -------------------------------------------------------------- matrices
    def pod_pairs(self) -> list[tuple[int, int]]:
        """Active ordered pod pairs (i, j) with traffic, i != j."""
        pairs = sorted({t.pair for t in self.real_tasks()})
        return pairs

    def undirected_pairs(self) -> list[tuple[int, int]]:
        pairs = sorted({tuple(sorted(t.pair)) for t in self.real_tasks()})
        return [(int(a), int(b)) for a, b in pairs]

    def traffic_matrix(self) -> np.ndarray:
        """Aggregated volume matrix (bytes) -- what TM-based baselines see."""
        P = self.cluster.num_pods
        tm = np.zeros((P, P))
        for t in self.real_tasks():
            tm[t.src_pod, t.dst_pod] += t.volume
        return tm

    def flow_matrix(self) -> np.ndarray:
        """Max single-task flow count per ordered pair (lower bound on
        concurrency; Alg. 2 computes the true concurrent bound)."""
        P = self.cluster.num_pods
        fm = np.zeros((P, P), dtype=np.int64)
        for t in self.real_tasks():
            fm[t.src_pod, t.dst_pod] = max(fm[t.src_pod, t.dst_pod], t.flows)
        return fm

    def tasks_on_pair(self) -> dict[tuple[int, int], list[int]]:
        out: dict[tuple[int, int], list[int]] = collections.defaultdict(list)
        for t in self.real_tasks():
            out[t.pair].append(t.tid)
        return dict(out)

    # ------------------------------------------------------------ NIC classes
    def nic_classes(self) -> tuple[list[tuple[tuple[int, ...], float]], ...]:
        """Collapse per-GPU NIC constraints (Eq. 10) into equivalence classes.

        Two GPUs with identical task membership produce identical constraints;
        after the paper's stage-level aggregation whole TP groups collapse.
        Returns (src_classes, dst_classes); each class is
        (tuple of task ids, capacity multiplier == 1.0) and represents
        sum_m r_m / F_m <= B for one representative GPU.
        """
        src_of: dict[int, list[int]] = collections.defaultdict(list)
        dst_of: dict[int, list[int]] = collections.defaultdict(list)
        for t in self.real_tasks():
            for g in t.src_gpus:
                src_of[g].append(t.tid)
            for g in t.dst_gpus:
                dst_of[g].append(t.tid)

        def classes(of: dict[int, list[int]]):
            seen: dict[tuple[int, ...], int] = {}
            out: list[tuple[tuple[int, ...], float]] = []
            for tids in of.values():
                key = tuple(sorted(tids))
                if key not in seen:
                    seen[key] = len(out)
                    out.append((key, 1.0))
            return out

        return classes(src_of), classes(dst_of)

    # ---------------------------------------------------------------- helpers
    def dep_arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        pre = np.array([d.pre for d in self.deps], dtype=np.int32)
        succ = np.array([d.succ for d in self.deps], dtype=np.int32)
        delta = np.array([d.delta for d in self.deps], dtype=np.float64)
        return pre, succ, delta

    def volumes(self) -> np.ndarray:
        return np.array([t.volume for t in self.tasks], dtype=np.float64)

    def flows(self) -> np.ndarray:
        return np.array([max(t.flows, 1) for t in self.tasks],
                        dtype=np.float64)

    def volume_by_kind(self) -> dict[str, float]:
        """Aggregate bytes per task kind (MoE-vs-dense traffic split)."""
        out: dict[str, float] = collections.defaultdict(float)
        for t in self.real_tasks():
            out[t.kind] += t.volume
        return dict(out)

    def ep_volume_fraction(self, by_kind: dict[str, float] | None = None
                           ) -> float:
        """Share of total inter-pod bytes carried by EP all-to-all tasks."""
        if by_kind is None:
            by_kind = self.volume_by_kind()
        total = sum(by_kind.values())
        ep = sum(v for k, v in by_kind.items() if k.startswith("ep_a2a"))
        return ep / total if total > 0 else 0.0

    def summary(self) -> dict:
        kinds = collections.Counter(t.kind for t in self.real_tasks())
        by_kind = self.volume_by_kind()
        return {
            "num_tasks": self.num_real_tasks,
            "num_deps": len(self.deps),
            "num_pods": self.cluster.num_pods,
            "pairs": len(self.pod_pairs()),
            "kinds": dict(kinds),
            "total_volume_gb": self.traffic_matrix().sum() / 1e9,
            "volume_by_kind_gb": {k: v / 1e9 for k, v in by_kind.items()},
            "ep_volume_fraction": self.ep_volume_fraction(by_kind),
        }


# default-argument sentinel for DagEnsemble.weights: lets the field carry a
# real ndarray type while __post_init__ substitutes uniform weights
_UNIFORM_WEIGHTS: np.ndarray = np.empty(0, dtype=np.float64)


@dataclass
class DagEnsemble:
    """A *set* of reduced CommDAGs sharing one physical cluster.

    The robust formulation (DELTA-Robust): OCS reconfiguration overhead
    forces one static logical topology to serve several workloads --
    co-tenant mixes, training phases, Model/Model^T placements, traffic
    growth scenarios.  An ensemble holds the named member DAGs, their
    mixture weights (normalized to sum 1) and the shared `ClusterSpec`
    every member must agree on (same pods, port budgets and NIC bandwidth;
    otherwise one port allocation cannot serve them all).

    `weights` drive the `weighted` objective; the `max-regret` objective
    ignores them and minimizes max_m makespan_m / ref_m where ref_m is
    member m's best single-DAG plan (see `repro.core.ga.delta_robust`).
    """

    members: list[CommDAG]
    names: list[str] = field(default_factory=list)
    weights: np.ndarray = field(default_factory=lambda: _UNIFORM_WEIGHTS)
    meta: dict = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.members:
            raise ValueError("DagEnsemble needs at least one member DAG")
        if not self.names:
            # auto-derived names: phases of the same job share meta["job"],
            # so disambiguate collisions with a positional suffix
            raw = [m.meta.get("job", f"member{i}")
                   for i, m in enumerate(self.members)]
            self.names = [n if raw.count(n) == 1 else f"{n}[{i}]"
                          for i, n in enumerate(raw)]
        if len(self.names) != len(self.members):
            raise ValueError(
                f"{len(self.names)} names for {len(self.members)} members")
        if len(set(self.names)) != len(self.names):
            raise ValueError(f"duplicate member names: {self.names}")
        if self.weights is None or self.weights is _UNIFORM_WEIGHTS:
            self.weights = np.ones(len(self.members))
        self.weights = np.asarray(self.weights, dtype=np.float64)
        if self.weights.shape != (len(self.members),):
            raise ValueError("weights must have one entry per member")
        if (self.weights <= 0).any():
            raise ValueError("weights must be positive")
        self.weights = self.weights / self.weights.sum()
        ref = self.members[0].cluster
        for name, m in zip(self.names, self.members):
            cl = m.cluster
            if (cl.num_pods != ref.num_pods
                    or tuple(cl.port_limits) != tuple(ref.port_limits)
                    or cl.nic_bandwidth != ref.nic_bandwidth):
                raise ValueError(
                    f"member {name!r} disagrees with the shared cluster: "
                    f"{cl.num_pods} pods / {cl.port_limits} ports / "
                    f"B={cl.nic_bandwidth:g} vs {ref.num_pods} / "
                    f"{ref.port_limits} / B={ref.nic_bandwidth:g}")

    # ------------------------------------------------------------------ basic
    @classmethod
    def singleton(cls, dag: CommDAG, name: str | None = None,
                  ) -> "DagEnsemble":
        return cls(members=[dag],
                   names=[name] if name is not None else [])

    @property
    def num_members(self) -> int:
        return len(self.members)

    @property
    def cluster(self) -> ClusterSpec:
        return self.members[0].cluster

    def __iter__(self) -> Iterator[tuple[str, float, CommDAG]]:
        return iter(zip(self.names, self.weights, self.members))

    def member(self, name: str) -> CommDAG:
        return self.members[self.names.index(name)]

    def plane_port_limits(self, num_planes: int
                          ) -> tuple[tuple[int, ...], ...]:
        """Per-plane port budgets of the shared cluster for a k-plane
        fabric: k tuples of per-pod budgets summing elementwise to the
        cluster's `port_limits` (see `ClusterSpec.plane_port_limits`)."""
        return self.cluster.plane_port_limits(num_planes)

    # ------------------------------------------------------------ union views
    def undirected_pairs(self) -> list[tuple[int, int]]:
        """Union of the members' active undirected pod pairs -- the genome /
        x-variable support of one shared topology."""
        pairs: set[tuple[int, int]] = set()
        for m in self.members:
            pairs.update(m.undirected_pairs())
        return sorted(pairs)

    def pod_pairs(self) -> list[tuple[int, int]]:
        pairs: set[tuple[int, int]] = set()
        for m in self.members:
            pairs.update(m.pod_pairs())
        return sorted(pairs)

    def traffic_matrix(self) -> np.ndarray:
        """Weight-averaged union traffic matrix (what a TM-based robust
        baseline would see)."""
        tm = np.zeros((self.cluster.num_pods,) * 2)
        for w, m in zip(self.weights, self.members):
            tm += w * m.traffic_matrix()
        return tm

    # -------------------------------------------------------------- profiles
    def ideal_makespans(self) -> np.ndarray:
        """Per-member makespan on an ideal non-blocking network (the NCT
        denominators; a lower bound on any ref used for regret)."""
        from repro.core.des import DESProblem, simulate  # no import cycle

        out = np.empty(self.num_members)
        P = self.cluster.num_pods
        for i, m in enumerate(self.members):
            res = simulate(DESProblem(m), np.zeros((P, P)), ideal=True)
            out[i] = res.makespan
        return out

    def summary(self) -> dict:
        return {
            "members": {
                name: {"weight": float(w), **dag.summary()}
                for name, w, dag in self
            },
            "num_pods": self.cluster.num_pods,
            "union_pairs": len(self.undirected_pairs()),
            "total_volume_gb": float(self.traffic_matrix().sum() / 1e9),
        }


def merge_parallel_deps(deps: Iterable[Dep]) -> list[Dep]:
    """Keep only the max-delta edge for duplicated (pre, succ) pairs."""
    best: dict[tuple[int, int], float] = {}
    for d in deps:
        key = (d.pre, d.succ)
        if key not in best or d.delta > best[key]:
            best[key] = d.delta
    return [Dep(p, s, dl) for (p, s), dl in sorted(best.items())]
