"""Discrete-event simulator for inter-pod communication DAGs (numpy ref).

This is the lightweight DES engine of paper Sec. IV-B: it chronologically
executes the reduced inter-pod DAG over a *fixed* logical topology, resolving
bandwidth contention with weighted max-min fair sharing (the conventional
fair-share policy of Eq. 17), and yields

  * per-task start/completion times (S_m, C_m) and the iteration makespan C,
  * the event timeline (the variable-length intervals of the MILP -- the DES
    trace is isomorphic to the MILP's event-driven formulation),
  * the critical path and the Normalized Communication Time (NCT) inputs.

Rate semantics (fluid model):
  per-flow rate phi_m, task rate r_m = F_m * phi_m, subject to
    link (i,j):  sum_{m in M_ij} r_m              <= x_ij * B       (Eq. 9)
    NIC class :  sum_{m at GPU g} phi_m           <= B              (Eq. 10)
  `ideal=True` drops the link constraints (ideal non-blocking electrical
  network), which defines the NCT denominator.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.dag import VIRTUAL, CommDAG

INF = float("inf")


# --------------------------------------------------------------------- setup
class DESProblem:
    """Precomputed arrays for repeated simulation of one CommDAG."""

    def __init__(self, dag: CommDAG):
        self.dag = dag
        n = dag.num_tasks
        self.n = n
        self.volume = dag.volumes()
        self.flows = dag.flows()
        self.B = dag.cluster.nic_bandwidth

        # ordered pod pairs with traffic
        self.pairs = dag.pod_pairs()
        parr = np.asarray(self.pairs, dtype=np.int64).reshape(-1, 2)
        self.pair_src = parr[:, 0]
        self.pair_dst = parr[:, 1]
        self.pair_index = {p: i for i, p in enumerate(self.pairs)}
        self.task_pair = np.full(n, -1, dtype=np.int64)
        for t in dag.real_tasks():
            self.task_pair[t.tid] = self.pair_index[t.pair]

        # dependency CSR (by successor)
        pre, succ, delta = dag.dep_arrays()
        order = np.argsort(succ, kind="stable")
        self.dep_pre = pre[order]
        self.dep_succ = succ[order]
        self.dep_delta = delta[order]
        self.pred_ptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(self.pred_ptr, self.dep_succ + 1, 1)
        self.pred_ptr = np.cumsum(self.pred_ptr)
        self.indegree = np.diff(self.pred_ptr)

        # successor CSR (by predecessor) for readiness propagation
        order2 = np.argsort(pre, kind="stable")
        self.succ_ptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(self.succ_ptr, pre[order2] + 1, 1)
        self.succ_ptr = np.cumsum(self.succ_ptr)
        self.succ_tid = succ[order2]
        self.succ_delta = delta[order2]

        # constraints: [links..., nic_src..., nic_dst...] as incidence CSR
        members: list[np.ndarray] = []
        weights: list[np.ndarray] = []
        tasks_on = dag.tasks_on_pair()
        for p in self.pairs:
            tids = np.array(tasks_on[p], dtype=np.int64)
            members.append(tids)
            weights.append(self.flows[tids])          # r = F * phi
        self.num_link_cons = len(self.pairs)
        src_classes, dst_classes = dag.nic_classes()
        for tids, _ in src_classes + dst_classes:
            arr = np.array(tids, dtype=np.int64)
            members.append(arr)
            weights.append(np.ones(len(arr)))
        self.num_cons = len(members)
        self.con_ptr = np.zeros(self.num_cons + 1, dtype=np.int64)
        for i, mm in enumerate(members):
            self.con_ptr[i + 1] = self.con_ptr[i] + len(mm)
        self.con_task = np.concatenate(members) if members else \
            np.zeros(0, dtype=np.int64)
        self.con_w = np.concatenate(weights) if weights else np.zeros(0)

    def link_caps(self, x: np.ndarray, ideal: bool = False) -> np.ndarray:
        """Capacity vector for all constraints given topology matrix x."""
        caps = np.full(self.num_cons, float(self.B))
        if ideal:
            caps[:self.num_link_cons] = INF
        else:
            caps[:self.num_link_cons] = np.asarray(x)[
                self.pair_src, self.pair_dst].astype(np.float64) * self.B
        return caps


def maxmin_fair_rates(problem: DESProblem, active: np.ndarray,
                      caps: np.ndarray) -> np.ndarray:
    """Weighted max-min fair per-flow rates phi for the active tasks.

    Progressive filling: raise phi uniformly for all unfrozen active tasks
    until a constraint saturates; freeze its tasks; repeat.
    Returns task rates r_m = F_m * phi_m (0 for inactive tasks).
    """
    n = problem.n
    phi = np.zeros(n)
    unfrozen = active.copy()
    ct, cw, cp = problem.con_task, problem.con_w, problem.con_ptr
    act_w = np.where(active[ct], cw, 0.0)

    for _ in range(problem.num_cons + 1):
        if not unfrozen.any():
            break
        unf_w = np.where(unfrozen[ct], cw, 0.0)
        used = np.add.reduceat(act_w * phi[ct], cp[:-1]) \
            if len(ct) else np.zeros(0)
        denom = np.add.reduceat(unf_w, cp[:-1]) if len(ct) else np.zeros(0)
        # reduceat on empty segments returns the next element; zero them out
        empty = cp[:-1] == cp[1:]
        used[empty] = 0.0
        denom[empty] = 0.0
        slack = caps - used
        with np.errstate(divide="ignore", invalid="ignore"):
            alpha_c = np.where(denom > 0, slack / denom, INF)
        alpha = alpha_c.min() if len(alpha_c) else INF
        if not np.isfinite(alpha):
            break
        alpha = max(alpha, 0.0)
        phi[unfrozen] += alpha
        # freeze members of (near-)saturated constraints
        sat = np.isfinite(alpha_c) & (alpha_c <= alpha * (1 + 1e-9) + 1e-18)
        if not sat.any():
            break
        for ci in np.nonzero(sat)[0]:
            unfrozen[ct[cp[ci]:cp[ci + 1]]] = False
    return problem.flows * phi * active


# -------------------------------------------------------------------- result
@dataclass
class DESResult:
    start: np.ndarray
    finish: np.ndarray
    makespan: float
    feasible: bool
    events: np.ndarray                 # sorted state-transition times
    task_interval: np.ndarray          # (n, 2) [k_start, k_end] 1-based
    critical_path: list[int] = field(default_factory=list)
    crit_delta: float = 0.0
    rate_trace: list[tuple[float, float, np.ndarray]] = field(
        default_factory=list)

    @property
    def comm_time(self) -> float:
        """Inter-pod communication time on the critical path."""
        return self.makespan - self.crit_delta

    @property
    def num_intervals(self) -> int:
        return max(len(self.events) - 1, 0)


# ----------------------------------------------------------------- simulate
def simulate(problem: DESProblem, x: np.ndarray, ideal: bool = False,
             record_rates: bool = False, max_events: int | None = None
             ) -> DESResult:
    """Run the DES for topology matrix x (symmetric, circuits per pair)."""
    n = problem.n
    caps = problem.link_caps(np.asarray(x), ideal=ideal)
    rem = problem.volume.copy()
    start = np.full(n, INF)
    finish = np.full(n, INF)
    ready_at = np.full(n, INF)
    missing = problem.indegree.copy()
    started = np.zeros(n, dtype=bool)
    done = np.zeros(n, dtype=bool)

    def complete(m: int, t: float) -> None:
        done[m] = True
        finish[m] = t
        lo, hi = problem.succ_ptr[m], problem.succ_ptr[m + 1]
        for k in range(lo, hi):
            s = problem.succ_tid[k]
            missing[s] -= 1
            if missing[s] == 0 and not started[s]:
                # all predecessors done: exact ready time is the max lag
                lo2, hi2 = problem.pred_ptr[s], problem.pred_ptr[s + 1]
                ready_at[s] = max(
                    finish[problem.dep_pre[j]] + problem.dep_delta[j]
                    for j in range(lo2, hi2))

    # virtual source completes at t = 0
    t = 0.0
    start[VIRTUAL] = 0.0
    started[VIRTUAL] = True
    complete(VIRTUAL, 0.0)
    # tasks with no predecessors at all start at 0 (defensive; normally the
    # virtual task precedes everything)
    for m in range(1, n):
        if problem.indegree[m] == 0:
            ready_at[m] = 0.0

    events = [0.0]
    trace: list[tuple[float, float, np.ndarray]] = []
    limit = max_events or (4 * n + 8)
    feasible = True

    for _ in range(limit):
        # start every task whose ready time has arrived
        newly = (~started) & (missing == 0) & (ready_at <= t + 1e-15)
        if newly.any():
            idx = np.nonzero(newly)[0]
            started[idx] = True
            start[idx] = np.maximum(ready_at[idx], 0.0)
            # zero-volume tasks complete instantly
            for m in idx:
                if rem[m] <= 0.0:
                    complete(m, t)
        if done.all():
            break
        active = started & ~done
        if active.any():
            rates = maxmin_fair_rates(problem, active, caps)
            act_idx = np.nonzero(active)[0]
            if (rates[act_idx] <= 0).any():
                feasible = False  # disconnected pair under this topology
                break
            dt_done = rem[act_idx] / rates[act_idx]
            t_complete = t + dt_done.min()
        else:
            rates = np.zeros(n)
            t_complete = INF
        pending = (~started) & (missing == 0)
        t_ready = ready_at[pending].min() if pending.any() else INF
        t_next = min(t_complete, t_ready)
        if not np.isfinite(t_next):
            feasible = False  # deadlock: nothing active, nothing ready
            break
        if record_rates and active.any():
            trace.append((t, t_next, rates.copy()))
        dt = t_next - t
        if active.any() and dt > 0:
            rem[active] = np.maximum(rem[active] - rates[active] * dt, 0.0)
        t = t_next
        if t > events[-1] + 1e-15:
            events.append(t)
        # completions: active tasks whose remaining volume hit zero
        for m in np.nonzero(active)[0]:
            if rem[m] <= 1e-9 * max(problem.volume[m], 1.0):
                rem[m] = 0.0
                complete(m, t)
    else:
        feasible = False

    makespan = float(np.nanmax(np.where(np.isfinite(finish), finish, np.nan))) \
        if feasible else INF
    ev = np.array(events)
    task_interval = _intervals_of(ev, start, finish, n)
    crit, crit_delta = ([], 0.0)
    if feasible:
        crit, crit_delta = _critical_path(problem, start, finish)
    return DESResult(start=start, finish=finish, makespan=makespan,
                     feasible=feasible, events=ev,
                     task_interval=task_interval, critical_path=crit,
                     crit_delta=crit_delta, rate_trace=trace)


def _intervals_of(events: np.ndarray, start: np.ndarray, finish: np.ndarray,
                  n: int) -> np.ndarray:
    """1-based [k_start, k_end] interval indices of each task's active span.

    Interval k (1-based) spans [events[k-1], events[k]].
    """
    out = np.zeros((n, 2), dtype=np.int64)
    if len(events) < 2:
        return out
    for m in range(n):
        if not np.isfinite(start[m]) or not np.isfinite(finish[m]):
            continue
        ks = int(np.searchsorted(events, start[m] + 1e-15, side="right"))
        ke = int(np.searchsorted(events, finish[m] - 1e-15, side="left"))
        ks = min(max(ks, 1), len(events) - 1)
        ke = min(max(ke, ks), len(events) - 1)
        out[m] = (ks, ke)
    return out


def _critical_path(problem: DESProblem, start: np.ndarray,
                   finish: np.ndarray) -> tuple[list[int], float]:
    """Backtrack binding predecessors from the last-finishing task."""
    cur = int(np.argmax(np.where(np.isfinite(finish), finish, -INF)))
    path = [cur]
    delta_sum = 0.0
    guard = 0
    while cur != VIRTUAL and guard <= problem.n + 1:
        guard += 1
        lo, hi = problem.pred_ptr[cur], problem.pred_ptr[cur + 1]
        if lo == hi:
            break
        best_j, best_v = -1, -INF
        for j in range(lo, hi):
            v = finish[problem.dep_pre[j]] + problem.dep_delta[j]
            if v > best_v:
                best_v, best_j = v, j
        delta_sum += problem.dep_delta[best_j]
        cur = int(problem.dep_pre[best_j])
        path.append(cur)
    path.reverse()
    return path, delta_sum


# --------------------------------------------------------------------- NCT
@dataclass(frozen=True)
class NCTReport:
    makespan: float
    ideal_makespan: float
    comm_time: float
    ideal_comm_time: float

    @property
    def nct(self) -> float:
        if self.ideal_comm_time <= 0:
            return 1.0 if self.comm_time <= 0 else INF
        return self.comm_time / self.ideal_comm_time

    @property
    def stretch(self) -> float:
        """End-to-end slowdown vs the contention-free ideal (>= 1); the
        makespan analogue of `nct`."""
        if self.ideal_makespan <= 0:
            return 1.0 if self.makespan <= 0 else INF
        return self.makespan / self.ideal_makespan


def evaluate_nct(problem: DESProblem, x: np.ndarray,
                 ideal_result: DESResult | None = None) -> NCTReport:
    res = simulate(problem, x)
    ideal = ideal_result or simulate(problem, x, ideal=True)
    return NCTReport(makespan=res.makespan, ideal_makespan=ideal.makespan,
                     comm_time=res.comm_time,
                     ideal_comm_time=ideal.comm_time)


def makespan_of(problem: DESProblem, x: np.ndarray) -> float:
    return simulate(problem, x).makespan
