"""JAX discrete-event simulator: fixed-trip-count, vmap-able over topologies.

TPU-native adaptation of the paper's "ParallelEvalDES" (Alg. 3 line 2): the
simulator state is a pytree of fixed-shape arrays and every state transition
is one `lax.while_loop` step, so a whole GA population evaluates as a single
batched XLA computation via `jax.vmap` (instead of the paper's 4 CPU
threads).  Semantics match `repro.core.des.simulate` exactly (validated by
tests/test_des_jax.py); only makespan/feasibility/start/finish are produced
(critical-path extraction stays on the numpy engine).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.des import DESProblem

INF = jnp.inf


class DESArrays(NamedTuple):
    """Static problem arrays (all jnp) for the JAX DES."""
    volume: jax.Array          # (n,)
    flows: jax.Array           # (n,)
    dep_pre: jax.Array         # (d,)
    dep_succ: jax.Array        # (d,)
    dep_delta: jax.Array       # (d,)
    indegree: jax.Array        # (n,)
    con_task: jax.Array        # (e,) incidence: task index
    con_id: jax.Array          # (e,) incidence: constraint index
    con_w: jax.Array           # (e,) weight on phi (F_m for links, 1 for NIC)
    link_pair_a: jax.Array     # (L,) src pod per link constraint
    link_pair_b: jax.Array     # (L,) dst pod per link constraint
    num_cons: int
    num_link_cons: int
    nic_bandwidth: float
    n: int

    @classmethod
    def from_problem(cls, problem: DESProblem) -> "DESArrays":
        cp = problem.con_ptr
        con_id = np.repeat(np.arange(problem.num_cons), np.diff(cp))
        pairs = np.array(problem.pairs, dtype=np.int32).reshape(-1, 2)
        if problem.volume[1:].min(initial=np.inf) <= 0:
            raise ValueError("JAX DES requires positive real-task volumes")
        # unit rescaling: volumes in "seconds at one-circuit rate" (B == 1)
        # keeps every quantity O(1) so the simulation is accurate even when
        # jax runs in float32 (x64 disabled).
        return cls(
            volume=jnp.asarray(problem.volume / problem.B),
            flows=jnp.asarray(problem.flows),
            dep_pre=jnp.asarray(problem.dep_pre, dtype=jnp.int32),
            dep_succ=jnp.asarray(problem.dep_succ, dtype=jnp.int32),
            dep_delta=jnp.asarray(problem.dep_delta),
            indegree=jnp.asarray(problem.indegree, dtype=jnp.int32),
            con_task=jnp.asarray(problem.con_task, dtype=jnp.int32),
            con_id=jnp.asarray(con_id, dtype=jnp.int32),
            con_w=jnp.asarray(problem.con_w),
            link_pair_a=jnp.asarray(pairs[:, 0], dtype=jnp.int32),
            link_pair_b=jnp.asarray(pairs[:, 1], dtype=jnp.int32),
            num_cons=problem.num_cons,
            num_link_cons=problem.num_link_cons,
            nic_bandwidth=1.0,   # rescaled (see volume)
            n=problem.n,
        )


def _maxmin(arr: DESArrays, active: jax.Array, caps: jax.Array) -> jax.Array:
    """Weighted max-min fair task rates (progressive filling)."""
    n, C = arr.n, arr.num_cons
    # hoist the loop-invariant active-membership weights out of the filling
    # loop; `active` is fixed for the duration of one rate computation
    act_w = jnp.where(active[arr.con_task], arr.con_w, 0.0)

    def cond(state):
        i, phi, unfrozen = state
        return jnp.logical_and(i < C + 1, unfrozen.any())

    def body(state):
        i, phi, unfrozen = state
        unf_w = jnp.where(unfrozen[arr.con_task], arr.con_w, 0.0)
        # one fused segment reduction for (used, denom) instead of two
        used, denom = jax.ops.segment_sum(
            jnp.stack([act_w * phi[arr.con_task], unf_w], axis=1),
            arr.con_id, num_segments=C).T
        slack = caps - used
        alpha_c = jnp.where(denom > 0, slack / jnp.maximum(denom, 1e-300), INF)
        alpha = jnp.maximum(jnp.min(alpha_c), 0.0)
        phi = jnp.where(unfrozen, phi + alpha, phi)
        sat = jnp.isfinite(alpha_c) & (alpha_c <= alpha * (1 + 1e-9) + 1e-18)
        task_sat = jnp.zeros(n, dtype=bool).at[arr.con_task].max(
            sat[arr.con_id])
        unfrozen = unfrozen & ~task_sat
        return i + 1, phi, unfrozen

    _, phi, _ = jax.lax.while_loop(
        cond, body, (0, jnp.zeros(n), active))
    return arr.flows * phi * active


def _simulate(arr: DESArrays, x: jax.Array, ideal_flag: jax.Array,
              max_events: int) -> tuple[jax.Array, jax.Array, jax.Array,
                                        jax.Array]:
    """Returns (makespan, feasible, start, finish)."""
    n = arr.n
    B = arr.nic_bandwidth
    # cap dtype follows the simulation dtype: hard-coding float64 is a
    # silent no-op downcast to float32 under default x64-disabled jax
    link_caps = x[arr.link_pair_a, arr.link_pair_b].astype(
        arr.volume.dtype) * B
    link_caps = jnp.where(ideal_flag, INF, link_caps)
    caps = jnp.concatenate(
        [link_caps, jnp.full(arr.num_cons - arr.num_link_cons, B)])

    # initial state: virtual task 0 done at t=0
    rem = arr.volume
    started = jnp.zeros(n, dtype=bool).at[0].set(True)
    done = jnp.zeros(n, dtype=bool).at[0].set(True)
    start = jnp.full(n, INF).at[0].set(0.0)
    finish = jnp.full(n, INF).at[0].set(0.0)
    missing = arr.indegree - jax.ops.segment_sum(
        (arr.dep_pre == 0).astype(jnp.int32), arr.dep_succ, num_segments=n)
    t = jnp.array(0.0)
    feasible = jnp.array(True)

    def ready_times(missing, started, finish):
        lag = finish[arr.dep_pre] + arr.dep_delta
        ready = jnp.zeros(n).at[arr.dep_succ].max(lag)
        ok = (missing == 0) & ~started
        return jnp.where(ok, ready, INF)

    def cond(state):
        i, t, *_ , feasible = state
        return (i < max_events) & jnp.isfinite(t) & feasible

    def body(state):
        i, t, rem, started, done, start, finish, missing, feasible = state
        ready = ready_times(missing, started, finish)
        eps = 1e-6 if rem.dtype == jnp.float32 else 1e-12
        newly = ready <= t * (1 + eps) + eps * 1e-3
        started = started | newly
        start = jnp.where(newly, ready, start)
        active = started & ~done
        rates = _maxmin(arr, active, caps)
        feasible = feasible & jnp.all(jnp.where(active, rates > 0, True))
        dt_done = jnp.where(active & (rates > 0), rem / jnp.maximum(rates,
                                                                    1e-300),
                            INF)
        t_complete = t + jnp.min(dt_done)
        # tasks started this step are no longer pending: their ready entry
        # drops out without recomputing the (gather + segment-max) pass
        t_ready = jnp.min(jnp.where(newly, INF, ready))
        t_next = jnp.minimum(t_complete, t_ready)
        dt = jnp.maximum(t_next - t, 0.0)
        rem = jnp.where(active, jnp.maximum(rem - rates * dt, 0.0), rem)
        veps = 1e-5 if rem.dtype == jnp.float32 else 1e-9
        # also complete tasks whose remaining *time* is below the float time
        # resolution at t -- otherwise `t + dt == t` stalls the simulation
        teps = 1e-5 if rem.dtype == jnp.float32 else 1e-12
        dt_rem = dt_done - dt   # remaining volume / rate after the advance
        newdone = active & jnp.isfinite(t_next) & (
            (rem <= veps * jnp.maximum(arr.volume, 1e-9))
            | (dt_rem <= teps * jnp.maximum(t_next, 1e-9)))
        finish = jnp.where(newdone, t_next, finish)
        done = done | newdone
        missing = missing - jax.ops.segment_sum(
            newdone[arr.dep_pre].astype(jnp.int32), arr.dep_succ,
            num_segments=n)
        all_done = done.all()
        t_out = jnp.where(all_done, -INF, t_next)  # exit condition
        return (i + 1, t_out, rem, started, done, start, finish, missing,
                feasible)

    state = (0, t, rem, started, done, start, finish, missing, feasible)
    state = jax.lax.while_loop(cond, body, state)
    _, _, _, _, done, start, finish, _, feasible = state
    feasible = feasible & done.all()
    makespan = jnp.where(feasible, jnp.max(jnp.where(jnp.isfinite(finish),
                                                     finish, -INF)), INF)
    return makespan, feasible, start, finish


class JaxDES:
    """Convenience wrapper: single + batched simulation of a CommDAG."""

    def __init__(self, problem: DESProblem, max_events: int | None = None):
        self.problem = problem
        self.arrays = DESArrays.from_problem(problem)
        self.max_events = int(max_events or (4 * problem.n + 8))

    @functools.cached_property
    def _single(self):
        arr, me = self.arrays, self.max_events
        return jax.jit(lambda x, ideal: _simulate(arr, x, ideal, me))

    def makespan(self, x, ideal: bool = False) -> float:
        ms, _, _, _ = self._single(jnp.asarray(x), jnp.asarray(ideal))
        return float(ms)

    def simulate(self, x, ideal: bool = False):
        ms, feas, start, finish = self._single(jnp.asarray(x),
                                               jnp.asarray(ideal))
        return (float(ms), bool(feas), np.asarray(start), np.asarray(finish))

    @functools.cached_property
    def _batched(self):
        arr, me = self.arrays, self.max_events
        return jax.jit(jax.vmap(
            lambda x: _simulate(arr, x, jnp.asarray(False), me)[:2]))

    def batch_makespan(self, xs) -> tuple[np.ndarray, np.ndarray]:
        """Makespans + feasibility for a (pop, P, P) batch of topologies."""
        ms, feas = self._batched(jnp.asarray(xs))
        return np.asarray(ms), np.asarray(feas)

    @functools.cached_property
    def _batched_genomes(self):
        arr, me = self.arrays, self.max_events
        P = self.problem.dag.cluster.num_pods

        def one(g, eu, ev):
            x = jnp.zeros((P, P), dtype=g.dtype)
            x = x.at[eu, ev].set(g).at[ev, eu].set(g)
            return _simulate(arr, x, jnp.asarray(False), me)[:2]

        return jax.jit(jax.vmap(one, in_axes=(0, None, None)))

    def batch_genome_makespan(self, genomes, edge_u, edge_v
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Fused GA generation-step fitness: scatter a (pop, E) genome batch
        onto (pop, P, P) topologies *on device* and simulate, all in one
        jitted call -- one host->device transfer for the genomes, one
        device->host for (makespan, feasible), independent of pop size."""
        ms, feas = self._batched_genomes(
            jnp.asarray(genomes),
            jnp.asarray(edge_u, dtype=jnp.int32),
            jnp.asarray(edge_v, dtype=jnp.int32))
        return np.asarray(ms), np.asarray(feas)
