"""JAX discrete-event simulator: fixed-trip-count, vmap-able over topologies.

TPU-native adaptation of the paper's "ParallelEvalDES" (Alg. 3 line 2): the
simulator state is a pytree of fixed-shape arrays and every state transition
is one `lax.while_loop` step, so a whole GA population evaluates as a single
batched XLA computation via `jax.vmap` (instead of the paper's 4 CPU
threads).  Semantics match `repro.core.des.simulate` exactly (validated by
tests/test_des_jax.py); only makespan/feasibility/start/finish are produced
(critical-path extraction stays on the numpy engine).

Three layers make repeated evaluation cheap (paper Sec. V's dual-track
acceleration argument only pays off when per-evaluation cost is flat):

  * the event loop advances to the next *distinct* event time each trip and
    retires every completion AND every start landing there in one step, so
    the trip count is bounded by distinct event times (<= 2n + eps), not by
    a per-task event budget;
  * the inner max-min fair-share rounds run their fused (used, denom)
    reduction pair through `repro.kernels.waterfill` (Pallas on TPU, dense
    jnp `ref` oracle as the CPU/interpret fallback, the legacy segment-sum
    path kept as `backend='segment'`), selectable via `DESOptions` or
    ``REPRO_DES_BACKEND``;
  * problems are padded up to quantized (tasks, deps, incidence, links)
    buckets and the jitted entry points live in a module-level LRU keyed by
    the bucket signature, so fleet replans, ensemble members, and trim
    candidates whose problems land in an existing bucket reuse compiled
    executables instead of re-jitting per `JaxDES(...)` instance (cache
    hit/miss counters: `des_cache_stats()`).

Bucket padding reuses the ensemble ghost semantics (`stack_problems`):
ghost tasks are born done, ghost deps target the virtual task, ghost
incidence entries carry zero weight, so padded results are identical to
the exact-shape simulation up to float summation order.
"""
from __future__ import annotations

import functools
import math
import os
from collections import OrderedDict
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.des import DESProblem
from repro.obs import get_counter, get_gauge, get_logger, span

INF = jnp.inf

_log = get_logger("repro.des_jax")

# compile-cache accounting lives in the shared metrics registry so callers
# (e.g. a FleetPlanner) can read *scoped* deltas instead of process-wide
# totals; `des_cache_stats()` stays the dict-shaped view of the same series
_HITS = get_counter("des_compile_hits_total",
                    "simulator constructions reusing a compiled bucket")
_MISSES = get_counter("des_compile_miss_total",
                      "simulator constructions forcing an XLA recompile")
_EVICTIONS = get_counter("des_compile_evictions_total",
                         "compile-cache LRU evictions")
_ENTRIES = get_gauge("des_compile_cache_entries",
                     "live compile-cache buckets")

MAXMIN_BACKENDS = ("auto", "pallas", "ref", "segment")


# ------------------------------------------------------------------ options
@dataclass(frozen=True)
class DESOptions:
    """Engine knobs for `JaxDES`/`EnsembleJaxDES`.

    Every ``None`` field resolves from the environment (so benchmarks and
    fleet deployments can flip backends without code changes):

      backend            $REPRO_DES_BACKEND or 'auto'
                         ('auto' -> 'pallas' on TPU, 'ref' elsewhere;
                          'segment' keeps the pre-kernel segment-sum path)
      interpret          Pallas interpret mode ('auto': on iff not on TPU)
      bucket             $REPRO_DES_BUCKET != '0'   (default on)
      bucket_quantum     $REPRO_DES_BUCKET_QUANTUM  (default 64; tasks,
                         deps and incidence entries round up to this)
      bucket_quantum_cons $REPRO_DES_BUCKET_QUANTUM_CONS (default 8; the
                         link and NIC constraint blocks round up to this)

    `warn_on_miss` logs a warning whenever constructing the simulator lands
    in a new compile bucket (an XLA recompile); the fleet loop sets it so
    jit churn inside online replanning is visible in benchmark logs.
    """

    backend: str | None = None
    interpret: bool | None = None
    bucket: bool | None = None
    bucket_quantum: int | None = None
    bucket_quantum_cons: int | None = None
    warn_on_miss: bool = False

    def resolve(self) -> "ResolvedDESOptions":
        backend = self.backend or os.environ.get(
            "REPRO_DES_BACKEND", "").strip() or "auto"
        if backend not in MAXMIN_BACKENDS:
            raise ValueError(f"unknown DES backend {backend!r}; "
                             f"pick from {MAXMIN_BACKENDS}")
        on_tpu = jax.default_backend() == "tpu"
        if backend == "auto":
            backend = "pallas" if on_tpu else "ref"
        interpret = self.interpret if self.interpret is not None \
            else not on_tpu
        bucket = self.bucket if self.bucket is not None \
            else os.environ.get("REPRO_DES_BUCKET", "1") != "0"
        q = int(self.bucket_quantum
                or os.environ.get("REPRO_DES_BUCKET_QUANTUM", "64"))
        qc = int(self.bucket_quantum_cons
                 or os.environ.get("REPRO_DES_BUCKET_QUANTUM_CONS", "8"))
        return ResolvedDESOptions(backend=backend, interpret=bool(interpret),
                                  bucket=bool(bucket), quantum=max(q, 1),
                                  quantum_cons=max(qc, 1),
                                  warn_on_miss=self.warn_on_miss)


@dataclass(frozen=True)
class ResolvedDESOptions:
    backend: str
    interpret: bool
    bucket: bool
    quantum: int
    quantum_cons: int
    warn_on_miss: bool


class PadSpec(NamedTuple):
    """Padded array sizes: tasks, deps, incidence entries, link constraints
    and total constraints (links + NIC classes, by position in `caps`)."""
    n: int
    d: int
    e: int
    links: int
    cons: int

    @classmethod
    def exact(cls, p: DESProblem) -> "PadSpec":
        return cls(n=p.n, d=len(p.dep_pre), e=len(p.con_task),
                   links=p.num_link_cons, cons=p.num_cons)

    def bucketed(self, opt: ResolvedDESOptions) -> "PadSpec":
        q, qc = opt.quantum, opt.quantum_cons
        links = _round_up(self.links, qc)
        return PadSpec(n=_round_up(self.n, q), d=_round_up(self.d, q),
                       e=_round_up(self.e, q), links=links,
                       cons=links + _round_up(self.cons - self.links, qc))


def _round_up(v: int, q: int) -> int:
    return int(math.ceil(max(int(v), 1) / q) * q)


def default_max_events(n: int) -> int:
    """Safety bound on event-loop trips: every trip retires at least one
    start or one completion event (see `_simulate`), and each task does
    each exactly once."""
    return 2 * int(n) + 16


class DESArrays(NamedTuple):
    """Static problem arrays (all jnp) for the JAX DES."""
    volume: jax.Array          # (n,)
    flows: jax.Array           # (n,)
    dep_pre: jax.Array         # (d,)
    dep_succ: jax.Array        # (d,)
    dep_delta: jax.Array       # (d,)
    indegree: jax.Array        # (n,)
    con_task: jax.Array        # (e,) incidence: task index
    con_id: jax.Array          # (e,) incidence: constraint index
    con_w: jax.Array           # (e,) weight on phi (F_m for links, 1 for NIC)
    link_pair_a: jax.Array     # (L,) src pod per link constraint
    link_pair_b: jax.Array     # (L,) dst pod per link constraint
    task_valid: jax.Array    # (n,) False for padding ghost tasks
    num_cons: int
    num_link_cons: int
    nic_bandwidth: float
    n: int

    @classmethod
    def from_problem(cls, problem: DESProblem,
                     pad: PadSpec | None = None) -> "DESArrays":
        pad = pad or PadSpec.exact(problem)
        fields = _problem_fields(problem, pad)
        return cls(**{k: jnp.asarray(v) for k, v in fields.items()},
                   num_cons=pad.cons, num_link_cons=pad.links,
                   nic_bandwidth=1.0,   # rescaled (see volume)
                   n=pad.n)


def _pad_to(a: np.ndarray, size: int, fill) -> np.ndarray:
    """Right-pad a 1-D array to `size` with `fill`."""
    a = np.asarray(a)
    if len(a) == size:
        return a
    out = np.full(size, fill, dtype=a.dtype)
    out[:len(a)] = a
    return out


def _problem_fields(p: DESProblem, pad: PadSpec) -> dict[str, np.ndarray]:
    """One problem's DES arrays padded to `pad` with ghost semantics.

      * ghost tasks: volume 0, flows 1, `task_valid` False -- born done,
        never scheduled (see `_simulate`);
      * ghost deps: (0 -> 0, delta 0) -- target the virtual task, which is
        done at t=0, so they never gate readiness;
      * ghost incidence entries: (task 0, constraint 0, weight 0) -- zero
        contribution to every used/denom reduction;
      * ghost link constraints: pair (0, 0) -- capacity x[0,0] * B == 0
        with no members, never binding;
      * ghost NIC constraints: capacity B with no members, never binding.

    Constraint ids are remapped so the NIC block starts at the padded link
    count (the caps vector in `_simulate` is [links..., NICs...] by
    position).  Unit rescaling: volumes in "seconds at one-circuit rate"
    (B == 1) keeps every quantity O(1) so the simulation stays accurate in
    float32 (x64 disabled).
    """
    cp = p.con_ptr
    con_id = np.repeat(np.arange(p.num_cons), np.diff(cp))
    con_id = np.where(con_id >= p.num_link_cons,
                      con_id + (pad.links - p.num_link_cons), con_id)
    pairs = np.array(p.pairs, dtype=np.int32).reshape(-1, 2)
    if p.volume[1:].min(initial=np.inf) <= 0:
        raise ValueError("JAX DES requires positive real-task volumes")
    return {
        "volume": _pad_to(p.volume / p.B, pad.n, 0.0),
        "flows": _pad_to(p.flows, pad.n, 1.0),
        "dep_pre": _pad_to(p.dep_pre.astype(np.int32), pad.d, 0),
        "dep_succ": _pad_to(p.dep_succ.astype(np.int32), pad.d, 0),
        "dep_delta": _pad_to(p.dep_delta, pad.d, 0.0),
        "indegree": _pad_to(p.indegree.astype(np.int32), pad.n, 0),
        "con_task": _pad_to(p.con_task.astype(np.int32), pad.e, 0),
        "con_id": _pad_to(con_id.astype(np.int32), pad.e, 0),
        "con_w": _pad_to(p.con_w, pad.e, 0.0),
        "link_pair_a": _pad_to(pairs[:, 0], pad.links, 0),
        "link_pair_b": _pad_to(pairs[:, 1], pad.links, 0),
        "task_valid": _pad_to(np.ones(p.n, dtype=bool), pad.n, False),
    }


# --------------------------------------------------------- fair-share rates
def _dense_incidence(arr: DESArrays) -> jax.Array:
    """(C, n) constraint-task weight matrix for the kernel backends (ghost
    incidence entries scatter zero weight)."""
    return jnp.zeros((arr.num_cons, arr.n), dtype=arr.con_w.dtype) \
        .at[arr.con_id, arr.con_task].add(arr.con_w)


def _maxmin(arr: DESArrays, active: jax.Array, caps: jax.Array,
            backend: str = "segment", interpret: bool = False,
            W: jax.Array | None = None) -> jax.Array:
    """Weighted max-min fair task rates (progressive filling).

    Each filling round needs, per constraint c, the fused reduction pair
    ``used_c = sum_m W[c,m] phi_m active_m`` / ``denom_c = sum_m W[c,m]
    unfrozen_m``.  Backend 'segment' computes it as one stacked
    `segment_sum` over the incidence entries; 'pallas'/'ref' stream the
    dense incidence matrix through `repro.kernels.waterfill.fill_round`
    (one MXU pass for both right-hand sides on TPU, a dense jnp matmul on
    the ref oracle)."""
    n, C = arr.n, arr.num_cons
    dense = backend != "segment"
    if dense and W is None:
        W = _dense_incidence(arr)
    if dense:
        from repro.kernels import ops
        active_f = active.astype(caps.dtype)

    # hoist the loop-invariant active-membership weights out of the filling
    # loop; `active` is fixed for the duration of one rate computation
    act_w = jnp.where(active[arr.con_task], arr.con_w, 0.0)

    def cond(state):
        i, phi, unfrozen = state
        return jnp.logical_and(i < C + 1, unfrozen.any())

    def body(state):
        i, phi, unfrozen = state
        if dense:
            used, denom = ops.fill_round(W, phi * active_f,
                                         unfrozen.astype(caps.dtype),
                                         backend=backend,
                                         interpret=interpret)
        else:
            unf_w = jnp.where(unfrozen[arr.con_task], arr.con_w, 0.0)
            # one fused segment reduction for (used, denom) instead of two
            used, denom = jax.ops.segment_sum(
                jnp.stack([act_w * phi[arr.con_task], unf_w], axis=1),
                arr.con_id, num_segments=C).T
        slack = caps - used
        alpha_c = jnp.where(denom > 0, slack / jnp.maximum(denom, 1e-300), INF)
        alpha = jnp.maximum(jnp.min(alpha_c), 0.0)
        phi = jnp.where(unfrozen, phi + alpha, phi)
        sat = jnp.isfinite(alpha_c) & (alpha_c <= alpha * (1 + 1e-9) + 1e-18)
        task_sat = jnp.zeros(n, dtype=bool).at[arr.con_task].max(
            sat[arr.con_id])
        unfrozen = unfrozen & ~task_sat
        return i + 1, phi, unfrozen

    _, phi, _ = jax.lax.while_loop(
        cond, body, (0, jnp.zeros(n), active))
    return arr.flows * phi * active


# --------------------------------------------------------------- event loop
class _StaticCfg(NamedTuple):
    """Hashable trace-static DES configuration (one compile bucket)."""
    n: int
    num_cons: int
    num_link_cons: int
    P: int
    max_events: int
    backend: str
    interpret: bool
    members: int            # 0 = single problem, M = stacked ensemble


def _simulate(arr: DESArrays, x: jax.Array, ideal_flag: jax.Array,
              mask: jax.Array, max_events: int, backend: str = "segment",
              interpret: bool = False) -> tuple[jax.Array, jax.Array,
                                                jax.Array, jax.Array]:
    """Returns (makespan, feasible, start, finish).

    Event-retirement loop: every trip computes the active fair-share rates
    once, advances to the next distinct event time, and retires *all*
    events landing there -- every completion inside the float-coalescing
    band around `t_next` and every start whose (post-completion) ready
    time has arrived.  Each trip therefore retires at least one start or
    completion, bounding the trip count by the number of distinct event
    times (`default_max_events`), independent of how many tasks share one.

    ``mask`` is the (P, P) per-link availability factor (1 = healthy,
    0 = dark, fractional = partially failed plane set).  It multiplies the
    link capacities only -- NIC caps are pod-local and unaffected -- and is
    a *traced* operand, so pricing a failure never leaves the compile
    bucket the healthy plan was jitted into.
    """
    n = arr.n
    B = arr.nic_bandwidth
    # cap dtype follows the simulation dtype: hard-coding float64 is a
    # silent no-op downcast to float32 under default x64-disabled jax
    link_caps = x[arr.link_pair_a, arr.link_pair_b].astype(
        arr.volume.dtype) * mask[arr.link_pair_a, arr.link_pair_b].astype(
        arr.volume.dtype) * B
    link_caps = jnp.where(ideal_flag, INF, link_caps)
    caps = jnp.concatenate(
        [link_caps, jnp.full(arr.num_cons - arr.num_link_cons, B)])
    # dense incidence for the kernel backends, built once per simulation
    # (one scatter) and reused by every fair-share round of every event
    W = _dense_incidence(arr) if backend != "segment" else None

    eps = 1e-6 if arr.volume.dtype == jnp.float32 else 1e-12
    veps = 1e-5 if arr.volume.dtype == jnp.float32 else 1e-9
    # tasks whose remaining *time* is below the float time resolution at t
    # complete too -- otherwise `t + dt == t` stalls the simulation
    teps = 1e-5 if arr.volume.dtype == jnp.float32 else 1e-12

    def retire_starts(t_now, started, finish, missing):
        """Start every pending task whose ready time has arrived at
        `t_now`; returns the next pending ready time as well."""
        lag = finish[arr.dep_pre] + arr.dep_delta
        ready = jnp.zeros(n).at[arr.dep_succ].max(lag)
        ready = jnp.where((missing == 0) & ~started, ready, INF)
        newly = ready <= t_now * (1 + eps) + eps * 1e-3
        t_ready = jnp.min(jnp.where(newly, INF, ready))
        return started | newly, newly, ready, t_ready

    # initial state: virtual task 0 done at t=0.  Padding ghost tasks
    # (task_valid False -- bucket padding or ensemble members stacked to a
    # common shape) are born done with finish 0, so they never contend,
    # never gate readiness and never contribute to the makespan.
    rem = arr.volume
    started = jnp.logical_not(arr.task_valid).at[0].set(True)
    done = started
    start = jnp.where(started, 0.0, INF)
    finish = start
    missing = arr.indegree - jax.ops.segment_sum(
        (arr.dep_pre == 0).astype(jnp.int32), arr.dep_succ, num_segments=n)
    # retire the t=0 start events before the loop
    started, newly, ready, t_ready = retire_starts(0.0, started, finish,
                                                   missing)
    start = jnp.where(newly, ready, start)
    feasible = jnp.array(True)

    def cond(state):
        i, t, *_ , feasible = state
        return (i < max_events) & jnp.isfinite(t) & feasible

    def body(state):
        (i, t, t_ready, rem, started, done, start, finish, missing,
         feasible) = state
        active = started & ~done
        rates = _maxmin(arr, active, caps, backend, interpret, W)
        feasible = feasible & jnp.all(jnp.where(active, rates > 0, True))
        dt_done = jnp.where(active & (rates > 0), rem / jnp.maximum(rates,
                                                                    1e-300),
                            INF)
        t_complete = t + jnp.min(dt_done)
        t_next = jnp.minimum(t_complete, t_ready)
        dt = jnp.maximum(t_next - t, 0.0)
        rem = jnp.where(active, jnp.maximum(rem - rates * dt, 0.0), rem)
        dt_rem = dt_done - dt   # remaining volume / rate after the advance
        newdone = active & jnp.isfinite(t_next) & (
            (rem <= veps * jnp.maximum(arr.volume, 1e-9))
            | (dt_rem <= teps * jnp.maximum(t_next, 1e-9)))
        finish = jnp.where(newdone, t_next, finish)
        done = done | newdone
        missing = missing - jax.ops.segment_sum(
            newdone[arr.dep_pre].astype(jnp.int32), arr.dep_succ,
            num_segments=n)
        # retire the start events at t_next in the same trip (readiness
        # recomputed against the post-completion finish/missing state)
        started, newly, ready, t_ready = retire_starts(t_next, started,
                                                       finish, missing)
        start = jnp.where(newly, ready, start)
        all_done = done.all()
        t_out = jnp.where(all_done, -INF, t_next)  # exit condition
        return (i + 1, t_out, t_ready, rem, started, done, start, finish,
                missing, feasible)

    state = (0, jnp.array(0.0), t_ready, rem, started, done, start, finish,
             missing, feasible)
    state = jax.lax.while_loop(cond, body, state)
    _, _, _, _, _, done, start, finish, _, feasible = state
    feasible = feasible & done.all()
    makespan = jnp.where(feasible, jnp.max(jnp.where(jnp.isfinite(finish),
                                                     finish, -INF)), INF)
    return makespan, feasible, start, finish


# ------------------------------------------------- compiled-executable LRU
# array-valued DESArrays leaves: everything before the first static field,
# derived from the NamedTuple itself so a future field insertion/reorder
# cannot silently misalign the leaves <-> statics reassembly
_ARRAY_FIELDS = DESArrays._fields[:DESArrays._fields.index("num_cons")]


class CompiledDES:
    """Lazily-built jitted entry points for one compile bucket.

    Shared by every `JaxDES`/`EnsembleJaxDES` whose padded problem lands in
    the bucket: the jitted callables close over only the static `_StaticCfg`
    and take the problem arrays as arguments, so XLA compiles each entry
    point once per bucket (batch-size variations are handled by jax's own
    per-shape cache on the same callable)."""

    def __init__(self, cfg: _StaticCfg):
        self.cfg = cfg

    def _rebuild(self, leaves: tuple) -> DESArrays:
        cfg = self.cfg
        return DESArrays(*leaves, num_cons=cfg.num_cons,
                         num_link_cons=cfg.num_link_cons,
                         nic_bandwidth=1.0, n=cfg.n)

    def _run(self, leaves, x, ideal, mask):
        cfg = self.cfg
        return _simulate(self._rebuild(leaves), x, ideal, mask,
                         cfg.max_events, cfg.backend, cfg.interpret)

    def _scatter(self, g, eu, ev):
        P = self.cfg.P
        x = jnp.zeros((P, P), dtype=g.dtype)
        return x.at[eu, ev].set(g).at[ev, eu].set(g)

    def _traced(self, entry: str, fn):
        """First-call `des.jit` span around a jitted entry point: the
        first invocation pays trace + XLA compile, so its duration IS the
        jit cost the benchmark span summaries separate from steady-state
        simulate time.  (Later batch-shape recompiles inside jax's own
        per-shape cache are not individually distinguished.)"""
        cfg = self.cfg
        state = {"first": True}

        def wrapper(*args):
            if state["first"]:
                state["first"] = False
                with span("des.jit", entry=entry, n=cfg.n,
                          members=cfg.members, backend=cfg.backend):
                    return fn(*args)
            return fn(*args)
        return wrapper

    @functools.cached_property
    def single(self):
        return self._traced("single", jax.jit(self._run))

    @functools.cached_property
    def batch_x(self):
        def f(leaves, xs, mask):
            return jax.vmap(
                lambda x: self._run(leaves, x, jnp.asarray(False),
                                    mask)[:2])(xs)
        return self._traced("batch_x", jax.jit(f))

    @functools.cached_property
    def batch_genomes(self):
        def f(leaves, genomes, eu, ev, mask):
            def one(g):
                return self._run(leaves, self._scatter(g, eu, ev),
                                 jnp.asarray(False), mask)[:2]
            return jax.vmap(one)(genomes)
        return self._traced("batch_genomes", jax.jit(f))

    @functools.cached_property
    def ensemble_genomes(self):
        # masks carries a leading member axis (M, P, P): the robust path
        # passes jnp.ones, the k-failure objective one failure scenario
        # per stacked member -- same compiled executable either way
        def one_member(leaves, x, mask):
            return self._run(leaves, x, jnp.asarray(False), mask)[:2]

        def one_genome(leaves, g, eu, ev, masks):
            x = self._scatter(g, eu, ev)
            return jax.vmap(one_member, in_axes=(0, None, 0))(leaves, x,
                                                              masks)

        return self._traced(
            "ensemble_genomes",
            jax.jit(jax.vmap(one_genome,
                             in_axes=(None, 0, None, None, None))))


_COMPILE_CACHE: OrderedDict[tuple, CompiledDES] = OrderedDict()


def _cache_max() -> int:
    return int(os.environ.get("REPRO_DES_CACHE_SIZE", "64"))


def des_cache_stats() -> dict:
    """Module-level compile-cache counters: `hits` are simulator
    constructions that reused an existing bucket's jitted executables,
    `misses` forced a fresh XLA compile.  Backed by the `repro.obs`
    registry (`des_compile_*` series), so planner-scoped deltas are
    available via `REGISTRY.scope()`."""
    return {"hits": int(_HITS.value()), "misses": int(_MISSES.value()),
            "evictions": int(_EVICTIONS.value()),
            "entries": len(_COMPILE_CACHE)}


def des_cache_clear() -> None:
    _COMPILE_CACHE.clear()
    for c in (_HITS, _MISSES, _EVICTIONS):
        c.reset()
    _ENTRIES.set(0)


def _compiled_for(cfg: _StaticCfg, pad: PadSpec,
                  warn_on_miss: bool = False) -> CompiledDES:
    key = (cfg, pad.d, pad.e)
    ent = _COMPILE_CACHE.get(key)
    if ent is not None:
        _HITS.inc()
        _COMPILE_CACHE.move_to_end(key)
        return ent
    # jit churn: every miss increments des_compile_miss_total whether or
    # not the caller opted into the warning, so the counter is the one
    # authoritative recompile signal (the log line is just its echo)
    _MISSES.inc()
    if warn_on_miss:
        _log.warning(
            "DES compile-cache miss: new bucket n=%d deps=%d inc=%d "
            "cons=%d/%d P=%d members=%d backend=%s -- XLA recompile inside "
            "a hot loop; widen the bucket quanta if this repeats",
            cfg.n, pad.d, pad.e, cfg.num_link_cons, cfg.num_cons, cfg.P,
            cfg.members, cfg.backend)
    ent = CompiledDES(cfg)
    _COMPILE_CACHE[key] = ent
    while len(_COMPILE_CACHE) > _cache_max():
        _COMPILE_CACHE.popitem(last=False)
        _EVICTIONS.inc()
    _ENTRIES.set(len(_COMPILE_CACHE))
    return ent


# ------------------------------------------------------------------ engines
class JaxDES:
    """Convenience wrapper: single + batched simulation of a CommDAG."""

    def __init__(self, problem: DESProblem, max_events: int | None = None,
                 options: DESOptions | None = None):
        self.problem = problem
        self.options = options or DESOptions()
        ropt = self.options.resolve()
        pad = PadSpec.exact(problem)
        if ropt.bucket:
            pad = pad.bucketed(ropt)
        self.pad = pad
        self.arrays = DESArrays.from_problem(problem, pad)
        self.max_events = int(max_events or default_max_events(pad.n))
        cfg = _StaticCfg(n=pad.n, num_cons=pad.cons,
                         num_link_cons=pad.links,
                         P=problem.dag.cluster.num_pods,
                         max_events=self.max_events, backend=ropt.backend,
                         interpret=ropt.interpret, members=0)
        self._compiled = _compiled_for(cfg, pad, ropt.warn_on_miss)
        self._leaves = tuple(getattr(self.arrays, f) for f in _ARRAY_FIELDS)
        self.P = problem.dag.cluster.num_pods

    def _mask(self, mask) -> jax.Array:
        """(P, P) link-availability factor; None means a healthy fabric.
        Always materialized (ones when healthy) so degraded calls hit the
        exact same traced signature -- no re-jit on the first failure."""
        if mask is None:
            return jnp.ones((self.P, self.P))
        return jnp.asarray(mask, dtype=jnp.float32)

    def makespan(self, x, ideal: bool = False, mask=None) -> float:
        with span("des.simulate", entry="single", n=self.pad.n):
            ms, _, _, _ = self._compiled.single(
                self._leaves, jnp.asarray(x), jnp.asarray(ideal),
                self._mask(mask))
            return float(ms)

    def simulate(self, x, ideal: bool = False, mask=None):
        with span("des.simulate", entry="single", n=self.pad.n):
            ms, feas, start, finish = self._compiled.single(
                self._leaves, jnp.asarray(x), jnp.asarray(ideal),
                self._mask(mask))
            n = self.problem.n    # strip bucket-padding ghost tasks
            return (float(ms), bool(feas), np.asarray(start)[:n],
                    np.asarray(finish)[:n])

    def batch_makespan(self, xs, mask=None) -> tuple[np.ndarray, np.ndarray]:
        """Makespans + feasibility for a (pop, P, P) batch of topologies."""
        xs = jnp.asarray(xs)
        with span("des.simulate", entry="batch_x", n=self.pad.n,
                  pop=int(xs.shape[0])):
            ms, feas = self._compiled.batch_x(self._leaves, xs,
                                              self._mask(mask))
            return np.asarray(ms), np.asarray(feas)

    def batch_genome_makespan(self, genomes, edge_u, edge_v, mask=None
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Fused GA generation-step fitness: scatter a (pop, E) genome batch
        onto (pop, P, P) topologies *on device* and simulate, all in one
        jitted call -- one host->device transfer for the genomes, one
        device->host for (makespan, feasible), independent of pop size."""
        genomes = jnp.asarray(genomes)
        with span("des.simulate", entry="batch_genomes", n=self.pad.n,
                  pop=int(genomes.shape[0])):
            ms, feas = self._compiled.batch_genomes(
                self._leaves, genomes,
                jnp.asarray(edge_u, dtype=jnp.int32),
                jnp.asarray(edge_v, dtype=jnp.int32), self._mask(mask))
            return np.asarray(ms), np.asarray(feas)


# ------------------------------------------------------------------ ensemble
def plane_state_genomes(lane_genomes: np.ndarray) -> np.ndarray:
    """Fabric-state expansion of a k-plane lane decomposition.

    `lane_genomes` is (..., k, E): per-plane circuit counts on the E
    union pairs, summing (over planes) to the total topology genome.
    Returns a float (..., k+1, E) stack -- state 0 is the full fabric
    (lane sum) and state p+1 is plane p dark (total minus lane p).  A
    pair carried entirely by the dark plane keeps a fractional
    ``total / k`` trickle instead of zeroing out: circuits are the only
    route between a pair, so a hard zero would price every single-lane
    pair as an infinite makespan (the same transient-buffering
    convention as `repro.core.ga.failure_scenarios`).  These are exactly
    the states a staggered rewire visits, so the GA's spare-lane fitness
    and the transition scheduler price the same physics.
    """
    lanes = np.asarray(lane_genomes, dtype=np.float64)
    if lanes.ndim < 2:
        raise ValueError(f"lane_genomes needs a (k, E) tail, "
                         f"got shape {lanes.shape}")
    k = lanes.shape[-2]
    total = lanes.sum(axis=-2, keepdims=True)           # (..., 1, E)
    eff = total - lanes                                 # (..., k, E)
    eff = np.where((eff <= 0) & (total > 0), total / k, eff)
    return np.concatenate([total, eff], axis=-2)        # (..., k+1, E)


def stack_problems(problems: list[DESProblem],
                   pad: PadSpec | None = None) -> DESArrays:
    """Pad member DES problems to one fixed shape and stack them.

    Every array field gains a leading member axis; the static shape fields
    take the across-member maxima (or the caller's larger `pad`, e.g. a
    compile bucket) so a single jitted `_simulate` serves all members
    (vmap over the member axis).  Ghost-padding semantics are documented on
    `_problem_fields`.
    """
    if not problems:
        raise ValueError("stack_problems needs at least one member")
    if pad is None:
        pad = member_pad(problems)
    B = problems[0].B
    if any(p.B != B for p in problems):
        raise ValueError("ensemble members must share the NIC bandwidth")
    member_fields = [_problem_fields(p, pad) for p in problems]
    stacked = {k: jnp.asarray(np.stack([f[k] for f in member_fields]))
               for k in _ARRAY_FIELDS}
    return DESArrays(**stacked, num_cons=pad.cons, num_link_cons=pad.links,
                     nic_bandwidth=1.0, n=pad.n)


def member_pad(problems: list[DESProblem]) -> PadSpec:
    """Across-member maxima of the exact per-member pad specs."""
    links = max(p.num_link_cons for p in problems)
    return PadSpec(
        n=max(p.n for p in problems),
        d=max(len(p.dep_pre) for p in problems),
        e=max(len(p.con_task) for p in problems),
        links=links,
        cons=links + max(p.num_cons - p.num_link_cons for p in problems))


class EnsembleJaxDES:
    """Batched DES over a `DagEnsemble`: members x genomes in one jit.

    Member problems are padded to a fixed shape (`stack_problems`) so GA
    fitness over a whole population stays O(1) host<->device transfers per
    generation regardless of ensemble size: one (pop, E) genome upload, one
    (pop, M) (makespan, feasible) download.
    """

    def __init__(self, problems: list[DESProblem],
                 max_events: int | None = None,
                 options: DESOptions | None = None):
        self.problems = problems
        self.options = options or DESOptions()
        ropt = self.options.resolve()
        pad = member_pad(problems)
        if ropt.bucket:
            pad = pad.bucketed(ropt)
        self.pad = pad
        self.arrays = stack_problems(problems, pad)
        self.max_events = int(max_events or default_max_events(pad.n))
        self.P = problems[0].dag.cluster.num_pods
        cfg = _StaticCfg(n=pad.n, num_cons=pad.cons,
                         num_link_cons=pad.links, P=self.P,
                         max_events=self.max_events, backend=ropt.backend,
                         interpret=ropt.interpret, members=len(problems))
        self._compiled = _compiled_for(cfg, pad, ropt.warn_on_miss)
        self._leaves = tuple(getattr(self.arrays, f) for f in _ARRAY_FIELDS)

    def _masks(self, masks) -> jax.Array:
        """(M, P, P) per-member availability factors (ones when healthy).
        The k-failure objective stacks one DAG M times and passes one
        failure scenario per member slot; the robust path leaves them at
        ones -- both share the compiled executable."""
        if masks is None:
            return jnp.ones((len(self.problems), self.P, self.P))
        masks = jnp.asarray(masks, dtype=jnp.float32)
        if masks.ndim == 2:
            masks = jnp.broadcast_to(masks, (len(self.problems), self.P,
                                             self.P))
        return masks

    def ensemble_genome_makespan(self, genomes, edge_u, edge_v, masks=None
                                 ) -> tuple[np.ndarray, np.ndarray]:
        """(pop, E) genomes over the union pairs -> (pop, M) makespans and
        feasibility, one fused jitted call (scatter + members x genomes
        vmap'd `_simulate`)."""
        genomes = jnp.asarray(genomes)
        with span("des.simulate", entry="ensemble_genomes", n=self.pad.n,
                  pop=int(genomes.shape[0]), members=len(self.problems)):
            ms, feas = self._compiled.ensemble_genomes(
                self._leaves, genomes,
                jnp.asarray(edge_u, dtype=jnp.int32),
                jnp.asarray(edge_v, dtype=jnp.int32), self._masks(masks))
            return np.asarray(ms), np.asarray(feas)

    def makespans(self, x, masks=None) -> tuple[np.ndarray, np.ndarray]:
        """Per-member (makespan, feasible) for one symmetric (P, P)
        topology, via the genome entry point (full-matrix scatter)."""
        eu = np.arange(self.P).repeat(self.P)
        ev = np.tile(np.arange(self.P), self.P)
        genome = np.asarray(x).reshape(-1)[None]
        ms, feas = self.ensemble_genome_makespan(genome, eu, ev, masks)
        return ms[0], feas[0]
