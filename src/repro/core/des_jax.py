"""JAX discrete-event simulator: fixed-trip-count, vmap-able over topologies.

TPU-native adaptation of the paper's "ParallelEvalDES" (Alg. 3 line 2): the
simulator state is a pytree of fixed-shape arrays and every state transition
is one `lax.while_loop` step, so a whole GA population evaluates as a single
batched XLA computation via `jax.vmap` (instead of the paper's 4 CPU
threads).  Semantics match `repro.core.des.simulate` exactly (validated by
tests/test_des_jax.py); only makespan/feasibility/start/finish are produced
(critical-path extraction stays on the numpy engine).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.des import DESProblem

INF = jnp.inf


class DESArrays(NamedTuple):
    """Static problem arrays (all jnp) for the JAX DES."""
    volume: jax.Array          # (n,)
    flows: jax.Array           # (n,)
    dep_pre: jax.Array         # (d,)
    dep_succ: jax.Array        # (d,)
    dep_delta: jax.Array       # (d,)
    indegree: jax.Array        # (n,)
    con_task: jax.Array        # (e,) incidence: task index
    con_id: jax.Array          # (e,) incidence: constraint index
    con_w: jax.Array           # (e,) weight on phi (F_m for links, 1 for NIC)
    link_pair_a: jax.Array     # (L,) src pod per link constraint
    link_pair_b: jax.Array     # (L,) dst pod per link constraint
    task_valid: jax.Array    # (n,) False for ensemble-padding ghost tasks
    num_cons: int
    num_link_cons: int
    nic_bandwidth: float
    n: int

    @classmethod
    def from_problem(cls, problem: DESProblem) -> "DESArrays":
        cp = problem.con_ptr
        con_id = np.repeat(np.arange(problem.num_cons), np.diff(cp))
        pairs = np.array(problem.pairs, dtype=np.int32).reshape(-1, 2)
        if problem.volume[1:].min(initial=np.inf) <= 0:
            raise ValueError("JAX DES requires positive real-task volumes")
        # unit rescaling: volumes in "seconds at one-circuit rate" (B == 1)
        # keeps every quantity O(1) so the simulation is accurate even when
        # jax runs in float32 (x64 disabled).
        return cls(
            volume=jnp.asarray(problem.volume / problem.B),
            flows=jnp.asarray(problem.flows),
            dep_pre=jnp.asarray(problem.dep_pre, dtype=jnp.int32),
            dep_succ=jnp.asarray(problem.dep_succ, dtype=jnp.int32),
            dep_delta=jnp.asarray(problem.dep_delta),
            indegree=jnp.asarray(problem.indegree, dtype=jnp.int32),
            con_task=jnp.asarray(problem.con_task, dtype=jnp.int32),
            con_id=jnp.asarray(con_id, dtype=jnp.int32),
            con_w=jnp.asarray(problem.con_w),
            link_pair_a=jnp.asarray(pairs[:, 0], dtype=jnp.int32),
            link_pair_b=jnp.asarray(pairs[:, 1], dtype=jnp.int32),
            task_valid=jnp.ones(problem.n, dtype=bool),
            num_cons=problem.num_cons,
            num_link_cons=problem.num_link_cons,
            nic_bandwidth=1.0,   # rescaled (see volume)
            n=problem.n,
        )


def _maxmin(arr: DESArrays, active: jax.Array, caps: jax.Array) -> jax.Array:
    """Weighted max-min fair task rates (progressive filling)."""
    n, C = arr.n, arr.num_cons
    # hoist the loop-invariant active-membership weights out of the filling
    # loop; `active` is fixed for the duration of one rate computation
    act_w = jnp.where(active[arr.con_task], arr.con_w, 0.0)

    def cond(state):
        i, phi, unfrozen = state
        return jnp.logical_and(i < C + 1, unfrozen.any())

    def body(state):
        i, phi, unfrozen = state
        unf_w = jnp.where(unfrozen[arr.con_task], arr.con_w, 0.0)
        # one fused segment reduction for (used, denom) instead of two
        used, denom = jax.ops.segment_sum(
            jnp.stack([act_w * phi[arr.con_task], unf_w], axis=1),
            arr.con_id, num_segments=C).T
        slack = caps - used
        alpha_c = jnp.where(denom > 0, slack / jnp.maximum(denom, 1e-300), INF)
        alpha = jnp.maximum(jnp.min(alpha_c), 0.0)
        phi = jnp.where(unfrozen, phi + alpha, phi)
        sat = jnp.isfinite(alpha_c) & (alpha_c <= alpha * (1 + 1e-9) + 1e-18)
        task_sat = jnp.zeros(n, dtype=bool).at[arr.con_task].max(
            sat[arr.con_id])
        unfrozen = unfrozen & ~task_sat
        return i + 1, phi, unfrozen

    _, phi, _ = jax.lax.while_loop(
        cond, body, (0, jnp.zeros(n), active))
    return arr.flows * phi * active


def _simulate(arr: DESArrays, x: jax.Array, ideal_flag: jax.Array,
              max_events: int) -> tuple[jax.Array, jax.Array, jax.Array,
                                        jax.Array]:
    """Returns (makespan, feasible, start, finish)."""
    n = arr.n
    B = arr.nic_bandwidth
    # cap dtype follows the simulation dtype: hard-coding float64 is a
    # silent no-op downcast to float32 under default x64-disabled jax
    link_caps = x[arr.link_pair_a, arr.link_pair_b].astype(
        arr.volume.dtype) * B
    link_caps = jnp.where(ideal_flag, INF, link_caps)
    caps = jnp.concatenate(
        [link_caps, jnp.full(arr.num_cons - arr.num_link_cons, B)])

    # initial state: virtual task 0 done at t=0.  Padding ghost tasks
    # (task_valid False -- ensemble members stacked to a common shape) are
    # born done with finish 0, so they never contend, never gate readiness
    # and never contribute to the makespan.
    rem = arr.volume
    started = jnp.logical_not(arr.task_valid).at[0].set(True)
    done = started
    start = jnp.where(started, 0.0, INF)
    finish = start
    missing = arr.indegree - jax.ops.segment_sum(
        (arr.dep_pre == 0).astype(jnp.int32), arr.dep_succ, num_segments=n)
    t = jnp.array(0.0)
    feasible = jnp.array(True)

    def ready_times(missing, started, finish):
        lag = finish[arr.dep_pre] + arr.dep_delta
        ready = jnp.zeros(n).at[arr.dep_succ].max(lag)
        ok = (missing == 0) & ~started
        return jnp.where(ok, ready, INF)

    def cond(state):
        i, t, *_ , feasible = state
        return (i < max_events) & jnp.isfinite(t) & feasible

    def body(state):
        i, t, rem, started, done, start, finish, missing, feasible = state
        ready = ready_times(missing, started, finish)
        eps = 1e-6 if rem.dtype == jnp.float32 else 1e-12
        newly = ready <= t * (1 + eps) + eps * 1e-3
        started = started | newly
        start = jnp.where(newly, ready, start)
        active = started & ~done
        rates = _maxmin(arr, active, caps)
        feasible = feasible & jnp.all(jnp.where(active, rates > 0, True))
        dt_done = jnp.where(active & (rates > 0), rem / jnp.maximum(rates,
                                                                    1e-300),
                            INF)
        t_complete = t + jnp.min(dt_done)
        # tasks started this step are no longer pending: their ready entry
        # drops out without recomputing the (gather + segment-max) pass
        t_ready = jnp.min(jnp.where(newly, INF, ready))
        t_next = jnp.minimum(t_complete, t_ready)
        dt = jnp.maximum(t_next - t, 0.0)
        rem = jnp.where(active, jnp.maximum(rem - rates * dt, 0.0), rem)
        veps = 1e-5 if rem.dtype == jnp.float32 else 1e-9
        # also complete tasks whose remaining *time* is below the float time
        # resolution at t -- otherwise `t + dt == t` stalls the simulation
        teps = 1e-5 if rem.dtype == jnp.float32 else 1e-12
        dt_rem = dt_done - dt   # remaining volume / rate after the advance
        newdone = active & jnp.isfinite(t_next) & (
            (rem <= veps * jnp.maximum(arr.volume, 1e-9))
            | (dt_rem <= teps * jnp.maximum(t_next, 1e-9)))
        finish = jnp.where(newdone, t_next, finish)
        done = done | newdone
        missing = missing - jax.ops.segment_sum(
            newdone[arr.dep_pre].astype(jnp.int32), arr.dep_succ,
            num_segments=n)
        all_done = done.all()
        t_out = jnp.where(all_done, -INF, t_next)  # exit condition
        return (i + 1, t_out, rem, started, done, start, finish, missing,
                feasible)

    state = (0, t, rem, started, done, start, finish, missing, feasible)
    state = jax.lax.while_loop(cond, body, state)
    _, _, _, _, done, start, finish, _, feasible = state
    feasible = feasible & done.all()
    makespan = jnp.where(feasible, jnp.max(jnp.where(jnp.isfinite(finish),
                                                     finish, -INF)), INF)
    return makespan, feasible, start, finish


class JaxDES:
    """Convenience wrapper: single + batched simulation of a CommDAG."""

    def __init__(self, problem: DESProblem, max_events: int | None = None):
        self.problem = problem
        self.arrays = DESArrays.from_problem(problem)
        self.max_events = int(max_events or (4 * problem.n + 8))

    @functools.cached_property
    def _single(self):
        arr, me = self.arrays, self.max_events
        return jax.jit(lambda x, ideal: _simulate(arr, x, ideal, me))

    def makespan(self, x, ideal: bool = False) -> float:
        ms, _, _, _ = self._single(jnp.asarray(x), jnp.asarray(ideal))
        return float(ms)

    def simulate(self, x, ideal: bool = False):
        ms, feas, start, finish = self._single(jnp.asarray(x),
                                               jnp.asarray(ideal))
        return (float(ms), bool(feas), np.asarray(start), np.asarray(finish))

    @functools.cached_property
    def _batched(self):
        arr, me = self.arrays, self.max_events
        return jax.jit(jax.vmap(
            lambda x: _simulate(arr, x, jnp.asarray(False), me)[:2]))

    def batch_makespan(self, xs) -> tuple[np.ndarray, np.ndarray]:
        """Makespans + feasibility for a (pop, P, P) batch of topologies."""
        ms, feas = self._batched(jnp.asarray(xs))
        return np.asarray(ms), np.asarray(feas)

    @functools.cached_property
    def _batched_genomes(self):
        arr, me = self.arrays, self.max_events
        P = self.problem.dag.cluster.num_pods

        def one(g, eu, ev):
            x = jnp.zeros((P, P), dtype=g.dtype)
            x = x.at[eu, ev].set(g).at[ev, eu].set(g)
            return _simulate(arr, x, jnp.asarray(False), me)[:2]

        return jax.jit(jax.vmap(one, in_axes=(0, None, None)))

    def batch_genome_makespan(self, genomes, edge_u, edge_v
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Fused GA generation-step fitness: scatter a (pop, E) genome batch
        onto (pop, P, P) topologies *on device* and simulate, all in one
        jitted call -- one host->device transfer for the genomes, one
        device->host for (makespan, feasible), independent of pop size."""
        ms, feas = self._batched_genomes(
            jnp.asarray(genomes),
            jnp.asarray(edge_u, dtype=jnp.int32),
            jnp.asarray(edge_v, dtype=jnp.int32))
        return np.asarray(ms), np.asarray(feas)


# ------------------------------------------------------------------ ensemble
def _pad_to(a: np.ndarray, size: int, fill) -> np.ndarray:
    """Right-pad a 1-D array to `size` with `fill`."""
    if len(a) == size:
        return np.asarray(a)
    out = np.full(size, fill, dtype=np.asarray(a).dtype)
    out[:len(a)] = a
    return out


def stack_problems(problems: list[DESProblem]) -> DESArrays:
    """Pad member DES problems to one fixed shape and stack them.

    Every array field gains a leading member axis; the static shape fields
    take the across-member maxima so a single jitted `_simulate` serves all
    members (vmap over the member axis).  Padding semantics:

      * ghost tasks: volume 0, flows 1, `task_valid` False -- born done,
        never scheduled (see `_simulate`);
      * ghost deps: (0 -> 0, delta 0) -- target the virtual task, which is
        done at t=0, so they never gate readiness;
      * ghost incidence entries: (task 0, constraint 0, weight 0) -- zero
        contribution to every used/denom segment sum;
      * ghost link constraints: pair (0, 0) -- capacity x[0,0] * B == 0
        with no members, never binding;
      * ghost NIC constraints: capacity B with no members, never binding.

    Constraint ids are remapped so every member's NIC block starts at the
    common padded link count L_max (the caps vector in `_simulate` is
    [links..., NICs...] by position).
    """
    if not problems:
        raise ValueError("stack_problems needs at least one member")
    n_max = max(p.n for p in problems)
    d_max = max(len(p.dep_pre) for p in problems)
    e_max = max(len(p.con_task) for p in problems)
    l_max = max(p.num_link_cons for p in problems)
    c_max = l_max + max(p.num_cons - p.num_link_cons for p in problems)
    B = problems[0].B
    if any(p.B != B for p in problems):
        raise ValueError("ensemble members must share the NIC bandwidth")

    fields: dict[str, list[np.ndarray]] = {k: [] for k in (
        "volume", "flows", "dep_pre", "dep_succ", "dep_delta", "indegree",
        "con_task", "con_id", "con_w", "link_pair_a", "link_pair_b",
        "task_valid")}
    for p in problems:
        cp = p.con_ptr
        con_id = np.repeat(np.arange(p.num_cons), np.diff(cp))
        # NIC constraints shift up to start at the padded link block end
        con_id = np.where(con_id >= p.num_link_cons,
                          con_id + (l_max - p.num_link_cons), con_id)
        pairs = np.array(p.pairs, dtype=np.int32).reshape(-1, 2)
        if p.volume[1:].min(initial=np.inf) <= 0:
            raise ValueError("JAX DES requires positive real-task volumes")
        fields["volume"].append(_pad_to(p.volume / B, n_max, 0.0))
        fields["flows"].append(_pad_to(p.flows, n_max, 1.0))
        fields["dep_pre"].append(
            _pad_to(p.dep_pre.astype(np.int32), d_max, 0))
        fields["dep_succ"].append(
            _pad_to(p.dep_succ.astype(np.int32), d_max, 0))
        fields["dep_delta"].append(_pad_to(p.dep_delta, d_max, 0.0))
        fields["indegree"].append(
            _pad_to(p.indegree.astype(np.int32), n_max, 0))
        fields["con_task"].append(
            _pad_to(p.con_task.astype(np.int32), e_max, 0))
        fields["con_id"].append(_pad_to(con_id.astype(np.int32), e_max, 0))
        fields["con_w"].append(_pad_to(p.con_w, e_max, 0.0))
        fields["link_pair_a"].append(_pad_to(pairs[:, 0], l_max, 0))
        fields["link_pair_b"].append(_pad_to(pairs[:, 1], l_max, 0))
        fields["task_valid"].append(
            _pad_to(np.ones(p.n, dtype=bool), n_max, False))
    stacked = {k: jnp.asarray(np.stack(v)) for k, v in fields.items()}
    return DESArrays(**stacked, num_cons=c_max, num_link_cons=l_max,
                     nic_bandwidth=1.0, n=n_max)


class EnsembleJaxDES:
    """Batched DES over a `DagEnsemble`: members x genomes in one jit.

    Member problems are padded to a fixed shape (`stack_problems`) so GA
    fitness over a whole population stays O(1) host<->device transfers per
    generation regardless of ensemble size: one (pop, E) genome upload, one
    (pop, M) (makespan, feasible) download.
    """

    def __init__(self, problems: list[DESProblem],
                 max_events: int | None = None):
        self.problems = problems
        self.arrays = stack_problems(problems)
        self.max_events = int(max_events
                              or (4 * max(p.n for p in problems) + 8))
        self.P = problems[0].dag.cluster.num_pods

    # array-valued DESArrays leaves: everything before the first static
    # field, derived from the NamedTuple itself so a future field
    # insertion/reorder cannot silently misalign the vmap reassembly
    _ARRAY_FIELDS = DESArrays._fields[:DESArrays._fields.index("num_cons")]

    def _member_arrays(self) -> tuple:
        """The stacked array leaves (leading member axis) for vmap."""
        return tuple(getattr(self.arrays, f) for f in self._ARRAY_FIELDS)

    def _rebuild(self, leaves: tuple) -> DESArrays:
        """One member's DESArrays from its vmapped leaves + the shared
        static fields (kept by `_replace`)."""
        return self.arrays._replace(**dict(zip(self._ARRAY_FIELDS, leaves)))

    @functools.cached_property
    def _batched_genomes(self):
        me, P = self.max_events, self.P
        rebuild = self._rebuild

        def one_member(leaves, x):
            return _simulate(rebuild(leaves), x, jnp.asarray(False), me)[:2]

        def one_genome(leaves, g, eu, ev):
            x = jnp.zeros((P, P), dtype=g.dtype)
            x = x.at[eu, ev].set(g).at[ev, eu].set(g)
            return jax.vmap(one_member, in_axes=(0, None))(leaves, x)

        return jax.jit(jax.vmap(one_genome, in_axes=(None, 0, None, None)))

    def ensemble_genome_makespan(self, genomes, edge_u, edge_v
                                 ) -> tuple[np.ndarray, np.ndarray]:
        """(pop, E) genomes over the union pairs -> (pop, M) makespans and
        feasibility, one fused jitted call (scatter + members x genomes
        vmap'd `_simulate`)."""
        ms, feas = self._batched_genomes(
            self._member_arrays(), jnp.asarray(genomes),
            jnp.asarray(edge_u, dtype=jnp.int32),
            jnp.asarray(edge_v, dtype=jnp.int32))
        return np.asarray(ms), np.asarray(feas)

    def makespans(self, x) -> tuple[np.ndarray, np.ndarray]:
        """Per-member (makespan, feasible) for one symmetric (P, P)
        topology, via the genome entry point (full-matrix scatter)."""
        eu = np.arange(self.P).repeat(self.P)
        ev = np.tile(np.arange(self.P), self.P)
        genome = np.asarray(x).reshape(-1)[None]
        ms, feas = self.ensemble_genome_makespan(genome, eu, ev)
        return ms[0], feas[0]
