"""DELTA-Fast: DES-accelerated domain-adapted genetic algorithm
(paper Sec. IV-B, Algs. 3, 5, 6) -- population-array-resident engine.

Genome = integer circuit counts over the active undirected pod pairs,
bounded by the Alg. 2 capacity bounds X̄ and repaired against the physical
port budgets U.  Fitness = DES makespan (primary) and total allocated
circuits (secondary, lexicographic tie-break exploiting O4's port saving).

The whole search loop is array-at-a-time: the population is a single
(pop, E) int array, Alg. 5 init / Alg. 6 repair / tournament selection /
uniform crossover / ±1 mutation are whole-population numpy ops, and fitness
is one fused genome->topology scatter + vmap DES per generation
(`JaxDES.batch_genome_makespan`), padded to a fixed batch shape so XLA
compiles the generation step exactly once.  A vectorized `np.unique` dedup
backed by a bytes-keyed cache keeps duplicate genomes away from the
simulator entirely.

Fitness backends:
  'numpy' -- repro.core.des.simulate per unique candidate
  'jax'   -- repro.core.des_jax fused batched evaluation (TPU-native
             adaptation of ParallelEvalDES)
  'auto'  -- jax for small/medium DAGs, numpy beyond.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.core.dag import CommDAG, DagEnsemble
from repro.core.des import DESProblem, simulate
from repro.core.xbound import x_upper_bound
from repro.obs import get_counter, span

_GENERATIONS = get_counter(
    "ga_generations_total", "GA generations executed")
_EVALUATIONS = get_counter(
    "ga_fitness_evaluations_total",
    "unique genomes scored by the DES (cache misses)")

if TYPE_CHECKING:   # pragma: no cover - annotation-only import
    from repro.core.des_jax import DESOptions

INF = float("inf")

# float32 relative slack for the batched-DES pre-filter in the trimming
# sweeps: accepts are always certified with the exact numpy DES, so the
# filter margin only guards against false *negatives*
_TRIM_FILTER_SLACK = 1e-3
# candidates the float32 filter rejected by more than this relative band
# are not exact-rechecked on termination: the engines agree to ~1e-5 on
# the equivalence suites, so a >5% f32 overshoot of an exactly-acceptable
# drop would need an f32 fair-share freeze flip with outsized schedule
# impact.  If one ever occurs, the cost is bounded -- the sweep retains
# ports it could have dropped; an accepted drop is always numpy-certified,
# so the makespan budget is never violated either way.
_TRIM_BACKSTOP_BAND = 5e-2


def _trim_filter_bands(ms: np.ndarray, feas: np.ndarray, budgets
                       ) -> tuple[np.ndarray, np.ndarray]:
    """(fits, near) f32 pre-filter bands shared by the trimming sweeps.

    `fits` passes the conservative accept filter; `near` is the ambiguous
    band exact-rechecked before termination.  f32-infeasible rows stay in
    the ambiguous band: the band bounds makespan divergence only, not a
    feasibility misjudgment (rare, and cheap to recheck exactly).
    Elementwise -- the ensemble sweep reduces across members afterwards.
    """
    fits = feas & (ms <= budgets * (1 + _TRIM_FILTER_SLACK) + 1e-12)
    near = ~feas | (ms <= budgets * (1 + _TRIM_BACKSTOP_BAND) + 1e-12)
    return fits, near


@dataclass
class GAOptions:
    pop_size: int = 48
    max_generations: int = 400
    patience: int = 60            # stop after N gens without improvement
    elite_frac: float = 0.15
    tournament: int = 3
    crossover_rate: float = 0.9
    mutation_rate: float = 0.25   # per-gene probability of a +/-1 step
    seed: int = 0
    backend: str = "auto"         # numpy | jax | auto
    jax_task_limit: int = 1200
    time_limit: float = 120.0
    port_weight: float = 1e-9     # lexicographic secondary objective
    # engine knobs for the jax DES (kernel backend, bucketed jit cache);
    # None inherits the env-driven defaults (see des_jax.DESOptions)
    des_options: "DESOptions | None" = None


@dataclass
class GAResult:
    x: np.ndarray
    makespan: float
    generations: int
    evaluations: int
    elapsed: float
    history: list[float] = field(default_factory=list)
    feasible: bool = True

    @property
    def total_ports(self) -> int:
        return int(self.x.sum())


class TopologySpace:
    """Genome <-> symmetric topology matrix mapping + Algs. 5/6.

    All hot-path operations take whole populations: genomes are rows of a
    (S, E) int array and every transform below is a single numpy expression
    over that array (incidence matvecs, fancy-indexed scatters).
    """

    def __init__(self, dag: CommDAG, xbar: np.ndarray | None = None):
        self.dag = dag
        xbar_m = np.asarray(xbar if xbar is not None else x_upper_bound(dag))
        self._setup(dag.cluster, dag.undirected_pairs(), xbar_m)

    @classmethod
    def for_ensemble(cls, ensemble: DagEnsemble,
                     xbar: np.ndarray | None = None, *,
                     port_limits: Sequence[int] | None = None,
                     min_circuits: int = 1) -> "TopologySpace":
        """Search space over the *union* of the members' active pairs.

        Per-pair capacity bound: the member-wise max of the Alg. 2 bounds
        (a circuit count useful to any member must stay reachable).

        `port_limits` overrides the cluster's per-pod budgets -- the
        k-plane decomposition searches sub-fabrics (a subset of each pod's
        ports) over the same pair space.  `min_circuits=0` admits empty
        pairs, which a *supplementary* plane needs (its lane only tops up
        pairs the base planes already connect)."""
        obj = cls.__new__(cls)
        obj.dag = None
        xbar_m = np.asarray(xbar if xbar is not None
                            else ensemble_x_upper_bound(ensemble))
        obj._setup(ensemble.cluster, ensemble.undirected_pairs(), xbar_m,
                   port_limits=port_limits, min_circuits=min_circuits)
        return obj

    def _setup(self, cluster, edges: list[tuple[int, int]],
               xbar_m: np.ndarray, *,
               port_limits: Sequence[int] | None = None,
               min_circuits: int = 1) -> None:
        self.P = cluster.num_pods
        self.U = np.asarray(port_limits if port_limits is not None
                            else cluster.port_limits, dtype=np.int64)
        if self.U.shape != (self.P,):
            raise ValueError(f"port_limits needs {self.P} entries, "
                             f"got shape {self.U.shape}")
        if min_circuits not in (0, 1):
            raise ValueError(f"min_circuits must be 0 or 1, "
                             f"got {min_circuits}")
        self.g_min = int(min_circuits)
        self.edges = edges
        self.E = len(self.edges)
        earr = np.asarray(self.edges, dtype=np.int64).reshape(-1, 2)
        self.edge_u = earr[:, 0]
        self.edge_v = earr[:, 1]
        self.xbar = np.maximum(
            self.g_min,
            np.minimum(xbar_m[self.edge_u, self.edge_v].astype(np.int64),
                       np.minimum(self.U[self.edge_u],
                                  self.U[self.edge_v])))
        # pod x edge incidence (each edge touches exactly two pods)
        self.inc = np.zeros((self.P, self.E), dtype=np.int64)
        self.inc[self.edge_u, np.arange(self.E)] = 1
        self.inc[self.edge_v, np.arange(self.E)] = 1
        self.degree = self.inc.sum(axis=1)
        # quick feasibility: connectivity needs one port per incident edge
        # (moot when empty pairs are admitted)
        if self.g_min > 0 and (self.degree > self.U).any():
            p = int(np.argmax(self.degree - self.U))
            raise ValueError(
                f"pod {p} has {int(self.degree[p])} active pairs but "
                f"only {self.U[p]} ports; placement is infeasible")

    # ------------------------------------------------------ genome <-> matrix
    def genome_of(self, x: np.ndarray) -> np.ndarray:
        """Project a (P, P) topology matrix onto the active-pair genome."""
        return np.asarray(x)[self.edge_u, self.edge_v].astype(np.int64)

    def to_matrix_batch(self, genomes: np.ndarray) -> np.ndarray:
        """(S, E) genomes -> (S, P, P) symmetric topologies in one scatter."""
        G = np.asarray(genomes, dtype=np.int64).reshape(-1, self.E)
        X = np.zeros((len(G), self.P, self.P), dtype=np.int64)
        X[:, self.edge_u, self.edge_v] = G
        X[:, self.edge_v, self.edge_u] = G
        return X

    def to_matrix(self, genome: np.ndarray) -> np.ndarray:
        return self.to_matrix_batch(np.asarray(genome)[None])[0]

    # ------------------------------------------------------------ feasibility
    def port_usage_batch(self, genomes: np.ndarray) -> np.ndarray:
        """(S, E) genomes -> (S, P) ports used per pod (incidence matvec)."""
        return np.asarray(genomes, dtype=np.int64).reshape(-1, self.E) \
            @ self.inc.T

    def port_usage(self, genome: np.ndarray) -> np.ndarray:
        return self.port_usage_batch(np.asarray(genome)[None])[0]

    def is_feasible_batch(self, genomes: np.ndarray) -> np.ndarray:
        G = np.asarray(genomes, dtype=np.int64).reshape(-1, self.E)
        return ((G >= self.g_min).all(axis=1) & (G <= self.xbar).all(axis=1)
                & (self.port_usage_batch(G) <= self.U).all(axis=1))

    def is_feasible(self, genome: np.ndarray) -> bool:
        return bool(self.is_feasible_batch(np.asarray(genome)[None])[0])

    # ---------------------------------------------------------------- Alg. 5
    def random_init_batch(self, rng: np.random.Generator,
                          size: int) -> np.ndarray:
        """Feasible random population: uniform in [1, X̄] then batched
        Alg. 6 repair.  Repair always succeeds here: the constructor
        guarantees degree <= U, and any over-budget pod necessarily has an
        incident edge with g > 1 to reduce."""
        if self.E == 0:
            return np.zeros((size, 0), dtype=np.int64)
        G = rng.integers(self.g_min, self.xbar + 1, size=(size, self.E),
                         dtype=np.int64)
        return self.repair_batch(G, rng)[0]

    def feasible_random_init(self, rng: np.random.Generator) -> np.ndarray:
        return self.random_init_batch(rng, 1)[0]

    # ---------------------------------------------------------------- Alg. 6
    def repair_batch(self, genomes: np.ndarray, rng: np.random.Generator
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Whole-population repair: clip to [1, X̄], then per round every
        over-budget pod of every genome drops one circuit from a random
        reducible incident edge (all genomes and pods act simultaneously;
        total over-usage strictly decreases each round, so the loop is
        bounded by the initial excess).  Returns (repaired, ok) where ok[s]
        marks genomes whose port budgets are satisfied."""
        G = np.clip(np.asarray(genomes, dtype=np.int64).reshape(-1, self.E),
                    self.g_min, self.xbar)
        S = len(G)
        if self.E == 0 or S == 0:
            return G, np.ones(S, dtype=bool)
        inc_b = self.inc.astype(bool)
        rounds = int(self.xbar.sum()) - self.E + 1
        for _ in range(max(rounds, 1)):
            over = self.port_usage_batch(G) > self.U        # (S, P)
            viol = np.nonzero(over.any(axis=1))[0]
            if len(viol) == 0:
                break
            Gv, overv = G[viol], over[viol]
            keys = rng.random((len(viol), self.E))
            cand = overv[:, :, None] & inc_b[None] \
                & (Gv > self.g_min)[:, None, :]
            masked = np.where(cand, keys[:, None, :], -1.0)  # (V, P, E)
            e_star = masked.argmax(axis=2)                   # (V, P)
            valid = masked.max(axis=2) >= 0.0                # (V, P)
            if not valid.any():
                break
            dec = np.zeros_like(Gv)
            s_idx, p_idx = np.nonzero(valid)
            np.add.at(dec, (s_idx, e_star[s_idx, p_idx]), 1)
            G[viol] = np.maximum(Gv - dec, self.g_min)
        return G, (self.port_usage_batch(G) <= self.U).all(axis=1)

    def repair(self, genome: np.ndarray, rng: np.random.Generator
               ) -> tuple[np.ndarray, bool]:
        G, ok = self.repair_batch(np.asarray(genome)[None], rng)
        return G[0], bool(ok[0])


class _CachedFitness:
    """Shared population-fitness plumbing for the single-DAG and ensemble
    engines: vectorized `np.unique` dedup backed by a bytes-keyed score
    cache, fixed-shape padding (a multiple of `pop_size`, so the jitted
    batch compiles once and every generation does O(1) host<->device
    transfers), and the lexicographic port penalty.  Subclasses provide
    `_raw_scores` mapping unique (S, E) genomes to makespan-like scores
    (lower is better, INF marks infeasible)."""

    def __init__(self, space: TopologySpace, opts: GAOptions, n_tasks: int):
        self.space = space
        self.opts = opts
        self.cache: dict[bytes, float] = {}
        self.evaluations = 0
        self.batch_calls = 0
        self._use_jax = opts.backend == "jax" or (
            opts.backend == "auto" and n_tasks <= opts.jax_task_limit)
        self._pad = max(int(opts.pop_size), 1)

    def _padded(self, genomes: np.ndarray) -> tuple[np.ndarray, int]:
        """Pad to the fixed batch shape; extra lanes are near-free on the
        batched while_loop, whose cost is the max-lane trip count."""
        k = len(genomes)
        pad = (-k) % self._pad
        if pad:
            genomes = np.concatenate(
                [genomes, np.repeat(genomes[:1], pad, axis=0)])
        return genomes, k

    def _raw_scores(self, genomes: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def __call__(self, population: np.ndarray) -> np.ndarray:
        G = np.ascontiguousarray(
            np.asarray(population, dtype=np.int64).reshape(-1, self.space.E))
        uniq, inv = np.unique(G, axis=0, return_inverse=True)
        inv = np.asarray(inv).reshape(-1)   # numpy 2.x inverse-shape drift
        keys = [row.tobytes() for row in uniq]
        miss = [i for i, key in enumerate(keys) if key not in self.cache]
        if miss:
            self.evaluations += len(miss)
            _EVALUATIONS.inc(len(miss))
            with span("ga.fitness_batch", pop=len(G), unique=len(uniq),
                      misses=len(miss)):
                vals = self._raw_scores(uniq[miss])
            sums = uniq[miss].sum(axis=1)
            for i, v, s in zip(miss, vals, sums):
                score = float(v)
                if np.isfinite(score):
                    score += self.opts.port_weight * float(s)
                self.cache[keys[i]] = score
        return np.array([self.cache[k] for k in keys])[inv]


class BatchedFitness(_CachedFitness):
    """Single-DAG fitness: one fused genome-scatter + vmap-DES call per
    generation on the jax backend (`JaxDES.batch_genome_makespan`)."""

    def __init__(self, dag: CommDAG, space: TopologySpace, opts: GAOptions):
        self.problem = DESProblem(dag)
        super().__init__(space, opts, self.problem.n)
        self._jd = None
        if self._use_jax and space.E > 0:
            try:
                from repro.core.des_jax import JaxDES
                self._jd = JaxDES(self.problem, options=opts.des_options)
            except Exception:   # pragma: no cover - jax always available here
                self._jd = None

    def _raw_makespans(self, genomes: np.ndarray) -> np.ndarray:
        """Makespan (INF if infeasible) for each unique genome row."""
        if self._jd is not None:
            genomes, k = self._padded(genomes)
            ms, feas = self._jd.batch_genome_makespan(
                genomes, self.space.edge_u, self.space.edge_v)
            self.batch_calls += 1
            return np.where(feas, ms, INF)[:k]
        return np.array([simulate(self.problem, x).makespan
                         for x in self.space.to_matrix_batch(genomes)])

    _raw_scores = _raw_makespans


# backwards-compatible alias (pre-vectorization name)
_Fitness = BatchedFitness


def _tournament_batch(fitness: np.ndarray, rng: np.random.Generator,
                      num: int, k: int) -> np.ndarray:
    """`num` independent k-way tournaments over the population, at once."""
    idx = rng.integers(0, len(fitness), size=(num, k))
    return idx[np.arange(num), np.argmin(fitness[idx], axis=1)]


def _variation_batch(pop: np.ndarray, fitness: np.ndarray,
                     space: TopologySpace, opts: GAOptions,
                     rng: np.random.Generator, num: int) -> np.ndarray:
    """Selection + uniform crossover + ±1 mutation for `num` children,
    as whole-population array ops (no per-genome loops)."""
    pa = _tournament_batch(fitness, rng, num, opts.tournament)
    pb = _tournament_batch(fitness, rng, num, opts.tournament)
    A, B = pop[pa], pop[pb]
    cross = rng.random(num) < opts.crossover_rate
    take_b = rng.random((num, space.E)) < 0.5
    children = np.where(cross[:, None] & take_b, B, A)
    mut = rng.random((num, space.E)) < opts.mutation_rate
    step = rng.integers(0, 2, size=(num, space.E)) * 2 - 1
    return np.clip(children + np.where(mut, step, 0), space.g_min,
                   space.xbar)


def _evolve(space: TopologySpace, fit, opts: GAOptions,
            rng: np.random.Generator, t0: float,
            seeds: list[np.ndarray] | None = None
            ) -> tuple[np.ndarray, float, list[float], int]:
    """The shared GA driver (Alg. 3 body): init + repair + generational
    loop, fitness-agnostic.  `fit` maps a (S, E) population to (S,) scores
    (lower is better); both `delta_fast` and `delta_robust` route through
    this exact loop, so a singleton ensemble consumes the RNG identically
    to the single-DAG path.  Returns (best_g, best_f, history, gen)."""
    pop = space.random_init_batch(rng, opts.pop_size)
    # seed candidates (e.g. baselines) -- repaired into the population
    for s in (seeds or []):
        g, ok = space.repair(space.genome_of(s), rng)
        if ok:
            pop[rng.integers(len(pop))] = g
    fitness = fit(pop)
    best_i = int(np.argmin(fitness))
    best_g, best_f = pop[best_i].copy(), float(fitness[best_i])
    history = [best_f]
    n_elite = max(1, int(opts.elite_frac * opts.pop_size))
    num_children = opts.pop_size - n_elite
    stall = 0
    gen = 0

    for gen in range(1, opts.max_generations + 1):
        if time.time() - t0 > opts.time_limit or stall >= opts.patience:
            break
        with span("ga.generation", gen=gen, pop=opts.pop_size):
            order = np.argsort(fitness, kind="stable")
            elite = pop[order[:n_elite]]
            children = _variation_batch(pop, fitness, space, opts, rng,
                                        num_children)
            children, _ = space.repair_batch(children, rng)
            pop = np.concatenate([elite, children], axis=0)
            fitness = fit(pop)
        _GENERATIONS.inc()
        i = int(np.argmin(fitness))
        if fitness[i] < best_f - 1e-15:
            best_f, best_g = float(fitness[i]), pop[i].copy()
            stall = 0
        else:
            stall += 1
        history.append(best_f)
    return best_g, best_f, history, gen


def delta_fast(dag: CommDAG, opts: GAOptions | None = None,
               xbar: np.ndarray | None = None,
               seeds: list[np.ndarray] | None = None) -> GAResult:
    """Alg. 3: SimBasedDomainAdaptedGA (population-array-resident)."""
    opts = opts or GAOptions()
    rng = np.random.default_rng(opts.seed)
    space = TopologySpace(dag, xbar)
    fit = BatchedFitness(dag, space, opts)
    t0 = time.time()

    if space.E == 0:    # no inter-pod traffic: the empty topology is optimal
        x = np.zeros((space.P, space.P), dtype=np.int64)
        ms = simulate(fit.problem, x).makespan
        return GAResult(x=x, makespan=float(ms), generations=0,
                        evaluations=1, elapsed=time.time() - t0,
                        history=[float(ms)], feasible=np.isfinite(ms))

    with span("ga.evolve", kind="delta_fast", pop=opts.pop_size,
              edges=space.E):
        best_g, _, history, gen = _evolve(space, fit, opts, rng, t0, seeds)

    # re-rank the best distinct candidates with the exact numpy DES (the
    # batched jax fitness may run in float32; ~1e-5 ranking noise)
    ranked = sorted(fit.cache.items(), key=lambda kv: kv[1])[:8]
    best_x, best_ms = space.to_matrix(best_g), INF
    for key, fval in ranked:
        if not np.isfinite(fval):
            continue
        g = np.frombuffer(key, dtype=np.int64)
        x = space.to_matrix(g)
        ms = simulate(fit.problem, x).makespan
        port_pen = opts.port_weight * float(g.sum())
        if ms + port_pen < best_ms:
            best_ms, best_x = ms + port_pen, x
    ms = simulate(fit.problem, best_x).makespan
    return GAResult(x=best_x, makespan=float(ms), generations=gen,
                    evaluations=fit.evaluations, elapsed=time.time() - t0,
                    history=history, feasible=np.isfinite(ms))


# ------------------------------------------------------------- DELTA-Robust
ROBUST_OBJECTIVES = ("weighted", "max-regret")


def ensemble_x_upper_bound(ensemble: DagEnsemble) -> np.ndarray:
    """Union-space Alg. 2 bound: elementwise max of the member bounds."""
    return np.maximum.reduce([x_upper_bound(m) for m in ensemble.members])


class EnsembleFitness(_CachedFitness):
    """Population fitness over a `DagEnsemble`.

    Same plumbing as `BatchedFitness` (shared `_CachedFitness` base), but
    every unique genome is scored against *all* ensemble members in one
    fused `EnsembleJaxDES.ensemble_genome_makespan` call (members x
    genomes vmap), then scalarized:

      weighted   : sum_m w_m * makespan_m
      max-regret : max_m  makespan_m / refs_m

    Any member-infeasible genome scores INF.
    """

    def __init__(self, ensemble: DagEnsemble, space: TopologySpace,
                 opts: GAOptions, objective: str, refs: np.ndarray):
        self.ensemble = ensemble
        self.problems = [DESProblem(m) for m in ensemble.members]
        super().__init__(space, opts, max(p.n for p in self.problems))
        self.objective = objective
        self.refs = np.asarray(refs, dtype=np.float64)
        self.weights = np.asarray(ensemble.weights, dtype=np.float64)
        self._jd = None
        if self._use_jax and space.E > 0:
            try:
                from repro.core.des_jax import EnsembleJaxDES
                self._jd = EnsembleJaxDES(self.problems,
                                          options=opts.des_options)
            except Exception:   # pragma: no cover - jax always available here
                self._jd = None

    def scalarize(self, ms: np.ndarray) -> np.ndarray:
        """(S, M) member makespans -> (S,) objective values (INF-safe)."""
        ms = np.asarray(ms, dtype=np.float64).reshape(-1, len(self.problems))
        with np.errstate(invalid="ignore"):
            if self.objective == "weighted":
                out = ms @ self.weights
            else:
                out = (ms / self.refs).max(axis=1)
        out[~np.isfinite(ms).all(axis=1)] = INF
        return out

    def member_makespans(self, genomes: np.ndarray) -> np.ndarray:
        """(S, E) genomes -> (S, M) makespans (INF where infeasible)."""
        genomes = np.asarray(genomes, dtype=np.int64).reshape(-1,
                                                              self.space.E)
        if self._jd is not None:
            genomes, k = self._padded(genomes)
            ms, feas = self._jd.ensemble_genome_makespan(
                genomes, self.space.edge_u, self.space.edge_v)
            self.batch_calls += 1
            return np.where(feas, ms, INF)[:k]
        out = np.empty((len(genomes), len(self.problems)))
        for s, x in enumerate(self.space.to_matrix_batch(genomes)):
            out[s] = [simulate(p, x).makespan for p in self.problems]
        return out

    def exact_member_makespans(self, genome: np.ndarray) -> np.ndarray:
        """Exact (numpy DES) per-member makespans of one genome."""
        x = self.space.to_matrix(genome)
        return np.array([simulate(p, x).makespan for p in self.problems])

    def _raw_scores(self, genomes: np.ndarray) -> np.ndarray:
        return self.scalarize(self.member_makespans(genomes))


@dataclass
class RobustGAResult:
    """One static topology scored against every ensemble member."""

    x: np.ndarray
    makespans: np.ndarray          # (M,) exact per-member DES makespans
    regrets: np.ndarray            # (M,) makespans / refs
    refs: np.ndarray               # (M,) reference (best single-DAG) spans
    weights: np.ndarray            # (M,) normalized mixture weights
    objective: str
    objective_value: float
    generations: int
    evaluations: int
    elapsed: float
    history: list[float] = field(default_factory=list)
    feasible: bool = True

    @property
    def worst_regret(self) -> float:
        return float(self.regrets.max()) if len(self.regrets) else INF

    @property
    def weighted_makespan(self) -> float:
        return float(self.makespans @ self.weights)

    @property
    def total_ports(self) -> int:
        return int(self.x.sum())


def delta_robust(ensemble: DagEnsemble, opts: GAOptions | None = None,
                 objective: str = "max-regret",
                 refs: np.ndarray | None = None,
                 xbar: np.ndarray | None = None,
                 seeds: list[np.ndarray] | None = None,
                 port_limits: Sequence[int] | None = None) -> RobustGAResult:
    """DELTA-Robust: one static topology for a *set* of DAGs.

    Runs the same domain-adapted GA as `delta_fast` (identical RNG stream
    and loop -- a singleton ensemble reduces exactly to the single-DAG
    path) over the union pair space, with per-genome fitness scored
    against every member in one fused vmap DES call.

    `refs` are the per-member reference makespans defining regret
    (member's best single-DAG plan).  When omitted they are computed here
    by running `delta_fast` per member with the same options.

    `port_limits` overrides the cluster's per-pod budgets: the k-plane
    decomposition (`delta_planes`) searches the base topology inside the
    first k-1 planes' combined budget.
    """
    opts = opts or GAOptions()
    if objective not in ROBUST_OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"pick from {ROBUST_OBJECTIVES}")
    t_start = time.time()
    if refs is None:
        refs = np.array([delta_fast(m, opts).makespan
                         for m in ensemble.members])
    refs = np.asarray(refs, dtype=np.float64)
    if refs.shape != (ensemble.num_members,):
        raise ValueError("refs must have one entry per ensemble member")
    if not (np.isfinite(refs) & (refs > 0)).all():
        raise ValueError(f"refs must be finite positive makespans: {refs}")

    rng = np.random.default_rng(opts.seed)
    space = TopologySpace.for_ensemble(ensemble, xbar,
                                       port_limits=port_limits)
    fit = EnsembleFitness(ensemble, space, opts, objective, refs)
    # the robust GA gets its own full time budget: the per-member ref
    # runs above must not eat into _evolve's wall-clock limit
    t0 = time.time()

    if space.E == 0:    # no member has inter-pod traffic
        x = np.zeros((space.P, space.P), dtype=np.int64)
        ms = fit.exact_member_makespans(np.zeros(0, dtype=np.int64))
        obj = float(fit.scalarize(ms[None])[0])
        return RobustGAResult(
            x=x, makespans=ms, regrets=ms / refs, refs=refs,
            weights=np.asarray(ensemble.weights),
            objective=objective, objective_value=obj, generations=0,
            evaluations=1, elapsed=time.time() - t_start, history=[obj],
            feasible=bool(np.isfinite(ms).all()))

    with span("ga.evolve", kind="delta_robust", pop=opts.pop_size,
              edges=space.E, members=ensemble.num_members):
        best_g, _, history, gen = _evolve(space, fit, opts, rng, t0, seeds)

    # re-rank the top distinct candidates with the exact numpy DES per
    # member (same float32-noise guard as delta_fast)
    ranked = sorted(fit.cache.items(), key=lambda kv: kv[1])[:8]
    best_key, best_score = best_g.tobytes(), INF
    best_ms = fit.exact_member_makespans(best_g)
    for key, fval in ranked:
        if not np.isfinite(fval):
            continue
        g = np.frombuffer(key, dtype=np.int64)
        ms = fit.exact_member_makespans(g)
        score = float(fit.scalarize(ms[None])[0])
        if np.isfinite(score):
            score += opts.port_weight * float(g.sum())
        if score < best_score:
            best_score, best_key, best_ms = score, key, ms
    best_g = np.frombuffer(best_key, dtype=np.int64)
    obj = float(fit.scalarize(best_ms[None])[0])
    return RobustGAResult(
        x=space.to_matrix(best_g), makespans=best_ms,
        regrets=best_ms / refs, refs=refs,
        weights=np.asarray(ensemble.weights), objective=objective,
        objective_value=obj, generations=gen, evaluations=fit.evaluations,
        elapsed=time.time() - t_start, history=history,
        feasible=bool(np.isfinite(best_ms).all()))


# ----------------------------------------------------------- DELTA-Failsafe
FAILSAFE_OBJECTIVES = ("worst", "weighted")


def failure_scenarios(dag: CommDAG, num_planes: int = 4, k: int = 1,
                      include_healthy: bool = True) -> list[np.ndarray]:
    """Fractional k-plane-loss masks for the k-failure worst-case plan.

    One scenario per active pod pair: k of the `num_planes` OCS planes
    serving that pair go dark, leaving (num_planes - k)/num_planes of its
    circuit capacity.  The haircut is *fractional* on purpose -- circuits
    are the only route between a pair, so a full kill would make the worst
    case inf for every topology.  The healthy fabric is scenario 0, keeping
    the worst-case plan honest on the intact fabric too.
    """
    P = dag.cluster.num_pods
    frac = max(num_planes - k, 0) / num_planes
    out = [np.ones((P, P))] if include_healthy else []
    for (i, j) in dag.undirected_pairs():
        m = np.ones((P, P))
        m[i, j] = m[j, i] = frac
        out.append(m)
    return out


class FailsafeFitness(EnsembleFitness):
    """k-failure fitness: ONE DAG scored under a stack of degradation
    masks through the per-member mask lane of `EnsembleJaxDES`.  Reuses
    the whole ensemble plumbing by treating each failure scenario as a
    member whose DAG is the same object."""

    def __init__(self, dag: CommDAG, scenarios: list[np.ndarray],
                 space: TopologySpace, opts: GAOptions, objective: str,
                 refs: np.ndarray):
        super().__init__(DagEnsemble([dag] * len(scenarios)), space, opts,
                         objective, refs)
        self.masks = np.stack([np.asarray(m, dtype=np.float64)
                               for m in scenarios])

    def member_makespans(self, genomes: np.ndarray) -> np.ndarray:
        genomes = np.asarray(genomes, dtype=np.int64).reshape(-1,
                                                              self.space.E)
        if self._jd is not None:
            genomes, k = self._padded(genomes)
            ms, feas = self._jd.ensemble_genome_makespan(
                genomes, self.space.edge_u, self.space.edge_v,
                masks=self.masks)
            self.batch_calls += 1
            return np.where(feas, ms, INF)[:k]
        out = np.empty((len(genomes), len(self.problems)))
        for s, x in enumerate(self.space.to_matrix_batch(genomes)):
            out[s] = [simulate(p, x * m).makespan
                      for p, m in zip(self.problems, self.masks)]
        return out

    def exact_member_makespans(self, genome: np.ndarray) -> np.ndarray:
        x = self.space.to_matrix(genome)
        return np.array([simulate(p, x * m).makespan
                         for p, m in zip(self.problems, self.masks)])


def delta_failsafe(dag: CommDAG, opts: GAOptions | None = None,
                   scenarios: list[np.ndarray] | None = None,
                   num_planes: int = 4, k: int = 1,
                   objective: str = "worst",
                   xbar: np.ndarray | None = None,
                   seeds: list[np.ndarray] | None = None) -> RobustGAResult:
    """k-failure worst-case plan: one topology whose DES makespan is
    minimized across a set of fabric-degradation scenarios (capacity
    masks), scored in one fused masks x genomes vmap call per generation.

    `scenarios` is a list of (P, P) availability masks (1 = healthy);
    omitted, it defaults to `failure_scenarios(dag, num_planes, k)`.
    `objective` is 'worst' (minimize the max scenario makespan) or
    'weighted' (uniform mean).  The repair policy also calls this with a
    single scenario -- the *current* fabric damage -- to produce a full
    replan optimized for the degraded fabric.
    """
    opts = opts or GAOptions()
    if objective not in FAILSAFE_OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"pick from {FAILSAFE_OBJECTIVES}")
    if scenarios is None:
        scenarios = failure_scenarios(dag, num_planes=num_planes, k=k)
    scenarios = [np.asarray(m, dtype=np.float64) for m in scenarios]
    if not scenarios:
        raise ValueError("delta_failsafe needs at least one scenario")
    t_start = time.time()
    rng = np.random.default_rng(opts.seed)
    space = TopologySpace(dag, xbar)
    refs = np.ones(len(scenarios))   # worst == max-regret w.r.t. unit refs
    eff = "max-regret" if objective == "worst" else "weighted"
    fit = FailsafeFitness(dag, scenarios, space, opts, eff, refs)
    t0 = time.time()

    if space.E == 0:    # no inter-pod traffic: nothing to degrade
        x = np.zeros((space.P, space.P), dtype=np.int64)
        ms = fit.exact_member_makespans(np.zeros(0, dtype=np.int64))
        obj = float(fit.scalarize(ms[None])[0])
        return RobustGAResult(
            x=x, makespans=ms, regrets=ms / refs, refs=refs,
            weights=np.asarray(fit.ensemble.weights), objective=objective,
            objective_value=obj, generations=0, evaluations=1,
            elapsed=time.time() - t_start, history=[obj],
            feasible=bool(np.isfinite(ms).all()))

    with span("ga.evolve", kind="delta_failsafe", pop=opts.pop_size,
              edges=space.E, members=len(scenarios)):
        best_g, _, history, gen = _evolve(space, fit, opts, rng, t0, seeds)

    # exact numpy re-rank per scenario (same f32-noise guard as the other
    # engines: masked makespans are certified before the winner is named)
    ranked = sorted(fit.cache.items(), key=lambda kv: kv[1])[:8]
    best_key, best_score = best_g.tobytes(), INF
    best_ms = fit.exact_member_makespans(best_g)
    for key, fval in ranked:
        if not np.isfinite(fval):
            continue
        g = np.frombuffer(key, dtype=np.int64)
        ms = fit.exact_member_makespans(g)
        score = float(fit.scalarize(ms[None])[0])
        if np.isfinite(score):
            score += opts.port_weight * float(g.sum())
        if score < best_score:
            best_score, best_key, best_ms = score, key, ms
    best_g = np.frombuffer(best_key, dtype=np.int64)
    obj = float(fit.scalarize(best_ms[None])[0])
    return RobustGAResult(
        x=space.to_matrix(best_g), makespans=best_ms,
        regrets=best_ms / refs, refs=refs,
        weights=np.asarray(fit.ensemble.weights), objective=objective,
        objective_value=obj, generations=gen, evaluations=fit.evaluations,
        elapsed=time.time() - t_start, history=history,
        feasible=bool(np.isfinite(best_ms).all()))


# -------------------------------------------------------------- DELTA-Planes
def split_across_planes(x: np.ndarray, plane_budgets) -> np.ndarray:
    """Split one topology across OCS planes, balanced per pair.

    `x` is a (P, P) symmetric circuit matrix; `plane_budgets` is (k', P)
    per-plane per-pod port budgets.  Circuits are assigned one at a time,
    heaviest pair first; each circuit goes to the plane with the smallest
    share of that pair so far (then the most endpoint headroom, then the
    lowest plane id), so every pair's per-plane share is within one of
    c/k' wherever budgets permit -- losing any single plane then costs a
    pair at most ceil(c/k') of its c circuits.  Deterministic: the fleet
    rebuilds plane books from journal replays and must land on identical
    arrays.

    When the balanced choice has no port headroom the circuit falls to
    any plane that fits; if none fits, one single-circuit swap between
    planes is attempted before giving up (per-plane budgets are near-
    uniform, so a feasible global topology virtually always splits).
    """
    x = np.asarray(x)
    budgets = np.asarray(plane_budgets, dtype=np.int64)
    if budgets.ndim != 2 or budgets.shape[1] != x.shape[0]:
        raise ValueError(f"plane_budgets shape {budgets.shape} does not "
                         f"match {x.shape[0]} pods")
    k, P = budgets.shape
    planes = np.zeros((k, P, P), dtype=np.int64)
    head = budgets.copy()

    def place(u: int, v: int) -> bool:
        fits = np.nonzero((head[:, u] > 0) & (head[:, v] > 0))[0]
        if len(fits) == 0:
            return False
        share = planes[fits, u, v]
        room = np.minimum(head[fits, u], head[fits, v])
        p = fits[np.lexsort((fits, -room, share))[0]]
        planes[p, u, v] += 1
        planes[p, v, u] += 1
        head[p, u] -= 1
        head[p, v] -= 1
        return True

    def swap_then_place(u: int, v: int) -> bool:
        # free a slot: move one circuit (a, b) out of a plane p that has
        # headroom at one endpoint, into a plane q that fits it, so (u, v)
        # can land in p
        for u0, v0 in ((u, v), (v, u)):
            for p in np.nonzero(head[:, u0] > 0)[0]:
                for b in np.nonzero(planes[p, v0] > 0)[0]:
                    for q in np.nonzero((head[:, v0] > 0)
                                        & (head[:, b] > 0))[0]:
                        if q == p:
                            continue
                        planes[p, v0, b] -= 1
                        planes[p, b, v0] -= 1
                        planes[q, v0, b] += 1
                        planes[q, b, v0] += 1
                        head[p, v0] += 1
                        head[p, b] += 1
                        head[q, v0] -= 1
                        head[q, b] -= 1
                        if place(u, v):
                            return True
        return False

    iu, iv = np.triu_indices(P, k=1)
    counts = np.asarray(x)[iu, iv].astype(np.int64)
    for idx in np.lexsort((iv, iu, -counts)):
        u, v, c = int(iu[idx]), int(iv[idx]), int(counts[idx])
        for _ in range(c):
            if not place(u, v) and not swap_then_place(u, v):
                raise ValueError(
                    f"cannot split pair ({u}, {v}) of {x[u, v]} circuits "
                    f"across plane budgets {budgets.tolist()}")
    return planes


class PlanesFitness(EnsembleFitness):
    """Spare-plane fitness for the k-plane decomposition.

    The genome is the SPARE plane's lane only; the first k-1 lanes are
    frozen to the balanced split of the stage-A weighted optimum.  Every
    candidate is scored across k+1 fabric states -- the full fabric plus
    each single plane dark (`plane_state_genomes`, the staggered-rewire /
    PlaneFailure states the scheduler actually visits) -- and all M
    ensemble members, in ONE fused `ensemble_genome_makespan` call over
    the (S*(k+1), E) float state stack.  Objective: worst state/member
    regret against the stage-A reference makespans, so the spare lane is
    shaped to absorb whichever plane loss hurts the worst member most.
    """

    def __init__(self, ensemble: DagEnsemble, base_lanes: np.ndarray,
                 space: TopologySpace, opts: GAOptions, refs: np.ndarray):
        super().__init__(ensemble, space, opts, "max-regret", refs)
        self.base_lanes = np.asarray(base_lanes, dtype=np.int64) \
            .reshape(-1, space.E)
        self.num_planes = len(self.base_lanes) + 1

    def _lane_stack(self, genomes: np.ndarray) -> np.ndarray:
        """(S, E) spare lanes -> (S, k, E) full per-plane lane stacks."""
        S = len(genomes)
        base = np.broadcast_to(self.base_lanes[None],
                               (S,) + self.base_lanes.shape)
        return np.concatenate(
            [base, genomes[:, None, :].astype(np.int64)], axis=1)

    def state_makespans(self, genomes: np.ndarray) -> np.ndarray:
        """(S, E) spare lanes -> (S, k+1, M) fabric-state makespans."""
        from repro.core.des_jax import plane_state_genomes
        genomes = np.asarray(genomes, dtype=np.int64).reshape(
            -1, self.space.E)
        S, M = len(genomes), len(self.problems)
        k1 = self.num_planes + 1
        states = plane_state_genomes(self._lane_stack(genomes)) \
            .reshape(S * k1, self.space.E)
        if self._jd is not None:
            padded, n = self._padded(states)
            ms, feas = self._jd.ensemble_genome_makespan(
                padded, self.space.edge_u, self.space.edge_v)
            self.batch_calls += 1
            return np.where(feas, ms, INF)[:n].reshape(S, k1, M)
        out = np.empty((S * k1, M))
        for s, g in enumerate(states):
            X = self._float_matrix(g)
            out[s] = [simulate(p, X).makespan for p in self.problems]
        return out.reshape(S, k1, M)

    def _float_matrix(self, g: np.ndarray) -> np.ndarray:
        """Float scatter (fractional trickle lanes break `to_matrix`)."""
        X = np.zeros((self.space.P, self.space.P))
        X[self.space.edge_u, self.space.edge_v] = g
        X[self.space.edge_v, self.space.edge_u] = g
        return X

    def exact_state_makespans(self, genome: np.ndarray) -> np.ndarray:
        """Exact (numpy DES) (k+1, M) state/member makespans of one
        spare lane."""
        from repro.core.des_jax import plane_state_genomes
        lanes = self._lane_stack(
            np.asarray(genome, dtype=np.int64).reshape(1, -1))
        states = plane_state_genomes(lanes)[0]          # (k+1, E)
        out = np.empty((len(states), len(self.problems)))
        for s, g in enumerate(states):
            X = self._float_matrix(g)
            out[s] = [simulate(p, X).makespan for p in self.problems]
        return out

    def _raw_scores(self, genomes: np.ndarray) -> np.ndarray:
        ms = self.state_makespans(genomes)              # (S, k+1, M)
        flat = ms.reshape(len(ms), -1)
        with np.errstate(invalid="ignore"):
            out = (ms / self.refs).reshape(len(ms), -1).max(axis=1)
        out[~np.isfinite(flat).all(axis=1)] = INF
        return out


@dataclass
class PlanesGAResult:
    """k-plane decomposition of one robust topology."""

    planes: np.ndarray             # (k, P, P) per-plane circuit counts
    lane_genomes: np.ndarray       # (k, E) the same, on the union pairs
    edges: list                    # the E union pairs
    x: np.ndarray                  # (P, P) total topology (planes.sum(0))
    makespans: np.ndarray          # (M,) exact full-fabric member makespans
    dark_makespans: np.ndarray     # (k, M) exact one-plane-dark makespans
    refs: np.ndarray               # (M,) stage-A reference makespans
    plane_port_limits: tuple       # (k, P) per-plane per-pod budgets
    objective_value: float         # worst state/member regret
    generations: int
    evaluations: int
    elapsed: float
    history: list = field(default_factory=list)
    feasible: bool = True

    @property
    def num_planes(self) -> int:
        return len(self.planes)

    @property
    def worst_dark_regret(self) -> float:
        if not len(self.dark_makespans):
            return INF
        return float((self.dark_makespans / self.refs).max())

    @property
    def total_ports(self) -> int:
        return int(self.x.sum())


def delta_planes(ensemble: DagEnsemble, opts: GAOptions | None = None,
                 num_planes: int = 4,
                 xbar: np.ndarray | None = None,
                 seeds: list[np.ndarray] | None = None) -> PlanesGAResult:
    """DELTA-Planes: decompose one robust topology across a k-plane OCS
    fabric so any single plane can go dark (fault OR staggered rewire)
    with bounded, pre-certified inflation.

    Two structured stages over the plane-indexed genome:

      1. base -- `delta_robust` (weighted objective) confined to the
         first k-1 planes' combined port budget, then split balanced
         across those planes (`split_across_planes`): the always-on
         carry capacity.
      2. spare -- a GA over the k-th plane's lane alone
         (`TopologySpace.for_ensemble(..., port_limits=spare,
         min_circuits=0)`), scored on the k+1 fabric states every
         staggered transition actually visits; the spare lane is shaped
         to absorb the worst-case member under the worst plane loss.

    Exact numpy re-rank certifies the winner's full state/member matrix
    before it is returned (same f32-noise guard as the other engines).
    """
    opts = opts or GAOptions()
    if num_planes < 2:
        raise ValueError(f"num_planes must be >= 2, got {num_planes}")
    t_start = time.time()
    budgets = np.asarray(ensemble.plane_port_limits(num_planes),
                         dtype=np.int64)
    base_budget = budgets[:-1].sum(axis=0)

    base = delta_robust(ensemble, opts, objective="weighted",
                        refs=np.ones(ensemble.num_members),
                        port_limits=base_budget)
    refs = np.asarray(base.makespans, dtype=np.float64)
    if not (np.isfinite(refs) & (refs > 0)).all():
        raise ValueError(
            f"base stage is infeasible under the first {num_planes - 1} "
            f"planes' budget {base_budget.tolist()}: makespans {refs}")
    base_planes = split_across_planes(base.x, budgets[:-1])

    space = TopologySpace.for_ensemble(ensemble, xbar,
                                       port_limits=budgets[-1],
                                       min_circuits=0)
    # the spare lane tops up what the base left under the union Alg. 2
    # bound (at least one extra circuit per pair stays searchable)
    extra = ensemble_x_upper_bound(ensemble)[
        space.edge_u, space.edge_v].astype(np.int64) \
        - base.x[space.edge_u, space.edge_v].astype(np.int64)
    space.xbar = np.minimum(space.xbar, np.maximum(extra, 1))
    base_lanes = base_planes[:, space.edge_u, space.edge_v]
    fit = PlanesFitness(ensemble, base_lanes, space, opts, refs)
    rng = np.random.default_rng(opts.seed + 1)   # distinct from stage 1
    t0 = time.time()

    def finish(spare_g: np.ndarray, gen: int,
               history: list[float]) -> PlanesGAResult:
        exact = fit.exact_state_makespans(spare_g)   # (k+1, M)
        spare_x = space.to_matrix(spare_g)
        planes = np.concatenate([base_planes, spare_x[None]], axis=0)
        lanes = np.concatenate([base_lanes, spare_g[None].astype(np.int64)],
                               axis=0)
        with np.errstate(invalid="ignore"):
            obj = float((exact / refs).max())
        return PlanesGAResult(
            planes=planes, lane_genomes=lanes, edges=list(space.edges),
            x=planes.sum(axis=0), makespans=exact[0],
            dark_makespans=exact[1:], refs=refs,
            plane_port_limits=tuple(map(tuple, budgets.tolist())),
            objective_value=obj, generations=gen,
            evaluations=fit.evaluations, elapsed=time.time() - t_start,
            history=history, feasible=bool(np.isfinite(exact).all()))

    if space.E == 0:    # no inter-pod traffic: all-dark states are free
        return finish(np.zeros(0, dtype=np.int64), 0, [])

    with span("ga.evolve", kind="delta_planes", pop=opts.pop_size,
              edges=space.E, members=ensemble.num_members,
              planes=num_planes):
        best_g, _, history, gen = _evolve(space, fit, opts, rng, t0, seeds)

    # exact numpy re-rank of the top spare lanes across the full
    # state/member matrix (f32-noise guard)
    ranked = sorted(fit.cache.items(), key=lambda kv: kv[1])[:4]
    best_key, best_score = best_g.tobytes(), INF
    for key, fval in ranked:
        if not np.isfinite(fval):
            continue
        g = np.frombuffer(key, dtype=np.int64)
        exact = fit.exact_state_makespans(g)
        with np.errstate(invalid="ignore"):
            score = float((exact / refs).max())
        if np.isfinite(score):
            score += opts.port_weight * float(g.sum())
        if score < best_score:
            best_score, best_key = score, key
    return finish(np.frombuffer(best_key, dtype=np.int64), gen, history)


def trim_ports_ensemble(ensemble: DagEnsemble, x: np.ndarray,
                        rel_tol: float = 1e-6,
                        backend: str = "auto") -> np.ndarray:
    """Robust analog of `trim_ports`: greedy port minimization certified
    against EVERY ensemble member -- a circuit is dropped only if no
    member's exact (numpy DES) makespan degrades beyond `rel_tol` of its
    value under the input topology.

    Batched like the single-DAG `trim_ports`: each round scores all
    drop-one candidates against all members in ONE
    `EnsembleJaxDES.ensemble_genome_makespan` call (candidates x members
    vmap over the shared compile bucket), then accepts the first fitting
    drop in the legacy cyclic order after certifying it per member with
    the exact numpy DES.  The float32 batch is a pre-filter only; the
    termination backstop exact-rechecks the ambiguous band (see
    `trim_ports`).  'auto' engages the batched path on wide fabrics
    (large union-pair count with enough droppable circuits to amortize
    the jit) and keeps the serial member sweep on fleet-scale ensembles
    of small phase DAGs, where that is faster."""
    problems = [DESProblem(m) for m in ensemble.members]
    x = np.asarray(x)
    base = np.array([simulate(p, x).makespan for p in problems])
    if not np.isfinite(base).all():
        return x
    x = x.copy()
    budgets = base * (1 + rel_tol)
    pairs = ensemble.undirected_pairs()
    E = len(pairs)
    if E == 0:
        return x
    earr = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    eu, ev = earr[:, 0], earr[:, 1]

    def exact_fits(xt: np.ndarray) -> bool:
        return all(simulate(p, xt).makespan <= b
                   for p, b in zip(problems, budgets))

    droppable_total = int(np.maximum(x[eu, ev] - 1, 0).sum())
    # the genome view only covers the union pairs: circuits anywhere else
    # would be invisible to the batched scatter, so fall back to serial
    off_pair = x.copy()
    off_pair[eu, ev] = 0
    off_pair[ev, eu] = 0
    jd = None
    if off_pair.sum() == 0 and (
            backend == "jax"
            or (backend == "auto"
                and max(p.n for p in problems) <= GAOptions.jax_task_limit
                and E >= 16 and droppable_total >= 32)):
        try:
            from repro.core.des_jax import EnsembleJaxDES
            jd = EnsembleJaxDES(problems)
        except Exception:   # pragma: no cover - jax always available here
            jd = None

    ptr = 0   # cyclic sweep pointer (matches trim_ports' pair ordering)
    while True:
        droppable = np.nonzero(x[eu, ev] > 1)[0]
        k = len(droppable)
        if k == 0:
            break
        g0 = x[eu, ev].astype(np.int64)
        G = np.repeat(g0[None], k, axis=0)
        G[np.arange(k), droppable] -= 1
        if jd is not None:
            pad = E - k
            batch = np.concatenate([G, np.repeat(G[:1], pad, axis=0)]) \
                if pad > 0 else G
            ms, feas = jd.ensemble_genome_makespan(batch, eu, ev)
            fits, near = _trim_filter_bands(ms, feas, budgets)
            # a candidate is worth exact-checking only if EVERY member is
            # in band: one member clearly over budget rejects it outright
            fits = fits.all(axis=1)[:k]
            near = near.all(axis=1)[:k]
        else:
            fits = np.ones(k, dtype=bool)   # certified serially below
            near = fits
        accepted = False
        scan = np.argsort((droppable - ptr) % E, kind="stable")
        for certify_band in (fits, ~fits & near) if jd is not None \
                else (fits,):
            for i in scan:
                if not certify_band[i]:
                    continue
                xt = x.copy()
                e = droppable[i]
                xt[eu[e], ev[e]] -= 1
                xt[ev[e], eu[e]] -= 1
                if exact_fits(xt):
                    x = xt
                    ptr = (int(e) + 1) % E
                    accepted = True
                    break
            if accepted:
                break
        if not accepted:
            break
    return x


def trim_ports(dag: CommDAG, x: np.ndarray, rel_tol: float = 1e-6,
               backend: str = "auto") -> np.ndarray:
    """Greedy port minimization for heuristic topologies (beyond-paper
    DELTA-Fast counterpart of Eq. 4): repeatedly drop the circuit whose
    removal leaves the DES makespan unchanged, exploiting the temporal
    slack of non-critical tasks.

    Batched: each round scores *all* drop-one candidates from the current
    topology in a single `JaxDES.batch_makespan` call (padded to a fixed
    shape so XLA compiles once), then accepts the first fitting drop in the
    legacy cyclic sweep order after certifying it against the exact numpy
    DES.  The float32 batch is only a pre-filter (with a conservative
    `_TRIM_FILTER_SLACK` margin): every accept is numpy-certified, so the
    budget is never violated, and before terminating the sweep exact-
    rechecks the batched scores' ambiguous band -- candidates the filter
    rejected by less than `_TRIM_BACKSTOP_BAND`, or flagged infeasible by
    the f32 engine: the only ones a bounded float32 DES error could have
    misjudged -- so termination needs no serial numpy pass over every
    clearly-over-budget candidate.  A float32 false
    negative mid-round can at most reorder accepts relative to the serial
    implementation; on the tested workloads the results are identical
    (see tests/test_ga_vectorized.py).
    """
    problem = DESProblem(dag)
    base = simulate(problem, np.asarray(x)).makespan
    if not np.isfinite(base):
        return x
    x = np.asarray(x).copy()
    budget = base * (1 + rel_tol)
    pairs = dag.undirected_pairs()
    E = len(pairs)
    if E == 0:
        return x
    earr = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    eu, ev = earr[:, 0], earr[:, 1]
    # 'auto' picks the batched path only where it can win: one batched call
    # evaluates E candidates in a single max-lane while_loop pass, so it
    # needs a wide fabric (large E) plus enough potential drops to amortize
    # the one-time XLA compile; on narrow pipeline DAGs (E < 16) the serial
    # numpy sweep is strictly faster and 'auto' keeps the legacy path
    droppable_total = int(np.maximum(x[eu, ev] - 1, 0).sum())
    jd = None
    if backend == "jax" or (backend == "auto"
                            and problem.n <= GAOptions.jax_task_limit
                            and E >= 16 and droppable_total >= 32):
        try:
            from repro.core.des_jax import JaxDES
            jd = JaxDES(problem)
        except Exception:   # pragma: no cover - jax always available here
            jd = None

    ptr = 0   # cyclic sweep pointer (matches the legacy pair ordering)
    while True:
        droppable = np.nonzero(x[eu, ev] > 1)[0]
        k = len(droppable)
        if k == 0:
            break
        xs = np.repeat(x[None], k, axis=0)
        rows = np.arange(k)
        xs[rows, eu[droppable], ev[droppable]] -= 1
        xs[rows, ev[droppable], eu[droppable]] -= 1
        if jd is not None:
            pad = E - k
            batch = np.concatenate([xs, np.repeat(xs[:1], pad, axis=0)]) \
                if pad else xs
            ms, feas = jd.batch_makespan(batch)
            # float32 filter with slack; every accept is numpy-certified
            fits, near = _trim_filter_bands(ms, feas, budget)
            fits, near = fits[:k], near[:k]
        else:
            fits = np.ones(k, dtype=bool)   # certified serially below
            near = fits
        accepted = False
        scan = np.argsort((droppable - ptr) % E, kind="stable")
        # first pass: filter-approved candidates; termination backstop:
        # the batched scores' ambiguous band (~fits & near) -- candidates
        # the float32 filter rejected by less than _TRIM_BACKSTOP_BAND,
        # the only ones a bounded f32 DES error could have misjudged.
        # Rejections beyond the band need no exact re-check, so the
        # termination round no longer re-simulates every candidate with
        # the numpy DES.
        for certify_band in ((fits, ~fits & near) if jd is not None
                             else (fits,)):
            for i in scan:
                if not certify_band[i]:
                    continue
                if simulate(problem, xs[i]).makespan <= budget:
                    x = xs[i]
                    ptr = (int(droppable[i]) + 1) % E
                    accepted = True
                    break
            if accepted:
                break
        if not accepted:
            break
    return x


def exhaustive_search(dag: CommDAG, limit: int = 200000
                      ) -> tuple[np.ndarray, float, int]:
    """Exact topology search by enumeration (tests / tiny instances)."""
    space = TopologySpace(dag)
    problem = DESProblem(dag)
    ranges = [range(1, int(b) + 1) for b in space.xbar]
    total = int(np.prod([len(r) for r in ranges]))
    if total > limit:
        raise ValueError(f"{total} combinations exceed limit {limit}")
    best = (INF, None)
    count = 0
    for combo in itertools.product(*ranges):
        g = np.asarray(combo, dtype=np.int64)
        if not space.is_feasible(g):
            continue
        count += 1
        ms = simulate(problem, space.to_matrix(g)).makespan
        if ms < best[0]:
            best = (ms, g)
    if best[1] is None:
        raise RuntimeError("no feasible topology")
    return space.to_matrix(best[1]), float(best[0]), count
