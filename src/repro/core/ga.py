"""DELTA-Fast: DES-accelerated domain-adapted genetic algorithm
(paper Sec. IV-B, Algs. 3, 5, 6) -- population-array-resident engine.

Genome = integer circuit counts over the active undirected pod pairs,
bounded by the Alg. 2 capacity bounds X̄ and repaired against the physical
port budgets U.  Fitness = DES makespan (primary) and total allocated
circuits (secondary, lexicographic tie-break exploiting O4's port saving).

The whole search loop is array-at-a-time: the population is a single
(pop, E) int array, Alg. 5 init / Alg. 6 repair / tournament selection /
uniform crossover / ±1 mutation are whole-population numpy ops, and fitness
is one fused genome->topology scatter + vmap DES per generation
(`JaxDES.batch_genome_makespan`), padded to a fixed batch shape so XLA
compiles the generation step exactly once.  A vectorized `np.unique` dedup
backed by a bytes-keyed cache keeps duplicate genomes away from the
simulator entirely.

Fitness backends:
  'numpy' -- repro.core.des.simulate per unique candidate
  'jax'   -- repro.core.des_jax fused batched evaluation (TPU-native
             adaptation of ParallelEvalDES)
  'auto'  -- jax for small/medium DAGs, numpy beyond.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.dag import CommDAG
from repro.core.des import DESProblem, simulate
from repro.core.xbound import x_upper_bound

INF = float("inf")


@dataclass
class GAOptions:
    pop_size: int = 48
    max_generations: int = 400
    patience: int = 60            # stop after N gens without improvement
    elite_frac: float = 0.15
    tournament: int = 3
    crossover_rate: float = 0.9
    mutation_rate: float = 0.25   # per-gene probability of a +/-1 step
    seed: int = 0
    backend: str = "auto"         # numpy | jax | auto
    jax_task_limit: int = 1200
    time_limit: float = 120.0
    port_weight: float = 1e-9     # lexicographic secondary objective


@dataclass
class GAResult:
    x: np.ndarray
    makespan: float
    generations: int
    evaluations: int
    elapsed: float
    history: list[float] = field(default_factory=list)
    feasible: bool = True

    @property
    def total_ports(self) -> int:
        return int(self.x.sum())


class TopologySpace:
    """Genome <-> symmetric topology matrix mapping + Algs. 5/6.

    All hot-path operations take whole populations: genomes are rows of a
    (S, E) int array and every transform below is a single numpy expression
    over that array (incidence matvecs, fancy-indexed scatters).
    """

    def __init__(self, dag: CommDAG, xbar: np.ndarray | None = None):
        self.dag = dag
        self.P = dag.cluster.num_pods
        self.U = np.asarray(dag.cluster.port_limits, dtype=np.int64)
        self.edges = dag.undirected_pairs()
        self.E = len(self.edges)
        earr = np.asarray(self.edges, dtype=np.int64).reshape(-1, 2)
        self.edge_u = earr[:, 0]
        self.edge_v = earr[:, 1]
        xbar_m = np.asarray(xbar if xbar is not None else x_upper_bound(dag))
        self.xbar = np.maximum(
            1, np.minimum(xbar_m[self.edge_u, self.edge_v].astype(np.int64),
                          np.minimum(self.U[self.edge_u],
                                     self.U[self.edge_v])))
        # pod x edge incidence (each edge touches exactly two pods)
        self.inc = np.zeros((self.P, self.E), dtype=np.int64)
        self.inc[self.edge_u, np.arange(self.E)] = 1
        self.inc[self.edge_v, np.arange(self.E)] = 1
        self.degree = self.inc.sum(axis=1)
        # quick feasibility: connectivity needs one port per incident edge
        if (self.degree > self.U).any():
            p = int(np.argmax(self.degree - self.U))
            raise ValueError(
                f"pod {p} has {int(self.degree[p])} active pairs but "
                f"only {self.U[p]} ports; placement is infeasible")

    # ------------------------------------------------------ genome <-> matrix
    def genome_of(self, x: np.ndarray) -> np.ndarray:
        """Project a (P, P) topology matrix onto the active-pair genome."""
        return np.asarray(x)[self.edge_u, self.edge_v].astype(np.int64)

    def to_matrix_batch(self, genomes: np.ndarray) -> np.ndarray:
        """(S, E) genomes -> (S, P, P) symmetric topologies in one scatter."""
        G = np.asarray(genomes, dtype=np.int64).reshape(-1, self.E)
        X = np.zeros((len(G), self.P, self.P), dtype=np.int64)
        X[:, self.edge_u, self.edge_v] = G
        X[:, self.edge_v, self.edge_u] = G
        return X

    def to_matrix(self, genome: np.ndarray) -> np.ndarray:
        return self.to_matrix_batch(np.asarray(genome)[None])[0]

    # ------------------------------------------------------------ feasibility
    def port_usage_batch(self, genomes: np.ndarray) -> np.ndarray:
        """(S, E) genomes -> (S, P) ports used per pod (incidence matvec)."""
        return np.asarray(genomes, dtype=np.int64).reshape(-1, self.E) \
            @ self.inc.T

    def port_usage(self, genome: np.ndarray) -> np.ndarray:
        return self.port_usage_batch(np.asarray(genome)[None])[0]

    def is_feasible_batch(self, genomes: np.ndarray) -> np.ndarray:
        G = np.asarray(genomes, dtype=np.int64).reshape(-1, self.E)
        return ((G >= 1).all(axis=1) & (G <= self.xbar).all(axis=1)
                & (self.port_usage_batch(G) <= self.U).all(axis=1))

    def is_feasible(self, genome: np.ndarray) -> bool:
        return bool(self.is_feasible_batch(np.asarray(genome)[None])[0])

    # ---------------------------------------------------------------- Alg. 5
    def random_init_batch(self, rng: np.random.Generator,
                          size: int) -> np.ndarray:
        """Feasible random population: uniform in [1, X̄] then batched
        Alg. 6 repair.  Repair always succeeds here: the constructor
        guarantees degree <= U, and any over-budget pod necessarily has an
        incident edge with g > 1 to reduce."""
        if self.E == 0:
            return np.zeros((size, 0), dtype=np.int64)
        G = rng.integers(1, self.xbar + 1, size=(size, self.E),
                         dtype=np.int64)
        return self.repair_batch(G, rng)[0]

    def feasible_random_init(self, rng: np.random.Generator) -> np.ndarray:
        return self.random_init_batch(rng, 1)[0]

    # ---------------------------------------------------------------- Alg. 6
    def repair_batch(self, genomes: np.ndarray, rng: np.random.Generator
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Whole-population repair: clip to [1, X̄], then per round every
        over-budget pod of every genome drops one circuit from a random
        reducible incident edge (all genomes and pods act simultaneously;
        total over-usage strictly decreases each round, so the loop is
        bounded by the initial excess).  Returns (repaired, ok) where ok[s]
        marks genomes whose port budgets are satisfied."""
        G = np.clip(np.asarray(genomes, dtype=np.int64).reshape(-1, self.E),
                    1, self.xbar)
        S = len(G)
        if self.E == 0 or S == 0:
            return G, np.ones(S, dtype=bool)
        inc_b = self.inc.astype(bool)
        rounds = int(self.xbar.sum()) - self.E + 1
        for _ in range(max(rounds, 1)):
            over = self.port_usage_batch(G) > self.U        # (S, P)
            viol = np.nonzero(over.any(axis=1))[0]
            if len(viol) == 0:
                break
            Gv, overv = G[viol], over[viol]
            keys = rng.random((len(viol), self.E))
            cand = overv[:, :, None] & inc_b[None] & (Gv > 1)[:, None, :]
            masked = np.where(cand, keys[:, None, :], -1.0)  # (V, P, E)
            e_star = masked.argmax(axis=2)                   # (V, P)
            valid = masked.max(axis=2) >= 0.0                # (V, P)
            if not valid.any():
                break
            dec = np.zeros_like(Gv)
            s_idx, p_idx = np.nonzero(valid)
            np.add.at(dec, (s_idx, e_star[s_idx, p_idx]), 1)
            G[viol] = np.maximum(Gv - dec, 1)
        return G, (self.port_usage_batch(G) <= self.U).all(axis=1)

    def repair(self, genome: np.ndarray, rng: np.random.Generator
               ) -> tuple[np.ndarray, bool]:
        G, ok = self.repair_batch(np.asarray(genome)[None], rng)
        return G[0], bool(ok[0])


class BatchedFitness:
    """Population fitness: vectorized dedup + cache + one batched DES call.

    Each call takes the whole (S, E) population, dedups it with
    `np.unique(axis=0)`, looks unique rows up in a bytes-keyed cache, and
    evaluates only the misses -- on the jax backend through the fused
    genome-scatter + vmap-DES entry point, padded to a multiple of
    `pop_size` so the XLA computation compiles once and every generation
    does O(1) host<->device transfers instead of O(pop)."""

    def __init__(self, dag: CommDAG, space: TopologySpace, opts: GAOptions):
        self.problem = DESProblem(dag)
        self.space = space
        self.opts = opts
        self.cache: dict[bytes, float] = {}
        self.evaluations = 0
        self.batch_calls = 0
        use_jax = opts.backend == "jax" or (
            opts.backend == "auto"
            and self.problem.n <= opts.jax_task_limit)
        self._jd = None
        if use_jax and space.E > 0:
            try:
                from repro.core.des_jax import JaxDES
                self._jd = JaxDES(self.problem)
            except Exception:   # pragma: no cover - jax always available here
                self._jd = None
        self._pad = max(int(opts.pop_size), 1)

    def _raw_makespans(self, genomes: np.ndarray) -> np.ndarray:
        """Makespan (INF if infeasible) for each unique genome row."""
        if self._jd is not None:
            k = len(genomes)
            # fixed batch shape (pop_size): XLA compiles the generation step
            # exactly once; extra lanes are near-free on the batched
            # while_loop, whose cost is dominated by the max-lane trip count
            pad = (-k) % self._pad
            if pad:
                genomes = np.concatenate(
                    [genomes, np.repeat(genomes[:1], pad, axis=0)])
            ms, feas = self._jd.batch_genome_makespan(
                genomes, self.space.edge_u, self.space.edge_v)
            self.batch_calls += 1
            return np.where(feas, ms, INF)[:k]
        return np.array([simulate(self.problem, x).makespan
                         for x in self.space.to_matrix_batch(genomes)])

    def __call__(self, population: np.ndarray) -> np.ndarray:
        G = np.ascontiguousarray(
            np.asarray(population, dtype=np.int64).reshape(-1, self.space.E))
        uniq, inv = np.unique(G, axis=0, return_inverse=True)
        inv = np.asarray(inv).reshape(-1)   # numpy 2.x inverse-shape drift
        keys = [row.tobytes() for row in uniq]
        miss = [i for i, key in enumerate(keys) if key not in self.cache]
        if miss:
            self.evaluations += len(miss)
            vals = self._raw_makespans(uniq[miss])
            sums = uniq[miss].sum(axis=1)
            for i, v, s in zip(miss, vals, sums):
                score = float(v)
                if np.isfinite(score):
                    score += self.opts.port_weight * float(s)
                self.cache[keys[i]] = score
        return np.array([self.cache[k] for k in keys])[inv]


# backwards-compatible alias (pre-vectorization name)
_Fitness = BatchedFitness


def _tournament_batch(fitness: np.ndarray, rng: np.random.Generator,
                      num: int, k: int) -> np.ndarray:
    """`num` independent k-way tournaments over the population, at once."""
    idx = rng.integers(0, len(fitness), size=(num, k))
    return idx[np.arange(num), np.argmin(fitness[idx], axis=1)]


def _variation_batch(pop: np.ndarray, fitness: np.ndarray,
                     space: TopologySpace, opts: GAOptions,
                     rng: np.random.Generator, num: int) -> np.ndarray:
    """Selection + uniform crossover + ±1 mutation for `num` children,
    as whole-population array ops (no per-genome loops)."""
    pa = _tournament_batch(fitness, rng, num, opts.tournament)
    pb = _tournament_batch(fitness, rng, num, opts.tournament)
    A, B = pop[pa], pop[pb]
    cross = rng.random(num) < opts.crossover_rate
    take_b = rng.random((num, space.E)) < 0.5
    children = np.where(cross[:, None] & take_b, B, A)
    mut = rng.random((num, space.E)) < opts.mutation_rate
    step = rng.integers(0, 2, size=(num, space.E)) * 2 - 1
    return np.clip(children + np.where(mut, step, 0), 1, space.xbar)


def delta_fast(dag: CommDAG, opts: GAOptions | None = None,
               xbar: np.ndarray | None = None,
               seeds: list[np.ndarray] | None = None) -> GAResult:
    """Alg. 3: SimBasedDomainAdaptedGA (population-array-resident)."""
    opts = opts or GAOptions()
    rng = np.random.default_rng(opts.seed)
    space = TopologySpace(dag, xbar)
    fit = BatchedFitness(dag, space, opts)
    t0 = time.time()

    if space.E == 0:    # no inter-pod traffic: the empty topology is optimal
        x = np.zeros((space.P, space.P), dtype=np.int64)
        ms = simulate(fit.problem, x).makespan
        return GAResult(x=x, makespan=float(ms), generations=0,
                        evaluations=1, elapsed=time.time() - t0,
                        history=[float(ms)], feasible=np.isfinite(ms))

    pop = space.random_init_batch(rng, opts.pop_size)
    # seed candidates (e.g. baselines) -- repaired into the population
    for s in (seeds or []):
        g, ok = space.repair(space.genome_of(s), rng)
        if ok:
            pop[rng.integers(len(pop))] = g
    fitness = fit(pop)
    best_i = int(np.argmin(fitness))
    best_g, best_f = pop[best_i].copy(), float(fitness[best_i])
    history = [best_f]
    n_elite = max(1, int(opts.elite_frac * opts.pop_size))
    num_children = opts.pop_size - n_elite
    stall = 0
    gen = 0

    for gen in range(1, opts.max_generations + 1):
        if time.time() - t0 > opts.time_limit or stall >= opts.patience:
            break
        order = np.argsort(fitness, kind="stable")
        elite = pop[order[:n_elite]]
        children = _variation_batch(pop, fitness, space, opts, rng,
                                    num_children)
        children, _ = space.repair_batch(children, rng)
        pop = np.concatenate([elite, children], axis=0)
        fitness = fit(pop)
        i = int(np.argmin(fitness))
        if fitness[i] < best_f - 1e-15:
            best_f, best_g = float(fitness[i]), pop[i].copy()
            stall = 0
        else:
            stall += 1
        history.append(best_f)

    # re-rank the best distinct candidates with the exact numpy DES (the
    # batched jax fitness may run in float32; ~1e-5 ranking noise)
    ranked = sorted(fit.cache.items(), key=lambda kv: kv[1])[:8]
    best_x, best_ms = space.to_matrix(best_g), INF
    for key, fval in ranked:
        if not np.isfinite(fval):
            continue
        g = np.frombuffer(key, dtype=np.int64)
        x = space.to_matrix(g)
        ms = simulate(fit.problem, x).makespan
        port_pen = opts.port_weight * float(g.sum())
        if ms + port_pen < best_ms:
            best_ms, best_x = ms + port_pen, x
    ms = simulate(fit.problem, best_x).makespan
    return GAResult(x=best_x, makespan=float(ms), generations=gen,
                    evaluations=fit.evaluations, elapsed=time.time() - t0,
                    history=history, feasible=np.isfinite(ms))


def trim_ports(dag: CommDAG, x: np.ndarray, rel_tol: float = 1e-6,
               backend: str = "auto") -> np.ndarray:
    """Greedy port minimization for heuristic topologies (beyond-paper
    DELTA-Fast counterpart of Eq. 4): repeatedly drop the circuit whose
    removal leaves the DES makespan unchanged, exploiting the temporal
    slack of non-critical tasks.

    Batched: each round scores *all* drop-one candidates from the current
    topology in a single `JaxDES.batch_makespan` call (padded to a fixed
    shape so XLA compiles once), then accepts the first fitting drop in the
    legacy cyclic sweep order after certifying it against the exact numpy
    DES.  The float32 batch is only a pre-filter (with a conservative
    1e-3 slack margin): every accept is numpy-certified, so the budget is
    never violated, and before terminating, any candidates the filter
    rejected are re-checked serially with the exact DES -- the sweep never
    stops while a single drop is still acceptable, matching the legacy
    termination condition.  A float32 false negative mid-round can at most
    reorder accepts relative to the serial implementation; on the tested
    workloads the results are identical (see tests/test_ga_vectorized.py).
    """
    problem = DESProblem(dag)
    base = simulate(problem, np.asarray(x)).makespan
    if not np.isfinite(base):
        return x
    x = np.asarray(x).copy()
    budget = base * (1 + rel_tol)
    pairs = dag.undirected_pairs()
    E = len(pairs)
    if E == 0:
        return x
    earr = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    eu, ev = earr[:, 0], earr[:, 1]
    # 'auto' picks the batched path only where it can win: one batched call
    # evaluates E candidates in a single max-lane while_loop pass, so it
    # needs a wide fabric (large E) plus enough potential drops to amortize
    # the one-time XLA compile; on narrow pipeline DAGs (E < 16) the serial
    # numpy sweep is strictly faster and 'auto' keeps the legacy path
    droppable_total = int(np.maximum(x[eu, ev] - 1, 0).sum())
    jd = None
    if backend == "jax" or (backend == "auto"
                            and problem.n <= GAOptions.jax_task_limit
                            and E >= 16 and droppable_total >= 32):
        try:
            from repro.core.des_jax import JaxDES
            jd = JaxDES(problem)
        except Exception:   # pragma: no cover - jax always available here
            jd = None

    ptr = 0   # cyclic sweep pointer (matches the legacy pair ordering)
    while True:
        droppable = np.nonzero(x[eu, ev] > 1)[0]
        k = len(droppable)
        if k == 0:
            break
        xs = np.repeat(x[None], k, axis=0)
        rows = np.arange(k)
        xs[rows, eu[droppable], ev[droppable]] -= 1
        xs[rows, ev[droppable], eu[droppable]] -= 1
        if jd is not None:
            pad = E - k
            batch = np.concatenate([xs, np.repeat(xs[:1], pad, axis=0)]) \
                if pad else xs
            ms, feas = jd.batch_makespan(batch)
            # float32 filter with slack; every accept is numpy-certified
            fits = (feas & (ms <= budget * (1 + 1e-3) + 1e-12))[:k]
        else:
            fits = np.ones(k, dtype=bool)   # certified serially below
        accepted = False
        scan = np.argsort((droppable - ptr) % E, kind="stable")
        for i in scan:
            if not fits[i]:
                continue
            if simulate(problem, xs[i]).makespan <= budget:
                x = xs[i]
                ptr = (int(droppable[i]) + 1) % E
                accepted = True
                break
        if not accepted and jd is not None and not fits.all():
            # termination backstop: re-check filter-rejected candidates
            # with the exact DES so a float32 false negative can never end
            # the sweep while a drop is still acceptable
            for i in scan:
                if fits[i]:
                    continue
                if simulate(problem, xs[i]).makespan <= budget:
                    x = xs[i]
                    ptr = (int(droppable[i]) + 1) % E
                    accepted = True
                    break
        if not accepted:
            break
    return x


def exhaustive_search(dag: CommDAG, limit: int = 200000
                      ) -> tuple[np.ndarray, float, int]:
    """Exact topology search by enumeration (tests / tiny instances)."""
    space = TopologySpace(dag)
    problem = DESProblem(dag)
    ranges = [range(1, int(b) + 1) for b in space.xbar]
    total = int(np.prod([len(r) for r in ranges]))
    if total > limit:
        raise ValueError(f"{total} combinations exceed limit {limit}")
    best = (INF, None)
    count = 0
    for combo in itertools.product(*ranges):
        g = np.asarray(combo, dtype=np.int64)
        if not space.is_feasible(g):
            continue
        count += 1
        ms = simulate(problem, space.to_matrix(g)).makespan
        if ms < best[0]:
            best = (ms, g)
    if best[1] is None:
        raise RuntimeError("no feasible topology")
    return space.to_matrix(best[1]), float(best[0]), count
