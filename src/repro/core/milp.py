"""Variable-length time-interval MILP (paper Sec. III-B, Eqs. 3-18).

Decision variables (per Fig. 4):
  x_e (integer circuits per undirected pod pair; Eq. 6 symmetry is built in),
  beta_{e,b} (binary expansion, Eq. 7), t_k / Delta_k (interval boundaries /
  durations), rho_{e,b,k} (Big-M linearized beta * Delta, Eq. 8),
  w_{m,k} (volume), y_{m,k} (activation), s_flag_{m,k} (rising edge),
  S_m / C_m / C, u_{p,k} (optional fairness reference, Eq. 17).

Solved with HiGHS via scipy.optimize.milp (Gurobi is unavailable offline;
see DESIGN.md).  Hot starting is realized as (a) an objective upper-bound
cut C <= C_incumbent and (b) a polish pre-pass that fixes the activation
pattern y to the DES trace and solves the restricted MILP to produce a
valid incumbent -- both prune branch & bound like a MIP start.

DELTA-Topo  = solve(..., fairness=True)   (rates degrade to fair sharing)
DELTA-Joint = solve(..., fairness=False)  (joint topology + rate control)
Port minimization (Eq. 4) = second lexicographic solve with C <= C*.

Internally volumes are scaled to GB and rates to GB/s to keep the
constraint matrix well conditioned.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from dataclasses import dataclass, field

import numpy as np
import scipy.sparse as sp
from scipy.optimize import Bounds, LinearConstraint, milp

from repro.core.dag import VIRTUAL, CommDAG, DagEnsemble
from repro.core.des import DESProblem, DESResult, simulate
from repro.core.pruning import (IndexWindows, estimate_t_up, profile_anchors,
                                task_time_index_pruning)
from repro.core.xbound import x_upper_bound
from repro.obs import get_counter, span

VOL = 1e9  # internal volume unit (GB)

_SOLVES = get_counter("milp_solves_total",
                      "MILP solver invocations by terminal status")
_FALLBACKS = get_counter(
    "fleet_fallbacks_total",
    "solve_resilient fallback transitions, by chain stage")


@dataclass
class MILPOptions:
    fairness: bool = False          # True: DELTA-Topo; False: DELTA-Joint
    port_min: bool = False          # lexicographic Eq. (4) second phase
    prune: bool = True              # Alg. 1 index windows
    anchor_margin: int = 1
    K: int | None = None            # default: profiled from baseline DES
    k_slack: int = 0                # extra intervals appended after K
    time_limit: float = 600.0
    mip_rel_gap: float = 1e-4
    hot_start: bool = True
    upper_bound: float | None = None   # externally supplied incumbent C
    seed_x: np.ndarray | None = None   # incumbent topology (e.g. delta-fast)
                                       # whose DES trace seeds the hot start
    xbar: np.ndarray | None = None     # Alg. 2 bounds (computed if None)
    t_up: float | None = None
    verbose: bool = False


@dataclass
class MILPResult:
    x: np.ndarray                 # (P, P) symmetric circuits
    makespan: float
    status: str
    solve_time: float
    start: np.ndarray             # S_m (n,)
    finish: np.ndarray            # C_m (n,)
    t: np.ndarray                 # interval boundaries t_1..t_{K+1}
    w: dict[tuple[int, int], float] = field(default_factory=dict)
    y: dict[tuple[int, int], int] = field(default_factory=dict)
    total_ports: int = 0
    port_min_applied: bool = False
    stats: dict = field(default_factory=dict)
    degraded: bool = False        # produced by a solve_resilient fallback
    fallback_stage: str = ""      # "" | "ga" | "current"

    @property
    def feasible(self) -> bool:
        # a time_limit return with no incumbent carries makespan=inf: the
        # finite check turns it into a clean fallback trigger instead of a
        # silently-invalid plan (see solve_resilient)
        return self.status in ("optimal", "feasible", "time_limit") \
            and bool(np.isfinite(self.makespan))


class _Model:
    """Sparse MILP assembler (lb <= A z <= ub)."""

    def __init__(self):
        self.nvar = 0
        self.lb: list[float] = []
        self.ub: list[float] = []
        self.integrality: list[int] = []
        self.obj: dict[int, float] = {}
        self.rows_i: list[int] = []
        self.rows_j: list[int] = []
        self.rows_v: list[float] = []
        self.row_lb: list[float] = []
        self.row_ub: list[float] = []
        self.nrow = 0

    def var(self, lb: float, ub: float, integer: bool = False) -> int:
        self.lb.append(lb)
        self.ub.append(ub)
        self.integrality.append(1 if integer else 0)
        self.nvar += 1
        return self.nvar - 1

    def vars(self, n: int, lb: float, ub: float, integer: bool = False
             ) -> np.ndarray:
        out = np.arange(self.nvar, self.nvar + n)
        self.lb += [lb] * n
        self.ub += [ub] * n
        self.integrality += [1 if integer else 0] * n
        self.nvar += n
        return out

    def row(self, coeffs: dict[int, float], lb: float, ub: float) -> None:
        for j, v in coeffs.items():
            if v != 0.0:
                self.rows_i.append(self.nrow)
                self.rows_j.append(j)
                self.rows_v.append(v)
        self.row_lb.append(lb)
        self.row_ub.append(ub)
        self.nrow += 1

    def solve(self, time_limit: float, mip_rel_gap: float, verbose: bool,
              phase: str = "main") -> tuple[str, np.ndarray | None, dict]:
        with span("milp.solve", phase=phase, nvars=self.nvar,
                  nrows=self.nrow) as sp_:
            c = np.zeros(self.nvar)
            for j, v in self.obj.items():
                c[j] = v
            A = sp.csc_matrix(
                (self.rows_v, (self.rows_i, self.rows_j)),
                shape=(self.nrow, self.nvar))
            res = milp(
                c=c,
                constraints=LinearConstraint(A, np.asarray(self.row_lb),
                                             np.asarray(self.row_ub)),
                bounds=Bounds(np.asarray(self.lb), np.asarray(self.ub)),
                integrality=np.asarray(self.integrality),
                options={"time_limit": time_limit,
                         "mip_rel_gap": mip_rel_gap, "disp": verbose},
            )
            status = {0: "optimal", 1: "iteration_limit", 2: "infeasible",
                      3: "unbounded", 4: "error"}.get(res.status, "error")
            if status == "iteration_limit":
                # the budget expired; with no incumbent (res.x is None) the
                # caller's z-None path returns makespan=inf, which the
                # finite-makespan `feasible` guard turns into a clean
                # fallback trigger rather than a silently-invalid plan
                status = "time_limit"
            sp_.set(status=status)
            _SOLVES.inc(phase=phase, status=status)
            info = {"mip_gap": getattr(res, "mip_gap", None),
                    "nvars": self.nvar, "nrows": self.nrow,
                    "message": res.message}
            return status, res.x, info


@dataclass
class _Layout:
    """Variable indices one assembled model's *extraction* needs.

    Assembly-only index maps (edge_of, Lbits, beta, rho, u) live as locals
    in the builders: storing them here was write-only plumbing (RPR001).
    """
    edges: list[tuple[int, int]]
    x: np.ndarray
    t: np.ndarray
    delta: np.ndarray
    w: dict[tuple[int, int], int]
    y: dict[tuple[int, int], int]
    s: dict[tuple[int, int], int]
    S: np.ndarray
    Cm: np.ndarray
    C: int
    K: int
    windows: IndexWindows


def _build_topology(md: _Model, cluster, edges: list[tuple[int, int]],
                    xbar: np.ndarray
                    ) -> tuple[np.ndarray, list[np.ndarray], list[int],
                               dict[tuple[int, int], int]]:
    """Shared topology block: x_e + Eq. (7) binary expansion + Eq. (5)
    port budgets.  Factored out of `_build` so the robust formulation can
    attach several per-member schedule blocks to ONE port allocation."""
    U = cluster.port_limits
    edge_of: dict[tuple[int, int], int] = {}
    for e_idx, (i, j) in enumerate(edges):
        edge_of[(i, j)] = e_idx
        edge_of[(j, i)] = e_idx

    # ---- x_e and binary expansion
    xv = np.empty(len(edges), dtype=np.int64)
    beta: list[np.ndarray] = []
    Lbits: list[int] = []
    for e_idx, (i, j) in enumerate(edges):
        hi = int(min(U[i], U[j], xbar[i, j]))
        hi = max(hi, 1)
        xv[e_idx] = md.var(1, hi, integer=True)
        L = int(np.floor(np.log2(hi))) + 1
        Lbits.append(L)
        beta.append(md.vars(L, 0, 1, integer=True))
        # Eq. (7)
        coeffs = {int(xv[e_idx]): 1.0}
        for b in range(L):
            coeffs[int(beta[e_idx][b])] = -(2.0 ** b)
        md.row(coeffs, 0.0, 0.0)

    # ---- Eq. (5): port budgets (symmetric circuits: one row per pod)
    for p in range(cluster.num_pods):
        coeffs = {int(xv[e]): 1.0 for e, (i, j) in enumerate(edges)
                  if i == p or j == p}
        if coeffs:
            md.row(coeffs, -np.inf, float(U[p]))
    return xv, beta, Lbits, edge_of


def _build_member(md: _Model, dag: CommDAG, fairness: bool,
                  windows: IndexWindows, t_up: float,
                  edges: list[tuple[int, int]],
                  edge_of: dict[tuple[int, int], int], xv: np.ndarray,
                  beta: list[np.ndarray], Lbits: list[int]) -> _Layout:
    """One member's schedule block (Eqs. 8-18 + optional Eq. 17) wired to
    the shared topology variables.  Every time/volume/activation variable
    is private to the member; only x/beta are shared."""
    n = dag.num_tasks
    K = windows.K
    B = dag.cluster.nic_bandwidth / VOL
    T = t_up

    vol = dag.volumes() / VOL
    flows = dag.flows()

    # ---- time variables
    tv = md.vars(K + 1, 0.0, T)
    md.ub[tv[0]] = 0.0  # t_1 = 0
    dv = md.vars(K, 0.0, T)
    for k in range(K):
        # Eq. (14): delta_k - t_{k+1} + t_k = 0
        md.row({int(dv[k]): 1.0, int(tv[k + 1]): -1.0, int(tv[k]): 1.0},
               0.0, 0.0)

    # ---- task windows and w/y/s variables
    wv: dict[tuple[int, int], int] = {}
    yv: dict[tuple[int, int], int] = {}
    sv: dict[tuple[int, int], int] = {}
    for m in range(1, n):
        for k in windows.allowed(m):
            wv[(m, k)] = md.var(0.0, float(vol[m]))
            yv[(m, k)] = md.var(0, 1, integer=True)
            sv[(m, k)] = md.var(0, 1, integer=True)

    Sv = np.zeros(n, dtype=np.int64)
    Cv = np.zeros(n, dtype=np.int64)
    for m in range(1, n):
        Sv[m] = md.var(0.0, T)
        Cv[m] = md.var(0.0, T)
    Cvar = md.var(0.0, T)

    # which intervals matter per ordered pair / per edge
    pair_ks: dict[tuple[int, int], set[int]] = {}
    for t_ in dag.real_tasks():
        ks = pair_ks.setdefault(t_.pair, set())
        ks.update(windows.allowed(t_.tid))
    edge_ks: dict[int, set[int]] = {}
    for pair, ks in pair_ks.items():
        edge_ks.setdefault(edge_of[pair], set()).update(ks)

    # ---- rho vars + Eq. (8) Big-M linearization (only needed (e, b, k))
    rho: dict[tuple[int, int], np.ndarray] = {}
    for e_idx in range(len(edges)):
        ks = sorted(edge_ks.get(e_idx, ()))
        for b in range(Lbits[e_idx]):
            arr = np.full(K + 1, -1, dtype=np.int64)
            for k in ks:
                r = md.var(0.0, T)
                arr[k] = r
                bvar = int(beta[e_idx][b])
                md.row({r: 1.0, bvar: -T}, -np.inf, 0.0)
                md.row({r: 1.0, int(dv[k - 1]): -1.0}, -np.inf, 0.0)
                md.row({r: 1.0, int(dv[k - 1]): -1.0, bvar: -T}, -T, np.inf)
            rho[(e_idx, b)] = arr

    # ---- Eq. (9): link capacity per ordered pair & interval
    tasks_on = dag.tasks_on_pair()
    for pair, tids in tasks_on.items():
        e_idx = edge_of[pair]
        for k in sorted(pair_ks[pair]):
            coeffs: dict[int, float] = {}
            for m in tids:
                if (m, k) in wv:
                    coeffs[wv[(m, k)]] = 1.0
            if not coeffs:
                continue
            for b in range(Lbits[e_idx]):
                coeffs[int(rho[(e_idx, b)][k])] = -B * (2.0 ** b)
            md.row(coeffs, -np.inf, 0.0)

    # ---- Eq. (10): NIC injection/reception per class & interval
    src_classes, dst_classes = dag.nic_classes()
    for tids, _ in src_classes + dst_classes:
        ks = set()
        for m in tids:
            ks.update(windows.allowed(m))
        for k in sorted(ks):
            coeffs: dict[int, float] = {}
            for m in tids:
                if (m, k) in wv:
                    coeffs[wv[(m, k)]] = 1.0 / flows[m]
            if not coeffs:
                continue
            coeffs[int(dv[k - 1])] = -B
            md.row(coeffs, -np.inf, 0.0)

    # ---- Eqs. (11)-(13): conservation, activation, single rising edge
    for m in range(1, n):
        ks = list(windows.allowed(m))
        md.row({wv[(m, k)]: 1.0 for k in ks}, float(vol[m]), float(vol[m]))
        for k in ks:
            md.row({wv[(m, k)]: 1.0, yv[(m, k)]: -float(vol[m])},
                   -np.inf, 0.0)
            coeffs = {sv[(m, k)]: 1.0, yv[(m, k)]: -1.0}
            if (m, k - 1) in yv:
                coeffs[yv[(m, k - 1)]] = 1.0
            md.row(coeffs, 0.0, np.inf)
        md.row({sv[(m, k)]: 1.0 for k in ks}, 1.0, 1.0)

    # ---- Eq. (15): temporal boundaries
    for (m, k), y_ in yv.items():
        md.row({int(Sv[m]): 1.0, int(tv[k - 1]): -1.0, y_: T}, -np.inf, T)
        md.row({int(Cv[m]): 1.0, int(tv[k]): -1.0, y_: -T}, -T, np.inf)

    # ---- Eq. (16): DAG precedence (virtual predecessor -> S lower bound)
    for d in dag.deps:
        if d.pre == VIRTUAL:
            md.lb[int(Sv[d.succ])] = max(md.lb[int(Sv[d.succ])],
                                         float(d.delta))
        else:
            md.row({int(Sv[d.succ]): 1.0, int(Cv[d.pre]): -1.0},
                   float(d.delta), np.inf)

    # ---- Eq. (18): makespan
    for m in range(1, n):
        md.row({Cvar: 1.0, int(Cv[m]): -1.0}, 0.0, np.inf)

    # ---- Eq. (17): optional fairness constraints
    uv: dict[tuple[int, int], int] = {}
    if fairness:
        for pair, tids in tasks_on.items():
            # tight Big-M: per-flow volume on this pair never exceeds the
            # largest per-flow task volume crossing it
            Mu = max(float(vol[m]) / float(flows[m]) for m in tids)
            for k in sorted(pair_ks[pair]):
                u_ = md.var(0.0, Mu)
                uv[(edge_of[pair], k)] = u_  # keyed per *ordered* pair use
                for m in tids:
                    if (m, k) not in wv:
                        continue
                    y_ = yv[(m, k)]
                    f = float(flows[m])
                    md.row({wv[(m, k)]: 1.0 / f, u_: -1.0, y_: Mu},
                           -np.inf, Mu)
                    md.row({u_: 1.0, wv[(m, k)]: -1.0 / f, y_: Mu},
                           -np.inf, Mu)

    return _Layout(edges=edges, x=xv, t=tv, delta=dv, w=wv, y=yv, s=sv,
                   S=Sv, Cm=Cv, C=Cvar, K=K, windows=windows)


def _build(dag: CommDAG, opts: MILPOptions, windows: IndexWindows,
           xbar: np.ndarray, t_up: float) -> tuple[_Model, _Layout]:
    """Single-DAG model: one topology block + one member block."""
    md = _Model()
    edges = dag.undirected_pairs()
    xv, beta, Lbits, edge_of = _build_topology(md, dag.cluster, edges, xbar)
    layout = _build_member(md, dag, opts.fairness, windows, t_up, edges,
                           edge_of, xv, beta, Lbits)
    return md, layout


def _extract(dag: CommDAG, md: _Model, lay: _Layout, z: np.ndarray,
             status: str, solve_time: float, stats: dict) -> MILPResult:
    P = dag.cluster.num_pods
    x = np.zeros((P, P), dtype=np.int64)
    for e_idx, (i, j) in enumerate(lay.edges):
        v = int(round(z[lay.x[e_idx]]))
        x[i, j] = x[j, i] = v
    n = dag.num_tasks
    # Tighten S_m / C_m to the actual transmission boundaries: the MILP only
    # brackets them (S <= first active t_k, C >= last active t_{k+1}), so we
    # recompute them from the activation pattern y and the solved interval
    # boundaries t.  This matters for critical-path extraction (NCT).
    start = np.zeros(n)
    finish = np.zeros(n)
    tgrid = z[lay.t]
    for m in range(1, n):
        # prefer intervals that actually carry volume (y may be spuriously 1
        # with w == 0 on non-critical tasks); fall back to the y pattern
        allowed = list(lay.windows.allowed(m))
        wvals = {k: float(z[lay.w[(m, k)]]) for k in allowed}
        wmax = max(wvals.values(), default=0.0)
        ks = [k for k in allowed if wvals[k] > 1e-7 * max(wmax, 1e-12)]
        if not ks:
            ks = [k for k in allowed if z[lay.y[(m, k)]] > 0.5]
        if ks:
            start[m] = tgrid[min(ks) - 1]
            finish[m] = tgrid[max(ks)]
        else:  # pragma: no cover - (13) forbids this
            start[m] = z[lay.S[m]]
            finish[m] = z[lay.Cm[m]]
    w = {k: float(v) * VOL for k, v in
         ((key, z[idx]) for key, idx in lay.w.items()) if v > 1e-9}
    y = {key: int(round(z[idx])) for key, idx in lay.y.items()
         if z[idx] > 0.5}
    return MILPResult(
        x=x, makespan=float(z[lay.C]), status=status, solve_time=solve_time,
        start=start, finish=finish, t=z[lay.t], w=w, y=y,
        total_ports=int(x.sum()), stats=stats)


def _apply_hot_start(md: _Model, lay: _Layout, dag: CommDAG,
                     baseline: DESResult, t_up: float) -> _Model:
    """Polish pre-pass: fix y/s to the DES trace -> restricted MILP."""
    fixed = dataclasses.replace  # noqa: F841  (documentation hook)
    import copy
    md2 = copy.deepcopy(md)
    ti = baseline.task_interval
    for (m, k), idx in lay.y.items():
        val = 1.0 if ti[m, 0] <= k <= ti[m, 1] else 0.0
        md2.lb[idx] = md2.ub[idx] = val
    for (m, k), idx in lay.s.items():
        val = 1.0 if k == ti[m, 0] else 0.0
        md2.lb[idx] = md2.ub[idx] = val
    return md2


def solve_delta_milp(dag: CommDAG, opts: MILPOptions | None = None
                     ) -> MILPResult:
    """DELTA-Topo / DELTA-Joint MILP with pruning, hot start and the
    optional lexicographic port-minimization phase."""
    opts = opts or MILPOptions()
    t0 = time.time()
    problem = DESProblem(dag)
    baseline, anchors, K_prof = profile_anchors(problem)
    if opts.seed_x is not None:
        # seed the anchors/polish trace from an incumbent topology (the
        # GA's array-resident result): the hot-start pre-pass then fixes
        # the activation pattern to a near-optimal schedule instead of the
        # one-circuit baseline.  K keeps the default profile as a floor so
        # the seeded windows never have fewer intervals than the baseline.
        with contextlib.suppress(RuntimeError):
            # an infeasible seed keeps the default profile
            sb, sa, sk = profile_anchors(problem, np.asarray(opts.seed_x))
            baseline, anchors, K_prof = sb, sa, max(sk, K_prof)
    t_up = opts.t_up or estimate_t_up(problem)
    K = opts.K or (K_prof + opts.k_slack)
    if opts.prune:
        windows = task_time_index_pruning(dag, K, anchors,
                                          anchor_margin=opts.anchor_margin)
    else:
        windows = task_time_index_pruning(dag, K, anchors=None)
    xbar = opts.xbar if opts.xbar is not None else \
        x_upper_bound(dag, t_up=t_up)

    with span("milp.build", K=K, tasks=dag.num_tasks):
        md, lay = _build(dag, opts, windows, xbar, t_up)
    md.obj = {lay.C: 1.0}
    prep_time = time.time() - t0

    incumbent = opts.upper_bound
    hot_time = 0.0
    if opts.hot_start:
        th = time.time()
        md_hot = _apply_hot_start(md, lay, dag, baseline, t_up)
        md_hot.obj = {lay.C: 1.0}
        st_h, z_h, _ = md_hot.solve(min(opts.time_limit / 4, 60.0),
                                    1e-3, False, phase="hot_start")
        if st_h in ("optimal", "time_limit") and z_h is not None:
            cand = float(z_h[lay.C]) * (1 + 1e-6) + 1e-9
            incumbent = min(incumbent, cand) if incumbent else cand
        hot_time = time.time() - th
    if incumbent is not None:
        md.ub[lay.C] = min(md.ub[lay.C], incumbent)

    ts = time.time()
    status, z, info = md.solve(opts.time_limit, opts.mip_rel_gap,
                               opts.verbose)
    solve_time = time.time() - ts
    if z is None:
        P = dag.cluster.num_pods
        return MILPResult(x=np.zeros((P, P), dtype=np.int64), makespan=np.inf,
                          status=status, solve_time=solve_time,
                          start=np.zeros(dag.num_tasks),
                          finish=np.zeros(dag.num_tasks),
                          t=np.zeros(K + 1),
                          stats={**info, "prep_time": prep_time,
                                 "hot_time": hot_time})
    info.update(prep_time=prep_time, hot_time=hot_time, K=K,
                kept_mk=windows.num_task_intervals(),
                incumbent=incumbent)
    result = _extract(dag, md, lay, z, status, solve_time, info)

    if opts.port_min and result.feasible:
        tp = time.time()
        md.ub[lay.C] = result.makespan * (1 + 1e-6) + 1e-9
        md.obj = {int(lay.x[e]): 1.0 for e in range(len(lay.edges))}
        st2, z2, info2 = md.solve(opts.time_limit, opts.mip_rel_gap,
                                  opts.verbose, phase="port_min")
        if st2 in ("optimal", "time_limit") and z2 is not None:
            r2 = _extract(dag, md, lay, z2, st2, time.time() - tp,
                          {**result.stats, "phase2": info2})
            r2.port_min_applied = True
            # keep phase-1 makespan (phase 2 only reduces ports)
            r2.makespan = min(result.makespan, r2.makespan) \
                if np.isfinite(r2.makespan) else result.makespan
            r2.solve_time = result.solve_time + r2.solve_time
            return r2
    return result


# ------------------------------------------------------------- DELTA-Robust
@dataclass
class RobustMILPResult:
    """Shared-x multi-member MILP solution."""

    x: np.ndarray                  # (P, P) the one shared topology
    makespans: np.ndarray          # (M,) per-member schedule makespans
    objective: str                 # weighted | max-regret
    objective_value: float
    status: str
    solve_time: float
    members: list[MILPResult] = field(default_factory=list)
    refs: np.ndarray | None = None
    stats: dict = field(default_factory=dict)

    @property
    def feasible(self) -> bool:
        # same finite guard as MILPResult: a budget expiry without an
        # incumbent must read infeasible, not silently valid
        return self.status in ("optimal", "feasible", "time_limit") \
            and bool(np.isfinite(self.makespans).all())

    @property
    def total_ports(self) -> int:
        return int(self.x.sum())


def solve_robust_milp(ensemble: DagEnsemble,
                      opts: MILPOptions | None = None,
                      objective: str = "weighted",
                      refs: np.ndarray | None = None) -> RobustMILPResult:
    """One shared port allocation, one schedule block per ensemble member.

    The Eq. 5-7 topology variables (x_e over the *union* of the members'
    active pairs, plus the binary expansion) are built once; every member
    then contributes its own Eq. 8-18 task/interval block (with its own
    per-member `task_time_index_pruning` windows and time grid) wired to
    the shared beta bits.  Objectives:

      weighted   : minimize sum_m w_m * C^m
      max-regret : minimize Z subject to Z >= C^m / refs_m (epigraph)

    `refs` (per-member reference makespans, e.g. the members' best
    single-DAG plans) are required for max-regret; when omitted they are
    computed by per-member `solve_delta_milp` runs with the same options.
    `opts.seed_x` (e.g. a delta-robust GA incumbent) adds a valid
    objective-level incumbent cut from its per-member DES makespans.
    `opts.port_min` runs the usual lexicographic second phase at a fixed
    objective value.
    """
    opts = opts or MILPOptions()
    if objective not in ("weighted", "max-regret"):
        raise ValueError(f"unknown objective {objective!r}")
    t0 = time.time()
    weights = np.asarray(ensemble.weights, dtype=np.float64)

    if refs is None and objective == "max-regret":
        single_opts = dataclasses.replace(opts, port_min=False, seed_x=None)
        refs = np.array([solve_delta_milp(m, single_opts).makespan
                         for m in ensemble.members])
    if refs is not None:
        refs = np.asarray(refs, dtype=np.float64)
        if refs.shape != (ensemble.num_members,):
            raise ValueError("refs must have one entry per member")
        if objective == "max-regret" and not (
                np.isfinite(refs) & (refs > 0)).all():
            raise ValueError(f"max-regret needs finite positive refs: {refs}")

    # per-member pruning profiles + the union topology bound
    problems = [DESProblem(m) for m in ensemble.members]
    windows_m: list[IndexWindows] = []
    t_up_m: list[float] = []
    xbar_u = None
    for dag_m, problem in zip(ensemble.members, problems):
        _, anchors, K_prof = profile_anchors(problem)
        if opts.seed_x is not None:
            # same guard as solve_delta_milp: the seed's objective cut
            # below is only attainable if the pruned windows can express
            # a schedule under the seed topology, so re-profile from it
            # (K keeps the baseline profile as a floor)
            with contextlib.suppress(RuntimeError):
                # an infeasible seed on this member keeps the default
                _, sa, sk = profile_anchors(problem,
                                            np.asarray(opts.seed_x))
                anchors, K_prof = sa, max(sk, K_prof)
        t_up = opts.t_up or estimate_t_up(problem)
        K = opts.K or (K_prof + opts.k_slack)
        anchors_used = anchors if opts.prune else None
        windows_m.append(task_time_index_pruning(
            dag_m, K, anchors_used, anchor_margin=opts.anchor_margin))
        t_up_m.append(t_up)
        xbar = opts.xbar if opts.xbar is not None else \
            x_upper_bound(dag_m, t_up=t_up)
        xbar_u = xbar if xbar_u is None else np.maximum(xbar_u, xbar)

    with span("milp.build", members=ensemble.num_members):
        md = _Model()
        edges = ensemble.undirected_pairs()
        xv, beta, Lbits, edge_of = _build_topology(md, ensemble.cluster,
                                                   edges, xbar_u)
        lays = [_build_member(md, dag_m, opts.fairness, win, t_up, edges,
                              edge_of, xv, beta, Lbits)
                for dag_m, win, t_up in zip(ensemble.members, windows_m,
                                            t_up_m)]

    # ---- objective
    if objective == "weighted":
        md.obj = {int(lay.C): float(w) for lay, w in zip(lays, weights)}
        obj_of = lambda z: float(sum(      # noqa: E731 - local reducer
            w * z[lay.C] for lay, w in zip(lays, weights)))
    else:
        z_ub = max(t / r for t, r in zip(t_up_m, refs))
        Z = md.var(0.0, float(z_ub))
        for lay, r in zip(lays, refs):
            md.row({Z: float(r), int(lay.C): -1.0}, 0.0, np.inf)
        # epsilon tie-break on the member makespans: the epigraph objective
        # alone leaves every non-binding C^m floating up to Z * ref_m
        eps = 1e-5
        md.obj = {Z: 1.0, **{int(lay.C): eps * float(w) / float(r)
                             for lay, w, r in zip(lays, weights, refs)}}
        obj_of = lambda z: float(z[Z])     # noqa: E731 - local reducer

    # ---- incumbent cut from a seed topology (GA result): its per-member
    # fair-share DES makespans are simultaneously achievable by one x, so
    # bounding the *objective* (never the individual C^m) is valid
    if opts.seed_x is not None:
        seed_ms = np.array([simulate(p, np.asarray(opts.seed_x)).makespan
                            for p in problems])
        if np.isfinite(seed_ms).all():
            slack = (1 + 1e-6)
            if objective == "weighted":
                cut = float(weights @ seed_ms) * slack + 1e-9
                md.row({int(lay.C): float(w)
                        for lay, w in zip(lays, weights)}, -np.inf, cut)
            else:
                md.ub[Z] = min(md.ub[Z],
                               float((seed_ms / refs).max()) * slack + 1e-9)
    prep_time = time.time() - t0

    ts = time.time()
    status, z, info = md.solve(opts.time_limit, opts.mip_rel_gap,
                               opts.verbose)
    solve_time = time.time() - ts
    P = ensemble.cluster.num_pods
    stats = {**info, "prep_time": prep_time,
             "K": [w.K for w in windows_m]}
    if z is None:
        return RobustMILPResult(
            x=np.zeros((P, P), dtype=np.int64),
            makespans=np.full(ensemble.num_members, np.inf),
            objective=objective, objective_value=np.inf, status=status,
            solve_time=solve_time, refs=refs, stats=stats)

    if opts.port_min:
        # lexicographic phase 2: fix the objective, minimize total circuits
        if objective == "weighted":
            md.row({int(lay.C): float(w)
                    for lay, w in zip(lays, weights)}, -np.inf,
                   obj_of(z) * (1 + 1e-6) + 1e-9)
        else:
            md.ub[Z] = obj_of(z) * (1 + 1e-6) + 1e-9
        md.obj = {int(xv[e]): 1.0 for e in range(len(edges))}
        st2, z2, info2 = md.solve(opts.time_limit, opts.mip_rel_gap,
                                  opts.verbose, phase="port_min")
        if st2 in ("optimal", "time_limit") and z2 is not None:
            status, z = st2, z2
            stats["phase2"] = info2

    members = [_extract(dag_m, md, lay, z, status, solve_time, {})
               for dag_m, lay in zip(ensemble.members, lays)]
    makespans = np.array([m.makespan for m in members])
    return RobustMILPResult(
        x=members[0].x, makespans=makespans, objective=objective,
        objective_value=obj_of(z), status=status, solve_time=solve_time,
        members=members, refs=refs, stats=stats)


# ----------------------------------------------------------- DELTA-Failsafe
def result_from_topology(dag: CommDAG, x: np.ndarray,
                         mask: np.ndarray | None = None,
                         status: str = "feasible") -> MILPResult:
    """Build a `validate_solution`-clean MILPResult from a topology.

    Runs the exact numpy DES with rate recording and converts its trace
    into the MILP's schedule encoding: `t` is the DES event grid, `w[(m,k)]`
    the volume task m moved inside interval k (each trace segment spans
    exactly one event interval), `start`/`finish` the DES task times.  With
    `mask`, capacity is degraded (`x * mask`) while the reported topology
    stays the integer circuit matrix -- real capacities only shrink, so the
    schedule still satisfies the nominal Eq. 9 link caps.  This is how the
    fallback chain always returns a *valid* plan even when no solver does.
    """
    problem = DESProblem(dag)
    x = np.asarray(x)
    x_int = np.rint(x).astype(np.int64)
    x_eff = x.astype(np.float64) * np.asarray(mask) if mask is not None \
        else x
    res = simulate(problem, x_eff, record_rates=True)
    n = dag.num_tasks
    if not res.feasible or not np.isfinite(res.makespan):
        return MILPResult(
            x=x_int, makespan=np.inf, status="infeasible", solve_time=0.0,
            start=np.zeros(n), finish=np.zeros(n), t=np.zeros(1),
            total_ports=int(x_int.sum()),
            stats={"from_topology": True, "masked": mask is not None})
    events = res.events
    w: dict[tuple[int, int], float] = {}
    for t0, t1, rates in res.rate_trace:
        if t1 <= t0:
            continue
        k = int(np.searchsorted(events, t0 + 1e-15, side="right"))
        k = min(max(k, 1), len(events) - 1)
        for m in np.nonzero(rates > 0)[0]:
            key = (int(m), k)
            w[key] = w.get(key, 0.0) + float(rates[m]) * (t1 - t0)
    y = {key: 1 for key in w}
    return MILPResult(
        x=x_int, makespan=float(res.makespan), status=status,
        solve_time=0.0, start=res.start, finish=res.finish, t=events,
        w=w, y=y, total_ports=int(x_int.sum()),
        stats={"from_topology": True, "masked": mask is not None})


def solve_resilient(dag: CommDAG, opts: MILPOptions | None = None, *,
                    budget_s: float | None = None, retries: int = 1,
                    backoff_s: float = 0.05,
                    ga_options=None,
                    current_x: np.ndarray | None = None,
                    mask: np.ndarray | None = None) -> MILPResult:
    """MILP solve with a wall-clock budget, retry/backoff on solver
    exceptions, and a graceful fallback chain that ALWAYS returns a valid
    plan:

      1. `solve_delta_milp` under the remaining budget (retried with
         backoff on exceptions; a budget expiry without an incumbent reads
         infeasible via the finite-makespan guard and falls through),
      2. a GA incumbent (`delta_fast`) converted to a schedule by
         `result_from_topology`,
      3. the current plan `current_x` with failed links masked (one
         circuit everywhere if no current plan exists).

    Fallback results carry `degraded=True` + `fallback_stage`, and every
    stage transition increments `fleet_fallbacks_total{stage=...}`.
    """
    opts = opts or MILPOptions()
    budget = float(budget_s) if budget_s is not None else opts.time_limit
    t0 = time.time()
    last_error: str | None = None

    for attempt in range(max(int(retries), 0) + 1):
        remaining = budget - (time.time() - t0)
        if remaining <= 0:
            _FALLBACKS.inc(stage="milp_budget")
            break
        try:
            run_opts = dataclasses.replace(
                opts, time_limit=min(opts.time_limit, remaining))
            result = solve_delta_milp(dag, run_opts)
        except Exception as exc:
            last_error = f"{type(exc).__name__}: {exc}"
            _FALLBACKS.inc(stage="milp_retry")
            if attempt < retries:
                time.sleep(min(backoff_s * (2 ** attempt), remaining))
            continue
        if result.feasible:
            result.stats.setdefault("resilient", {}).update(
                attempts=attempt + 1, budget_s=budget)
            return result
        last_error = f"status={result.status}"
        break
    _FALLBACKS.inc(stage="milp")

    # ---- stage 2: GA incumbent
    try:
        from repro.core.ga import delta_fast
        ga = delta_fast(dag, ga_options)
        if ga.feasible:
            res = result_from_topology(dag, ga.x, status="feasible")
            if res.feasible:
                res.degraded = True
                res.fallback_stage = "ga"
                res.stats["resilient"] = {"milp_error": last_error,
                                          "budget_s": budget}
                _FALLBACKS.inc(stage="ga")
                return res
    except Exception as exc:   # pragma: no cover - GA is pure numpy/jax
        last_error = f"{last_error}; ga {type(exc).__name__}: {exc}"

    # ---- stage 3: the current plan, failed links masked
    if current_x is None:
        P = dag.cluster.num_pods
        current_x = np.zeros((P, P), dtype=np.int64)
        for (i, j) in dag.undirected_pairs():
            current_x[i, j] = current_x[j, i] = 1
    res = result_from_topology(dag, current_x, mask=mask, status="feasible")
    res.degraded = True
    res.fallback_stage = "current"
    res.stats["resilient"] = {"milp_error": last_error, "budget_s": budget}
    _FALLBACKS.inc(stage="current")
    return res


def validate_solution(dag: CommDAG, res: MILPResult, tol: float = 1e-5
                      ) -> list[str]:
    """Independent feasibility check of a MILP schedule (unit-scaled)."""
    errors: list[str] = []
    B = dag.cluster.nic_bandwidth
    # conservation
    vol_sent = {m: 0.0 for m in range(1, dag.num_tasks)}
    for (m, _k), v in res.w.items():
        vol_sent[m] += v
    for t_ in dag.real_tasks():
        if abs(vol_sent[t_.tid] - t_.volume) > tol * max(t_.volume, 1.0):
            errors.append(f"conservation task {t_.tid}")
    # precedence
    for d in dag.deps:
        pre_c = 0.0 if d.pre == VIRTUAL else res.finish[d.pre]
        if res.start[d.succ] + tol < pre_c + d.delta - 1e-9:
            errors.append(f"precedence {d.pre}->{d.succ}")
    # port budgets
    U = dag.cluster.port_limits
    for p in range(dag.cluster.num_pods):
        if res.x[p].sum() > U[p]:
            errors.append(f"ports pod {p}")
    # link capacity per interval: aggregate volume over all tasks sharing
    # an ordered pod pair must fit the pair's circuits (Eq. 9)
    t = res.t
    agg: dict[tuple[tuple[int, int], int], float] = {}
    for (m, k), v in res.w.items():
        agg_key = (dag.tasks[m].pair, k)
        agg[agg_key] = agg.get(agg_key, 0.0) + v
    for (pair, k), v in agg.items():
        dt = t[k] - t[k - 1]
        cap = res.x[pair] * B * dt
        if v > cap * (1 + 1e-6) + tol * VOL:
            errors.append(f"link cap pair {pair} interval {k}")
    # NIC injection/reception per equivalence class & interval (Eq. 10):
    # sum_m w_{m,k} / F_m <= B * Delta_k for every GPU's task set
    src_classes, dst_classes = dag.nic_classes()
    flows = dag.flows()
    w_of_task: dict[int, list[tuple[int, float]]] = {}
    for (m, k), v in res.w.items():
        w_of_task.setdefault(m, []).append((k, v))
    for side, classes in (("src", src_classes), ("dst", dst_classes)):
        for ci, (tids, mult) in enumerate(classes):
            per_k: dict[int, float] = {}
            for m in tids:
                for k, v in w_of_task.get(m, ()):
                    per_k[k] = per_k.get(k, 0.0) + v / flows[m]
            for k, v in per_k.items():
                dt = t[k] - t[k - 1]
                if v > B * dt * mult * (1 + 1e-6) + tol * VOL:
                    errors.append(f"nic {side} class {ci} interval {k}")
    return errors
