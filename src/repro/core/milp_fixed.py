"""Fixed-time-step MILP (paper Appendix A) -- complexity baseline.

Uniform slices of length dt over [0, T_up].  Kept deliberately close to the
appendix formulation (Eqs. 19-30); used only on small instances to
demonstrate the variable-length-interval formulation's advantage (the paper:
tens of hours at 0.1 ms resolution even with pruning).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.dag import VIRTUAL, CommDAG
from repro.core.des import DESProblem
from repro.core.milp import _Model, VOL
from repro.core.pruning import estimate_t_up
from repro.core.xbound import x_upper_bound


@dataclass
class FixedStepResult:
    x: np.ndarray
    makespan: float
    status: str
    solve_time: float
    num_slices: int
    stats: dict


def solve_fixed_step(dag: CommDAG, dt: float, t_up: float | None = None,
                     fairness: bool = False, time_limit: float = 600.0,
                     mip_rel_gap: float = 1e-4) -> FixedStepResult:
    md = _Model()
    B = dag.cluster.nic_bandwidth / VOL
    U = dag.cluster.port_limits
    n = dag.num_tasks
    vol = dag.volumes() / VOL
    flows = dag.flows()
    if t_up is None:
        t_up = estimate_t_up(DESProblem(dag))
    # headroom: every Eq.-28 dependency and every task duration rounds *up*
    # to the grid, so the discrete optimum can exceed the continuous bound
    # substantially (measured +12.5% on GPT-7B at dt = makespan/40) -- give
    # the horizon 2x slack; this only inflates the variable count, which is
    # the point of this complexity baseline
    T = int(np.ceil(2.0 * t_up / dt)) + dag.num_tasks
    xbar = x_upper_bound(dag, t_up=t_up)

    edges = dag.undirected_pairs()
    edge_of = {}
    xv = np.empty(len(edges), dtype=np.int64)
    for e, (i, j) in enumerate(edges):
        edge_of[(i, j)] = e
        edge_of[(j, i)] = e
        hi = max(1, int(min(U[i], U[j], xbar[i, j])))
        xv[e] = md.var(1, hi, integer=True)
    for p in range(dag.cluster.num_pods):
        coeffs = {int(xv[e]): 1.0 for e, (i, j) in enumerate(edges)
                  if p in (i, j)}
        if coeffs:
            md.row(coeffs, -np.inf, float(U[p]))

    # per-task slice variables
    rv = {}
    yv = {}
    Sv = {}
    Cvv = {}
    for m in range(1, n):
        cap = float(flows[m]) * B
        for t in range(1, T + 1):
            rv[(m, t)] = md.var(0.0, cap)
            yv[(m, t)] = md.var(0, 1, integer=True)
            Sv[(m, t)] = md.var(0, 1, integer=True)
            Cvv[(m, t)] = md.var(0, 1, integer=True)
    Cvar = md.var(0.0, T * dt)   # the discrete optimum can exceed t_up

    tasks_on = dag.tasks_on_pair()
    for (i, j), tids in tasks_on.items():
        e = edge_of[(i, j)]
        for t in range(1, T + 1):
            coeffs = {rv[(m, t)]: 1.0 for m in tids}
            coeffs[int(xv[e])] = -B
            md.row(coeffs, -np.inf, 0.0)                      # Eq. 22
    src_classes, dst_classes = dag.nic_classes()
    for tids, _ in src_classes + dst_classes:
        for t in range(1, T + 1):
            coeffs = {rv[(m, t)]: 1.0 / flows[m] for m in tids}
            md.row(coeffs, -np.inf, B)                        # Eq. 23

    for m in range(1, n):
        md.row({Sv[(m, t)]: 1.0 for t in range(1, T + 1)}, 1.0, 1.0)
        md.row({Cvv[(m, t)]: 1.0 for t in range(1, T + 1)}, 1.0, 1.0)
        for t in range(1, T + 1):
            coeffs = {yv[(m, t)]: 1.0, Sv[(m, t)]: -1.0, Cvv[(m, t)]: 1.0}
            if t > 1:
                coeffs[yv[(m, t - 1)]] = -1.0
            md.row(coeffs, 0.0, 0.0)                          # Eq. 25
            md.row({rv[(m, t)]: 1.0,
                    yv[(m, t)]: -float(flows[m]) * B}, -np.inf, 0.0)  # 27
        md.row({rv[(m, t)]: dt for t in range(1, T + 1)},
               float(vol[m]), np.inf)                         # Eq. 26
        md.row({Cvar: 1.0, **{Cvv[(m, t)]: -t * dt
                              for t in range(1, T + 1)}}, 0.0, np.inf)  # 30

    for d in dag.deps:                                        # Eq. 28
        if d.pre == VIRTUAL:
            lagged = int(np.ceil(d.delta / dt))
            md.row({Sv[(d.succ, t)]: float(t) for t in range(1, T + 1)},
                   1.0 + lagged, np.inf)
        else:
            coeffs = {Sv[(d.succ, t)]: float(t) for t in range(1, T + 1)}
            for t in range(1, T + 1):
                coeffs[Cvv[(d.pre, t)]] = coeffs.get(Cvv[(d.pre, t)], 0.0) \
                    - float(t)
            md.row(coeffs, float(np.ceil(d.delta / dt)), np.inf)

    if fairness:                                              # Eq. 29
        for tids in tasks_on.values():
            Mu = max(float(flows[m]) * B for m in tids)
            for t in range(1, T + 1):
                u_ = md.var(0.0, Mu)
                for m in tids:
                    md.row({rv[(m, t)]: 1.0 / flows[m], u_: -1.0,
                            yv[(m, t)]: Mu}, -np.inf, Mu)
                    md.row({u_: 1.0, rv[(m, t)]: -1.0 / flows[m],
                            yv[(m, t)]: Mu}, -np.inf, Mu)

    md.obj = {Cvar: 1.0}
    t0 = time.time()
    status, z, info = md.solve(time_limit, mip_rel_gap, False)
    solve_time = time.time() - t0
    P = dag.cluster.num_pods
    x = np.zeros((P, P), dtype=np.int64)
    makespan = np.inf
    if z is not None:
        for e, (i, j) in enumerate(edges):
            x[i, j] = x[j, i] = int(round(z[xv[e]]))
        makespan = float(z[Cvar])
    return FixedStepResult(x=x, makespan=makespan, status=status,
                           solve_time=solve_time, num_slices=T, stats=info)
