"""MILP search-space pruning (paper Sec. IV-A, Algs. 1 & 4).

* `cal_task_time_windows` (Alg. 4): earliest start / latest completion per
  task from forward/backward longest-path propagation with minimum physical
  durations tau_m = V_m / (F_m * B).
* `task_time_index_pruning` (Alg. 1): feasible interval-index windows
  [k_min, k_max] per task, combining whole-graph topological bounds with
  DES-profiled anchors for intermediate tasks.

The virtual source task (tid 0) participates with k = 0 / EST = LCT = 0 and
is excluded from the returned windows' consumers (the MILP models it as
constant offsets).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.dag import VIRTUAL, CommDAG
from repro.core.des import DESProblem, DESResult, simulate


def min_durations(dag: CommDAG) -> np.ndarray:
    """tau_m = V_m / (F_m * B): minimum physical duration of each task."""
    tau = np.zeros(dag.num_tasks)
    B = dag.cluster.nic_bandwidth
    for t in dag.real_tasks():
        tau[t.tid] = t.volume / (t.flows * B)
    return tau


# ------------------------------------------------------------------- Alg. 4
def cal_task_time_windows(dag: CommDAG, t_up: float
                          ) -> tuple[np.ndarray, np.ndarray]:
    """Earliest start time and latest completion time per task (Alg. 4)."""
    n = dag.num_tasks
    tau = min_durations(dag)
    est = np.zeros(n)
    lct = np.full(n, float(t_up))
    lct[VIRTUAL] = 0.0

    order = dag.topo_order()
    preds = dag.preds()
    succs = dag.succs()
    # Step 2: forward propagation
    for v in order:
        for d in preds.get(v, ()):
            est[v] = max(est[v], est[d.pre] + tau[d.pre] + d.delta)
    # Step 3: backward propagation
    for u in reversed(order):
        for d in succs.get(u, ()):
            lct[u] = min(lct[u], lct[d.succ] - tau[d.succ] - d.delta)
    return est, lct


def estimate_t_up(problem: DESProblem, slack: float = 1.05) -> float:
    """Coarse iteration-time upper bound: DES on the minimal connected
    topology (one circuit per active pair -- worst feasible contention)."""
    P = problem.dag.cluster.num_pods
    x = np.zeros((P, P), dtype=np.int64)
    for i, j in problem.dag.undirected_pairs():
        x[i, j] = x[j, i] = 1
    res = simulate(problem, x)
    if not res.feasible:  # pragma: no cover - defensive
        raise RuntimeError("minimal topology infeasible; DAG disconnected?")
    return float(res.makespan) * slack


# ------------------------------------------------------------------- Alg. 1
@dataclass(frozen=True)
class IndexWindows:
    k_min: np.ndarray   # (n,) 1-based first allowed interval (0 for virtual)
    k_max: np.ndarray   # (n,) 1-based last allowed interval
    K: int

    def allowed(self, m: int) -> range:
        return range(int(self.k_min[m]), int(self.k_max[m]) + 1)

    def num_task_intervals(self) -> int:
        real = slice(1, None)
        return int(np.sum(self.k_max[real] - self.k_min[real] + 1))


def task_time_index_pruning(dag: CommDAG, K: int,
                            anchors: np.ndarray | None = None,
                            anchor_margin: int = 1) -> IndexWindows:
    """Alg. 1: prune feasible interval indices per task.

    anchors: (n, 2) array of [k_start, k_end] from a baseline DES profile
    (DESResult.task_interval); only tasks *with successors* are anchored
    (intermediate tasks -- their position in the event sequence is rigid).
    anchor_margin widens the profiled window on both sides.
    """
    n = dag.num_tasks
    k_min = np.ones(n, dtype=np.int64)
    k_max = np.full(n, K, dtype=np.int64)
    k_min[VIRTUAL] = 0
    k_max[VIRTUAL] = 0

    has_succ = np.zeros(n, dtype=bool)
    for d in dag.deps:
        has_succ[d.pre] = True

    # Step 1: anchoring of intermediate tasks from the DES profile
    if anchors is not None:
        for m in range(1, n):
            if has_succ[m] and anchors[m, 0] >= 1:
                k_min[m] = max(1, int(anchors[m, 0]) - anchor_margin)
                k_max[m] = min(K, int(anchors[m, 1]) + anchor_margin)

    preds = dag.preds()
    succs = dag.succs()
    order = dag.topo_order()
    # Step 2: forward pass (earliest index)
    for v in order:
        for d in preds.get(v, ()):
            bump = 2 if d.delta > 0 else 1
            k_min[v] = max(k_min[v], k_min[d.pre] + bump)
    # Step 3: backward pass (latest index)
    for u in reversed(order):
        for d in succs.get(u, ()):
            bump = 2 if d.delta > 0 else 1
            k_max[u] = min(k_max[u], k_max[d.succ] - bump)

    # emptiness must be checked on the *unclipped* propagated values:
    # clipping into [1, K] first would silently repair a genuinely
    # infeasible window (e.g. k_max < 1 after the backward pass) into
    # [1, 1].  No clip is needed after the check: k_min >= 1 and only
    # increases, k_max <= K and only decreases, so any window passing the
    # check is already inside [1, K].
    if (k_max[1:] < k_min[1:]).any():
        bad = int(np.sum(k_max[1:] < k_min[1:]))
        raise ValueError(
            f"{bad} tasks have empty index windows; increase K or "
            f"anchor_margin")
    return IndexWindows(k_min=k_min, k_max=k_max, K=K)


def profile_anchors(problem: DESProblem, x: np.ndarray | None = None
                    ) -> tuple[DESResult, np.ndarray, int]:
    """Baseline DES profile used for anchoring and for K selection.

    Returns (result, anchors, K).  Default profiling topology: one circuit
    per active pair (the same baseline as estimate_t_up).
    """
    if x is None:
        P = problem.dag.cluster.num_pods
        x = np.zeros((P, P), dtype=np.int64)
        for i, j in problem.dag.undirected_pairs():
            x[i, j] = x[j, i] = 1
    res = simulate(problem, x)
    if not res.feasible:
        raise RuntimeError("anchor profile simulation infeasible")
    return res, res.task_interval, res.num_intervals


def pruning_stats(dag: CommDAG, windows: IndexWindows) -> dict:
    n_real = dag.num_real_tasks
    dense = n_real * windows.K
    kept = windows.num_task_intervals()
    return {"tasks": n_real, "K": windows.K, "dense_mk": dense,
            "kept_mk": kept, "reduction": 1.0 - kept / max(dense, 1)}
