"""1F1B schedule -> full computation-communication DAG -> reduced inter-pod
communication DAG (paper Sec. III-A, Fig. 3).

The full DAG contains three node kinds:
  * compute nodes  F(r, b, s) / B(r, b, s) with fixed durations,
  * intra-pod communication nodes (fixed durations, electrical network),
  * inter-pod communication nodes (durations decided by the topology).

Dependency categories (paper Fig. 3a):
  (1) data dependencies  (activation / gradient / encoder-output arrival,
      plus the expert-parallel all-to-all of MoE stages: dispatch + combine
      per MoE layer, aggregated per (replica, microbatch, stage, direction)
      and wired between the F/B compute nodes so it contends with the PP
      transfer on the same boundary),
  (2) scheduling dependencies (1F1B op order per stage GPU),
  (3) gradient dependencies (DP sync waits for the last microbatch backward).

EP placement assumption: EP groups stride across DP replicas within a
stage (Placement.ep_groups), so the all-to-all is inter-pod even when a
replica's whole pipeline fits in one pod.  Under the single-replica
projection (reduce_replicas=True) each EP group is represented by the pair
0 -> 1 plus its isomorphic wraparound image 1 -> 0 -- the same
representative-pair treatment as the DP ring, port-exact per pod but
concentrating the (ep-1)-peer fan-out onto one pod pair.  jobs with ep == 1
build DAGs bit-identical to the pre-MoE builder.

Graph reduction replaces chains of intra-pod nodes between inter-pod tasks by
rigid-delay edges delta (Eq. 2).  Because completion-to-start edges over a
stage's op chain are quadratic in microbatch count, we prune every candidate
edge that is *dominated* by a two-edge path (o -> m -> n) with
delta1 + tau_min(m) + delta2 >= delta, where tau_min(m) = V_m / (F_m * B) is
m's minimum physical duration (valid in every feasible schedule because
Eq. 10 caps r_m <= F_m * B).  Domination is transitive, so one-hop checking
is sound; for homogeneous pipelines this brings |D| back to O(|M|).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from dataclasses import dataclass, field

from repro.core.cluster import ClusterSpec, Placement
from repro.core.dag import VIRTUAL, CommDAG, CommTask, Dep, make_virtual
from repro.core.traffic import JobSpec


# --------------------------------------------------------------------- 1F1B
def order_1f1b(stage: int, num_stages: int, num_microbatches: int
               ) -> list[tuple[str, int]]:
    """Execution order of ('F'|'B', microbatch) ops on one stage GPU."""
    mb = num_microbatches
    warmup = min(num_stages - stage - 1, mb)
    order: list[tuple[str, int]] = [("F", b) for b in range(1, warmup + 1)]
    for i in range(1, mb - warmup + 1):
        order.append(("F", warmup + i))
        order.append(("B", i))
    for b in range(mb - warmup + 1, mb + 1):
        order.append(("B", b))
    return order


# ----------------------------------------------------------------- full DAG
@dataclass
class _Node:
    kind: str                 # comp | intra | inter
    duration: float = 0.0     # comp / intra only
    task: CommTask | None = None  # inter only (tid assigned later)


@dataclass
class FullDAG:
    """Intermediate complete computation-communication DAG."""
    nodes: list[_Node] = field(default_factory=list)
    edges: list[tuple[int, int]] = field(default_factory=list)

    def add(self, node: _Node) -> int:
        self.nodes.append(node)
        return len(self.nodes) - 1

    def link(self, u: int | None, v: int | None) -> None:
        if u is not None and v is not None:
            self.edges.append((u, v))

    def stats(self) -> dict:
        kinds = collections.Counter(n.kind for n in self.nodes)
        return {"nodes": len(self.nodes), "edges": len(self.edges),
                **dict(kinds)}


def build_full_dag(job: JobSpec, cluster: ClusterSpec,
                   placement: Placement | None = None,
                   reduce_replicas: bool = True) -> FullDAG:
    """Build the complete computation-communication DAG of one iteration."""
    placement = placement or job.placement()
    S, MB = job.pp, job.num_microbatches
    replicas = [0] if (reduce_replicas or job.dp == 1) else list(range(job.dp))
    g = FullDAG()

    def comm_node(src_pod: int, dst_pod: int, volume: float, flows: int,
                  src_gpus, dst_gpus, kind: str, tag: tuple) -> int:
        if src_pod == dst_pod:
            dur = volume / (flows * cluster.intra_pod_bandwidth)
            return g.add(_Node("intra", duration=dur))
        task = CommTask(tid=-1, src_pod=src_pod, dst_pod=dst_pod, flows=flows,
                        volume=volume, src_gpus=tuple(src_gpus),
                        dst_gpus=tuple(dst_gpus), kind=kind, tag=tag)
        return g.add(_Node("inter", task=task))

    # compute nodes per (replica, microbatch, stage)
    fwd: dict[tuple[int, int, int], int] = {}
    bwd: dict[tuple[int, int, int], int] = {}
    for r, s in itertools.product(replicas, range(S)):
        for b in range(1, MB + 1):
            fwd[(r, b, s)] = g.add(_Node("comp", duration=job.fwd_duration(s)))
            bwd[(r, b, s)] = g.add(_Node("comp", duration=job.bwd_duration(s)))

    # (2) scheduling dependencies: 1F1B op order per stage
    for r, s in itertools.product(replicas, range(S)):
        order = order_1f1b(s, S, MB)
        nodes = [fwd[(r, b, s)] if k == "F" else bwd[(r, b, s)]
                 for k, b in order]
        for u, v in zip(nodes, nodes[1:]):
            g.link(u, v)

    # (1) data dependencies via PP / xattn communications
    pp_fwd: dict[tuple[int, int, int], int] = {}
    pp_bwd: dict[tuple[int, int, int], int] = {}
    for r in replicas:
        for s in range(S - 1):
            pod_s, pod_n = placement.pod_of(r, s), placement.pod_of(r, s + 1)
            for b in range(1, MB + 1):
                cf = comm_node(pod_s, pod_n, job.pp_volume(), job.tp,
                               placement.gpu_ids(r, s),
                               placement.gpu_ids(r, s + 1),
                               "pp_fwd", (r, b, s))
                pp_fwd[(r, b, s)] = cf
                g.link(fwd[(r, b, s)], cf)
                g.link(cf, fwd[(r, b, s + 1)])
                cb = comm_node(pod_n, pod_s, job.pp_volume(), job.tp,
                               placement.gpu_ids(r, s + 1),
                               placement.gpu_ids(r, s),
                               "pp_bwd", (r, b, s + 1))
                pp_bwd[(r, b, s + 1)] = cb
                g.link(bwd[(r, b, s + 1)], cb)
                g.link(cb, bwd[(r, b, s)])
        # last stage: backward directly follows its own forward (loss);
        # covered by the scheduling chain, add the data edge for clarity.
        for b in range(1, MB + 1):
            g.link(fwd[(r, b, S - 1)], bwd[(r, b, S - 1)])

    # encoder-decoder cross-attention broadcast (whisper-style pipelines)
    if job.enc_stages and job.enc_stages < S:
        e_last = job.enc_stages - 1
        for r in replicas:
            for s_dec in range(job.enc_stages, S):
                pod_e = placement.pod_of(r, e_last)
                pod_d = placement.pod_of(r, s_dec)
                for b in range(1, MB + 1):
                    cx = comm_node(pod_e, pod_d, job.xattn_volume(), job.tp,
                                   placement.gpu_ids(r, e_last),
                                   placement.gpu_ids(r, s_dec),
                                   "xattn", (r, b, s_dec))
                    g.link(fwd[(r, b, e_last)], cx)
                    g.link(cx, fwd[(r, b, s_dec)])

    # (1c) expert-parallel all-to-all (MoE dispatch + combine per stage).
    # EP groups stride across DP replicas within a stage, so the all-to-all
    # crosses pods even when a replica's whole pipeline fits in one pod.
    # Each task aggregates one replica's full a2a egress for one
    # (microbatch, MoE stage, direction) onto its representative ring pair;
    # under the single-replica projection we keep the pair 0 -> 1 plus the
    # isomorphic wraparound image 1 -> 0, exactly like the DP ring below.
    # The fwd a2a is wired F(s) -> a2a -> F(s+1) (B(s) at the last stage)
    # and the bwd a2a B(s) -> a2a -> B(s-1): with atomic compute nodes the
    # intra-layer dispatch/combine collapses onto the stage boundary, where
    # it contends with the PP transfer -- the concurrent-demand burst the
    # traffic-matrix view obscures.
    ep_span = placement.ep_span
    if ep_span >= 2 and any(job.moe_stage_layers):
        if reduce_replicas:
            # projection: pair 0 -> 1 plus wraparound image, replica-0 gates
            # (ep_span >= 2 implies dp >= 2, so replica 1's pods exist)
            ep_groups = [([(0, 1), (1, 0)], [0])]
        else:
            # collective gating: every group member's compute node bounds
            # every pair task of its group
            ep_groups = [
                ([(g * ep_span + i, g * ep_span + (i + 1) % ep_span)
                  for i in range(ep_span)],
                 list(range(g * ep_span, (g + 1) * ep_span)))
                for g in range(job.dp // ep_span)]
        for pairs, gates in ep_groups:
            for s in range(S):
                vol = job.ep_a2a_stage_volume(s)
                if vol <= 0.0:
                    continue
                for b in range(1, MB + 1):
                    for r_src, r_dst in pairs:
                        pod_s = placement.pod_of(r_src, s)
                        pod_d = placement.pod_of(r_dst, s)
                        ca = comm_node(pod_s, pod_d, vol, job.tp,
                                       placement.gpu_ids(r_src, s),
                                       placement.gpu_ids(r_dst, s),
                                       "ep_a2a_fwd", (r_src, b, s))
                        cb = comm_node(pod_d, pod_s, vol, job.tp,
                                       placement.gpu_ids(r_dst, s),
                                       placement.gpu_ids(r_src, s),
                                       "ep_a2a_bwd", (r_dst, b, s))
                        for r in gates:
                            g.link(fwd[(r, b, s)], ca)
                            g.link(ca, fwd[(r, b, s + 1)] if s < S - 1
                                   else bwd[(r, b, s)])
                            g.link(bwd[(r, b, s)], cb)
                            if s > 0:
                                g.link(cb, bwd[(r, b, s - 1)])

    # (3) gradient dependencies: DP ring sync per stage after last backward
    if job.dp >= 2:
        if reduce_replicas:
            # single-replica projection: model the ring link 0 -> 1 plus the
            # isomorphic wraparound image (dp-1 -> 0) mapped onto pods 1 -> 0.
            ring_pairs = [(0, 1), (1, 0)]
        else:
            ring_pairs = [(r, (r + 1) % job.dp) for r in range(job.dp)]
        for s in range(S):
            for r_src, r_dst in ring_pairs:
                pod_s = placement.pod_of(r_src, s)
                pod_d = placement.pod_of(r_dst, s)
                dpn = comm_node(pod_s, pod_d, job.dp_volume(s), job.tp,
                                placement.gpu_ids(r_src, s),
                                placement.gpu_ids(r_dst, s),
                                "dp", (r_src, r_dst, s))
                # collective start: every participating replica must finish
                # its last backward; in the projection replicas are
                # synchronized so replica 0's suffices.
                for r in replicas:
                    g.link(bwd[(r, MB, s)], dpn)
    return g


# ---------------------------------------------------------------- reduction
def reduce_dag(full: FullDAG, cluster: ClusterSpec,
               prune_dominated: bool = True,
               meta: dict | None = None) -> CommDAG:
    """Collapse intra-pod nodes into rigid-delay edges between inter-pod
    tasks (paper Fig. 3b) with dominance pruning."""
    n = len(full.nodes)
    preds: list[list[int]] = [[] for _ in range(n)]
    succs: list[list[int]] = [[] for _ in range(n)]
    indeg = [0] * n
    for u, v in full.edges:
        succs[u].append(v)
        preds[v].append(u)
        indeg[v] += 1

    # assign tids to inter-pod tasks in topological order
    order: list[int] = []
    queue = collections.deque(i for i in range(n) if indeg[i] == 0)
    deg = list(indeg)
    while queue:
        u = queue.popleft()
        order.append(u)
        for v in succs[u]:
            deg[v] -= 1
            if deg[v] == 0:
                queue.append(v)
    if len(order) != n:
        raise ValueError("full DAG has a cycle")

    tasks: list[CommTask] = [make_virtual()]
    tid_of: dict[int, int] = {}
    for u in order:
        node = full.nodes[u]
        if node.kind == "inter":
            tid = len(tasks)
            tid_of[u] = tid
            tasks.append(dataclasses.replace(node.task, tid=tid))

    # propagate {origin inter-pod task -> max accumulated intra-pod lag}
    lag: list[dict[int, float]] = [dict() for _ in range(n)]
    edges: dict[tuple[int, int], float] = {}
    for u in order:
        node = full.nodes[u]
        acc: dict[int, float] = {}
        if not preds[u]:
            acc[VIRTUAL] = 0.0
        for p in preds[u]:
            for o, d in lag[p].items():
                if d > acc.get(o, -1.0):
                    acc[o] = d
        if node.kind == "inter":
            tid = tid_of[u]
            for o, d in acc.items():
                key = (o, tid)
                if d > edges.get(key, -1.0):
                    edges[key] = d
            lag[u] = {tid: 0.0}
        else:
            dur = node.duration
            lag[u] = {o: d + dur for o, d in acc.items()}

    if prune_dominated:
        edges = _prune_dominated(edges, tasks, cluster)

    deps = [Dep(pre, succ, delta) for (pre, succ), delta in sorted(edges.items())]
    return CommDAG(tasks=tasks, deps=deps, cluster=cluster, meta=meta or {})


def _prune_dominated(edges: dict[tuple[int, int], float],
                     tasks: list[CommTask], cluster: ClusterSpec,
                     eps: float = 1e-12) -> dict[tuple[int, int], float]:
    """Drop (o, n, delta) if some 2-path o -> m -> n already enforces it."""
    tau_min = [0.0] * len(tasks)
    for t in tasks:
        if not t.is_virtual:
            tau_min[t.tid] = t.volume / (t.flows * cluster.nic_bandwidth)
    out_of: dict[int, list[tuple[int, float]]] = collections.defaultdict(list)
    for (o, m), d in edges.items():
        out_of[o].append((m, d))
    kept: dict[tuple[int, int], float] = {}
    for (o, nn), delta in edges.items():
        dominated = False
        for m, d1 in out_of[o]:
            if m == nn:
                continue
            d2 = edges.get((m, nn))
            if d2 is not None and d1 + tau_min[m] + d2 >= delta - eps:
                dominated = True
                break
        if not dominated:
            kept[(o, nn)] = delta
    return kept


# ------------------------------------------------------------------- facade
def build_comm_dag(job: JobSpec, inter_pod_gbps: float = 400.0,
                   reduce_replicas: bool = True,
                   reverse_stages: bool = False,
                   cluster: ClusterSpec | None = None,
                   prune_dominated: bool = True) -> CommDAG:
    """JobSpec -> reduced inter-pod CommDAG (the paper's (M, D) input)."""
    placement = job.placement(reverse_stages)
    if cluster is None:
        cluster = job.cluster(inter_pod_gbps, reverse_stages=reverse_stages)
    full = build_full_dag(job, cluster, placement,
                          reduce_replicas=reduce_replicas)
    meta = {"job": job.name, "full_dag": full.stats(),
            "reduce_replicas": reduce_replicas,
            "reverse_stages": reverse_stages,
            "inter_pod_gbps": inter_pod_gbps}
    return reduce_dag(full, cluster, prune_dominated=prune_dominated,
                      meta=meta)
