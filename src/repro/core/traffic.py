"""Analytic traffic/duration model for LLM training iterations (paper F1).

The paper generates communication traces with simAI; because LLM traffic is
deterministic given (model, parallelism, schedule) -- feature F1 -- we compute
the same quantities analytically:

  PP activation/gradient volume per microbatch boundary:
      V_pp = micro_tokens * d_model * act_bytes
  DP gradient-sync volume per stage (unidirectional ring all-reduce, so the
  single-replica projection of Sec. IV-A1 stays port-exact):
      V_dp = 2 * (dp-1)/dp * stage_param_bytes   per ring link r -> r+1
  EP all-to-all volume per MoE dispatch (== combine) per replica:
      V_ep = micro_tokens * d_model * act_bytes * top_k * (ep-1)/ep
  (each routed token copy leaves the local expert shard with probability
  (ep-1)/ep; forward and backward each perform one dispatch + one combine
  per MoE layer, so one stage contributes 2 * n_moe_layers(stage) * V_ep
  per direction).  EP groups stride across DP replicas within a stage --
  replica r exchanges tokens with the other min(ep, dp) - 1 replicas of its
  group, whose stage-s shards live in different pods.  When ep > dp
  (jamba-style expert sharding inside the TP group) the cross-replica span
  saturates at dp and the intra-pod fraction of the all-to-all is still
  charged to V_ep -- a deliberate, slightly conservative upper bound.
  compute durations from a FLOPs model:
      fwd(b, s) = 2 * active_stage_params[s] * micro_tokens / (tp * gpu_flops)
      bwd       = 2 * fwd
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.core.cluster import GBPS, ClusterSpec, Placement


@dataclass(frozen=True)
class JobSpec:
    """Everything DELTA needs to know about one training job.

    stage_params: parameters *synchronized by DP* per pipeline stage (bytes
      are derived with grad_bytes).  For MoE models this includes all experts.
    active_stage_params: parameters touched per token (MoE: routed experts
      only) -- drives compute durations.
    moe_experts / moe_top_k / moe_every: MoE routing shape (from
      ModelConfig); moe_top_k drives the EP all-to-all volume.
    moe_stage_layers: number of MoE layers hosted by each pipeline stage
      (pp entries; make_job derives it from ModelConfig.is_moe_layer).
      Empty means no EP traffic is modeled even if ep > 1.
    ep: expert-parallel degree.  EP groups stride across DP replicas within
      a stage (see module docstring); ep == 1 disables EP traffic entirely
      and yields DAGs bit-identical to the pre-MoE builder.
    """

    name: str
    tp: int
    pp: int
    dp: int
    num_microbatches: int
    micro_tokens: int
    d_model: int
    stage_params: tuple[float, ...]
    active_stage_params: tuple[float, ...] = ()
    gpus_per_pod_per_replica: int = 16
    ep: int = 1
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1
    moe_stage_layers: tuple[int, ...] = ()
    act_bytes: int = 2
    grad_bytes: int = 2
    gpu_flops: float = 140e12   # effective per-GPU throughput (bf16 * MFU)
    enc_stages: int = 0         # >0: first enc_stages stages form an encoder
    enc_tokens: int = 0         # encoder frames per microbatch (whisper stub)
    seq_len: int = 4096

    def __post_init__(self) -> None:
        if len(self.stage_params) != self.pp:
            raise ValueError("stage_params must have pp entries")
        if self.active_stage_params and \
                len(self.active_stage_params) != self.pp:
            raise ValueError("active_stage_params must have pp entries")
        if self.num_microbatches < 1 or self.pp < 1:
            raise ValueError("bad schedule sizes")
        if self.moe_stage_layers and len(self.moe_stage_layers) != self.pp:
            raise ValueError("moe_stage_layers must have pp entries")
        if self.ep > 1:
            if self.ep <= self.dp and self.dp % self.ep:
                raise ValueError(
                    f"ep={self.ep} must divide dp={self.dp} (EP groups "
                    f"stride across DP replicas within a stage)")
            if self.ep > self.dp and self.ep % self.dp:
                raise ValueError(
                    f"ep={self.ep} > dp={self.dp} requires dp | ep (the "
                    f"per-replica remainder shards inside the TP group)")

    @property
    def active(self) -> tuple[float, ...]:
        return self.active_stage_params or self.stage_params

    # ------------------------------------------------------------- placement
    def placement(self, reverse_stages: bool = False) -> Placement:
        return Placement(tp=self.tp, pp=self.pp, dp=self.dp,
                         gpus_per_pod_per_replica=self.gpus_per_pod_per_replica,
                         ep=self.ep,
                         reverse_stages=reverse_stages)

    def cluster(self, inter_pod_gbps: float = 400.0,
                reverse_stages: bool = False, **kw) -> ClusterSpec:
        return self.placement(reverse_stages).cluster(
            nic_bandwidth=inter_pod_gbps * GBPS, **kw)

    # --------------------------------------------------------------- volumes
    def pp_volume(self) -> float:
        """Activation (== gradient) bytes crossing one stage boundary per
        microbatch, aggregated over the TP group (paper task aggregation)."""
        return float(self.micro_tokens * self.d_model * self.act_bytes)

    def xattn_volume(self) -> float:
        """Encoder-output bytes consumed by each decoder stage (enc-dec)."""
        return float(self.enc_tokens * self.d_model * self.act_bytes)

    def dp_volume(self, stage: int) -> float:
        bytes_ = self.stage_params[stage] * self.grad_bytes
        return float(2.0 * (self.dp - 1) / self.dp * bytes_)

    def ep_a2a_volume(self) -> float:
        """Bytes a replica's stage GPUs inject per MoE dispatch (== per
        combine), aggregated over the TP group: each of the top_k routed
        token copies leaves the local expert shard with prob. (ep-1)/ep."""
        if self.ep <= 1 or self.moe_top_k <= 0:
            return 0.0
        return float(self.micro_tokens * self.d_model * self.act_bytes
                     * self.moe_top_k * (self.ep - 1) / self.ep)

    def ep_a2a_stage_volume(self, stage: int) -> float:
        """Per-direction (fwd or bwd) EP all-to-all bytes for one
        (replica, microbatch) at `stage`: dispatch + combine for every MoE
        layer the stage hosts."""
        if not self.moe_stage_layers:
            return 0.0
        return 2.0 * self.moe_stage_layers[stage] * self.ep_a2a_volume()

    # -------------------------------------------------------------- durations
    def fwd_duration(self, stage: int) -> float:
        tokens = self.micro_tokens
        if self.enc_stages and stage < self.enc_stages:
            tokens = max(self.enc_tokens, 1)
        return 2.0 * self.active[stage] * tokens / (self.tp * self.gpu_flops)

    def bwd_duration(self, stage: int) -> float:
        return 2.0 * self.fwd_duration(stage)

    def intra_pp_duration(self, cluster: ClusterSpec) -> float:
        """Duration of a stage-boundary transfer when both stages share a
        pod (electrical intra-pod network)."""
        return self.pp_volume() / (self.tp * cluster.intra_pod_bandwidth)

    # ------------------------------------------------------------- reporting
    def total_params(self) -> float:
        return float(sum(self.stage_params))

    def iteration_tokens(self) -> int:
        return self.num_microbatches * self.micro_tokens

    def scaled(self, **overrides) -> "JobSpec":
        return dataclasses.replace(self, **overrides)


def ideal_step_compute_time(job: JobSpec) -> float:
    """Pipeline-unaware lower bound on compute time (for sanity checks)."""
    per_mb = sum(job.fwd_duration(s) + job.bwd_duration(s)
                 for s in range(job.pp))
    return per_mb * job.num_microbatches / job.pp
