"""Alg. 2: XUpperBoundEstimation -- capacity upper bounds for x_ij.

Circuits beyond the maximum concurrent inter-pod flow weight are provably
useless (NIC-bound injection, paper O2), and dependency-linked tasks can
never transmit concurrently.  Per ordered pod pair we scan the EST/LCT
interval sequence and solve a Maximum-Weight Independent Set on the conflict
graph (vertices = co-windowed tasks, weights = flow counts F_m, edges =
mutual reachability in the transitive closure of D).

Transitive closure backends:
  * 'bitset'  -- topological DP over numpy uint64 bitsets, O(|D| * n / 64);
                 the fast CPU path used by default.
  * 'kernel'  -- repeated boolean matrix squaring via the Pallas kernel
                 (repro.kernels.ops.transitive_closure), the TPU-shaped path
                 the paper describes ("via matrix squaring").
Both are cross-validated in tests.
"""
from __future__ import annotations

import numpy as np

from repro.core.dag import CommDAG
from repro.core.pruning import cal_task_time_windows, estimate_t_up
from repro.core.des import DESProblem


# ---------------------------------------------------------------- closures
def reachability_bitset(dag: CommDAG) -> np.ndarray:
    """Boolean reachability matrix over tasks (strict: no self loops)."""
    n = dag.num_tasks
    words = (n + 63) // 64
    reach = np.zeros((n, words), dtype=np.uint64)
    preds = dag.preds()
    for v in dag.topo_order():
        row = reach[v]
        for d in preds.get(v, ()):
            row |= reach[d.pre]
            row[d.pre >> 6] |= np.uint64(1) << np.uint64(d.pre & 63)
    # rows hold ancestor bitsets -> transpose to get reachability[u, v]
    bits = np.unpackbits(reach.view(np.uint8), axis=1, bitorder="little")
    return bits[:, :n].astype(bool).T


def reachability_kernel(dag: CommDAG) -> np.ndarray:
    """Closure via repeated boolean matrix squaring (Pallas/MXU path)."""
    from repro.kernels import ops
    n = dag.num_tasks
    adj = np.zeros((n, n), dtype=bool)
    for d in dag.deps:
        adj[d.pre, d.succ] = True
    return np.asarray(ops.transitive_closure(adj))


def reachability(dag: CommDAG, backend: str = "auto") -> np.ndarray:
    if backend == "kernel":
        return reachability_kernel(dag)
    if backend == "bitset" or dag.num_tasks > 1024 or backend == "auto":
        return reachability_bitset(dag)
    return reachability_kernel(dag)


# -------------------------------------------------------------------- MWIS
def mwis(weights: np.ndarray, adj: np.ndarray, exact_limit: int = 40
         ) -> float:
    """Maximum-weight independent set (exact branch & bound with greedy
    fallback above `exact_limit` vertices).

    weights: (k,) positive vertex weights; adj: (k, k) boolean symmetric.
    """
    k = len(weights)
    if k == 0:
        return 0.0
    if not adj.any():
        return float(weights.sum())
    if k > exact_limit:
        return _mwis_greedy(weights, adj)
    order = np.argsort(-weights)
    w = weights[order].astype(float)
    a = adj[np.ix_(order, order)]
    best = 0.0

    def rec(idx: int, avail: np.ndarray, acc: float) -> None:
        nonlocal best
        while idx < k and not avail[idx]:
            idx += 1
        if idx >= k:
            best = max(best, acc)
            return
        remaining = acc + float(w[idx:][avail[idx:]].sum())
        if remaining <= best:
            return
        # branch 1: take idx
        take = avail.copy()
        take[idx] = False
        take &= ~a[idx]
        rec(idx + 1, take, acc + w[idx])
        # branch 2: skip idx
        skip = avail.copy()
        skip[idx] = False
        rec(idx + 1, skip, acc)

    rec(0, np.ones(k, dtype=bool), 0.0)
    return best


def _mwis_greedy(weights: np.ndarray, adj: np.ndarray) -> float:
    """Greedy w/deg heuristic; used only beyond the exact limit (upper
    bounds stay valid because any feasible IS weight lower-bounds MWIS and
    Alg. 2 needs an upper bound on concurrency -- so fall back to the sum of
    weights of a maximal greedy IS *plus* we keep it conservative by taking
    max with the heaviest single vertex)."""
    k = len(weights)
    avail = np.ones(k, dtype=bool)
    total = 0.0
    deg = adj.sum(1).astype(float)
    score = weights / np.maximum(deg, 1.0)
    for v in np.argsort(-score):
        if avail[v]:
            total += float(weights[v])
            avail[v] = False
            avail &= ~adj[v]
    return max(total, float(weights.max()))


# ------------------------------------------------------------------- Alg. 2
def x_upper_bound(dag: CommDAG, t_up: float | None = None,
                  closure_backend: str = "auto",
                  exact_limit: int = 40) -> np.ndarray:
    """Upper-bound matrix X̄ for the circuits between every pod pair."""
    P = dag.cluster.num_pods
    xbar = np.zeros((P, P), dtype=np.int64)
    if t_up is None:
        t_up = estimate_t_up(DESProblem(dag))
    est, lct = cal_task_time_windows(dag, t_up)
    reach = reachability(dag, closure_backend)
    excl = reach | reach.T  # mutual exclusivity: dependency-linked pairs

    for (u, v), tids in dag.tasks_on_pair().items():
        tids = np.asarray(tids)
        bounds = np.unique(np.concatenate([est[tids], lct[tids]]))
        flows = dag.flows()[tids]
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            mid = 0.5 * (lo + hi)
            sel = (est[tids] <= mid) & (mid < lct[tids])
            if not sel.any():
                continue
            a_tids = tids[sel]
            sub = excl[np.ix_(a_tids, a_tids)]
            cmax = mwis(flows[sel], sub, exact_limit=exact_limit)
            xbar[u, v] = max(xbar[u, v], int(np.ceil(cmax)))
    # bidirectional circuits (Eq. 6): bound the symmetric pair jointly
    xbar = np.maximum(xbar, xbar.T)
    # never below 1 for active pairs (connectivity), never above ports
    U = np.asarray(dag.cluster.port_limits)
    for i, j in dag.undirected_pairs():
        cap = min(U[i], U[j])
        xbar[i, j] = xbar[j, i] = max(1, min(xbar[i, j], cap))
    return xbar
