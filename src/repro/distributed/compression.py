"""Gradient compression: int8 ring all-reduce with error feedback.

`ring_allreduce_int8` is a jax-native ring reduce-scatter + all-gather over
`lax.ppermute` whose every hop carries int8 payloads -- 4x less wire
traffic than bf16/fp32 all-reduce, which directly shrinks the DP volumes
DELTA provisions circuits for.  All hops share one conservative global
scale (pmax * n / 127) so partial sums never clip; the per-device
quantization residual is returned for error feedback (re-injected into the
next step's gradients, restoring convergence -- residual boundedness is
asserted in tests).

Run inside shard_map with the data axis bound, e.g.:

    fn = jax.shard_map(lambda v: ring_allreduce_int8(v, "data")[0],
                       mesh=mesh, in_specs=P("data"), out_specs=P("data"))
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array, scale: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ring_allreduce_int8(x: jax.Array, axis_name: str
                        ) -> tuple[jax.Array, jax.Array]:
    """All-reduce(sum) of a flat f32 vector with int8 ring hops.

    Returns (sum, residual): `sum` is identical on every device up to int8
    quantization; `residual` is this device's local quantization error
    (x - dequant(quant(x))) for error feedback.
    """
    if hasattr(jax.lax, "axis_size"):
        n = jax.lax.axis_size(axis_name)
    else:                      # jax < 0.5: psum of a unit weight is static
        n = jax.lax.psum(1, axis_name)
    me = jax.lax.axis_index(axis_name)
    if n == 1:
        return x, jnp.zeros_like(x)
    size = x.shape[0]
    pad = (-size) % n
    xp = jnp.pad(x.astype(jnp.float32), (0, pad))
    chunks = xp.reshape(n, -1)
    # conservative shared scale: any partial sum of n int8 payloads fits
    scale = jax.lax.pmax(jnp.max(jnp.abs(xp)), axis_name) * n / 127.0 \
        + 1e-20
    residual = xp - _dequantize(_quantize(xp, scale), scale)
    perm = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: at step s rank r sends its partial sum of chunk
    # (r - s) and accumulates the received chunk (r - s - 1); after n-1
    # hops rank r owns the complete sum of chunk (r + 1) % n.
    acc = chunks
    for step in range(n - 1):
        send_idx = (me - step) % n
        recv_idx = (me - step - 1) % n
        buf = _quantize(acc[send_idx], scale)
        recv = jax.lax.ppermute(buf, axis_name, perm)
        acc = acc.at[recv_idx].add(_dequantize(recv, scale))
    own = (me + 1) % n
    final_own = _dequantize(_quantize(acc[own], scale), scale)
    out = jnp.zeros_like(chunks).at[own].set(final_own)

    # all-gather the reduced chunks around the ring (int8 payloads)
    buf = _quantize(acc[own], scale)
    for step in range(n - 1):
        recv = jax.lax.ppermute(buf, axis_name, perm)
        idx = (me - step) % n
        out = out.at[idx].set(_dequantize(recv, scale))
        buf = recv
    total = out.reshape(-1)[:size]
    return total, residual.reshape(-1)[:size]


def mean_grads_int8(grads: Any, axis_name: str, residual: Any | None = None
                    ) -> tuple[Any, Any]:
    """Tree-level DP gradient mean via the int8 ring, with error feedback.

    Call inside shard_map/pmap with `axis_name` bound.  residual: pytree of
    f32 like grads (or None on the first step).
    """
    n = jax.lax.axis_size(axis_name)

    def one(g, r):
        v = g.astype(jnp.float32).reshape(-1)
        if r is not None:
            v = v + r.reshape(-1)
        total, res = ring_allreduce_int8(v, axis_name)
        return (total / n).reshape(g.shape).astype(g.dtype), \
            res.reshape(g.shape)

    if residual is None:
        residual = jax.tree.map(lambda g: None, grads)
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
