"""Fault tolerance and straggler mitigation for the training driver.

On a real multi-pod deployment each component maps to the corresponding
fleet mechanism (health service, preemption notices, rescheduler); here the
mechanisms are implemented host-side and exercised by tests and
examples/train_lm.py --simulate-failure:

  * `StepWatchdog`    -- wall-clock budget per step; a step exceeding
                         `timeout_factor` x the trailing median is flagged
                         as a straggler (counter + callback hook, e.g. to
                         trigger re-dispatch or checkpoint-now).
  * `run_resilient`   -- step-loop wrapper: on exception it restores the
                         latest checkpoint and replays (the deterministic
                         data pipeline makes replay exact).
  * `FailureInjector` -- deterministic fault injection for tests/demos.
"""
from __future__ import annotations

import logging
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable

log = logging.getLogger("repro.ft")


@dataclass
class StepWatchdog:
    timeout_factor: float = 3.0
    min_history: int = 5
    on_straggler: Callable[[int, float, float], None] | None = None
    history: list[float] = field(default_factory=list)
    stragglers: int = 0

    def observe(self, step: int, duration: float) -> bool:
        """Record a step duration; returns True when flagged."""
        flagged = False
        if len(self.history) >= self.min_history:
            med = statistics.median(self.history[-50:])
            if duration > self.timeout_factor * med:
                self.stragglers += 1
                flagged = True
                log.warning("straggler step %d: %.3fs vs median %.3fs",
                            step, duration, med)
                if self.on_straggler:
                    self.on_straggler(step, duration, med)
        self.history.append(duration)
        return flagged


@dataclass
class FailureInjector:
    """Raises RuntimeError at the given step indices (once each)."""
    fail_at: tuple[int, ...] = ()
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")

    @classmethod
    def from_trace(cls, trace: list[dict]) -> "FailureInjector":
        """Build from a shared-format fault trace
        (`repro.fleet.faults.FaultInjector.trace` /
        `step_failure_trace`): only `step_failure` entries are
        training-loop faults; fabric entries (link/port/plane) belong to
        the fleet layer (`repro.fleet.fault_events_from_trace`) and are
        skipped here, so one seeded trace drives both failure models."""
        steps = sorted({int(ev["step"]) for ev in trace
                        if ev.get("kind") == "step_failure"})
        return cls(fail_at=tuple(steps))

    def to_trace(self) -> list[dict]:
        """Export as shared-format `step_failure` entries."""
        from repro.fleet.faults import step_failure_trace
        return step_failure_trace(self.fail_at)


def run_resilient(num_steps: int,
                  do_step: Callable[[int], dict],
                  save_ckpt: Callable[[int], None],
                  restore_ckpt: Callable[[], int],
                  ckpt_every: int = 50,
                  max_restarts: int = 3,
                  watchdog: StepWatchdog | None = None) -> dict:
    """Checkpointed, restartable step loop.

    do_step(step) -> metrics dict; save_ckpt(step) persists state;
    restore_ckpt() reloads the latest checkpoint and returns its step.
    Deterministic data (repro.training.data) makes post-restore replay
    bit-exact with the unfailed run.
    """
    restarts = 0
    step = 0
    metrics: dict = {}
    while step < num_steps:
        try:
            t0 = time.time()
            metrics = do_step(step)
            if watchdog is not None:
                watchdog.observe(step, time.time() - t0)
            step += 1
            if step % ckpt_every == 0 or step == num_steps:
                save_ckpt(step)
        except Exception as exc:   # noqa: BLE001 - any failure is fatal-ish
            restarts += 1
            if restarts > max_restarts:
                raise
            log.warning("step %d failed (%s); restoring checkpoint "
                        "(restart %d/%d)", step, exc, restarts, max_restarts)
            step = restore_ckpt()
    return {"metrics": metrics, "restarts": restarts,
            "stragglers": watchdog.stragglers if watchdog else 0,
            "steps": step}
