"""PartitionSpec rules for every architecture on the production meshes.

Meshes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
The pod axis extends data parallelism across pods (which is exactly the
inter-pod DP traffic DELTA plans for).

Assignment is divisibility-driven: each rule lists candidate tensor dims in
priority order and takes the first one divisible by the axis-group size, so
the same rules cover kv_heads=8 on a 16-way model axis (falls through to
head_dim), 32 experts on 16 (expert-parallel), 8 experts on 16 (expert
tensor-parallel on d_ff), batch=1 on long_500k (falls through to the KV
sequence dim), etc.  FSDP (ZeRO-3-style data-axis parameter sharding) is
enabled automatically for models above `FSDP_THRESHOLD` parameters.
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

FSDP_THRESHOLD = 30e9

MODEL_AXES = ("model",)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _group_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def assign(shape: tuple[int, ...], mesh: Mesh,
           rules: list[tuple[tuple[str, ...], list[int]]],
           skip_dims: tuple[int, ...] = ()) -> P:
    """First-divisible-dim assignment of axis groups to tensor dims."""
    spec: list = [None] * len(shape)
    for axes, dims in rules:
        need = _group_size(mesh, axes)
        if need <= 1:
            continue
        for d in dims:
            if d >= len(shape) or d in skip_dims:
                continue
            if spec[d] is None and shape[d] % need == 0 and shape[d] >= need:
                spec[d] = axes if len(axes) > 1 else axes[0]
                break
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_spec(pathstr: str, shape: tuple[int, ...], mesh: Mesh,
               fsdp: bool) -> P:
    d_ax = data_axes(mesh)
    m = MODEL_AXES
    # leaves under groups/encoder carry a leading stack dim (scanned over)
    off = 1 if ("groups" in pathstr or "encoder" in pathstr) else 0
    skip = (0,) if off else ()

    def R(*rules) -> P:
        shifted = [(axes, [d + off for d in dims]) for axes, dims in rules]
        return assign(shape, mesh, shifted, skip_dims=skip)

    leaf = pathstr.rsplit("/", 1)[-1]
    if len(shape) - off < 1 or leaf in ("step",):
        return P()
    if leaf in ("ln1", "ln2", "lnx", "final_ln", "norm_w", "conv_b",
                "A_log", "dt_bias", "qn", "kn"):
        return P()
    if leaf == "embed":
        return R((m, [0, 1]))
    if leaf == "head":
        return R((m, [1, 0]))
    if leaf == "router":
        return R((m, [1]))
    if leaf == "wq":                               # (D, H, hd)
        rules = [(m, [1, 2, 0])]
        if fsdp:
            rules.append((d_ax, [0]))
        return R(*rules)
    if leaf in ("wk", "wv"):                       # (D, KV, hd)
        # shard KV heads when divisible, otherwise REPLICATE: head_dim
        # sharding turns every attention einsum into an all-reduce of the
        # (Sq x Sk) scores (GQA KV tensors are small; expanded at use)
        rules = [(m, [1])]
        if fsdp:
            rules.append((d_ax, [0]))
        return R(*rules)
    if leaf in ("bq", "bk", "bv"):                 # (H, hd)
        return R((m, [0, 1]))
    if leaf == "wo" and "attn" in pathstr:         # (H, hd, D)
        rules = [(m, [0, 1])]
        if fsdp:
            rules.append((d_ax, [2]))
        return R(*rules)
    if leaf in ("wi", "wg") and "moe" in pathstr:  # (E, D, F)
        rules = [(m, [0, 2, 1])]
        if fsdp:
            rules.append((d_ax, [1]))
        return R(*rules)
    if leaf == "wo" and "moe" in pathstr:          # (E, F, D)
        rules = [(m, [0, 1])]
        if fsdp:
            rules.append((d_ax, [2]))
        return R(*rules)
    if leaf in ("wi", "wg"):                       # (D, F)
        rules = [(m, [1])]
        if fsdp:
            rules.append((d_ax, [0]))
        return R(*rules)
    if leaf == "wo":                               # (F, D)
        rules = [(m, [0])]
        if fsdp:
            rules.append((d_ax, [1]))
        return R(*rules)
    if leaf == "in_proj":                          # (D, Z)
        rules = [(m, [1])]
        if fsdp:
            rules.append((d_ax, [0]))
        return R(*rules)
    if leaf == "out_proj":                         # (d_in, D)
        rules = [(m, [0])]
        if fsdp:
            rules.append((d_ax, [1]))
        return R(*rules)
    if leaf == "conv_w":                           # (K, C)
        return R((m, [1]))
    # fallback: model-shard the last divisible dim
    n = len(shape)
    return R((m, list(range(n - off - 1, -1, -1))))


def cache_spec(pathstr: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    d_ax = data_axes(mesh)
    m = MODEL_AXES
    leaf = pathstr.rsplit("/", 1)[-1]
    if leaf in ("pos",) or len(shape) == 0:
        return P()
    if leaf in ("k", "v"):       # (G, B, S, KV, hd)
        return assign(shape, mesh, [(d_ax, [1, 2]), (m, [3, 4])],
                      skip_dims=(0,))
    if leaf == "conv":           # (G, B, W, C)
        return assign(shape, mesh, [(d_ax, [1]), (m, [3])], skip_dims=(0,))
    if leaf == "ssm":            # (G, B, nh, hd, n)
        return assign(shape, mesh, [(d_ax, [1]), (m, [2, 3])],
                      skip_dims=(0,))
    if leaf == "enc":            # (B, T, D)
        return assign(shape, mesh, [(d_ax, [0])])
    return P()


def batch_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    return assign(shape, mesh, [(data_axes(mesh), [0])])


def tree_specs(tree: Any, mesh: Mesh, kind: str,
               cfg: ModelConfig | None = None,
               fsdp: bool | None = None) -> Any:
    """kind: params | state | cache | batch."""
    if fsdp is None:
        fsdp = bool(cfg and cfg.total_params() > FSDP_THRESHOLD)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        pathstr = _path_str(path)
        if kind in ("params", "state"):
            return param_spec(pathstr, shape, mesh, fsdp)
        if kind == "cache":
            return cache_spec(pathstr, shape, mesh)
        return batch_spec(shape, mesh)

    return jax.tree_util.tree_map_with_path(one, tree)


def named(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
