"""Multi-tenant fleet planner: port ledger, admission, surplus reallocation,
the event-driven replanning loop (paper Sec. VI as a long-lived service)
and the telemetry-driven control plane that steers it.  Entry point:
`repro.core.api.plan` (kind="fleet") or `FleetPlanner` + `ControlPlane`.
"""
from repro.fleet.admission import (AdmissionController, AdmissionError,
                                   FleetSpec, Tenant, shrink_to_limits)
from repro.fleet.control import ControllerConfig, ControlPlane
from repro.fleet.events import (EVENT_KINDS, EVENTS_VERSION, FAULT_EVENTS,
                                PLANE_EVENTS, TELEMETRY_EVENTS, JobArrival,
                                JobDeparture, LinkFailure, LinkRecovery,
                                PhaseTransition, PlaneFailure, PlaneRecovery,
                                PlaneRewireStep, PlaneTransitionSummary,
                                PortFailure, PortRecovery, TelemetrySample,
                                TrafficChange, event_kind, rebuild_event,
                                serialize_event)
from repro.fleet.faults import (FabricHealth, FaultInjector,
                                step_failure_trace)
from repro.fleet.ledger import LedgerError, PortLedger, TenantAccount
from repro.fleet.loop import FleetPlanner, arrivals, fault_events_from_trace
from repro.fleet.plancache import CachedPlan, PlanCache, dag_signature
from repro.fleet.planes import (PlaneBook, StaggeredTransition, TenantLane,
                                TransitionResult, effective_topology,
                                split_plan)
from repro.fleet.realloc import (ReallocResult, candidate_boosts,
                                 circuit_changes, port_demand, reallocate,
                                 waterfill_grants)
from repro.fleet.telemetry import (DEFAULT_DWELL_S, DriftEstimator,
                                   DwellEstimator, synthesize_telemetry,
                                   traffic_drift)

__all__ = [
    "AdmissionController", "AdmissionError", "FleetSpec", "Tenant",
    "shrink_to_limits", "ControllerConfig", "ControlPlane",
    "EVENT_KINDS", "EVENTS_VERSION", "FAULT_EVENTS", "PLANE_EVENTS",
    "TELEMETRY_EVENTS", "JobArrival", "JobDeparture", "LinkFailure",
    "LinkRecovery", "PhaseTransition", "PlaneFailure", "PlaneRecovery",
    "PlaneRewireStep", "PlaneTransitionSummary", "PortFailure",
    "PortRecovery", "TelemetrySample", "TrafficChange", "event_kind",
    "rebuild_event", "serialize_event", "FabricHealth", "FaultInjector",
    "step_failure_trace", "LedgerError", "PortLedger", "TenantAccount",
    "FleetPlanner", "arrivals", "fault_events_from_trace", "CachedPlan",
    "PlanCache", "dag_signature", "PlaneBook", "StaggeredTransition",
    "TenantLane", "TransitionResult", "effective_topology", "split_plan",
    "ReallocResult", "candidate_boosts",
    "circuit_changes", "port_demand", "reallocate", "waterfill_grants",
    "DEFAULT_DWELL_S", "DriftEstimator", "DwellEstimator",
    "synthesize_telemetry", "traffic_drift",
]
