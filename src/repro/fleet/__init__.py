"""Multi-tenant fleet planner: port ledger, admission, surplus reallocation
and the event-driven replanning loop (paper Sec. VI as a long-lived
service).  Entry point: `repro.core.api.fleet_optimize` or `FleetPlanner`.
"""
from repro.fleet.admission import (AdmissionController, AdmissionError,
                                   FleetSpec, Tenant)
from repro.fleet.ledger import LedgerError, PortLedger, TenantAccount
from repro.fleet.loop import (FleetPlanner, JobArrival, JobDeparture,
                              TrafficChange, arrivals)
from repro.fleet.plancache import CachedPlan, PlanCache, dag_signature
from repro.fleet.realloc import (ReallocResult, candidate_boosts,
                                 port_demand, reallocate, waterfill_grants)

__all__ = [
    "AdmissionController", "AdmissionError", "FleetSpec", "Tenant",
    "LedgerError", "PortLedger", "TenantAccount",
    "FleetPlanner", "JobArrival", "JobDeparture", "TrafficChange",
    "arrivals", "CachedPlan", "PlanCache", "dag_signature",
    "ReallocResult", "candidate_boosts", "port_demand", "reallocate",
    "waterfill_grants",
]
