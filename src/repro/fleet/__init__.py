"""Multi-tenant fleet planner: port ledger, admission, surplus reallocation
and the event-driven replanning loop (paper Sec. VI as a long-lived
service).  Entry point: `repro.core.api.fleet_optimize` or `FleetPlanner`.
"""
from repro.fleet.admission import (AdmissionController, AdmissionError,
                                   FleetSpec, Tenant, shrink_to_limits)
from repro.fleet.faults import (FabricHealth, FaultInjector,
                                step_failure_trace)
from repro.fleet.ledger import LedgerError, PortLedger, TenantAccount
from repro.fleet.loop import (FAULT_EVENTS, FleetPlanner, JobArrival,
                              JobDeparture, LinkFailure, LinkRecovery,
                              PlaneFailure, PlaneRecovery, PortFailure,
                              PortRecovery, TrafficChange, arrivals,
                              fault_events_from_trace)
from repro.fleet.plancache import CachedPlan, PlanCache, dag_signature
from repro.fleet.realloc import (ReallocResult, candidate_boosts,
                                 port_demand, reallocate, waterfill_grants)

__all__ = [
    "AdmissionController", "AdmissionError", "FleetSpec", "Tenant",
    "shrink_to_limits", "FabricHealth", "FaultInjector",
    "step_failure_trace", "LedgerError", "PortLedger", "TenantAccount",
    "FAULT_EVENTS", "FleetPlanner", "JobArrival", "JobDeparture",
    "LinkFailure", "LinkRecovery", "PlaneFailure", "PlaneRecovery",
    "PortFailure", "PortRecovery", "TrafficChange", "arrivals",
    "fault_events_from_trace", "CachedPlan", "PlanCache", "dag_signature",
    "ReallocResult", "candidate_boosts", "port_demand", "reallocate",
    "waterfill_grants",
]
