"""Admission and placement: JobSpec -> fleet pods -> planned tenant.

Arriving jobs are placed first-fit onto a contiguous window of fleet pods
whose free (pool) ports cover the job's fair-share entitlement -- one port
per GPU the job owns in the pod (paper Sec. V-A1).  Co-tenancy is the
normal case: two jobs share a pod whenever the pod's physical port count
covers both entitlements (the Fig. 10 Model/Model^T deployment).

Each admitted tenant gets its *local* view of the cluster: a ClusterSpec of
its pod window with `port_limits = ledger.limits` gathered over the window,
and a reduced CommDAG built by `repro.core.schedule.build_comm_dag`.
Planning is DELTA-Fast (+ greedy `trim_ports` for donors) behind the
fleet-wide PlanCache.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.cluster import GBPS, ClusterSpec
from repro.core.dag import CommDAG
from repro.core.des import DESProblem, simulate
from repro.core.ga import GAOptions, delta_fast, trim_ports
from repro.core.schedule import build_comm_dag
from repro.core.traffic import JobSpec
from repro.fleet.ledger import LedgerError, PortLedger, gather, scatter
from repro.fleet.plancache import CachedPlan, PlanCache


@dataclass(frozen=True)
class FleetSpec:
    """The physical fleet: pods, OCS ports per pod, per-port bandwidth."""

    num_pods: int
    ports_per_pod: int
    nic_gbps: float = 400.0
    intra_pod_bandwidth: float = 900e9

    @property
    def nic_bandwidth(self) -> float:
        return self.nic_gbps * GBPS

    def capacity(self) -> np.ndarray:
        return np.full(self.num_pods, self.ports_per_pod, dtype=np.int64)


@dataclass
class Tenant:
    """One admitted job: placement, local DAG, and its committed plan."""

    name: str
    job: JobSpec
    pods: tuple[int, ...]           # fleet pod ids, local pod i -> pods[i]
    reverse_stages: bool
    port_min: bool
    dag: CommDAG
    plan: CachedPlan | None = None
    base_plan: CachedPlan | None = None   # within-entitlement plan; grants
    _des: object = field(default=None, repr=False)  # restore to this
    _xbar: object = field(default=None, repr=False)

    @property
    def num_local_pods(self) -> int:
        return len(self.pods)

    def local_usage(self) -> np.ndarray:
        """Per-local-pod ports wired by the committed topology."""
        if self.plan is None:
            return np.zeros(self.num_local_pods, dtype=np.int64)
        return self.plan.x.sum(axis=1).astype(np.int64)

    def fleet_usage(self, num_fleet_pods: int) -> np.ndarray:
        return scatter(self.local_usage(), self.pods, num_fleet_pods)

    def des(self):
        """Cached JaxDES for batched candidate evaluation (realloc)."""
        if self._des is None:
            from repro.core.des_jax import JaxDES
            self._des = JaxDES(DESProblem(self.dag))
        return self._des

    def xbar(self):
        """Cached Alg. 2 circuit upper bounds (the DAG never changes)."""
        if self._xbar is None:
            from repro.core.xbound import x_upper_bound
            self._xbar = x_upper_bound(self.dag)
        return self._xbar


class AdmissionError(RuntimeError):
    """No pod window can host the job's entitlement."""


class AdmissionController:
    """Places jobs on fleet pods and plans them through the cache."""

    def __init__(self, fleet: FleetSpec, ledger: PortLedger,
                 cache: PlanCache | None = None,
                 ga_options: GAOptions | None = None):
        self.fleet = fleet
        self.ledger = ledger
        # no `or`: an empty PlanCache is falsy (it has __len__)
        self.cache = cache if cache is not None else PlanCache()
        self.ga_options = ga_options

    # ------------------------------------------------------------ placement
    def entitlement(self, job: JobSpec,
                    reverse_stages: bool = False) -> np.ndarray:
        """Per-local-pod fair-share ports (== GPUs owned in the pod)."""
        placement = job.placement(reverse_stages)
        return np.asarray(placement.port_limits(), dtype=np.int64)

    def find_window(self, job: JobSpec,
                    reverse_stages: bool = False) -> int:
        """First-fit base pod for the job's window.

        Checked against `headroom()`, not `pool()`: donated ports stay
        reserved for their donor (withdrawable on traffic growth) and must
        never be consumed by a new tenant's permanent entitlement."""
        ent = self.entitlement(job, reverse_stages)
        k = len(ent)
        if k > self.fleet.num_pods:
            raise AdmissionError(
                f"job {job.name!r} spans {k} pods, fleet has "
                f"{self.fleet.num_pods}")
        head = self.ledger.headroom()
        for base in range(self.fleet.num_pods - k + 1):
            if (head[base:base + k] >= ent).all():
                return base
        raise AdmissionError(
            f"no {k}-pod window with {ent.tolist()} free ports "
            f"(headroom={head.tolist()})")

    # ------------------------------------------------------------ admission
    def admit(self, name: str, job: JobSpec, *,
              reverse_stages: bool = False, port_min: bool = False,
              base_pod: int | None = None) -> Tenant:
        """Place, ledger-admit, build the local DAG, and plan the tenant."""
        ent = self.entitlement(job, reverse_stages)
        base = self.find_window(job, reverse_stages) if base_pod is None \
            else base_pod
        pods = tuple(range(base, base + len(ent)))
        if pods and pods[-1] >= self.fleet.num_pods:
            raise AdmissionError(f"window {pods} exceeds the fleet")
        head = self.ledger.headroom()[list(pods)]
        if (ent > head).any():
            raise AdmissionError(
                f"window {pods} has headroom {head.tolist()}, job needs "
                f"{ent.tolist()} (donated ports stay reserved)")
        self.ledger.admit(name, scatter(ent, pods, self.fleet.num_pods))
        try:
            tenant = self._build_and_plan(name, job, pods, reverse_stages,
                                          port_min)
        except Exception:
            self.ledger.release(name)
            raise
        return tenant

    def _build_and_plan(self, name: str, job: JobSpec, pods: tuple[int, ...],
                        reverse_stages: bool, port_min: bool) -> Tenant:
        dag = self.build_dag(name, job, pods, reverse_stages)
        tenant = Tenant(name=name, job=job, pods=pods,
                        reverse_stages=reverse_stages, port_min=port_min,
                        dag=dag)
        self.plan(tenant)
        return tenant

    def build_dag(self, name: str, job: JobSpec, pods: tuple[int, ...],
                  reverse_stages: bool) -> CommDAG:
        limits = gather(self.ledger.limits(name), pods)
        cluster = ClusterSpec(
            num_pods=len(pods), port_limits=tuple(int(u) for u in limits),
            nic_bandwidth=self.fleet.nic_bandwidth,
            intra_pod_bandwidth=self.fleet.intra_pod_bandwidth)
        return build_comm_dag(job, reverse_stages=reverse_stages,
                              cluster=cluster)

    # ------------------------------------------------------------- planning
    def plan(self, tenant: Tenant) -> CachedPlan:
        """Port-aware DELTA-Fast solve behind the plan cache; commits the
        resulting allocation to the ledger."""

        def solve() -> CachedPlan:
            problem = DESProblem(tenant.dag)
            ideal = simulate(problem, np.zeros((len(tenant.pods),) * 2),
                             ideal=True)
            ga = delta_fast(tenant.dag, self.ga_options)
            x = ga.x
            if tenant.port_min and np.isfinite(ga.makespan):
                x = trim_ports(tenant.dag, x)
            res = simulate(problem, x)
            nct = res.comm_time / ideal.comm_time \
                if ideal.comm_time > 0 else float("inf")
            return CachedPlan(
                x=x, makespan=res.makespan, comm_time=res.comm_time,
                nct=nct, ideal_comm_time=ideal.comm_time,
                details={"generations": ga.generations,
                         "evaluations": ga.evaluations,
                         "port_min": tenant.port_min})

        plan, hit = self.cache.get_or_plan(
            tenant.dag, solve, extra=("delta-fast", tenant.port_min))
        plan.details["cache_hit"] = hit
        tenant.plan = plan
        tenant.base_plan = plan.copy()
        self.ledger.commit(tenant.name,
                           tenant.fleet_usage(self.fleet.num_pods))
        return plan

    # ------------------------------------------------------------ departure
    def depart(self, tenant: Tenant) -> None:
        try:
            self.ledger.release(tenant.name)
        except LedgerError:   # already released (defensive)
            pass
