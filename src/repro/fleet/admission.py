"""Admission and placement: JobSpec -> fleet pods -> planned tenant.

Arriving jobs are placed first-fit onto a contiguous window of fleet pods
whose free (pool) ports cover the job's fair-share entitlement -- one port
per GPU the job owns in the pod (paper Sec. V-A1).  Co-tenancy is the
normal case: two jobs share a pod whenever the pod's physical port count
covers both entitlements (the Fig. 10 Model/Model^T deployment).

Each admitted tenant gets its *local* view of the cluster: a ClusterSpec of
its pod window with `port_limits = ledger.limits` gathered over the window,
and a reduced CommDAG built by `repro.core.schedule.build_comm_dag`.
Planning is DELTA-Fast (+ greedy `trim_ports` for donors) behind the
fleet-wide PlanCache.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field

import numpy as np

from repro.core.cluster import GBPS, ClusterSpec
from repro.core.dag import CommDAG, DagEnsemble
from repro.core.des import DESProblem, simulate
from repro.core.ga import (GAOptions, delta_failsafe, delta_fast,
                           delta_robust, trim_ports, trim_ports_ensemble)
from repro.core.schedule import build_comm_dag
from repro.core.traffic import JobSpec
from repro.fleet.ledger import LedgerError, PortLedger, gather, scatter
from repro.fleet.plancache import CachedPlan, PlanCache, dag_signature
from repro.fleet.realloc import (_candidate_genomes, _genome_view,
                                 _greedy_fill, _scatter, circuit_changes)
from repro.fleet.telemetry import DEFAULT_DWELL_S
from repro.obs import get_counter, get_logger, span

INF = float("inf")

_log = get_logger("repro.fleet")
_PLANS = get_counter("fleet_plans_total",
                     "tenant planning solves, by path and cache outcome")
_ROBUST_DEGRADED = get_counter(
    "fleet_robust_degraded_total",
    "robust replans degraded to a single-DAG plan (empty union space or "
    "infeasible member references)")
_REPAIRS = get_counter("fleet_repairs_total",
                       "fabric repair decisions, by chosen option")
_STEERS = get_counter("fleet_steer_decisions_total",
                      "priced phase-change decisions, by chosen option")


@dataclass(frozen=True)
class FleetSpec:
    """The physical fleet: pods, OCS ports per pod, per-port bandwidth."""

    num_pods: int
    ports_per_pod: int
    nic_gbps: float = 400.0
    intra_pod_bandwidth: float = 900e9

    @property
    def nic_bandwidth(self) -> float:
        return self.nic_gbps * GBPS

    def capacity(self) -> np.ndarray:
        return np.full(self.num_pods, self.ports_per_pod, dtype=np.int64)


@dataclass
class Tenant:
    """One admitted job: placement, local DAG, and its committed plan."""

    name: str
    job: JobSpec
    pods: tuple[int, ...]           # fleet pod ids, local pod i -> pods[i]
    reverse_stages: bool
    port_min: bool
    dag: CommDAG
    dag_history: list[CommDAG] = field(default_factory=list)
    plan: CachedPlan | None = None
    base_plan: CachedPlan | None = None   # within-entitlement plan; grants
    _des: object = field(default=None, repr=False)  # restore to this
    _xbar: object = field(default=None, repr=False)

    @property
    def num_local_pods(self) -> int:
        return len(self.pods)

    def local_usage(self) -> np.ndarray:
        """Per-local-pod ports wired by the committed topology."""
        if self.plan is None:
            return np.zeros(self.num_local_pods, dtype=np.int64)
        return self.plan.x.sum(axis=1).astype(np.int64)

    def fleet_usage(self, num_fleet_pods: int) -> np.ndarray:
        return scatter(self.local_usage(), self.pods, num_fleet_pods)

    def des(self):
        """Cached JaxDES for batched candidate evaluation (realloc).

        Lives on the fleet's hot replanning path, so a compile-bucket miss
        here (an XLA recompile per surplus pass) is a perf regression worth
        surfacing -- `warn_on_miss` logs it."""
        if self._des is None:
            from repro.core.des_jax import DESOptions, JaxDES
            self._des = JaxDES(DESProblem(self.dag),
                               options=DESOptions(warn_on_miss=True))
        return self._des

    def xbar(self):
        """Cached Alg. 2 circuit upper bounds (the DAG never changes)."""
        if self._xbar is None:
            from repro.core.xbound import x_upper_bound
            self._xbar = x_upper_bound(self.dag)
        return self._xbar


class AdmissionError(RuntimeError):
    """No pod window can host the job's entitlement."""


class AdmissionController:
    """Places jobs on fleet pods and plans them through the cache."""

    def __init__(self, fleet: FleetSpec, ledger: PortLedger,
                 cache: PlanCache | None = None,
                 ga_options: GAOptions | None = None):
        self.fleet = fleet
        self.ledger = ledger
        # no `or`: an empty PlanCache is falsy (it has __len__)
        self.cache = cache if cache is not None else PlanCache()
        self.ga_options = ga_options

    # ------------------------------------------------------------ placement
    def entitlement(self, job: JobSpec,
                    reverse_stages: bool = False) -> np.ndarray:
        """Per-local-pod fair-share ports (== GPUs owned in the pod)."""
        placement = job.placement(reverse_stages)
        return np.asarray(placement.port_limits(), dtype=np.int64)

    def find_window(self, job: JobSpec,
                    reverse_stages: bool = False) -> int:
        """First-fit base pod for the job's window.

        Checked against `headroom()`, not `pool()`: donated ports stay
        reserved for their donor (withdrawable on traffic growth) and must
        never be consumed by a new tenant's permanent entitlement."""
        ent = self.entitlement(job, reverse_stages)
        k = len(ent)
        if k > self.fleet.num_pods:
            raise AdmissionError(
                f"job {job.name!r} spans {k} pods, fleet has "
                f"{self.fleet.num_pods}")
        head = self.ledger.headroom()
        for base in range(self.fleet.num_pods - k + 1):
            if (head[base:base + k] >= ent).all():
                return base
        raise AdmissionError(
            f"no {k}-pod window with {ent.tolist()} free ports "
            f"(headroom={head.tolist()})")

    # ------------------------------------------------------------ admission
    def admit(self, name: str, job: JobSpec, *,
              reverse_stages: bool = False, port_min: bool = False,
              base_pod: int | None = None) -> Tenant:
        """Place, ledger-admit, build the local DAG, and plan the tenant."""
        ent = self.entitlement(job, reverse_stages)
        base = self.find_window(job, reverse_stages) if base_pod is None \
            else base_pod
        pods = tuple(range(base, base + len(ent)))
        if pods and pods[-1] >= self.fleet.num_pods:
            raise AdmissionError(f"window {pods} exceeds the fleet")
        head = self.ledger.headroom()[list(pods)]
        if (ent > head).any():
            raise AdmissionError(
                f"window {pods} has headroom {head.tolist()}, job needs "
                f"{ent.tolist()} (donated ports stay reserved)")
        self.ledger.admit(name, scatter(ent, pods, self.fleet.num_pods))
        try:
            with span("fleet.admit", tenant=name, pods=len(pods)):
                tenant = self._build_and_plan(name, job, pods,
                                              reverse_stages, port_min)
        except Exception:
            self.ledger.release(name)
            raise
        return tenant

    def _build_and_plan(self, name: str, job: JobSpec, pods: tuple[int, ...],
                        reverse_stages: bool, port_min: bool) -> Tenant:
        dag = self.build_dag(name, job, pods, reverse_stages)
        tenant = Tenant(name=name, job=job, pods=pods,
                        reverse_stages=reverse_stages, port_min=port_min,
                        dag=dag)
        self.plan(tenant)
        return tenant

    def build_dag(self, name: str, job: JobSpec, pods: tuple[int, ...],
                  reverse_stages: bool) -> CommDAG:
        limits = gather(self.ledger.limits(name), pods)
        cluster = ClusterSpec(
            num_pods=len(pods), port_limits=tuple(int(u) for u in limits),
            nic_bandwidth=self.fleet.nic_bandwidth,
            intra_pod_bandwidth=self.fleet.intra_pod_bandwidth)
        return build_comm_dag(job, reverse_stages=reverse_stages,
                              cluster=cluster)

    # ------------------------------------------------------------- planning
    def _solve_single(self, dag: CommDAG, port_min: bool) -> CachedPlan:
        """One port-aware DELTA-Fast solve of a local-view CommDAG."""
        problem = DESProblem(dag)
        P = dag.cluster.num_pods
        ideal = simulate(problem, np.zeros((P, P)), ideal=True)
        ga = delta_fast(dag, self.ga_options)
        x = ga.x
        if port_min and np.isfinite(ga.makespan):
            x = trim_ports(dag, x)
        res = simulate(problem, x)
        nct = res.comm_time / ideal.comm_time \
            if ideal.comm_time > 0 else float("inf")
        return CachedPlan(
            x=x, makespan=res.makespan, comm_time=res.comm_time,
            nct=nct, ideal_comm_time=ideal.comm_time,
            details={"generations": ga.generations,
                     "evaluations": ga.evaluations,
                     "port_min": port_min})

    def single_plan(self, dag: CommDAG,
                    port_min: bool) -> tuple[CachedPlan, bool]:
        """Cache-backed single-DAG plan (the unit every planning path --
        admission, robust references, traffic changes -- shares)."""
        return self.cache.get_or_plan(
            dag, lambda: self._solve_single(dag, port_min),
            extra=("delta-fast", port_min))

    def plan(self, tenant: Tenant) -> CachedPlan:
        """Port-aware DELTA-Fast solve behind the plan cache; commits the
        resulting allocation to the ledger."""
        with span("fleet.plan", tenant=tenant.name) as sp:
            plan, hit = self.single_plan(tenant.dag, tenant.port_min)
            sp.set(cache_hit=bool(hit))
        _PLANS.inc(path="single", cache="hit" if hit else "miss")
        plan.details["cache_hit"] = hit
        tenant.plan = plan
        tenant.base_plan = plan.copy()
        self.ledger.commit(tenant.name,
                           tenant.fleet_usage(self.fleet.num_pods))
        return plan

    def plan_robust(self, tenant: Tenant, incumbents: list[CommDAG],
                    objective: str = "max-regret") -> CachedPlan:
        """Robust plan over {incumbent DAGs + the tenant's current DAG}.

        Instead of replanning from scratch on every phase/traffic change --
        which assumes the OCS can rewire for free -- the tenant keeps one
        static topology scored against the whole set, so flipping back to
        a previous phase needs no reconfiguration.  Incumbents whose local
        cluster view no longer matches (e.g. recorded under different
        donated-port limits) are dropped; with no usable incumbent this
        degrades to the plain `plan` path.
        """
        from repro.core.ga import ROBUST_OBJECTIVES
        if objective not in ROBUST_OBJECTIVES:
            # fail fast: the except below degrades solve-time ValueErrors
            # to a plain plan and must not swallow a config typo
            raise ValueError(f"unknown objective {objective!r}; "
                             f"pick from {ROBUST_OBJECTIVES}")
        cl = tenant.dag.cluster
        usable = [d for d in incumbents
                  if d.cluster.num_pods == cl.num_pods
                  and tuple(d.cluster.port_limits) == tuple(cl.port_limits)
                  and d.cluster.nic_bandwidth == cl.nic_bandwidth]
        # drop incumbents identical to the current DAG (phase flip-flops)
        cur_sig = dag_signature(tenant.dag)
        seen = {cur_sig}
        members, sigs = [tenant.dag], [cur_sig]
        for d in usable:
            sig = dag_signature(d)
            if sig not in seen:
                seen.add(sig)
                members.append(d)
                sigs.append(sig)
        if len(members) == 1:
            return self.plan(tenant)

        def member_refs() -> tuple[np.ndarray, int]:
            """Max-regret reference makespans, amortized through the fleet
            PlanCache: the refs ARE the members' best single-DAG plans,
            which the cache already stores from admission / previous phase
            plans, so they are never re-solved here on a hit."""
            refs, hits = [], 0
            for d in members:
                plan, hit = self.single_plan(d, tenant.port_min)
                refs.append(plan.makespan)
                hits += int(hit)
            return np.asarray(refs, dtype=np.float64), hits

        def solve() -> CachedPlan:
            refs, ref_hits = member_refs()
            if not (np.isfinite(refs) & (refs > 0)).all():
                raise ValueError(
                    f"infeasible member reference plans: {refs}")
            ensemble = DagEnsemble(
                members, names=[f"phase{i}" for i in range(len(members))])
            rob = delta_robust(ensemble, self.ga_options,
                               objective=objective, refs=refs)
            x = rob.x
            makespans = rob.makespans
            if tenant.port_min and rob.feasible:
                # port-min donors keep donating on the robust path: trim
                # circuits certified against EVERY member, so the freed
                # ports never break another phase's makespan
                from repro.core.api import evaluate_on_ensemble
                x = trim_ports_ensemble(ensemble, x)
                makespans = evaluate_on_ensemble(ensemble, x)
            problem = DESProblem(tenant.dag)
            ideal = simulate(problem, np.zeros((len(tenant.pods),) * 2),
                             ideal=True)
            res = simulate(problem, x)
            nct = res.comm_time / ideal.comm_time \
                if ideal.comm_time > 0 else float("inf")
            return CachedPlan(
                x=x, makespan=res.makespan, comm_time=res.comm_time,
                nct=nct, ideal_comm_time=ideal.comm_time,
                details={"robust": True, "objective": objective,
                         "port_min": tenant.port_min,
                         "ref_cache_hits": ref_hits,
                         "num_members": len(members),
                         "member_makespans": makespans.tolist(),
                         "member_regrets": (makespans / rob.refs).tolist(),
                         "worst_regret": float(
                             (makespans / rob.refs).max()),
                         "generations": rob.generations,
                         "evaluations": rob.evaluations})

        try:
            with span("fleet.plan_robust", tenant=tenant.name,
                      members=len(members)):
                plan, hit = self.cache.get_or_plan(
                    tenant.dag, solve,
                    extra=("delta-robust", objective, tenant.port_min,
                           tuple(sorted(sigs))))
        except ValueError as exc:
            # the robust search space can be empty even when every phase
            # plans fine alone: the *union* of active pairs may exceed a
            # pod's port budget (one circuit per incident pair is the
            # connectivity floor), and an incumbent member may have become
            # unplannable under the current limits (infeasible refs).
            # Degrade to the current-DAG plan instead of killing the
            # online replanning loop -- but never silently: the counter is
            # the authoritative degrade signal, the log line its echo.
            _ROBUST_DEGRADED.inc()
            _log.warning(
                "robust replan for tenant %r degraded to a single-DAG "
                "plan (%d members): %s", tenant.name, len(members), exc)
            return self.plan(tenant)
        _PLANS.inc(path="robust", cache="hit" if hit else "miss")
        plan.details["cache_hit"] = hit
        tenant.plan = plan
        tenant.base_plan = plan.copy()
        self.ledger.commit(tenant.name,
                           tenant.fleet_usage(self.fleet.num_pods))
        return plan

    # --------------------------------------------------------------- repair
    def repair(self, tenant: Tenant, mask: np.ndarray, *,
               rng: np.random.Generator | None = None,
               num_random: int = 8,
               dwell_s: float = DEFAULT_DWELL_S,
               reconfig_s_per_circuit: float = 0.01,
               replan_threshold: float = 1.2) -> dict:
        """Price and apply one repair decision for a tenant under a fabric
        capacity `mask` (its local (P, P) availability factor).

        Three options compete on the FastReChain-style price

            cost = delay + dwell_s * max(ms / ms_healthy - 1, 0)

        where `delay` is the option's reconfiguration delay (changed
        circuits x `reconfig_s_per_circuit`, zero for keep), `ms` its
        exact masked-DES makespan, and `ms_healthy` the incumbent
        topology's healthy makespan -- i.e. seconds of rewiring downtime
        now, plus the makespan inflation *relative to the healthy
        incumbent* (clamped at zero) paid on every iteration for the
        remaining phase dwell.  `dwell_s` defaults to the
        `DEFAULT_DWELL_S` prior; the fleet loop passes its per-tenant
        telemetry estimate (`FleetPlanner.dwell_for`).  An infeasible
        (partitioned) option prices at infinity:

          keep     run the incumbent topology through the degraded fabric
                   (zero delay, possibly large inflation -- or inf on a
                   partition);
          rewire   a mask-aware candidate portfolio within the tenant's
                   CURRENT ledger limits, scored in one fused masked
                   `batch_genome_makespan` call (cheap local surgery);
          replan   full DELTA-Failsafe GA solve against the mask, only
                   attempted when the best local option still inflates the
                   makespan beyond `replan_threshold` (it is the expensive
                   option, and cache-keyed by the rounded mask).

        The winner is certified with the exact numpy DES under the mask and
        committed to `tenant.plan` (and `base_plan`, so later grant
        revocations restore the *repaired* topology).  The caller commits
        the ledger allocation.  A mask of all-ones re-prices the plan at
        healthy capacity and reports option "healthy".
        """
        mask = np.asarray(mask, dtype=np.float64)
        problem = DESProblem(tenant.dag)
        x0 = np.asarray(tenant.plan.x, dtype=np.int64)
        # the committed plan's makespan may hold a *masked* value from a
        # previous repair -- always re-derive the healthy baseline
        healthy = simulate(problem, x0)
        ms_healthy = healthy.makespan
        ideal = tenant.plan.ideal_comm_time

        def nct_of(comm_time: float) -> float:
            return comm_time / ideal if ideal > 0 else INF

        if float(mask.min(initial=1.0)) >= 1.0 - 1e-12:
            tenant.plan.makespan = healthy.makespan
            tenant.plan.comm_time = healthy.comm_time
            tenant.plan.nct = nct_of(healthy.comm_time)
            tenant.base_plan = tenant.plan.copy()
            _REPAIRS.inc(option="healthy")
            return {"tenant": tenant.name, "option": "healthy",
                    "makespan": healthy.makespan,
                    "ms_healthy": ms_healthy, "delay_s": 0.0,
                    "cost_s": 0.0, "changed_circuits": 0, "options": {}}

        def price(ms: float, delay: float) -> float:
            """Seconds of delay now + expected seconds lost to the slowdown
            over one phase dwell.  An infeasible (partitioned) option is
            infinitely expensive."""
            if not np.isfinite(ms):
                return INF
            infl = max(ms / ms_healthy - 1.0, 0.0) \
                if np.isfinite(ms_healthy) and ms_healthy > 0 else 0.0
            return delay + dwell_s * infl

        # (name, x, masked makespan, delay, cost) -- list order breaks ties
        ms_keep = simulate(problem, x0.astype(np.float64) * mask).makespan
        options = [("keep", x0, ms_keep, 0.0, price(ms_keep, 0.0))]

        limits = gather(self.ledger.limits(tenant.name), tenant.pods)
        pairs = tenant.dag.undirected_pairs()
        if pairs:
            P = len(tenant.pods)
            eu, ev, g0, rem = _genome_view(x0, pairs, P)
            usage0 = rem.sum(axis=1)
            rng = rng if rng is not None else np.random.default_rng(0)
            G = _candidate_genomes(tenant.dag, g0, usage0, limits, eu, ev,
                                   rng, num_random=num_random)
            # mask-aware fill: a circuit on a degraded pair delivers only
            # `frac` of its bandwidth, so compensating lost capacity means
            # over-provisioning exactly those pairs (dead pairs excluded)
            vol = tenant.dag.traffic_matrix()
            uvol = vol[eu, ev] + vol[ev, eu]
            frac = mask[eu, ev]
            w_base = np.where(frac > 0, uvol / np.maximum(frac, 1e-9), -INF)
            g_mask = _greedy_fill(
                g0, usage0, limits, eu, ev,
                lambda g: w_base / np.maximum(g, 1))
            G = np.vstack([G, g_mask[None]])
            _, first = np.unique(G, axis=0, return_index=True)
            G = G[np.sort(first)]
            ms_c, feas = tenant.des().batch_genome_makespan(G, eu, ev,
                                                            mask=mask)
            score = np.where(feas, np.asarray(ms_c), INF)
            best = int(np.argmin(score))
            x_rw = _scatter(G[best], eu, ev, P) + rem
            cert = simulate(problem, x_rw.astype(np.float64) * mask)
            delay = circuit_changes(x_rw, x0) * reconfig_s_per_circuit
            options.append(("rewire", x_rw, cert.makespan, delay,
                            price(cert.makespan, delay)))

        best_ms = min(o[2] for o in options)
        inflation = best_ms / ms_healthy \
            if np.isfinite(ms_healthy) and ms_healthy > 0 else INF
        if inflation > replan_threshold:
            def solve_failsafe() -> CachedPlan:
                res = delta_failsafe(tenant.dag, self.ga_options,
                                     scenarios=[mask])
                cert = simulate(problem,
                                np.asarray(res.x, np.float64) * mask)
                return CachedPlan(
                    x=np.asarray(res.x, dtype=np.int64),
                    makespan=cert.makespan, comm_time=cert.comm_time,
                    nct=nct_of(cert.comm_time), ideal_comm_time=ideal,
                    details={"failsafe": True,
                             "generations": res.generations,
                             "evaluations": res.evaluations})

            with span("fleet.repair_replan", tenant=tenant.name):
                plan_fs, hit = self.cache.get_or_plan(
                    tenant.dag, solve_failsafe,
                    extra=("delta-failsafe",
                           np.round(mask, 6).tobytes().hex()))
            _PLANS.inc(path="failsafe", cache="hit" if hit else "miss")
            x_fs = np.asarray(plan_fs.x, dtype=np.int64)
            ms_fs = plan_fs.makespan
            if (x_fs.sum(axis=1) > limits).any():
                # the failsafe GA solves against the dag's admission-time
                # port limits; the ledger may have seized ports since, so
                # clamp the plan to what the tenant may wire today
                x_fs = shrink_to_limits(x_fs, limits)
                ms_fs = simulate(
                    problem, x_fs.astype(np.float64) * mask).makespan
            delay = circuit_changes(x_fs, x0) * reconfig_s_per_circuit
            options.append(("replan", x_fs, ms_fs, delay,
                            price(ms_fs, delay)))

        name_w, x_w, _ms_w, delay_w, cost_w = min(options,
                                                  key=lambda o: o[4])
        res = simulate(problem, x_w.astype(np.float64) * mask)
        tenant.plan.x = np.asarray(x_w, dtype=np.int64)
        tenant.plan.makespan = res.makespan
        tenant.plan.comm_time = res.comm_time
        tenant.plan.nct = nct_of(res.comm_time)
        tenant.base_plan = tenant.plan.copy()
        _REPAIRS.inc(option=name_w)
        return {"tenant": tenant.name, "option": name_w,
                "ms_healthy": ms_healthy, "makespan": res.makespan,
                "delay_s": delay_w, "cost_s": cost_w,
                "changed_circuits": int(circuit_changes(x_w, x0)),
                "options": {n: {"makespan": m, "delay_s": d, "cost_s": c}
                            for n, _x, m, d, c in options}}

    # --------------------------------------------------------- phase change
    def change(self, tenant: Tenant, x_incumbent: np.ndarray, *,
               dwell_s: float, reconfig_s_per_circuit: float,
               mask: np.ndarray | None = None) -> dict:
        """Price and apply one steered phase change: `tenant` is the NEW
        tenant (its DAG already rebuilt for the arriving phase) and
        `x_incumbent` the topology committed for the previous phase.

        Two options compete on the same break-even as `repair`, priced
        against the best known plan for the new phase (`ms_new`):

          keep     run the new phase through the incumbent topology --
                   zero delay, `dwell_s * max(ms_keep / ms_new - 1, 0)`
                   expected seconds lost to inflation over the estimated
                   remaining dwell;
          replan   rewire to the new phase's cache-amortized DELTA-Fast
                   plan -- inflation-free but pays `changed_circuits x
                   reconfig_s_per_circuit` of rewiring delay now.

        Replan wins only if `dwell_s x inflation > delay` (strictly: ties
        keep the incumbent, a free hysteresis).  The winner is certified
        with the exact (masked, when `mask` is given) numpy DES,
        committed to `tenant.plan`/`base_plan` and the ledger.
        """
        problem = DESProblem(tenant.dag)
        P = len(tenant.pods)
        ideal = simulate(problem, np.zeros((P, P)), ideal=True)

        def msim(x):
            xe = np.asarray(x, dtype=np.float64)
            return simulate(problem, xe * mask if mask is not None else xe)

        x0 = np.asarray(x_incumbent, dtype=np.int64)
        keep_res = msim(x0)
        with span("fleet.change", tenant=tenant.name) as sp:
            plan_new, hit = self.single_plan(tenant.dag, tenant.port_min)
            sp.set(cache_hit=bool(hit))
        _PLANS.inc(path="steer", cache="hit" if hit else "miss")
        x_new = np.asarray(plan_new.x, dtype=np.int64)
        # the cached plan solved against admission-time limits; the ledger
        # may have seized ports since (cf. repair's failsafe clamp)
        limits = gather(self.ledger.limits(tenant.name), tenant.pods)
        if (x_new.sum(axis=1) > limits).any():
            x_new = shrink_to_limits(x_new, limits)
        new_res = msim(x_new)
        ms_new, ms_keep = new_res.makespan, keep_res.makespan
        delay = circuit_changes(x_new, x0) * reconfig_s_per_circuit
        if not np.isfinite(ms_keep):
            inflation, cost_keep = INF, INF
        elif np.isfinite(ms_new) and ms_new > 0:
            inflation = max(ms_keep / ms_new - 1.0, 0.0)
            cost_keep = dwell_s * inflation
        else:
            inflation, cost_keep = 0.0, 0.0
        cost_replan = delay if np.isfinite(ms_new) else INF
        if cost_replan < cost_keep:
            chosen, res, x_w = "replan", new_res, x_new
        else:
            chosen, res, x_w = "keep", keep_res, x0
        nct = res.comm_time / ideal.comm_time \
            if ideal.comm_time > 0 else INF
        tenant.plan = CachedPlan(
            x=np.asarray(x_w, dtype=np.int64).copy(),
            makespan=res.makespan, comm_time=res.comm_time, nct=nct,
            ideal_comm_time=ideal.comm_time,
            details={"steered": True, "option": chosen, "cache_hit": hit})
        tenant.base_plan = tenant.plan.copy()
        self.ledger.commit(tenant.name,
                           tenant.fleet_usage(self.fleet.num_pods))
        _STEERS.inc(option=chosen)
        return {"tenant": tenant.name, "option": chosen,
                "dwell_s": float(dwell_s), "ms_keep": ms_keep,
                "ms_replan": ms_new, "inflation": float(inflation),
                "delay_s": float(delay),
                "cost_keep_s": float(cost_keep),
                "cost_replan_s": float(cost_replan),
                "changed_circuits": int(circuit_changes(x_w, x0)),
                "cache_hit": bool(hit), "masked": mask is not None}

    def replan_reduced(self, tenant: Tenant) -> dict:
        """Rebuild the tenant's local view under its CURRENT ledger limits
        (after a port seizure or restoration) and replan through the cache.

        If the reduced budget makes the GA space infeasible (placement
        degree above the port budget), fall back to deterministically
        shrinking the incumbent topology to fit -- priced honestly with the
        exact DES, possibly at an infinite makespan if shrinking
        partitioned the job."""
        tenant.dag = self.build_dag(tenant.name, tenant.job, tenant.pods,
                                    tenant.reverse_stages)
        tenant._des = None
        tenant._xbar = None
        limits = gather(self.ledger.limits(tenant.name), tenant.pods)
        x_old = None if tenant.plan is None \
            else np.asarray(tenant.plan.x, dtype=np.int64)
        try:
            with span("fleet.replan_reduced", tenant=tenant.name):
                plan = self.plan(tenant)
            return {"tenant": tenant.name, "path": "replan",
                    "ports": int(plan.x.sum()), "makespan": plan.makespan,
                    "limits": limits.tolist()}
        except (ValueError, LedgerError) as exc:
            if x_old is None:
                raise
            x = shrink_to_limits(x_old, limits)
            problem = DESProblem(tenant.dag)
            P = len(tenant.pods)
            ideal = simulate(problem, np.zeros((P, P)), ideal=True)
            res = simulate(problem, x)
            nct = res.comm_time / ideal.comm_time \
                if ideal.comm_time > 0 else INF
            tenant.plan = CachedPlan(
                x=x, makespan=res.makespan, comm_time=res.comm_time,
                nct=nct, ideal_comm_time=ideal.comm_time,
                details={"shrunk": True, "error": type(exc).__name__})
            tenant.base_plan = tenant.plan.copy()
            self.ledger.commit(tenant.name,
                               tenant.fleet_usage(self.fleet.num_pods))
            _PLANS.inc(path="shrink", cache="miss")
            _log.warning(
                "reduced replan for tenant %r fell back to topology "
                "shrinking (limits %s): %s", tenant.name, limits.tolist(),
                exc)
            return {"tenant": tenant.name, "path": "shrink",
                    "ports": int(x.sum()), "makespan": res.makespan,
                    "limits": limits.tolist()}

    # ------------------------------------------------------------ departure
    def depart(self, tenant: Tenant) -> None:
        with contextlib.suppress(LedgerError):   # already released
            self.ledger.release(tenant.name)


def shrink_to_limits(x: np.ndarray, limits: np.ndarray) -> np.ndarray:
    """Deterministically drop circuits until per-pod usage fits `limits`:
    repeatedly remove one circuit from the most-oversubscribed pod's
    largest pair.  Always terminates with `x.sum(axis=1) <= limits`."""
    x = np.asarray(x, dtype=np.int64).copy()
    limits = np.asarray(limits, dtype=np.int64)
    while True:
        over = x.sum(axis=1) - limits
        p = int(np.argmax(over))
        if over[p] <= 0:
            break
        q = int(np.argmax(x[p]))
        if x[p, q] <= 0:   # pragma: no cover - over>0 implies a circuit
            break
        x[p, q] -= 1
        x[q, p] -= 1
    return x

