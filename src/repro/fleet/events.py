"""Versioned fleet event schema: every event the planner or the control
plane consumes, with ONE serialize/rebuild path.

Before this module, `repro.obs.journal` hand-maintained a per-kind
serializer for every event class living in `repro.fleet.loop` -- adding an
event meant editing two files and keeping their shapes in sync by hand.
Now the schema lives here: frozen dataclasses registered under a stable
``kind`` string, serialized generically from their fields (tuples <->
lists, JobSpec <-> its field dict, numpy scalars unboxed) and rebuilt by
the field annotations.  `obs.journal` just delegates.

Schema versioning: `serialize_event` stamps ``"v": EVENTS_VERSION`` on
every entry.  Version history:

  1  PR-7 journal shapes (arrival/departure/traffic_change + fault events)
  2  adds the control-plane telemetry events (`TelemetrySample`,
     `PhaseTransition`) and the ``steered`` flag on `TrafficChange`
  3  adds the staggered-reconfiguration plane events (`PlaneRewireStep`,
     `PlaneTransitionSummary`) -- decision *outputs* journaled under the
     ``plane_event`` record kind, not replayable inputs, so
     `ControlPlane.replay` skips them and regenerates identical steps by
     re-driving the deterministic scheduler

Rebuild is backward compatible: missing fields take their dataclass
defaults, so v1/v2 journals replay unchanged.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.core.traffic import JobSpec

__all__ = [
    "EVENTS_VERSION", "EVENT_KINDS", "FAULT_EVENTS", "PLANE_EVENTS",
    "TELEMETRY_EVENTS", "FleetEvent", "JobArrival", "JobDeparture",
    "TrafficChange", "LinkFailure", "LinkRecovery", "PortFailure",
    "PortRecovery", "PlaneFailure", "PlaneRecovery", "PlaneRewireStep",
    "PlaneTransitionSummary", "TelemetrySample", "PhaseTransition",
    "serialize_event", "rebuild_event", "event_kind",
]

EVENTS_VERSION = 3


# ------------------------------------------------------------ fleet events
@dataclass(frozen=True)
class JobArrival:
    name: str
    job: JobSpec
    reverse_stages: bool = False
    port_min: bool = False
    donate_surplus: bool | None = None   # default: == port_min
    base_pod: int | None = None


@dataclass(frozen=True)
class JobDeparture:
    name: str


@dataclass(frozen=True)
class TrafficChange:
    """Replace a tenant's JobSpec in place (same placement footprint).

    ``steered=True`` marks a change issued by the control plane: the
    planner prices keep-vs-replan against the tenant's estimated dwell
    (FastReChain break-even) instead of replanning unconditionally, and
    journal replay skips the entry (the replaying controller re-issues it
    from the telemetry stream)."""
    name: str
    job: JobSpec
    steered: bool = False


@dataclass(frozen=True)
class LinkFailure:
    """A pod pair loses `fraction` of its circuit capacity (OCS plane
    segment or fiber bundle serving that pair)."""
    pair: tuple[int, int]
    fraction: float = 1.0


@dataclass(frozen=True)
class LinkRecovery:
    pair: tuple[int, int]


@dataclass(frozen=True)
class PortFailure:
    """`count` physical OCS ports on `pod` go dark (ledger-visible)."""
    pod: int
    count: int = 1


@dataclass(frozen=True)
class PortRecovery:
    pod: int
    count: int = 1


@dataclass(frozen=True)
class PlaneFailure:
    """A whole OCS plane goes dark: a uniform 1/num_planes capacity
    haircut on every pod pair (also what staggered reconfiguration of a
    parallel-plane fabric looks like)."""
    plane: int


@dataclass(frozen=True)
class PlaneRecovery:
    plane: int


# ------------------------------------------------ staggered-rewire events
@dataclass(frozen=True)
class PlaneRewireStep:
    """One single-plane rewire inside a staggered A->B transition.

    The plane is dark for `delay_s` while its circuits move; the recorded
    `peak_inflation` is the CERTIFIED (numpy-oracle) worst per-tenant
    makespan inflation of the intermediate fabric state, the exact number
    the SLO was checked against.  `direction` is ``forward`` for the
    planned order and ``rollback`` when the scheduler is un-rewiring an
    already-done plane to return to plan A."""
    transition: str                     # transition id (journal-scoped)
    plane: int
    seq: int                            # step index within the transition
    direction: str = "forward"
    peak_inflation: float = 1.0
    delay_s: float = 0.0
    changed_circuits: int = 0
    tenants: tuple[str, ...] = ()


@dataclass(frozen=True)
class PlaneTransitionSummary:
    """Terminal record of one staggered transition: either every plane
    was rewired to plan B (`outcome='committed'`) or the scheduler rolled
    back to plan A (`outcome='rolled_back'`); the fleet is never left
    between plans."""
    transition: str
    outcome: str
    steps: int = 0
    peak_inflation: float = 1.0
    total_delay_s: float = 0.0
    tenants: tuple[str, ...] = ()
    planes: tuple[int, ...] = ()


# -------------------------------------------------------- telemetry events
@dataclass(frozen=True)
class TelemetrySample:
    """One measurement window from a tenant's fabric: the observed per-pod-
    pair rate matrix (bytes/s, local pod ids) over [t, t + dt), plus the
    per-pair queue depth (bytes still to move) at the window start."""
    t: float
    tenant: str
    dt: float
    rates: tuple[tuple[float, ...], ...]
    queues: tuple[tuple[float, ...], ...] = ()
    phase: str | None = None


@dataclass(frozen=True)
class PhaseTransition:
    """A workload self-reports entering a named phase at time `t` (the
    marker the dwell estimator closes its previous phase against)."""
    t: float
    tenant: str
    phase: str


FleetEvent = (JobArrival | JobDeparture | TrafficChange | LinkFailure
              | LinkRecovery | PortFailure | PortRecovery | PlaneFailure
              | PlaneRecovery)

FAULT_EVENTS = (LinkFailure, LinkRecovery, PortFailure, PortRecovery,
                PlaneFailure, PlaneRecovery)

PLANE_EVENTS = (PlaneRewireStep, PlaneTransitionSummary)

TELEMETRY_EVENTS = (TelemetrySample, PhaseTransition)

EVENT_KINDS: dict[str, type] = {
    "arrival": JobArrival,
    "departure": JobDeparture,
    "traffic_change": TrafficChange,
    "link_failure": LinkFailure,
    "link_recovery": LinkRecovery,
    "port_failure": PortFailure,
    "port_recovery": PortRecovery,
    "plane_failure": PlaneFailure,
    "plane_recovery": PlaneRecovery,
    "plane_rewire": PlaneRewireStep,
    "plane_transition": PlaneTransitionSummary,
    "telemetry": TelemetrySample,
    "phase_transition": PhaseTransition,
}

_KIND_OF = {cls: kind for kind, cls in EVENT_KINDS.items()}


def event_kind(event) -> str:
    """The stable journal ``kind`` string for a live event."""
    try:
        return _KIND_OF[type(event)]
    except KeyError:
        raise TypeError(f"unknown fleet event {event!r}") from None


# ------------------------------------------------------------ single serde
def _encode(value):
    if isinstance(value, JobSpec):
        return dataclasses.asdict(value)
    if isinstance(value, (tuple, list)):
        return [_encode(v) for v in value]
    # numpy scalars sneak in via event constructors fed from arrays
    if hasattr(value, "item") and not isinstance(value, (str, bytes)):
        return value.item()
    return value


def serialize_event(event) -> dict:
    """FleetEvent / telemetry event -> JSON-safe dict (kind + fields)."""
    kind = event_kind(event)
    out: dict = {"kind": kind, "v": EVENTS_VERSION}
    for f in dataclasses.fields(event):
        out[f.name] = _encode(getattr(event, f.name))
    return out


def _jobspec_from_dict(data: dict) -> JobSpec:
    kw = dict(data)
    for f in dataclasses.fields(JobSpec):
        # JSON round-trips tuples as lists; restore tuple-typed fields
        if f.name in kw and isinstance(kw[f.name], list):
            kw[f.name] = tuple(kw[f.name])
    return JobSpec(**kw)


def _deep_tuple(value):
    if isinstance(value, (list, tuple)):
        return tuple(_deep_tuple(v) for v in value)
    return value


def _decode(annotation: str, value):
    """Coerce a JSON value back to its dataclass field type.  Annotations
    are strings (PEP 563 is active in this module); optional fields keep
    None as-is."""
    if value is None:
        return None
    ann = annotation.replace(" ", "")
    if ann == "JobSpec":
        return _jobspec_from_dict(value)
    if ann.startswith("tuple"):
        return _deep_tuple(value)
    if ann.startswith("bool"):
        return bool(value)
    if ann.startswith("int"):
        return int(value)
    if ann.startswith("float"):
        return float(value)
    if ann.startswith("str"):
        return str(value)
    return value


def rebuild_event(data: dict):
    """Inverse of `serialize_event`.  Fields absent from the entry (older
    schema versions) take their dataclass defaults."""
    kind = data.get("kind")
    cls = EVENT_KINDS.get(kind)
    if cls is None:
        raise ValueError(f"unknown journal event kind {kind!r}")
    kw = {}
    for f in dataclasses.fields(cls):
        if f.name in data:
            kw[f.name] = _decode(str(f.type), data[f.name])
    return cls(**kw)
