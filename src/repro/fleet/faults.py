"""Fabric failure model for the fleet planner (DELTA-Failsafe).

Two pieces live here:

`FabricHealth` is the planner's book of record for what is broken *right
now*: per-pod-pair link degradation fractions and dark OCS planes.  Its
`mask()` is the (P, P) capacity-availability factor threaded through the
degraded-mode DES (`JaxDES.makespan(..., mask=...)`): 1.0 means a healthy
pair, 0.25 means three of four planes serving that pair are dark, 0.0 a
fabric partition.  A dark plane multiplies *every* pair uniformly — a plane
carries 1/num_planes of each logical circuit, so losing it is a uniform
capacity haircut, which is also exactly what a staggered plane
reconfiguration looks like (ROADMAP "parallel OCS planes").

`FaultInjector` turns a seed into a reproducible *fault trace*: a list of
plain dicts (`{"step": ..., "kind": ..., ...}`) that both the fleet layer
(via `to_fleet_events`) and the training-loop failure model in
`repro.distributed.fault_tolerance` (via `FailureInjector.from_trace`)
consume, so chaos tests and the step-level injector share one seeded
failure model instead of two disconnected ones.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

TRACE_KINDS = ("link_failure", "link_recovery", "port_failure",
               "port_recovery", "plane_failure", "plane_recovery",
               "step_failure")


@dataclass
class FabricHealth:
    """Current fabric damage: per-pair link fractions and dark planes."""

    num_pods: int
    num_planes: int = 4
    dark_planes: set[int] = field(default_factory=set)
    link_frac: np.ndarray = field(default=None)  # type: ignore[assignment]

    def __post_init__(self) -> None:
        if self.link_frac is None:
            self.link_frac = np.ones((self.num_pods, self.num_pods))
        else:
            self.link_frac = np.asarray(self.link_frac, dtype=np.float64)

    # ------------------------------------------------------------- events
    def fail_link(self, pair: tuple[int, int], fraction: float = 1.0) -> None:
        """Degrade a pod pair: `fraction` of its circuit capacity is lost
        (cumulative — two 0.5 failures kill the pair)."""
        i, j = int(pair[0]), int(pair[1])
        frac = max(0.0, float(self.link_frac[i, j]) - float(fraction))
        self.link_frac[i, j] = self.link_frac[j, i] = frac

    def recover_link(self, pair: tuple[int, int]) -> None:
        i, j = int(pair[0]), int(pair[1])
        self.link_frac[i, j] = self.link_frac[j, i] = 1.0

    def fail_plane(self, plane: int) -> None:
        self.dark_planes.add(int(plane))

    def recover_plane(self, plane: int) -> None:
        self.dark_planes.discard(int(plane))

    # ------------------------------------------------------------ queries
    @property
    def plane_factor(self) -> float:
        up = self.num_planes - len(self.dark_planes)
        return max(up, 0) / self.num_planes

    @property
    def healthy(self) -> bool:
        return not self.dark_planes and bool((self.link_frac >= 1.0).all())

    def mask(self) -> np.ndarray:
        """(P, P) per-pair capacity availability in [0, 1]."""
        return self.link_frac * self.plane_factor

    def local_mask(self, pods: Sequence[int]) -> np.ndarray:
        """Restrict the fleet mask to a tenant's local pod window."""
        idx = np.asarray(list(pods), dtype=np.int64)
        return self.mask()[np.ix_(idx, idx)]

    def degraded_pairs(self) -> list[tuple[int, int]]:
        """Upper-triangle pod pairs with any capacity loss (fleet ids)."""
        m = self.mask()
        out = []
        for i in range(self.num_pods):
            for j in range(i + 1, self.num_pods):
                if m[i, j] < 1.0:
                    out.append((i, j))
        return out

    def availability(self) -> float:
        """Mean per-pair capacity availability in [0, 1] (off-diagonal
        mean of `mask()`): the one-number fabric health summary the
        snapshot round-trip property pins."""
        if self.num_pods < 2:
            return float(self.plane_factor)
        m = self.mask()
        iu, iv = np.triu_indices(self.num_pods, k=1)
        return float(m[iu, iv].mean())

    def affects(self, pods: Iterable[int]) -> bool:
        """Does the current damage touch a tenant spanning `pods`?"""
        if self.dark_planes:
            return True
        idx = np.asarray(list(pods), dtype=np.int64)
        return bool((self.link_frac[np.ix_(idx, idx)] < 1.0).any())

    # ----------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        return {"num_pods": self.num_pods,
                "num_planes": self.num_planes,
                "dark_planes": sorted(self.dark_planes),
                "link_frac": self.link_frac.tolist()}

    @classmethod
    def from_snapshot(cls, snap: dict) -> "FabricHealth":
        return cls(num_pods=snap["num_pods"],
                   num_planes=snap["num_planes"],
                   dark_planes=set(snap["dark_planes"]),
                   link_frac=np.asarray(snap["link_frac"]))


class FaultInjector:
    """Seeded generator of reproducible fault traces.

    A trace is a list of plain dicts, one per fault, each carrying
    `step` (monotone event index), `kind` (one of TRACE_KINDS) and the
    kind's parameters.  Transient *flaps* are emitted as a failure
    immediately followed by its recovery at the next step.
    """

    def __init__(self, num_pods: int, num_planes: int = 4, *, seed: int = 0,
                 link_rate: float = 0.5, port_rate: float = 0.25,
                 plane_rate: float = 0.15, flap_rate: float = 0.3,
                 max_fraction: float = 1.0, max_ports: int = 4):
        self.num_pods = int(num_pods)
        self.num_planes = int(num_planes)
        self.rng = np.random.default_rng(seed)
        self.rates = {"link": link_rate, "port": port_rate,
                      "plane": plane_rate}
        self.flap_rate = float(flap_rate)
        self.max_fraction = float(max_fraction)
        self.max_ports = int(max_ports)
        # planes currently dark *within the generated trace*: a second
        # plane_failure for an already-dark plane would make its matching
        # plane_recovery ambiguous, so draws exclude them
        self._dark: set[int] = set()

    def _one(self, step: int) -> list[dict]:
        kinds = list(self.rates)
        probs = np.asarray([self.rates[k] for k in kinds], dtype=np.float64)
        probs /= probs.sum()
        kind = kinds[int(self.rng.choice(len(kinds), p=probs))]
        flap = bool(self.rng.random() < self.flap_rate)
        if kind == "plane" and len(self._dark) >= self.num_planes:
            kind = "link"   # every plane is already dark; keep the trace
        if kind == "link":
            i = int(self.rng.integers(self.num_pods))
            j = int(self.rng.integers(self.num_pods - 1))
            j = j if j < i else j + 1
            frac = float(self.rng.uniform(0.25, self.max_fraction))
            ev = {"step": step, "kind": "link_failure",
                  "pair": (min(i, j), max(i, j)), "fraction": round(frac, 3)}
            rec = {"kind": "link_recovery", "pair": ev["pair"]}
        elif kind == "port":
            pod = int(self.rng.integers(self.num_pods))
            count = int(self.rng.integers(1, self.max_ports + 1))
            ev = {"step": step, "kind": "port_failure",
                  "pod": pod, "count": count}
            rec = {"kind": "port_recovery", "pod": pod, "count": count}
        else:
            # collision-free draw: uniform over the planes still lit
            healthy = sorted(set(range(self.num_planes)) - self._dark)
            plane = int(healthy[int(self.rng.integers(len(healthy)))])
            ev = {"step": step, "kind": "plane_failure", "plane": plane}
            rec = {"kind": "plane_recovery", "plane": plane}
            self._dark.add(plane)
            if flap:
                self._dark.discard(plane)   # its recovery is in the trace
        if flap:
            return [ev, {"step": step + 1, **rec}]
        return [ev]

    def trace(self, length: int) -> list[dict]:
        """Generate `length` fault events (flap recoveries included)."""
        out: list[dict] = []
        self._dark = set()   # each trace() restarts from a lit fabric
        step = 0
        while len(out) < length:
            events = self._one(step)
            out.extend(events)
            step = out[-1]["step"] + 1
        return out[:length]


def step_failure_trace(fail_at: Iterable[int]) -> list[dict]:
    """Wrap training-step failure indices in the shared trace format."""
    return [{"step": int(s), "kind": "step_failure"} for s in sorted(
        set(int(s) for s in fail_at))]
