"""Double-entry OCS port ledger for multi-tenant pods (paper Sec. VI).

Every fleet pod owns a fixed number of physical OCS ports.  A tenant admitted
onto a pod span holds, per pod:

  entitled   fair-share ports (== its GPUs in the pod, paper Sec. V-A1)
  donated    entitled ports the tenant has returned to the shared pool
             (port-minimized plans free these, Fig. 9/10)
  granted    surplus ports received from the pool on top of its entitlement
  allocated  ports wired into the tenant's currently committed topology

`limits = entitled - donated + granted` is the port budget the planner may
use (the `ClusterSpec.port_limits` of the tenant's local view), and

      sum_t limits_t  +  pool  ==  capacity          (per pod, exactly)

is the conservation equation `check()` enforces: ports never appear or
vanish, they only move between tenants and the pool.  Per tenant,
`allocated + surplus == limits` with `surplus >= 0`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np


class LedgerError(RuntimeError):
    """An operation would violate port conservation."""


@dataclass
class TenantAccount:
    """Per-tenant port books, all arrays indexed by *fleet* pod id."""

    name: str
    entitled: np.ndarray
    donated: np.ndarray = field(default=None)  # type: ignore[assignment]
    granted: np.ndarray = field(default=None)  # type: ignore[assignment]
    allocated: np.ndarray = field(default=None)  # type: ignore[assignment]

    def __post_init__(self) -> None:
        self.entitled = np.asarray(self.entitled, dtype=np.int64)
        zeros = np.zeros_like(self.entitled)
        for f in ("donated", "granted", "allocated"):
            if getattr(self, f) is None:
                setattr(self, f, zeros.copy())

    @property
    def limits(self) -> np.ndarray:
        return self.entitled - self.donated + self.granted

    @property
    def surplus(self) -> np.ndarray:
        return self.limits - self.allocated


class PortLedger:
    """Tracks per-pod port capacity, per-tenant allocations and surplus."""

    def __init__(self, capacity: Sequence[int]):
        self.capacity = np.asarray(capacity, dtype=np.int64)
        if (self.capacity < 0).any():
            raise LedgerError("negative pod capacity")
        self.num_pods = len(self.capacity)
        self.accounts: dict[str, TenantAccount] = {}

    # ------------------------------------------------------------- queries
    def __contains__(self, name: str) -> bool:
        return name in self.accounts

    def account(self, name: str) -> TenantAccount:
        try:
            return self.accounts[name]
        except KeyError:
            raise LedgerError(f"unknown tenant {name!r}") from None

    def limits(self, name: str) -> np.ndarray:
        return self.account(name).limits

    def surplus(self, name: str) -> np.ndarray:
        return self.account(name).surplus

    def pool(self) -> np.ndarray:
        """Per-pod ports owned by no tenant (grantable)."""
        total = sum((a.limits for a in self.accounts.values()),
                    np.zeros_like(self.capacity))
        return self.capacity - total

    def headroom(self) -> np.ndarray:
        """Per-pod ports free for *new entitlements*: donated ports stay
        reserved for their donor (withdrawable), so admission only sees
        capacity minus everything entitled or granted."""
        total = sum((a.entitled + a.granted for a in self.accounts.values()),
                    np.zeros_like(self.capacity))
        return self.capacity - total

    # ---------------------------------------------------------- lifecycle
    def admit(self, name: str, entitled: Sequence[int]) -> TenantAccount:
        if name in self.accounts:
            raise LedgerError(f"tenant {name!r} already admitted")
        ent = np.asarray(entitled, dtype=np.int64)
        if ent.shape != self.capacity.shape or (ent < 0).any():
            raise LedgerError(f"bad entitlement shape/sign for {name!r}")
        if (ent > self.pool()).any():
            raise LedgerError(
                f"admitting {name!r} needs {ent.tolist()} ports but the "
                f"pool has {self.pool().tolist()}")
        acct = TenantAccount(name=name, entitled=ent)
        self.accounts[name] = acct
        return acct

    def release(self, name: str) -> TenantAccount:
        """Remove a tenant; its limits return to the pool implicitly."""
        return self.accounts.pop(self.account(name).name)

    # ------------------------------------------------------------ postings
    def commit(self, name: str, allocated: Sequence[int]) -> None:
        """Record the ports wired by the tenant's committed topology."""
        acct = self.account(name)
        alloc = np.asarray(allocated, dtype=np.int64)
        if alloc.shape != self.capacity.shape or (alloc < 0).any():
            raise LedgerError(f"bad allocation shape/sign for {name!r}")
        if (alloc > acct.limits).any():
            raise LedgerError(
                f"{name!r} would wire {alloc.tolist()} ports with limits "
                f"{acct.limits.tolist()}")
        acct.allocated = alloc

    def donate(self, name: str, amount: Sequence[int] | None = None
               ) -> np.ndarray:
        """Move (part of) a tenant's surplus entitlement into the pool."""
        acct = self.account(name)
        amt = acct.surplus.copy() if amount is None \
            else np.asarray(amount, dtype=np.int64)
        # donations come from the entitlement, never from received grants
        amt = np.minimum(amt, acct.entitled - acct.donated - np.maximum(
            acct.allocated - acct.granted, 0))
        amt = np.maximum(amt, 0)
        if (amt > acct.surplus).any():
            raise LedgerError(f"{name!r} cannot donate more than surplus")
        acct.donated += amt
        return amt

    def withdraw_donation(self, name: str,
                          amount: Sequence[int] | None = None) -> np.ndarray:
        """Take donated ports back (traffic grew); limited by the pool."""
        acct = self.account(name)
        want = acct.donated.copy() if amount is None \
            else np.asarray(amount, dtype=np.int64)
        amt = np.minimum(np.minimum(want, acct.donated),
                         np.maximum(self.pool(), 0))
        acct.donated -= amt
        return amt

    def grant(self, name: str, amount: Sequence[int]) -> None:
        """Grant pool ports to a (bottlenecked) tenant."""
        acct = self.account(name)
        amt = np.asarray(amount, dtype=np.int64)
        if (amt < 0).any():
            raise LedgerError("negative grant")
        if (amt > self.pool()).any():
            raise LedgerError(
                f"granting {amt.tolist()} to {name!r} exceeds pool "
                f"{self.pool().tolist()}")
        acct.granted += amt

    def reclaim(self, name: str, amount: Sequence[int] | None = None
                ) -> np.ndarray:
        """Return (part of) a tenant's grants to the pool."""
        acct = self.account(name)
        amt = acct.granted.copy() if amount is None \
            else np.minimum(np.asarray(amount, dtype=np.int64), acct.granted)
        if (amt < 0).any():
            raise LedgerError("negative reclaim")
        if (acct.allocated > acct.limits - amt).any():
            raise LedgerError(
                f"reclaiming {amt.tolist()} from {name!r} would strand its "
                f"committed allocation; commit a smaller plan first")
        acct.granted -= amt
        return amt

    # ---------------------------------------------------------- invariants
    def check(self) -> None:
        """Raise LedgerError unless port conservation holds exactly."""
        total = np.zeros_like(self.capacity)
        for acct in self.accounts.values():
            for f in ("entitled", "donated", "granted", "allocated"):
                if (getattr(acct, f) < 0).any():
                    raise LedgerError(f"{acct.name!r}.{f} went negative")
            if (acct.donated > acct.entitled).any():
                raise LedgerError(f"{acct.name!r} donated beyond entitlement")
            if (acct.allocated > acct.limits).any():
                raise LedgerError(f"{acct.name!r} allocated beyond limits")
            if (acct.allocated + acct.surplus != acct.limits).any():
                raise LedgerError(f"{acct.name!r} books don't balance")
            total += acct.limits
        pool = self.capacity - total
        if (pool < 0).any():
            raise LedgerError(
                f"pool went negative: {pool.tolist()} (capacity "
                f"{self.capacity.tolist()})")
        if (total + pool != self.capacity).any():  # pragma: no cover
            raise LedgerError("conservation equation violated")

    def snapshot(self) -> dict:
        """JSON-friendly state dump (benchmarks / debugging)."""
        return {
            "capacity": self.capacity.tolist(),
            "pool": self.pool().tolist(),
            "tenants": {
                n: {"entitled": a.entitled.tolist(),
                    "donated": a.donated.tolist(),
                    "granted": a.granted.tolist(),
                    "allocated": a.allocated.tolist(),
                    "surplus": a.surplus.tolist()}
                for n, a in self.accounts.items()},
        }


def scatter(local: Sequence[int], pods: Iterable[int],
            num_pods: int) -> np.ndarray:
    """Expand a tenant-local per-pod vector onto fleet pod ids."""
    out = np.zeros(num_pods, dtype=np.int64)
    for value, pod in zip(local, pods):
        out[pod] = int(value)
    return out


def gather(fleet_vec: np.ndarray, pods: Iterable[int]) -> np.ndarray:
    """Restrict a fleet per-pod vector to a tenant's local pod order."""
    return np.asarray([int(fleet_vec[p]) for p in pods], dtype=np.int64)
