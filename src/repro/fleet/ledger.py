"""Double-entry OCS port ledger for multi-tenant pods (paper Sec. VI).

Every fleet pod owns a fixed number of physical OCS ports.  A tenant admitted
onto a pod span holds, per pod:

  entitled   fair-share ports (== its GPUs in the pod, paper Sec. V-A1)
  donated    entitled ports the tenant has returned to the shared pool
             (port-minimized plans free these, Fig. 9/10)
  granted    surplus ports received from the pool on top of its entitlement
  allocated  ports wired into the tenant's currently committed topology
  seized     entitled ports taken out of service by a hardware failure

`limits = entitled - seized - donated + granted` is the port budget the
planner may use (the `ClusterSpec.port_limits` of the tenant's local view).
With `failed` the per-pod count of dark physical ports,

      sum_t limits_t  +  pool  +  failed  ==  capacity    (per pod, exactly)

is the conservation equation `check()` enforces: ports never appear or
vanish, they only move between tenants, the pool and the failed set.  Per
tenant, `allocated + surplus == limits` with `surplus >= 0`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np


class LedgerError(RuntimeError):
    """An operation would violate port conservation."""


# default-argument sentinel for TenantAccount's book arrays: keeps the
# fields typed as real ndarrays while __post_init__ substitutes zeros
# shaped like `entitled`
_UNSET_BOOK: np.ndarray = np.empty(0, dtype=np.int64)


@dataclass
class TenantAccount:
    """Per-tenant port books, all arrays indexed by *fleet* pod id."""

    name: str
    entitled: np.ndarray
    donated: np.ndarray = field(default_factory=lambda: _UNSET_BOOK)
    granted: np.ndarray = field(default_factory=lambda: _UNSET_BOOK)
    allocated: np.ndarray = field(default_factory=lambda: _UNSET_BOOK)
    seized: np.ndarray = field(default_factory=lambda: _UNSET_BOOK)

    def __post_init__(self) -> None:
        self.entitled = np.asarray(self.entitled, dtype=np.int64)
        zeros = np.zeros_like(self.entitled)
        for f in ("donated", "granted", "allocated", "seized"):
            if getattr(self, f) is None or getattr(self, f) is _UNSET_BOOK:
                setattr(self, f, zeros.copy())

    @property
    def limits(self) -> np.ndarray:
        return self.entitled - self.seized - self.donated + self.granted

    @property
    def surplus(self) -> np.ndarray:
        return self.limits - self.allocated


class PortLedger:
    """Tracks per-pod port capacity, per-tenant allocations and surplus."""

    def __init__(self, capacity: Sequence[int]):
        self.capacity = np.asarray(capacity, dtype=np.int64)
        if (self.capacity < 0).any():
            raise LedgerError("negative pod capacity")
        self.num_pods = len(self.capacity)
        self.accounts: dict[str, TenantAccount] = {}
        # physical ports taken out of service by hardware failures
        self.failed = np.zeros_like(self.capacity)

    # ------------------------------------------------------------- queries
    def __contains__(self, name: str) -> bool:
        return name in self.accounts

    def account(self, name: str) -> TenantAccount:
        try:
            return self.accounts[name]
        except KeyError:
            raise LedgerError(f"unknown tenant {name!r}") from None

    def limits(self, name: str) -> np.ndarray:
        return self.account(name).limits

    def surplus(self, name: str) -> np.ndarray:
        return self.account(name).surplus

    def pool(self) -> np.ndarray:
        """Per-pod ports owned by no tenant (grantable)."""
        total = sum((a.limits for a in self.accounts.values()),
                    np.zeros_like(self.capacity))
        return self.capacity - self.failed - total

    def headroom(self) -> np.ndarray:
        """Per-pod ports free for *new entitlements*: donated ports stay
        reserved for their donor (withdrawable), so admission only sees
        capacity minus failed ports and everything entitled or granted."""
        total = sum((a.entitled - a.seized + a.granted
                     for a in self.accounts.values()),
                    np.zeros_like(self.capacity))
        return self.capacity - self.failed - total

    # ---------------------------------------------------------- lifecycle
    def admit(self, name: str, entitled: Sequence[int]) -> TenantAccount:
        if name in self.accounts:
            raise LedgerError(f"tenant {name!r} already admitted")
        ent = np.asarray(entitled, dtype=np.int64)
        if ent.shape != self.capacity.shape or (ent < 0).any():
            raise LedgerError(f"bad entitlement shape/sign for {name!r}")
        if (ent > self.pool()).any():
            raise LedgerError(
                f"admitting {name!r} needs {ent.tolist()} ports but the "
                f"pool has {self.pool().tolist()}")
        acct = TenantAccount(name=name, entitled=ent)
        self.accounts[name] = acct
        return acct

    def release(self, name: str) -> TenantAccount:
        """Remove a tenant; its limits return to the pool implicitly."""
        return self.accounts.pop(self.account(name).name)

    # ------------------------------------------------------------ postings
    def commit(self, name: str, allocated: Sequence[int]) -> None:
        """Record the ports wired by the tenant's committed topology."""
        acct = self.account(name)
        alloc = np.asarray(allocated, dtype=np.int64)
        if alloc.shape != self.capacity.shape or (alloc < 0).any():
            raise LedgerError(f"bad allocation shape/sign for {name!r}")
        if (alloc > acct.limits).any():
            raise LedgerError(
                f"{name!r} would wire {alloc.tolist()} ports with limits "
                f"{acct.limits.tolist()}")
        acct.allocated = alloc

    def donate(self, name: str, amount: Sequence[int] | None = None
               ) -> np.ndarray:
        """Move (part of) a tenant's surplus entitlement into the pool."""
        acct = self.account(name)
        amt = acct.surplus.copy() if amount is None \
            else np.asarray(amount, dtype=np.int64)
        # donations come from the (surviving) entitlement, never from grants
        amt = np.minimum(amt, acct.entitled - acct.seized - acct.donated
                         - np.maximum(acct.allocated - acct.granted, 0))
        amt = np.maximum(amt, 0)
        if (amt > acct.surplus).any():
            raise LedgerError(f"{name!r} cannot donate more than surplus")
        acct.donated += amt
        return amt

    def withdraw_donation(self, name: str,
                          amount: Sequence[int] | None = None) -> np.ndarray:
        """Take donated ports back (traffic grew); limited by the pool."""
        acct = self.account(name)
        want = acct.donated.copy() if amount is None \
            else np.asarray(amount, dtype=np.int64)
        amt = np.minimum(np.minimum(want, acct.donated),
                         np.maximum(self.pool(), 0))
        acct.donated -= amt
        return amt

    def grant(self, name: str, amount: Sequence[int]) -> None:
        """Grant pool ports to a (bottlenecked) tenant."""
        acct = self.account(name)
        amt = np.asarray(amount, dtype=np.int64)
        if (amt < 0).any():
            raise LedgerError("negative grant")
        if (amt > self.pool()).any():
            raise LedgerError(
                f"granting {amt.tolist()} to {name!r} exceeds pool "
                f"{self.pool().tolist()}")
        acct.granted += amt

    def reclaim(self, name: str, amount: Sequence[int] | None = None
                ) -> np.ndarray:
        """Return (part of) a tenant's grants to the pool."""
        acct = self.account(name)
        amt = acct.granted.copy() if amount is None \
            else np.minimum(np.asarray(amount, dtype=np.int64), acct.granted)
        if (amt < 0).any():
            raise LedgerError("negative reclaim")
        if (acct.allocated > acct.limits - amt).any():
            raise LedgerError(
                f"reclaiming {amt.tolist()} from {name!r} would strand its "
                f"committed allocation; commit a smaller plan first")
        acct.granted -= amt
        return amt

    # ------------------------------------------------------------ failures
    def fail_ports(self, pod: int, count: int) -> list[str]:
        """Take `count` physical ports on `pod` out of service.

        Ports are consumed in escalation order: the free pool first (which
        includes donated reservations), then surplus grants pulled back from
        tenants, then surplus entitlement (recorded as `seized`), and only
        as a last resort ports wired into committed topologies.  Returns the
        names of *stranded* tenants — those whose committed allocation now
        exceeds their limits.  The caller must re-commit a smaller plan for
        each before the next `check()`.
        """
        pod, count = int(pod), int(count)
        if count < 0:
            raise LedgerError("negative failure count")
        count = min(count, int(self.capacity[pod] - self.failed[pod]))
        remaining = count
        stranded: list[str] = []

        def from_pool() -> int:
            take = min(remaining, max(int(self.pool()[pod]), 0))
            self.failed[pod] += take
            return remaining - take

        remaining = from_pool()
        # pull surplus grants back into the pool, then fail them there
        for name in sorted(self.accounts):
            if remaining <= 0:
                break
            acct = self.accounts[name]
            free = min(int(acct.granted[pod]), int(acct.surplus[pod]),
                       remaining)
            if free > 0:
                amt = np.zeros_like(self.capacity)
                amt[pod] = free
                self.reclaim(name, amt)
                remaining = from_pool()
        # seize surplus entitlement (no stranding yet)
        for name in sorted(self.accounts):
            if remaining <= 0:
                break
            acct = self.accounts[name]
            take = min(int(acct.surplus[pod]),
                       int(acct.entitled[pod] - acct.seized[pod]
                           - acct.donated[pod]), remaining)
            if take > 0:
                acct.seized[pod] += take
                self.failed[pod] += take
                remaining -= take
        # strand: seize entitlement wired into committed topologies
        for name in sorted(self.accounts):
            if remaining <= 0:
                break
            acct = self.accounts[name]
            take = min(int(acct.entitled[pod] - acct.seized[pod]
                           - acct.donated[pod]), remaining)
            if take > 0:
                acct.seized[pod] += take
                self.failed[pod] += take
                remaining -= take
                stranded.append(name)
        # last resort: force-reclaim grants wired into topologies
        for name in sorted(self.accounts):
            if remaining <= 0:
                break
            acct = self.accounts[name]
            take = min(int(acct.granted[pod]), remaining)
            if take > 0:
                acct.granted[pod] -= take
                self.failed[pod] += take
                remaining -= take
                if name not in stranded:
                    stranded.append(name)
        if remaining > 0:  # pragma: no cover - count clamped above
            raise LedgerError(f"could not fail {remaining} ports on pod {pod}")
        return stranded

    def restore_ports(self, pod: int, count: int) -> int:
        """Bring failed ports on `pod` back: seized entitlements are made
        whole first (deterministic tenant order), the rest returns to the
        pool.  Returns the number of ports actually restored."""
        pod, count = int(pod), int(count)
        if count < 0:
            raise LedgerError("negative restore count")
        count = min(count, int(self.failed[pod]))
        remaining = count
        for name in sorted(self.accounts):
            if remaining <= 0:
                break
            acct = self.accounts[name]
            take = min(int(acct.seized[pod]), remaining)
            if take > 0:
                acct.seized[pod] -= take
                self.failed[pod] -= take
                remaining -= take
        self.failed[pod] -= remaining
        return count

    # ---------------------------------------------------------- invariants
    def check(self) -> None:
        """Raise LedgerError unless port conservation holds exactly."""
        if (self.failed < 0).any() or (self.failed > self.capacity).any():
            raise LedgerError(f"failed ports out of range: "
                              f"{self.failed.tolist()}")
        total = np.zeros_like(self.capacity)
        for acct in self.accounts.values():
            for f in ("entitled", "donated", "granted", "allocated",
                      "seized"):
                if (getattr(acct, f) < 0).any():
                    raise LedgerError(f"{acct.name!r}.{f} went negative")
            if (acct.seized > acct.entitled).any():
                raise LedgerError(f"{acct.name!r} seized beyond entitlement")
            if (acct.donated > acct.entitled - acct.seized).any():
                raise LedgerError(f"{acct.name!r} donated beyond entitlement")
            if (acct.allocated > acct.limits).any():
                raise LedgerError(f"{acct.name!r} allocated beyond limits")
            if (acct.allocated + acct.surplus != acct.limits).any():
                raise LedgerError(f"{acct.name!r} books don't balance")
            total += acct.limits
        pool = self.capacity - self.failed - total
        if (pool < 0).any():
            raise LedgerError(
                f"pool went negative: {pool.tolist()} (capacity "
                f"{self.capacity.tolist()}, failed {self.failed.tolist()})")
        if (total + pool + self.failed != self.capacity).any():
            raise LedgerError("conservation equation violated")

    def snapshot(self) -> dict:
        """JSON-friendly state dump (benchmarks / debugging)."""
        return {
            "capacity": self.capacity.tolist(),
            "pool": self.pool().tolist(),
            "failed": self.failed.tolist(),
            "tenants": {
                n: {"entitled": a.entitled.tolist(),
                    "donated": a.donated.tolist(),
                    "granted": a.granted.tolist(),
                    "allocated": a.allocated.tolist(),
                    "seized": a.seized.tolist(),
                    "surplus": a.surplus.tolist()}
                for n, a in self.accounts.items()},
        }

    @classmethod
    def from_snapshot(cls, snap: dict) -> "PortLedger":
        """Rebuild a ledger from a `snapshot()` dict (crash recovery)."""
        ledger = cls(snap["capacity"])
        ledger.failed = np.asarray(snap.get("failed",
                                            [0] * ledger.num_pods),
                                   dtype=np.int64)
        for name, books in snap["tenants"].items():
            ledger.accounts[name] = TenantAccount(
                name=name,
                entitled=books["entitled"],
                donated=np.asarray(books["donated"], dtype=np.int64),
                granted=np.asarray(books["granted"], dtype=np.int64),
                allocated=np.asarray(books["allocated"], dtype=np.int64),
                seized=np.asarray(books.get("seized",
                                            [0] * ledger.num_pods),
                                  dtype=np.int64))
        ledger.check()
        return ledger


def scatter(local: Sequence[int], pods: Iterable[int],
            num_pods: int) -> np.ndarray:
    """Expand a tenant-local per-pod vector onto fleet pod ids."""
    out = np.zeros(num_pods, dtype=np.int64)
    for value, pod in zip(local, pods):
        out[pod] = int(value)
    return out


def gather(fleet_vec: np.ndarray, pods: Iterable[int]) -> np.ndarray:
    """Restrict a fleet per-pod vector to a tenant's local pod order."""
    return np.asarray([int(fleet_vec[p]) for p in pods], dtype=np.int64)
