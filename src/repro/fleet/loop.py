"""Event-driven fleet replanning loop.

The planner is a long-lived service consuming a stream of events:

  JobArrival     admit + place the job, plan its topology (cache-aware),
                 optionally donate the port savings of a port-minimized plan
  JobDeparture   release the tenant; its ports return to the pool
  TrafficChange  swap the tenant's JobSpec (same footprint), replan

After every event the loop runs a surplus pass: the grantable pool is
waterfilled across bandwidth-bottlenecked tenants (NCT above threshold) and
each boosted tenant is re-optimized with one batched `JaxDES` evaluation
(`repro.fleet.realloc`).  The `PortLedger` conservation invariant is
checked after every event.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.cluster import split_port_budgets
from repro.core.des import DESProblem, simulate
from repro.core.ga import GAOptions, ROBUST_OBJECTIVES
from repro.fleet.admission import (AdmissionController, AdmissionError,
                                   FleetSpec, Tenant)
# the event schema lives in repro.fleet.events (single serialize/rebuild
# path); re-exported here so existing `from repro.fleet.loop import ...`
# call sites keep working
from repro.fleet.events import (FAULT_EVENTS, FleetEvent, JobArrival,
                                JobDeparture, LinkFailure, LinkRecovery,
                                PlaneFailure, PlaneRecovery, PortFailure,
                                PortRecovery, TrafficChange, serialize_event)
from repro.fleet.faults import FabricHealth
from repro.fleet.planes import (PlaneBook, StaggeredTransition, TenantLane,
                                split_plan)
from repro.fleet.ledger import LedgerError, PortLedger, gather, scatter
from repro.fleet.plancache import PlanCache
from repro.fleet.realloc import port_demand, reallocate, waterfill_grants
from repro.fleet.telemetry import DEFAULT_DWELL_S
from repro.obs import REGISTRY, FleetJournal, get_counter, get_gauge, span

__all__ = ["FAULT_EVENTS", "FleetEvent", "FleetPlanner", "JobArrival",
           "JobDeparture", "LinkFailure", "LinkRecovery", "PlaneFailure",
           "PlaneRecovery", "PortFailure", "PortRecovery", "TrafficChange",
           "arrivals", "fault_events_from_trace"]

_EVENTS = get_counter("fleet_events_total",
                      "fleet events handled, by kind and outcome")
_GRANTS = get_counter("fleet_granted_ports_total",
                      "surplus ports granted by the waterfill pass")
_TENANTS = get_gauge("fleet_tenants", "currently admitted tenants")
_SNAPSHOTS = get_counter("fleet_snapshots_total",
                         "planner state snapshots written to the journal")


# ------------------------------------------------------------------- events
def fault_events_from_trace(trace: list[dict]) -> list[FleetEvent]:
    """Shared-trace-format dicts (`repro.fleet.faults.FaultInjector`) ->
    live fleet fault events, in trace order (step_failure entries are
    training-loop faults, not fleet events; they are skipped here)."""
    out: list[FleetEvent] = []
    for ev in trace:
        kind = ev["kind"]
        if kind == "link_failure":
            out.append(LinkFailure(pair=tuple(ev["pair"]),
                                   fraction=float(ev.get("fraction", 1.0))))
        elif kind == "link_recovery":
            out.append(LinkRecovery(pair=tuple(ev["pair"])))
        elif kind == "port_failure":
            out.append(PortFailure(pod=int(ev["pod"]),
                                   count=int(ev.get("count", 1))))
        elif kind == "port_recovery":
            out.append(PortRecovery(pod=int(ev["pod"]),
                                    count=int(ev.get("count", 1))))
        elif kind == "plane_failure":
            out.append(PlaneFailure(plane=int(ev["plane"])))
        elif kind == "plane_recovery":
            out.append(PlaneRecovery(plane=int(ev["plane"])))
        elif kind != "step_failure":
            raise ValueError(f"unknown trace kind {kind!r}")
    return out


# ------------------------------------------------------------------ planner
class FleetPlanner:
    """Cluster-wide multi-tenant port manager (paper Sec. VI as a service)."""

    def __init__(self, fleet: FleetSpec,
                 ga_options: GAOptions | None = None,
                 cache: PlanCache | None = None,
                 nct_threshold: float = 1.005,
                 donors_can_receive: bool = False,
                 auto_realloc: bool = True,
                 num_random_candidates: int = 8,
                 robust_replan: bool = False,
                 robust_objective: str = "max-regret",
                 robust_history: int = 3,
                 seed: int = 0,
                 journal: FleetJournal | None = None,
                 num_planes: int = 4,
                 dwell_s: float = DEFAULT_DWELL_S,
                 reconfig_s_per_circuit: float = 0.01,
                 replan_threshold: float = 1.2,
                 snapshot_every: int = 0,
                 plane_slo: float = 3.0,
                 staggered: bool = True):
        self.fleet = fleet
        self.ledger = PortLedger(fleet.capacity())
        self.cache = cache if cache is not None else PlanCache()
        self.admission = AdmissionController(fleet, self.ledger, self.cache,
                                             ga_options)
        self.tenants: dict[str, Tenant] = {}
        self.nct_threshold = nct_threshold
        self.donors_can_receive = donors_can_receive
        self.auto_realloc = auto_realloc
        self.num_random_candidates = num_random_candidates
        # robust phase changes: instead of replanning from scratch, a
        # TrafficChange plans one static topology over {incumbent DAGs +
        # the arriving workload} (DELTA-Robust), bounded to the last
        # `robust_history` distinct incumbent phases.  Validate the
        # objective HERE: plan_robust degrades ValueErrors from the solve
        # to a plain plan (empty union space / infeasible refs), which
        # must never mask a configuration typo
        if robust_objective not in ROBUST_OBJECTIVES:
            raise ValueError(
                f"unknown robust_objective {robust_objective!r}; "
                f"pick from {ROBUST_OBJECTIVES}")
        self.robust_replan = robust_replan
        self.robust_objective = robust_objective
        self.robust_history = robust_history
        self.rng = np.random.default_rng(seed)
        self.realloc_batches = 0        # batched JaxDES calls issued
        self.realloc_candidates = 0     # topologies evaluated inside them
        # fabric failure state + repair-pricing knobs (DELTA-Failsafe).
        # `dwell_s` is the phase-dwell PRIOR (DEFAULT_DWELL_S): every
        # priced decision asks `dwell_for(name)`, which prefers the
        # per-tenant estimate a ControlPlane keeps current from telemetry
        self.health = FabricHealth(fleet.num_pods, num_planes)
        self.dwell_s = float(dwell_s)
        self.dwell_estimates: dict[str, float] = {}
        self.reconfig_s_per_circuit = float(reconfig_s_per_circuit)
        self.replan_threshold = float(replan_threshold)
        self.snapshot_every = int(snapshot_every)
        # DELTA-Planes: per-tenant lane decompositions + staggered rewires.
        # Topology changes on live tenants (traffic replans, fault repairs,
        # surplus boosts) apply through a `StaggeredTransition` -- one plane
        # dark at a time, each step SLO-checked -- instead of an atomic
        # full-fabric swap.  Unsplittable plans fall back to the atomic
        # path (pre-planes behavior), recorded per transition
        self.num_planes = int(num_planes)
        self.plane_slo = float(plane_slo)
        self.staggered = bool(staggered) and self.num_planes >= 2
        self.planes = PlaneBook(self.num_planes)
        self.transitions: list[dict] = []
        self._transition_seq = 0
        self._events_handled = 0
        self._degraded: set[str] = set()   # tenants priced under a mask
        self._shrunk: set[str] = set()     # tenants replanned under seizure
        self.history: list[dict] = []
        # structured decision log (JSONL-backed when given a path)
        self.journal = journal if journal is not None else FleetJournal()
        # planner-scoped metric view: report() reads DELTAS against this
        # snapshot, so two planners in one process never pollute each
        # other's compile-cache hit rate
        self._obs_scope = REGISTRY.scope()

    # ---------------------------------------------------------------- dwell
    def dwell_for(self, name: str) -> float:
        """Expected remaining phase dwell for a tenant: the telemetry
        estimate when a control plane maintains one, else the prior."""
        return float(self.dwell_estimates.get(name, self.dwell_s))

    def set_dwell_estimate(self, name: str, dwell_s: float) -> None:
        self.dwell_estimates[name] = float(dwell_s)

    # -------------------------------------------------------------- events
    def handle(self, event: FleetEvent) -> dict:
        # surplus grants are revocable leases: take them all back (restoring
        # each tenant's cached within-entitlement plan) before mutating the
        # fleet, then let the end-of-event surplus pass redistribute from
        # scratch over the new tenant mix
        kind = {JobArrival: "arrival", JobDeparture: "departure",
                TrafficChange: "traffic_change",
                LinkFailure: "link_failure", LinkRecovery: "link_recovery",
                PortFailure: "port_failure", PortRecovery: "port_recovery",
                PlaneFailure: "plane_failure",
                PlaneRecovery: "plane_recovery"}.get(type(event), "unknown")
        who = getattr(event, "name", "fabric")
        with span("fleet.handle", kind=kind, tenant=who):
            self.revoke_grants()
            try:
                if isinstance(event, JobArrival):
                    record = self._on_arrival(event)
                elif isinstance(event, JobDeparture):
                    record = self._on_departure(event)
                elif isinstance(event, TrafficChange):
                    record = self._on_traffic_change(event)
                elif isinstance(event, (LinkFailure, LinkRecovery,
                                        PlaneFailure, PlaneRecovery)):
                    record = self._on_fabric_change(event, kind)
                elif isinstance(event, (PortFailure, PortRecovery)):
                    record = self._on_port_change(event, kind)
                else:
                    raise TypeError(f"unknown fleet event {event!r}")
            except Exception as exc:
                # the event failed after grants were revoked: re-run the
                # surplus pass so running tenants get their boosts back,
                # then propagate
                _EVENTS.inc(kind=kind, outcome="error")
                self.journal.record("fleet_error", event_kind=kind,
                                    tenant=who,
                                    error=type(exc).__name__)
                if self.auto_realloc:
                    self.replan_surplus()
                raise
            if self.auto_realloc:
                record["realloc"] = self.replan_surplus()
            self.ledger.check()
            self._sync_planes()
            self.history.append(record)
            _EVENTS.inc(kind=kind, outcome="ok")
            _TENANTS.set(len(self.tenants))
            self.journal.record_event(event, record)
            self._events_handled += 1
            if self.snapshot_every > 0 \
                    and self._events_handled % self.snapshot_every == 0:
                self.journal.record("fleet_snapshot", state=self.snapshot())
                _SNAPSHOTS.inc()
            return record

    def process(self, events) -> list[dict]:
        return [self.handle(e) for e in events]

    # ------------------------------------------------------------- arrival
    def _on_arrival(self, ev: JobArrival) -> dict:
        if ev.name in self.tenants:
            raise AdmissionError(f"tenant {ev.name!r} already admitted")
        tenant = self.admission.admit(
            ev.name, ev.job, reverse_stages=ev.reverse_stages,
            port_min=ev.port_min, base_pod=ev.base_pod)
        self.tenants[ev.name] = tenant
        donate = ev.port_min if ev.donate_surplus is None \
            else ev.donate_surplus
        donated = self.ledger.donate(ev.name) if donate \
            else np.zeros(self.fleet.num_pods, dtype=np.int64)
        plan = tenant.plan
        return {"event": "arrival", "tenant": ev.name,
                "pods": list(tenant.pods),
                "cache_hit": bool(plan.details.get("cache_hit")),
                "nct": plan.nct, "ports": int(plan.x.sum()),
                "donated_ports": int(donated.sum())}

    # ----------------------------------------------------------- departure
    def _on_departure(self, ev: JobDeparture) -> dict:
        tenant = self.tenants.pop(ev.name, None)
        if tenant is None:
            raise LedgerError(f"unknown tenant {ev.name!r}")
        self.admission.depart(tenant)
        self.planes.pop(ev.name)
        return {"event": "departure", "tenant": ev.name,
                "pods": list(tenant.pods)}

    # ------------------------------------------------------ traffic change
    def _on_traffic_change(self, ev: TrafficChange) -> dict:
        tenant = self.tenants.get(ev.name)
        if tenant is None:
            raise LedgerError(f"unknown tenant {ev.name!r}")
        old_ent = self.admission.entitlement(tenant.job,
                                             tenant.reverse_stages)
        new_ent = self.admission.entitlement(ev.job, tenant.reverse_stages)
        if not np.array_equal(old_ent, new_ent):
            raise AdmissionError(
                f"traffic change for {ev.name!r} alters the placement "
                f"footprint; depart + re-arrive instead")
        # grants were already revoked in handle(); take donations back too
        self.ledger.withdraw_donation(ev.name)
        nct_before = tenant.plan.nct if tenant.plan else float("inf")
        x_before = None if tenant.plan is None else \
            np.asarray(tenant.plan.x, dtype=np.int64).copy()
        incumbents = (tenant.dag_history + [tenant.dag])[
            -self.robust_history:] if self.robust_history > 0 else []
        new_tenant = Tenant(
            name=ev.name, job=ev.job, pods=tenant.pods,
            reverse_stages=tenant.reverse_stages, port_min=tenant.port_min,
            dag=self.admission.build_dag(ev.name, ev.job, tenant.pods,
                                         tenant.reverse_stages),
            dag_history=incumbents)
        decision = None
        if ev.steered and tenant.plan is not None:
            # control-plane change: price keep-vs-replan with the tenant's
            # estimated remaining dwell (FastReChain break-even) instead
            # of replanning unconditionally
            mask = self.health.local_mask(tenant.pods)
            if float(mask.min(initial=1.0)) >= 1.0 - 1e-12:
                mask = None
            decision = self.admission.change(
                new_tenant, x_incumbent=tenant.plan.x,
                dwell_s=self.dwell_for(ev.name),
                reconfig_s_per_circuit=self.reconfig_s_per_circuit,
                mask=mask)
            if mask is None:
                self._degraded.discard(ev.name)
            else:
                self._degraded.add(ev.name)
        elif self.robust_replan:
            self.admission.plan_robust(new_tenant, incumbents,
                                       objective=self.robust_objective)
        else:
            self.admission.plan(new_tenant)
        self.tenants[ev.name] = new_tenant
        transition = None
        if x_before is not None and new_tenant.plan is not None:
            transition = self._apply_staggered(
                {ev.name: (x_before, new_tenant.plan.x)}, "traffic_change")
            if transition is not None \
                    and transition["status"] == "rolled_back":
                # the new topology could not be reached within the SLO:
                # keep the OLD circuits, priced on the NEW dag
                self._revert_plan(ev.name, x_before)
        donated = self.ledger.donate(ev.name) if tenant.port_min \
            else np.zeros(self.fleet.num_pods, dtype=np.int64)
        details = new_tenant.plan.details
        record = {"event": "traffic_change", "tenant": ev.name,
                  "nct_before": nct_before, "nct": new_tenant.plan.nct,
                  "cache_hit": bool(details.get("cache_hit")),
                  "robust": bool(details.get("robust")),
                  "robust_members": details.get("num_members", 1),
                  "worst_regret": details.get("worst_regret"),
                  "donated_ports": int(donated.sum())}
        if decision is not None:
            record["steered"] = True
            record["decision"] = decision
        if transition is not None:
            record["transition"] = transition
        return record

    # ------------------------------------------------------- fabric faults
    def _on_fabric_change(self, ev, kind: str) -> dict:
        """Link / plane capacity events: mutate FabricHealth, then run the
        priced repair decision for every tenant the damage (old or new)
        touches, plus every tenant still priced under a previous mask."""
        affected = {n for n, t in self.tenants.items()
                    if self.health.affects(t.pods)}
        if isinstance(ev, LinkFailure):
            self.health.fail_link(ev.pair, ev.fraction)
        elif isinstance(ev, LinkRecovery):
            self.health.recover_link(ev.pair)
        elif isinstance(ev, PlaneFailure):
            self.health.fail_plane(ev.plane)
        else:
            self.health.recover_plane(ev.plane)
        affected |= {n for n, t in self.tenants.items()
                     if self.health.affects(t.pods)}
        affected |= self._degraded & set(self.tenants)
        repairs = []
        for name in sorted(affected):
            if self.tenants[name].plan is None:  # pragma: no cover
                continue
            repairs.append(self._repair_tenant(name))
        mask = self.health.mask()
        record = {"event": kind,
                  "mask_min": float(mask.min()) if mask.size else 1.0,
                  "healthy": self.health.healthy, "repairs": repairs}
        if hasattr(ev, "pair"):
            record["pair"] = list(ev.pair)
        else:
            record["plane"] = ev.plane
        return record

    def _repair_tenant(self, name: str) -> dict:
        """One priced repair decision + ledger commit + degraded-set
        bookkeeping for a single tenant under the current fabric mask."""
        tenant = self.tenants[name]
        x_before = None if tenant.plan is None else \
            np.asarray(tenant.plan.x, dtype=np.int64).copy()
        decision = self.admission.repair(
            tenant, self.health.local_mask(tenant.pods), rng=self.rng,
            num_random=self.num_random_candidates,
            dwell_s=self.dwell_for(name),
            reconfig_s_per_circuit=self.reconfig_s_per_circuit,
            replan_threshold=self.replan_threshold)
        self.ledger.commit(name, tenant.fleet_usage(self.fleet.num_pods))
        if decision["option"] == "healthy":
            self._degraded.discard(name)
        else:
            self._degraded.add(name)
        if x_before is not None \
                and not np.array_equal(x_before, tenant.plan.x):
            # a rewire/replan repair moves circuits: stagger it too.  The
            # engine reads the CURRENT dark planes live, so a repair fired
            # by a PlaneFailure prices every step against the already-
            # degraded fabric (doubly-dark intermediate states)
            transition = self._apply_staggered(
                {name: (x_before, tenant.plan.x)}, "repair")
            if transition is not None \
                    and transition["status"] == "rolled_back":
                self._revert_plan(name, x_before)
            if transition is not None:
                decision["transition"] = transition
        return decision

    def _on_port_change(self, ev, kind: str) -> dict:
        """Port failures hit the ledger (escalating pool -> grants ->
        seized entitlement -> stranding); stranded tenants are replanned
        under their reduced limits before the end-of-event check()."""
        record: dict = {"event": kind, "pod": ev.pod, "count": ev.count}
        replans: list[dict] = []
        replanned: list[str] = []
        if isinstance(ev, PortFailure):
            stranded = self.ledger.fail_ports(ev.pod, ev.count)
            for name in sorted(stranded):
                tenant = self.tenants.get(name)
                if tenant is None:   # pragma: no cover - defensive
                    continue
                replans.append(self.admission.replan_reduced(tenant))
                self._shrunk.add(name)
                replanned.append(name)
            record["stranded"] = sorted(stranded)
        else:
            record["restored"] = int(
                self.ledger.restore_ports(ev.pod, ev.count))
            # shrunk tenants whose seizures are fully healed get their
            # original budget (and, via the cache, original plan) back
            for name in sorted(self._shrunk & set(self.tenants)):
                if self.ledger.account(name).seized.sum() == 0:
                    replans.append(
                        self.admission.replan_reduced(self.tenants[name]))
                    self._shrunk.discard(name)
                    replanned.append(name)
        # replan_reduced prices against the healthy fabric; on a damaged
        # fabric the committed plan must carry masked pricing, so run the
        # repair decision on every tenant that was just replanned
        repairs = [self._repair_tenant(name) for name in replanned
                   if self.health.affects(self.tenants[name].pods)]
        if repairs:
            record["repairs"] = repairs
        record["replans"] = replans
        record["failed_ports"] = int(self.ledger.failed.sum())
        return record

    # ------------------------------------------- staggered plane rewires
    def _tenant_budgets(self, name: str, pods) -> np.ndarray:
        """Per-plane port budgets for a tenant's local pod window, derived
        from its CURRENT ledger limits (entitlement + grants - seizures)
        by the deterministic `split_port_budgets` rule -- a pure function
        of the event stream, so journal replay reproduces bit-identical
        lane stacks."""
        limits = gather(self.ledger.limits(name), pods)
        return np.asarray(
            split_port_budgets(tuple(int(u) for u in limits),
                               self.num_planes), dtype=np.int64)

    def _lane_stack(self, name: str, x: np.ndarray) -> np.ndarray | None:
        """The tenant's lane stack for topology `x`: the book entry when
        it already sums to `x`, else a fresh deterministic split (None if
        `x` does not decompose under the per-plane budgets)."""
        book = self.planes.get(name)
        if book is not None and np.array_equal(book.sum(axis=0), x):
            return book
        return split_plan(x, self._tenant_budgets(
            name, self.tenants[name].pods))

    def _apply_staggered(self, movers: dict, reason: str) -> dict | None:
        """Apply ``{name: (x_old, x_new)}`` topology changes as ONE
        staggered transition.  Returns the JSON-safe transition record,
        or None when staggering is off, nothing actually moved, or any
        mover's plan does not decompose (the caller keeps the atomic
        swap it already made -- pre-planes behavior).  A ``rolled_back``
        record means the caller must revert the movers to x_old
        (`_revert_plan`)."""
        if not self.staggered:
            return None
        movers = {n: (np.asarray(a, dtype=np.int64),
                      np.asarray(b, dtype=np.int64))
                  for n, (a, b) in movers.items()
                  if not np.array_equal(a, b)}
        if not movers:
            return None
        lanes: list[TenantLane] = []
        assignments: dict[str, np.ndarray] = {}
        for name in sorted(movers):
            x_old, x_new = movers[name]
            tenant = self.tenants[name]
            planes_a = self._lane_stack(name, x_old)
            budgets = self._tenant_budgets(name, tenant.pods)
            planes_b = split_plan(x_new, budgets)
            if planes_a is None or planes_b is None:
                return None
            lanes.append(TenantLane(name=name, dag=tenant.dag,
                                    pods=tenant.pods, planes_a=planes_a,
                                    planes_b=planes_b))
            assignments[name] = planes_b
        # bystanders suffer every intermediate dark plane too and count
        # toward the SLO; an unsplittable bystander simply is not priced
        for name in sorted(set(self.tenants) - set(movers)):
            tenant = self.tenants[name]
            if tenant.plan is None:
                continue
            planes = self._lane_stack(
                name, np.asarray(tenant.plan.x, dtype=np.int64))
            if planes is None:
                continue
            lanes.append(TenantLane(name=name, dag=tenant.dag,
                                    pods=tenant.pods, planes_a=planes,
                                    planes_b=planes))
        tid = f"t{self._transition_seq}"
        self._transition_seq += 1
        engine = StaggeredTransition(
            lanes, self.health, slo=self.plane_slo,
            reconfig_s_per_circuit=self.reconfig_s_per_circuit,
            transition_id=tid)
        result = engine.run()
        # plane events are decision OUTPUTS: journaled for audit under
        # their own record kind (EVENTS_VERSION 3), skipped by replay --
        # the replaying planner regenerates identical steps by re-driving
        # this deterministic scheduler
        for step in result.steps:
            self.journal.record("plane_event",
                                event=serialize_event(step))
        self.journal.record("plane_event",
                            event=serialize_event(result.summary))
        if result.committed:
            for name, planes in assignments.items():
                self.planes.assign(name, planes)
        record = result.record()
        record["reason"] = reason
        self.transitions.append(record)
        return record

    def _revert_plan(self, name: str, x_old: np.ndarray) -> None:
        """Roll a tenant's committed plan back to `x_old` after a
        rolled-back transition, certified on its CURRENT dag under the
        fabric mask (the admission.repair keep-path conventions)."""
        tenant = self.tenants[name]
        x_old = np.asarray(x_old, dtype=np.int64)
        problem = DESProblem(tenant.dag)
        mask = self.health.local_mask(tenant.pods)
        degraded = float(mask.min(initial=1.0)) < 1.0 - 1e-12
        res = simulate(problem, x_old.astype(np.float64) * mask) \
            if degraded else simulate(problem, x_old)
        ideal = tenant.plan.ideal_comm_time
        tenant.plan.x = x_old
        tenant.plan.makespan = res.makespan
        tenant.plan.comm_time = res.comm_time
        tenant.plan.nct = res.comm_time / ideal if ideal > 0 \
            else float("inf")
        tenant.base_plan = tenant.plan.copy()
        self.ledger.commit(name, tenant.fleet_usage(self.fleet.num_pods))
        if degraded:
            self._degraded.add(name)

    def _sync_planes(self) -> None:
        """End-of-event safety net: every tenant whose committed plan.x
        is not what its book entry sums to gets a fresh deterministic
        split.  This covers the atomic-exempt paths -- arrival's initial
        assignment, grant revocation restoring base plans, seizure
        shrinks -- where no incumbent circuits move plane-by-plane.
        Unsplittable plans leave no entry (a pure atomic tenant)."""
        if not self.staggered:
            return
        for name in sorted(set(self.planes.lanes) - set(self.tenants)):
            self.planes.pop(name)
        for name in sorted(self.tenants):
            tenant = self.tenants[name]
            if tenant.plan is None:
                continue
            x = np.asarray(tenant.plan.x, dtype=np.int64)
            total = self.planes.total(name)
            if total is not None and np.array_equal(total, x):
                continue
            planes = split_plan(x, self._tenant_budgets(name, tenant.pods))
            if planes is None:
                self.planes.pop(name)
            else:
                self.planes.assign(name, planes)

    # -------------------------------------------------------- surplus pass
    def revoke_grants(self) -> int:
        """Take back every outstanding grant, restoring base plans."""
        revoked = 0
        for tenant in self.tenants.values():
            acct = self.ledger.account(tenant.name)
            if acct.granted.sum() == 0:
                continue
            if tenant.base_plan is not None:
                tenant.plan = tenant.base_plan.copy()
            self.ledger.commit(tenant.name,
                               tenant.fleet_usage(self.fleet.num_pods))
            revoked += int(self.ledger.reclaim(tenant.name).sum())
        return revoked

    def bottlenecked(self) -> list[Tenant]:
        """Tenants whose comm time exceeds the non-blocking ideal by more
        than the threshold.  Port-minimized donors opted into minimal ports
        (their savings belong to co-tenants, Fig. 10) and are excluded
        unless `donors_can_receive` is set."""
        return [t for t in self.tenants.values()
                if t.plan is not None and np.isfinite(t.plan.nct)
                and t.plan.nct > self.nct_threshold
                and (self.donors_can_receive or not t.port_min)]

    def replan_surplus(self) -> list[dict]:
        """Waterfill the pool across bottlenecked tenants, re-optimize each
        boosted tenant with one batched DES evaluation."""
        pool = self.ledger.pool()
        needy = self.bottlenecked()
        if pool.sum() <= 0 or not needy:
            return []
        with span("fleet.surplus_pass", needy=len(needy),
                  pool=int(pool.sum())):
            return self._surplus_pass(pool, needy)

    def _surplus_pass(self, pool: np.ndarray,
                      needy: list[Tenant]) -> list[dict]:
        demands = np.stack([
            scatter(port_demand(t.dag, t.plan.x, xbar=t.xbar()), t.pods,
                    self.fleet.num_pods) for t in needy])
        grants = waterfill_grants(demands, pool)
        outcomes: list[dict] = []
        for tenant, g in zip(needy, grants):
            if g.sum() <= 0:
                continue
            self.ledger.grant(tenant.name, g)
            _GRANTS.inc(int(g.sum()))
            boosted = gather(self.ledger.limits(tenant.name), tenant.pods)
            # a degraded tenant's committed plan is priced against the
            # fabric mask; the surplus pass must keep pricing it that way
            # or a grant would silently revert the plan to healthy numbers
            mask = (self.health.local_mask(tenant.pods)
                    if tenant.name in self._degraded else None)
            res = reallocate(
                tenant.dag, tenant.plan.x, boosted,
                tenant.plan.ideal_comm_time, des=tenant.des(), rng=self.rng,
                num_random=self.num_random_candidates,
                base_makespan=tenant.plan.makespan,
                base_comm_time=tenant.plan.comm_time, mask=mask,
                dwell_s=self.dwell_for(tenant.name),
                reconfig_s_per_circuit=self.reconfig_s_per_circuit)
            self.realloc_batches += res.batch_calls
            self.realloc_candidates += res.num_candidates
            nct_before = tenant.plan.nct
            improved = res.improved
            transition = None
            if improved:
                # stagger the boost BEFORE committing it; a rolled-back
                # transition declines the boost (plan unchanged, the
                # grant goes back to the pool below)
                transition = self._apply_staggered(
                    {tenant.name: (tenant.plan.x, res.x)}, "surplus")
                if transition is not None \
                        and transition["status"] == "rolled_back":
                    improved = False
            if improved:
                tenant.plan.x = res.x
                tenant.plan.makespan = res.makespan
                tenant.plan.comm_time = res.comm_time
                tenant.plan.nct = res.nct
                self.ledger.commit(tenant.name,
                                   tenant.fleet_usage(self.fleet.num_pods))
            # hand unused grant back to the pool either way
            acct = self.ledger.account(tenant.name)
            returned = self.ledger.reclaim(
                tenant.name, np.minimum(acct.granted, acct.surplus))
            outcome = {
                "tenant": tenant.name, "granted": int(g.sum()),
                "kept": int(g.sum() - returned.sum()),
                "nct_before": nct_before, "nct_after": tenant.plan.nct,
                "improved": improved,
                "candidates": res.num_candidates}
            if transition is not None:
                outcome["transition"] = transition
            outcomes.append(outcome)
        return outcomes

    # ---------------------------------------------------- crash recovery
    def snapshot(self) -> dict:
        """Full JSON-safe planner state: ledger, fabric health, rng,
        tenants (DAGs + plans), plan cache and decision history.  Written
        to the journal every `snapshot_every` events; `restore`/`recover`
        are the inverse."""
        from repro.obs.journal import (_jobspec_to_dict, serialize_dag,
                                       serialize_plan)
        return {
            "ledger": self.ledger.snapshot(),
            "health": self.health.snapshot(),
            "planes": self.planes.snapshot(),
            "transition_seq": self._transition_seq,
            "transitions": list(self.transitions),
            "rng_state": self.rng.bit_generator.state,
            "dwell_estimates": dict(self.dwell_estimates),
            "degraded": sorted(self._degraded),
            "shrunk": sorted(self._shrunk),
            "events_handled": self._events_handled,
            "realloc": {"batches": self.realloc_batches,
                        "candidates": self.realloc_candidates},
            "cache_stats": [self.cache.hits, self.cache.misses],
            "cache": {sig: serialize_plan(p)
                      for sig, p in self.cache._store.items()},
            "tenants": {
                name: {"job": _jobspec_to_dict(t.job),
                       "pods": list(t.pods),
                       "reverse_stages": t.reverse_stages,
                       "port_min": t.port_min,
                       "dag": serialize_dag(t.dag),
                       "dag_history": [serialize_dag(d)
                                       for d in t.dag_history],
                       "plan": serialize_plan(t.plan),
                       "base_plan": serialize_plan(t.base_plan)}
                for name, t in self.tenants.items()},
            # copy: the in-memory journal keeps snapshot dicts by
            # reference, and the live history keeps growing after this
            "history": list(self.history),
        }

    @classmethod
    def restore(cls, snap: dict, fleet: FleetSpec,
                **kwargs) -> "FleetPlanner":
        """Rebuild a planner from a `snapshot()` dict.  Constructor
        options (`ga_options`, thresholds, `journal`, ...) are re-supplied
        via kwargs; everything stateful comes from the snapshot."""
        from repro.obs.journal import (_jobspec_from_dict, rebuild_dag,
                                       rebuild_plan)
        planner = cls(fleet, **kwargs)
        planner.ledger = PortLedger.from_snapshot(snap["ledger"])
        planner.admission.ledger = planner.ledger
        planner.health = FabricHealth.from_snapshot(snap["health"])
        # pre-v3 snapshots carry no plane book; `_sync_planes` rebuilds it
        # deterministically on the next handled event
        if "planes" in snap:
            planner.planes = PlaneBook.from_snapshot(snap["planes"])
        planner._transition_seq = int(snap.get("transition_seq", 0))
        planner.transitions = list(snap.get("transitions", []))
        planner.rng = np.random.default_rng(0)
        planner.rng.bit_generator.state = snap["rng_state"]
        planner.dwell_estimates = {
            k: float(v) for k, v in snap.get("dwell_estimates", {}).items()}
        planner._degraded = set(snap.get("degraded", ()))
        planner._shrunk = set(snap.get("shrunk", ()))
        planner._events_handled = int(snap.get("events_handled", 0))
        planner.realloc_batches = int(snap["realloc"]["batches"])
        planner.realloc_candidates = int(snap["realloc"]["candidates"])
        hits, misses = snap.get("cache_stats", (0, 0))
        planner.cache.hits, planner.cache.misses = int(hits), int(misses)
        # in-place: admission shares this PlanCache object
        planner.cache._store.clear()
        planner.cache._store.update(
            {sig: rebuild_plan(p) for sig, p in snap.get("cache",
                                                         {}).items()})
        for name, ts in snap.get("tenants", {}).items():
            planner.tenants[name] = Tenant(
                name=name, job=_jobspec_from_dict(ts["job"]),
                pods=tuple(ts["pods"]),
                reverse_stages=bool(ts["reverse_stages"]),
                port_min=bool(ts["port_min"]),
                dag=rebuild_dag(ts["dag"]),
                dag_history=[rebuild_dag(d) for d in ts["dag_history"]],
                plan=rebuild_plan(ts["plan"]),
                base_plan=rebuild_plan(ts["base_plan"]))
        planner.history = list(snap.get("history", []))
        planner.ledger.check()
        _TENANTS.set(len(planner.tenants))
        return planner

    @classmethod
    def recover(cls, entries, fleet: FleetSpec, **kwargs) -> "FleetPlanner":
        """Crash recovery from a journal (a path or its entry list):
        restore the most recent `fleet_snapshot`, then replay the tail of
        `fleet_event` entries through `handle()`.  With no snapshot the
        whole journal is replayed from a fresh planner."""
        from repro.obs.journal import rebuild_event
        if isinstance(entries, (str, os.PathLike)):
            entries = FleetJournal.load(entries)
        snap_idx = max((i for i, e in enumerate(entries)
                        if e.get("kind") == "fleet_snapshot"), default=None)
        if snap_idx is None:
            planner = cls(fleet, **kwargs)
            tail = entries
        else:
            planner = cls.restore(entries[snap_idx]["state"], fleet,
                                  **kwargs)
            tail = entries[snap_idx + 1:]
        for e in tail:
            if e.get("kind") == "fleet_event":
                planner.handle(rebuild_event(e["event"]))
        return planner

    # ------------------------------------------------------------- reports
    def report(self) -> dict:
        from repro.core.des_jax import des_cache_stats
        sc = self._obs_scope
        return {
            "tenants": {
                name: {"pods": list(t.pods), "nct": t.plan.nct,
                       "makespan": t.plan.makespan,
                       "ports": int(t.plan.x.sum()),
                       "reverse_stages": t.reverse_stages,
                       "port_min": t.port_min}
                for name, t in self.tenants.items() if t.plan is not None},
            "ledger": self.ledger.snapshot(),
            "cache": self.cache.stats(),
            # jit churn accounting: misses are XLA recompiles; a healthy
            # fleet loop is all hits after warm-up.  Hits/misses/evictions
            # are DELTAS against the registry scope captured at planner
            # construction, so a second planner in the same process does
            # not pollute this planner's numbers; `entries` is the live
            # process-wide cache size (a gauge, not attributable)
            "des_cache": {
                "hits": int(sc.delta("des_compile_hits_total")),
                "misses": int(sc.delta("des_compile_miss_total")),
                "evictions": int(sc.delta("des_compile_evictions_total")),
                "entries": des_cache_stats()["entries"]},
            "events": {k or "total": int(v) for k, v in
                       sc.deltas("fleet_events_total").items() if v},
            "realloc": {"batches": self.realloc_batches,
                        "candidates": self.realloc_candidates,
                        "granted_ports": int(
                            sc.delta("fleet_granted_ports_total"))},
            "planes": {
                "staggered": self.staggered,
                "num_planes": self.num_planes,
                "tracked": sorted(self.planes.lanes),
                "transitions": len(self.transitions),
                "committed": sum(t["status"] == "committed"
                                 for t in self.transitions),
                "rolled_back": sum(t["status"] == "rolled_back"
                                   for t in self.transitions),
                "rewire_steps": int(sc.delta("planes_rewire_steps_total")),
                "peak_inflation": max(
                    (t["peak_inflation"] for t in self.transitions),
                    default=1.0)},
        }


def arrivals(*specs) -> list[JobArrival]:
    """Convenience: (name, job[, kwargs]) tuples -> JobArrival events.
    JobArrival instances pass through unchanged."""
    events = []
    for spec in specs:
        if isinstance(spec, JobArrival):
            events.append(spec)
            continue
        name, job = spec[0], spec[1]
        kw = dict(spec[2]) if len(spec) > 2 else {}
        events.append(JobArrival(name=name, job=job, **kw))
    return events
