"""Topology plan cache keyed by a structural CommDAG signature.

Production AIDC fleets see the same (model, parallelism, schedule) jobs over
and over -- LLM traffic is deterministic given those three (paper feature
F1), so two jobs with isomorphic reduced DAGs and equal port budgets have
identical optimal topologies.  The signature hashes exactly the inputs the
planner consumes: tasks, deps, port limits, NIC bandwidth and the planning
options -- *not* the fleet pod ids, so a repeated workload admitted onto a
different pod span still hits.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.core.dag import CommDAG


def dag_signature(dag: CommDAG, extra: tuple = ()) -> str:
    """Stable content hash of the planner-visible parts of a CommDAG."""
    h = hashlib.sha256()
    cl = dag.cluster
    h.update(repr((cl.num_pods, tuple(int(u) for u in cl.port_limits),
                   float(cl.nic_bandwidth))).encode())
    for t in dag.tasks:
        h.update(repr((t.tid, t.src_pod, t.dst_pod, t.flows,
                       round(float(t.volume), 6), t.kind)).encode())
    for d in dag.deps:
        h.update(repr((d.pre, d.succ, round(float(d.delta), 12))).encode())
    h.update(repr(extra).encode())
    return h.hexdigest()


@dataclass
class CachedPlan:
    """What re-admitting an identical workload needs: the topology and its
    quality numbers (all local-pod indexed)."""

    x: np.ndarray
    makespan: float
    comm_time: float
    nct: float
    ideal_comm_time: float
    details: dict = field(default_factory=dict)

    def copy(self) -> "CachedPlan":
        return CachedPlan(x=self.x.copy(), makespan=self.makespan,
                          comm_time=self.comm_time, nct=self.nct,
                          ideal_comm_time=self.ideal_comm_time,
                          details=dict(self.details))


class PlanCache:
    """signature -> CachedPlan with hit/miss accounting."""

    def __init__(self, max_entries: int = 1024):
        self.max_entries = max_entries
        self._store: dict[str, CachedPlan] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    def get_or_plan(self, dag: CommDAG, planner: Callable[[], CachedPlan],
                    extra: tuple = ()) -> tuple[CachedPlan, bool]:
        """Return (plan, hit).  `planner` runs only on a miss."""
        sig = dag_signature(dag, extra)
        cached = self._store.get(sig)
        if cached is not None:
            self.hits += 1
            return cached.copy(), True
        self.misses += 1
        plan = planner()
        if len(self._store) >= self.max_entries:   # drop oldest entry
            self._store.pop(next(iter(self._store)))
        self._store[sig] = plan.copy()
        return plan, False

    def stats(self) -> dict[str, Any]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._store)}
