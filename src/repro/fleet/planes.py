"""DELTA-Planes: k-plane fabric decomposition + staggered, SLO-guarded
zero-downtime transitions.

The fabric is k parallel OCS planes; a tenant's logical topology x is
carried as k per-plane lane allocations summing to x (`PlaneBook`,
`split_plan` -- the balanced split of `repro.core.ga.split_across_planes`
under the deterministic `split_port_budgets` budgets).  Moving the fleet
from incumbent plan A to target plan B then never needs a full-fabric
dark window: `StaggeredTransition` rewires one plane at a time, and every
intermediate state is exactly "one plane dark" (the plane being rewired;
`FabricHealth.fail_plane` physics) plus the already-rewired planes'
*new* circuits.

Every step is priced with the masked numpy DES oracle
(`repro.core.des.simulate` on the float effective topology -- certified,
never the float32 jax path), steps are greedily ordered to minimize the
certified peak per-tenant makespan inflation, and a round where every
remaining step would breach the inflation SLO triggers rollback to plan A
(rollback steps are forced -- the fleet is never stranded between plans).
The scheduler reads its `FabricHealth` reference LIVE at every step: a
`PlaneFailure` landing mid-transition changes the next round's reference
and candidate pricing, so the engine re-prices against the doubly-
degraded fabric and either continues or rolls back.

Pricing conventions (shared with `plane_state_genomes` and
`failure_scenarios`):

  * the reference makespan is re-measured each round from the CURRENT
    mixed state under the fabric's own damage (marginal-cost semantics:
    a step's inflation is its slowdown on top of what the fabric already
    imposes);
  * a pair carried entirely by dark planes keeps a fractional ``x/k``
    trickle while at least one plane is lit (transient buffering);
    with ALL planes dark it prices as a true blackout (capacity 0 ->
    infinite makespan), so a full-fabric dark window can never pass an
    SLO check;
  * link damage (`FabricHealth.link_frac`) multiplies on top; the
    fabric's dark planes enter through the explicit lane subtraction,
    NOT through `plane_factor` (that would double-count them).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.des import DESProblem, simulate
from repro.fleet.events import PlaneRewireStep, PlaneTransitionSummary
from repro.fleet.faults import FabricHealth
from repro.fleet.realloc import plane_circuit_changes
from repro.obs import get_counter, span

INF = float("inf")

_STEPS = get_counter("planes_rewire_steps_total",
                     "staggered single-plane rewire steps performed")
_ROLLBACKS = get_counter("planes_rollbacks_total",
                         "staggered transitions rolled back to plan A")


def split_plan(x: np.ndarray, budgets) -> np.ndarray | None:
    """Balanced per-plane split of a tenant plan, or None when the plan
    does not decompose under the per-plane budgets (integrality can make
    the split infeasible even when x fits the summed budget -- the fleet
    then falls back to an atomic swap for that tenant)."""
    from repro.core.ga import split_across_planes
    try:
        return split_across_planes(x, budgets)
    except ValueError:
        return None


def effective_topology(planes: np.ndarray, dark: set[int] | frozenset[int]
                       ) -> np.ndarray:
    """Float effective topology of a (k, P, P) lane stack with the given
    planes dark.  Pairs carried entirely by dark planes keep an ``x/k``
    trickle while any plane is lit, and collapse to 0 (blackout) when
    every plane is dark -- see the module docstring."""
    planes = np.asarray(planes)
    k = len(planes)
    x = planes.sum(axis=0).astype(np.float64)
    idx = [p for p in dark if 0 <= p < k]
    eff = x - planes[idx].sum(axis=0) if idx else x.copy().astype(np.float64)
    if len(idx) >= k:
        return np.zeros_like(x)
    return np.where((eff <= 0) & (x > 0), x / k, eff)


@dataclass
class PlaneBook:
    """Fleet-level registry of per-tenant lane decompositions.

    One (k, P_local, P_local) int array per tenant, planes summing to the
    tenant's committed plan.x.  The book is part of the planner snapshot
    and must restore / replay to bit-identical arrays."""

    num_planes: int
    lanes: dict[str, np.ndarray] = field(default_factory=dict)

    def assign(self, name: str, planes: np.ndarray) -> None:
        planes = np.asarray(planes, dtype=np.int64)
        if planes.ndim != 3 or len(planes) != self.num_planes:
            raise ValueError(f"need a ({self.num_planes}, P, P) stack, "
                             f"got shape {planes.shape}")
        self.lanes[name] = planes

    def get(self, name: str) -> np.ndarray | None:
        return self.lanes.get(name)

    def pop(self, name: str) -> None:
        self.lanes.pop(name, None)

    def total(self, name: str) -> np.ndarray | None:
        planes = self.lanes.get(name)
        return None if planes is None else planes.sum(axis=0)

    def snapshot(self) -> dict:
        return {"num_planes": self.num_planes,
                "lanes": {name: planes.tolist()
                          for name, planes in sorted(self.lanes.items())}}

    @classmethod
    def from_snapshot(cls, snap: dict) -> "PlaneBook":
        book = cls(num_planes=int(snap["num_planes"]))
        for name, planes in snap.get("lanes", {}).items():
            book.assign(name, np.asarray(planes, dtype=np.int64))
        return book


@dataclass
class TenantLane:
    """One tenant's A->B lane pair inside a transition.  Bystanders (not
    changing topology) carry planes_a == planes_b: they still suffer each
    intermediate dark plane and count toward the SLO."""

    name: str
    dag: object                  # CommDAG (local pod ids)
    pods: tuple[int, ...]        # fleet pod ids (for link_frac windows)
    planes_a: np.ndarray         # (k, P_local, P_local)
    planes_b: np.ndarray

    def __post_init__(self) -> None:
        self.planes_a = np.asarray(self.planes_a, dtype=np.int64)
        self.planes_b = np.asarray(self.planes_b, dtype=np.int64)
        if self.planes_a.shape != self.planes_b.shape:
            raise ValueError(
                f"{self.name}: lane stacks disagree "
                f"{self.planes_a.shape} vs {self.planes_b.shape}")


@dataclass
class TransitionResult:
    transition: str
    committed: bool
    status: str                       # "committed" | "rolled_back"
    steps: list[PlaneRewireStep]
    summary: PlaneTransitionSummary

    @property
    def peak_inflation(self) -> float:
        return self.summary.peak_inflation

    @property
    def total_delay_s(self) -> float:
        return self.summary.total_delay_s

    def record(self) -> dict:
        """JSON-safe report payload."""
        return {"transition": self.transition, "status": self.status,
                "steps": len(self.steps),
                "peak_inflation": self.summary.peak_inflation,
                "total_delay_s": self.summary.total_delay_s,
                "planes": list(self.summary.planes),
                "tenants": list(self.summary.tenants)}


class StaggeredTransition:
    """One staggered A->B fleet transition (see the module docstring).

    Drive it with `run()` (loops `step()` until committed or rolled
    back), or step manually -- `step()` returns the performed
    `PlaneRewireStep` or None when every remaining candidate breaches
    the SLO (the caller then calls `rollback()`).  `health` is read live
    at each pricing round, so fabric damage landing between steps is
    priced into the remaining schedule automatically.
    """

    def __init__(self, lanes: list[TenantLane], health: FabricHealth, *,
                 slo: float = 3.0, reconfig_s_per_circuit: float = 0.01,
                 transition_id: str = "t0"):
        if not lanes:
            raise ValueError("a transition needs at least one tenant lane")
        ks = {len(t.planes_a) for t in lanes}
        if len(ks) != 1:
            raise ValueError(f"tenants disagree on plane count: {ks}")
        self.num_planes = ks.pop()
        self.lanes = lanes
        self.health = health
        self.slo = float(slo)
        self.reconfig_s_per_circuit = float(reconfig_s_per_circuit)
        self.transition_id = str(transition_id)
        self._problems = {t.name: DESProblem(t.dag) for t in lanes}
        self._deltas = {t.name: plane_circuit_changes(t.planes_b,
                                                      t.planes_a)
                        for t in lanes}
        # planes whose target lanes differ from the incumbent for any
        # tenant; the rest are no-ops and never go dark
        self.pending = [p for p in range(self.num_planes)
                        if any(int(self._deltas[t.name][p]) for t in lanes)]
        self.done: list[int] = []     # rewire order, for rollback
        self.steps: list[PlaneRewireStep] = []
        self._seq = 0

    # ------------------------------------------------------------- pricing
    def mixed_planes(self, lane: TenantLane) -> np.ndarray:
        """The tenant's CURRENT lane stack: rewired planes carry B lanes,
        the rest still carry A."""
        planes = lane.planes_a.copy()
        for p in self.done:
            planes[p] = lane.planes_b[p]
        return planes

    def _link_local(self, lane: TenantLane) -> np.ndarray:
        idx = np.asarray(lane.pods, dtype=np.int64)
        return self.health.link_frac[np.ix_(idx, idx)]

    def _price(self, dark: set[int]) -> dict[str, float]:
        """Certified per-tenant makespans of the current mixed state with
        `dark` planes down (numpy oracle; float effective topology)."""
        out = {}
        for lane in self.lanes:
            eff = effective_topology(self.mixed_planes(lane), dark)
            out[lane.name] = float(simulate(
                self._problems[lane.name],
                eff * self._link_local(lane)).makespan)
        return out

    def _peak_inflation(self, refs: dict[str, float],
                        dark: set[int]) -> float:
        """Worst per-tenant inflation of a candidate state vs the current
        references (both oracle numbers)."""
        peak = 1.0
        for name, ms in self._price(dark).items():
            ref = refs[name]
            if not np.isfinite(ms):
                return INF
            if np.isfinite(ref) and ref > 0:
                peak = max(peak, ms / ref)
        return peak

    def _step_delay(self, plane: int) -> tuple[float, int]:
        changed = sum(int(self._deltas[t.name][plane]) for t in self.lanes)
        return changed * self.reconfig_s_per_circuit, changed

    # ------------------------------------------------------------ stepping
    def step(self) -> PlaneRewireStep | None:
        """Price every pending single-plane rewire against the live
        fabric, perform the cheapest one.  Returns the step record, or
        None when all remaining candidates breach the SLO (caller must
        `rollback()`); raises if nothing is pending."""
        if not self.pending:
            raise RuntimeError("transition already complete")
        fabric_dark = set(self.health.dark_planes)
        refs = self._price(fabric_dark)
        best: tuple[float, int] | None = None
        for q in self.pending:
            peak = self._peak_inflation(refs, fabric_dark | {q})
            if best is None or (peak, q) < best:
                best = (peak, q)
        peak, q = best
        if peak > self.slo:
            return None
        return self._perform(q, peak, "forward")

    def _perform(self, plane: int, peak: float,
                 direction: str) -> PlaneRewireStep:
        delay_s, changed = self._step_delay(plane)
        if direction == "forward":
            self.pending.remove(plane)
            self.done.append(plane)
        else:
            self.done.remove(plane)
            self.pending.append(plane)
            self.pending.sort()
        rec = PlaneRewireStep(
            transition=self.transition_id, plane=int(plane), seq=self._seq,
            direction=direction, peak_inflation=float(peak),
            delay_s=float(delay_s), changed_circuits=int(changed),
            tenants=tuple(t.name for t in self.lanes))
        self._seq += 1
        self.steps.append(rec)
        _STEPS.inc()
        return rec

    def rollback(self) -> list[PlaneRewireStep]:
        """Un-rewire the done planes in reverse order, back to plan A.
        Rollback steps are priced (certified, for the record) but FORCED
        regardless of the SLO: stranding the fleet between plans is worse
        than a breaching step."""
        out = []
        fabric_dark = set(self.health.dark_planes)
        for p in list(reversed(self.done)):
            refs = self._price(fabric_dark)
            peak = self._peak_inflation(refs, fabric_dark | {p})
            out.append(self._perform(p, peak, "rollback"))
        _ROLLBACKS.inc()
        return out

    def run(self) -> TransitionResult:
        with span("planes.transition", id=self.transition_id,
                  tenants=len(self.lanes), planes=self.num_planes):
            while self.pending:
                if self.step() is None:
                    self.rollback()
                    return self._result("rolled_back")
        return self._result("committed")

    def _result(self, status: str) -> TransitionResult:
        peak = max((s.peak_inflation for s in self.steps), default=1.0)
        summary = PlaneTransitionSummary(
            transition=self.transition_id, outcome=status,
            steps=len(self.steps), peak_inflation=float(peak),
            total_delay_s=float(sum(s.delay_s for s in self.steps)),
            tenants=tuple(t.name for t in self.lanes),
            planes=tuple(s.plane for s in self.steps))
        return TransitionResult(
            transition=self.transition_id, committed=(status == "committed"),
            status=status, steps=list(self.steps), summary=summary)
