"""Surplus-port reallocation engine (paper Sec. VI, Fig. 10).

Port-minimized DELTA plans free >= 20% of a tenant's fair-share ports; this
module waterfills that surplus across bandwidth-bottlenecked co-tenants and
re-optimizes each boosted tenant's topology.

Two deliberately cheap mechanisms replace a full re-solve:

  * `waterfill_grants` -- max-min fair progressive filling of the per-pod
    surplus pool over tenant demands.  The inner used/denominator reductions
    are the same fused matvec pair as the DES fair-share loop, so they run
    through `repro.kernels` (`fill_matvec`: Pallas on TPU, jnp ref on CPU)
    whenever there is more than one item to fill.

  * `reallocate` -- generates a portfolio of boosted candidate topologies
    (traffic-weighted, concentrated, round-robin, randomized) and evaluates
    the *whole portfolio* in ONE `JaxDES.batch_makespan` vmap call instead
    of per-candidate Python-loop simulations.  The incumbent topology is
    always candidate 0, and the winner is certified against the exact numpy
    DES, so a reallocation can never worsen a tenant's NCT.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.dag import CommDAG
from repro.core.des import DESProblem, simulate
from repro.core.xbound import x_upper_bound

INF = float("inf")


# ------------------------------------------------------------- waterfilling
def waterfill_grants(demands: np.ndarray, supply: np.ndarray,
                     use_kernel: bool | None = None) -> np.ndarray:
    """Max-min fair integer split of per-pod surplus among tenants.

    demands: (T, P) max extra ports tenant t can exploit in pod p.
    supply:  (P,)  grantable pool ports per pod.
    Returns integer grants (T, P) with column sums <= supply and
    grants <= demands.
    """
    demands = np.asarray(demands, dtype=np.float64)
    supply = np.asarray(supply, dtype=np.float64)
    T, P = demands.shape
    if T == 0 or P == 0 or demands.sum() == 0 or supply.sum() == 0:
        return np.zeros((T, P), dtype=np.int64)

    # items = (tenant, pod) cells; constraint p sums its column cells
    demand = demands.reshape(-1)                       # (N,) N = T*P
    item_pod = np.tile(np.arange(P), T)
    N = len(demand)
    if use_kernel is None:
        use_kernel = N >= 2
    W = np.zeros((P, N))
    W[item_pod, np.arange(N)] = 1.0

    level = np.zeros(N)
    unfrozen = demand > 0
    for _ in range(N + P + 1):
        if not unfrozen.any():
            break
        if use_kernel:
            from repro.kernels.ops import fill_matvec
            rhs = np.stack([level, unfrozen.astype(np.float64)], axis=1)
            out = np.asarray(fill_matvec(W, rhs))
            used, denom = out[:, 0], out[:, 1]
        else:
            used = np.bincount(item_pod, weights=level, minlength=P)
            denom = np.bincount(item_pod, weights=unfrozen.astype(float),
                                minlength=P)
        slack = np.maximum(supply - used, 0.0)
        with np.errstate(divide="ignore", invalid="ignore"):
            alpha_pod = np.where(denom > 0, slack / np.maximum(denom, 1e-300),
                                 INF)
        alpha_item = np.where(unfrozen, demand - level, INF)
        alpha = min(float(alpha_pod.min()), float(alpha_item.min()))
        if not np.isfinite(alpha):
            break
        level = np.where(unfrozen, level + alpha, level)
        pod_sat = alpha_pod <= alpha * (1 + 1e-12) + 1e-12
        unfrozen &= ~(pod_sat[item_pod]) & (level < demand - 1e-12)
        if alpha <= 0 and not pod_sat.any():   # pragma: no cover
            break

    # integerize: floor, then hand out each pod's remaining whole ports to
    # the cells with the largest fractional part (and demand headroom)
    grants = np.floor(level + 1e-9).astype(np.int64)
    frac = level - grants
    demand_i = demands.astype(np.int64).reshape(-1)
    grants = np.minimum(grants, demand_i)
    for p in range(P):
        cells = np.nonzero(item_pod == p)[0]
        left = int(supply[p]) - int(grants[cells].sum())
        for i in cells[np.argsort(-frac[cells])]:
            if left <= 0:
                break
            if grants[i] < demand_i[i]:
                grants[i] += 1
                left -= 1
    return grants.reshape(T, P)


def port_demand(dag: CommDAG, x: np.ndarray,
                xbar: np.ndarray | None = None) -> np.ndarray:
    """Max useful extra ports per local pod: beyond the Alg. 2 concurrency
    bound X̄ extra circuits cannot raise any task's rate."""
    if xbar is None:
        xbar = x_upper_bound(dag)
    want = np.zeros(dag.cluster.num_pods, dtype=np.int64)
    for i, j in dag.undirected_pairs():
        extra = max(int(xbar[i, j]) - int(x[i, j]), 0)
        want[i] += extra
        want[j] += extra
    return want


# ------------------------------------------------------- candidate topologies
def _greedy_fill(x: np.ndarray, limits: np.ndarray, pairs: list,
                 weight_of, max_add: int | None = None) -> np.ndarray:
    """Add circuits one at a time to the heaviest addable pair."""
    x = x.copy()
    usage = x.sum(axis=1)
    added = 0
    while max_add is None or added < max_add:
        best, best_w = None, -INF
        for (i, j) in pairs:
            if usage[i] < limits[i] and usage[j] < limits[j]:
                w = weight_of(i, j, x)
                if w > best_w:
                    best, best_w = (i, j), w
        if best is None:
            break
        i, j = best
        x[i, j] += 1
        x[j, i] += 1
        usage[i] += 1
        usage[j] += 1
        added += 1
    return x


def candidate_boosts(dag: CommDAG, x0: np.ndarray, limits: np.ndarray,
                     rng: np.random.Generator,
                     num_random: int = 8) -> np.ndarray:
    """Portfolio of boosted topologies within per-pod `limits`.

    Candidate 0 is always `x0` itself, so the portfolio minimum can never
    be worse than the incumbent.
    """
    pairs = dag.undirected_pairs()
    vol = dag.traffic_matrix()
    uvol = {(i, j): vol[i, j] + vol[j, i] for i, j in pairs}
    limits = np.asarray(limits, dtype=np.int64)

    cands = [x0.copy()]
    # (a) per-circuit volume: relieve the most oversubscribed pair first
    cands.append(_greedy_fill(
        x0, limits, pairs, lambda i, j, x: uvol[(i, j)] / max(x[i, j], 1)))
    # (b) concentrated: everything to the single heaviest pair
    if pairs:
        hot = max(pairs, key=lambda p: uvol[p])
        cands.append(_greedy_fill(x0, limits, [hot], lambda i, j, x: 1.0))
    # (c) round-robin: spread evenly (least-loaded pair first)
    cands.append(_greedy_fill(
        x0, limits, pairs, lambda i, j, x: -float(x[i, j])))
    # (d) randomized greedy fills
    for _ in range(num_random):
        jitter = {p: rng.random() for p in pairs}
        cands.append(_greedy_fill(
            x0, limits, pairs,
            lambda i, j, x: jitter[(i, j)] * uvol[(i, j)] / max(x[i, j], 1)))

    uniq: dict[bytes, np.ndarray] = {}
    for c in cands:
        uniq.setdefault(c.tobytes(), c)
    out = list(uniq.values())
    # keep the incumbent at index 0
    out.sort(key=lambda c: 0 if c.tobytes() == x0.tobytes() else 1)
    return np.stack(out)


# ------------------------------------------------------------- reallocation
@dataclass
class ReallocResult:
    x: np.ndarray
    makespan: float
    comm_time: float
    nct: float
    improved: bool
    num_candidates: int
    batch_calls: int = 1
    details: dict = field(default_factory=dict)


def reallocate(dag: CommDAG, x0: np.ndarray, boosted_limits: np.ndarray,
               ideal_comm_time: float, des=None,
               rng: np.random.Generator | None = None,
               num_random: int = 8,
               base_makespan: float | None = None,
               base_comm_time: float | None = None) -> ReallocResult:
    """Re-optimize one tenant's topology under boosted port limits.

    All candidates are scored by a single batched `JaxDES.batch_makespan`
    call; the winner is certified with the exact numpy DES and only
    accepted if it does not worsen the tenant's communication time.
    Pass `base_makespan`/`base_comm_time` (the incumbent's known exact
    quality, e.g. from the committed plan) to skip re-simulating `x0`.
    """
    rng = rng or np.random.default_rng(0)
    problem = DESProblem(dag)
    xs = candidate_boosts(dag, x0, boosted_limits, rng,
                          num_random=num_random)
    if des is None:
        from repro.core.des_jax import JaxDES
        des = JaxDES(problem)
    ms, feas = des.batch_makespan(xs)            # ONE vmap over candidates
    score = np.where(feas, ms, INF)
    # lexicographic tie-break: fewer total ports on ~equal makespan
    ports = xs.reshape(len(xs), -1).sum(axis=1)
    finite = score[np.isfinite(score)]
    ref = float(finite.min()) if len(finite) and finite.min() > 0 else 1.0
    rel = np.where(np.isfinite(score), np.round(score / ref, 6), INF)
    best = int(np.lexsort((ports, rel))[0])

    if base_makespan is None or base_comm_time is None:
        base = simulate(problem, x0)
        base_makespan, base_comm_time = base.makespan, base.comm_time
    makespan, comm_time = base_makespan, base_comm_time
    if best != 0:
        cand = simulate(problem, xs[best])        # certify the winner
        if cand.feasible and cand.comm_time <= base_comm_time * (1 + 1e-9):
            makespan, comm_time = cand.makespan, cand.comm_time
        else:
            best = 0                              # never worsen the tenant
    nct = comm_time / ideal_comm_time if ideal_comm_time > 0 else INF
    return ReallocResult(
        x=xs[best].copy(), makespan=makespan, comm_time=comm_time,
        nct=nct, improved=best != 0, num_candidates=len(xs),
        details={"scores_finite": int(np.isfinite(score).sum())})
