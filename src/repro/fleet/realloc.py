"""Surplus-port reallocation engine (paper Sec. VI, Fig. 10).

Port-minimized DELTA plans free >= 20% of a tenant's fair-share ports; this
module waterfills that surplus across bandwidth-bottlenecked co-tenants and
re-optimizes each boosted tenant's topology.

Two deliberately cheap mechanisms replace a full re-solve:

  * `waterfill_grants` -- max-min fair progressive filling of the per-pod
    surplus pool over tenant demands.  The inner used/denominator reductions
    are the same fused matvec pair as the DES fair-share loop, so they run
    through `repro.kernels` (`fill_matvec`: Pallas on TPU, jnp ref on CPU)
    whenever there is more than one item to fill.

  * `reallocate` -- generates a portfolio of boosted candidate genomes
    (traffic-weighted, concentrated, round-robin, randomized) over the
    active pod pairs and evaluates the *whole portfolio* in ONE
    `JaxDES.batch_genome_makespan` call: the genome->topology scatter and
    the vmap DES run fused on device, so the host ships (K, E) ints instead
    of (K, P, P) matrices.  The incumbent is always candidate 0, and the
    winner is certified against the exact numpy DES, so a reallocation can
    never worsen a tenant's NCT.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.dag import CommDAG
from repro.core.des import DESProblem, simulate
from repro.core.xbound import x_upper_bound

INF = float("inf")


# ------------------------------------------------------------- waterfilling
def waterfill_grants(demands: np.ndarray, supply: np.ndarray,
                     use_kernel: bool | None = None) -> np.ndarray:
    """Max-min fair integer split of per-pod surplus among tenants.

    demands: (T, P) max extra ports tenant t can exploit in pod p.
    supply:  (P,)  grantable pool ports per pod.
    Returns integer grants (T, P) with column sums <= supply and
    grants <= demands.
    """
    demands = np.asarray(demands, dtype=np.float64)
    supply = np.asarray(supply, dtype=np.float64)
    T, P = demands.shape
    if T == 0 or P == 0 or demands.sum() == 0 or supply.sum() == 0:
        return np.zeros((T, P), dtype=np.int64)

    # items = (tenant, pod) cells; constraint p sums its column cells
    demand = demands.reshape(-1)                       # (N,) N = T*P
    item_pod = np.tile(np.arange(P), T)
    N = len(demand)
    if use_kernel is None:
        use_kernel = N >= 2
    W = np.zeros((P, N))
    W[item_pod, np.arange(N)] = 1.0

    level = np.zeros(N)
    unfrozen = demand > 0
    for _ in range(N + P + 1):
        if not unfrozen.any():
            break
        if use_kernel:
            from repro.kernels.ops import fill_matvec
            rhs = np.stack([level, unfrozen.astype(np.float64)], axis=1)
            out = np.asarray(fill_matvec(W, rhs))
            used, denom = out[:, 0], out[:, 1]
        else:
            used = np.bincount(item_pod, weights=level, minlength=P)
            denom = np.bincount(item_pod, weights=unfrozen.astype(float),
                                minlength=P)
        slack = np.maximum(supply - used, 0.0)
        with np.errstate(divide="ignore", invalid="ignore"):
            alpha_pod = np.where(denom > 0, slack / np.maximum(denom, 1e-300),
                                 INF)
        alpha_item = np.where(unfrozen, demand - level, INF)
        alpha = min(float(alpha_pod.min()), float(alpha_item.min()))
        if not np.isfinite(alpha):
            break
        level = np.where(unfrozen, level + alpha, level)
        pod_sat = alpha_pod <= alpha * (1 + 1e-12) + 1e-12
        unfrozen &= ~(pod_sat[item_pod]) & (level < demand - 1e-12)
        if alpha <= 0 and not pod_sat.any():   # pragma: no cover
            break

    # integerize: floor, then hand out each pod's remaining whole ports to
    # the cells with the largest fractional part (and demand headroom)
    grants = np.floor(level + 1e-9).astype(np.int64)
    frac = level - grants
    demand_i = demands.astype(np.int64).reshape(-1)
    grants = np.minimum(grants, demand_i)
    for p in range(P):
        cells = np.nonzero(item_pod == p)[0]
        left = int(supply[p]) - int(grants[cells].sum())
        for i in cells[np.argsort(-frac[cells])]:
            if left <= 0:
                break
            if grants[i] < demand_i[i]:
                grants[i] += 1
                left -= 1
    return grants.reshape(T, P)


def circuit_changes(x_new: np.ndarray, x_old: np.ndarray) -> int:
    """Circuits the OCS must tear down or set up to move between plans."""
    d = np.abs(np.asarray(x_new, np.int64) - np.asarray(x_old, np.int64))
    return int(np.triu(d, k=1).sum())


def plane_circuit_changes(planes_new: np.ndarray,
                          planes_old: np.ndarray) -> np.ndarray:
    """Per-plane rewire sizes between two (k, P, P) lane decompositions:
    entry p is the `circuit_changes` of plane p alone, i.e. the work (and
    dark time) of that plane's step in a staggered transition."""
    a = np.asarray(planes_new, np.int64)
    b = np.asarray(planes_old, np.int64)
    if a.shape != b.shape or a.ndim != 3:
        raise ValueError(f"plane stacks disagree: {a.shape} vs {b.shape}")
    d = np.abs(a - b)
    return np.triu(d, k=1).sum(axis=(1, 2)).astype(np.int64)


def _edge_arrays(pairs) -> tuple[np.ndarray, np.ndarray]:
    earr = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    return earr[:, 0], earr[:, 1]


def port_demand(dag: CommDAG, x: np.ndarray,
                xbar: np.ndarray | None = None) -> np.ndarray:
    """Max useful extra ports per local pod: beyond the Alg. 2 concurrency
    bound X̄ extra circuits cannot raise any task's rate."""
    if xbar is None:
        xbar = x_upper_bound(dag)
    want = np.zeros(dag.cluster.num_pods, dtype=np.int64)
    pairs = dag.undirected_pairs()
    if not pairs:
        return want
    eu, ev = _edge_arrays(pairs)
    extra = np.maximum(np.asarray(xbar)[eu, ev].astype(np.int64)
                       - np.asarray(x)[eu, ev].astype(np.int64), 0)
    np.add.at(want, eu, extra)
    np.add.at(want, ev, extra)
    return want


# ------------------------------------------------------- candidate topologies
def _greedy_fill(g0: np.ndarray, usage0: np.ndarray, limits: np.ndarray,
                 eu: np.ndarray, ev: np.ndarray, weight_fn,
                 max_add: int | None = None) -> np.ndarray:
    """Add circuits one at a time to the heaviest addable pair.

    Genome-array form: `g0` is the (E,) circuit vector over the undirected
    pairs (eu, ev), `usage0` the per-pod ports already consumed outside the
    genome, and `weight_fn(g) -> (E,)` the current per-pair weights (-inf
    marks pairs a strategy never fills).  Each step is one vectorized
    argmax instead of a Python scan over pairs."""
    g = g0.copy()
    usage = usage0.copy()
    np.add.at(usage, eu, g)
    np.add.at(usage, ev, g)
    added = 0
    while max_add is None or added < max_add:
        addable = (usage[eu] < limits[eu]) & (usage[ev] < limits[ev])
        w = np.where(addable, weight_fn(g), -INF)
        e = int(np.argmax(w))
        if not np.isfinite(w[e]):
            break
        g[e] += 1
        usage[eu[e]] += 1
        usage[ev[e]] += 1
        added += 1
    return g


def _candidate_genomes(dag: CommDAG, g0: np.ndarray, usage0: np.ndarray,
                       limits: np.ndarray, eu: np.ndarray, ev: np.ndarray,
                       rng: np.random.Generator,
                       num_random: int = 8) -> np.ndarray:
    """Portfolio of boosted genomes within per-pod `limits`; row 0 is
    always `g0` itself, so the portfolio minimum can never be worse than
    the incumbent."""
    vol = dag.traffic_matrix()
    uvol = vol[eu, ev] + vol[ev, eu]
    cands = [g0.copy()]
    # (a) per-circuit volume: relieve the most oversubscribed pair first
    cands.append(_greedy_fill(g0, usage0, limits, eu, ev,
                              lambda g: uvol / np.maximum(g, 1)))
    # (b) concentrated: everything to the single heaviest pair
    hot = np.where(np.arange(len(eu)) == int(np.argmax(uvol)), 1.0, -INF)
    cands.append(_greedy_fill(g0, usage0, limits, eu, ev, lambda g: hot))
    # (c) round-robin: spread evenly (least-loaded pair first)
    cands.append(_greedy_fill(g0, usage0, limits, eu, ev,
                              lambda g: -g.astype(np.float64)))
    # (d) randomized greedy fills
    for _ in range(num_random):
        jitter = rng.random(len(eu))
        cands.append(_greedy_fill(g0, usage0, limits, eu, ev,
                                  lambda g: jitter * uvol / np.maximum(g, 1)))
    G = np.stack(cands)
    # vectorized dedup, keeping first occurrences (incumbent stays row 0)
    _, first = np.unique(G, axis=0, return_index=True)
    return G[np.sort(first)]


def _scatter(g: np.ndarray, eu: np.ndarray, ev: np.ndarray,
             P: int) -> np.ndarray:
    x = np.zeros((P, P), dtype=np.int64)
    x[eu, ev] = g
    x[ev, eu] = g
    return x


def _genome_view(x0: np.ndarray, pairs, P: int):
    """Split a topology into (eu, ev, genome, rem): the active-pair circuit
    vector plus the off-pair remainder `rem` (circuits on pairs without
    traffic, preserved verbatim through candidate generation)."""
    eu, ev = _edge_arrays(pairs)
    g0 = np.asarray(x0)[eu, ev].astype(np.int64)
    rem = np.asarray(x0) - _scatter(g0, eu, ev, P)
    return eu, ev, g0, rem


def candidate_boosts(dag: CommDAG, x0: np.ndarray, limits: np.ndarray,
                     rng: np.random.Generator,
                     num_random: int = 8) -> np.ndarray:
    """Portfolio of boosted topologies within per-pod `limits` (matrix
    view of `_candidate_genomes`; candidate 0 is always `x0`)."""
    pairs = dag.undirected_pairs()
    if not pairs:
        return np.asarray(x0)[None].copy()
    P = dag.cluster.num_pods
    eu, ev, g0, rem = _genome_view(x0, pairs, P)
    G = _candidate_genomes(dag, g0, rem.sum(axis=1),
                           np.asarray(limits, np.int64),
                           eu, ev, rng, num_random=num_random)
    return np.stack([_scatter(g, eu, ev, P) + rem for g in G])


# ------------------------------------------------------------- reallocation
@dataclass
class ReallocResult:
    x: np.ndarray
    makespan: float
    comm_time: float
    nct: float
    improved: bool
    num_candidates: int
    batch_calls: int = 1
    details: dict = field(default_factory=dict)


def reallocate(dag: CommDAG, x0: np.ndarray, boosted_limits: np.ndarray,
               ideal_comm_time: float, des=None,
               rng: np.random.Generator | None = None,
               num_random: int = 8,
               base_makespan: float | None = None,
               base_comm_time: float | None = None,
               mask: np.ndarray | None = None,
               dwell_s: float | None = None,
               reconfig_s_per_circuit: float = 0.0) -> ReallocResult:
    """Re-optimize one tenant's topology under boosted port limits.

    All candidate genomes are scored by a single fused
    `JaxDES.batch_genome_makespan` call; the winner is certified with the
    exact numpy DES and only accepted if it does not worsen the tenant's
    communication time.
    Pass `base_makespan`/`base_comm_time` (the incumbent's known exact
    quality, e.g. from the committed plan) to skip re-simulating `x0`.
    With `mask` (a (P, P) fabric availability factor), every evaluation --
    batch scoring, base and certification sims -- runs at degraded
    capacity, so grants to a tenant on a damaged fabric are priced against
    the fabric it actually has.
    With `dwell_s` (the tenant's expected remaining phase dwell) and a
    positive `reconfig_s_per_circuit`, an improving winner must also clear
    the reconfiguration break-even: the comm time it saves over the dwell,
    `dwell_s * (1 - comm_new / comm_base)`, must cover the rewiring delay
    `changed_circuits * reconfig_s_per_circuit` -- otherwise the boost is
    declined (`details["rejected"] = "break_even"`).
    """

    def _sim(x):
        xe = np.asarray(x, dtype=np.float64)
        return simulate(problem, xe * mask if mask is not None else xe)

    rng = rng or np.random.default_rng(0)
    problem = DESProblem(dag)
    pairs = dag.undirected_pairs()
    if not pairs:
        if base_makespan is None or base_comm_time is None:
            base = _sim(x0)
            base_makespan, base_comm_time = base.makespan, base.comm_time
        nct = base_comm_time / ideal_comm_time if ideal_comm_time > 0 else INF
        return ReallocResult(x=np.asarray(x0).copy(), makespan=base_makespan,
                             comm_time=base_comm_time, nct=nct,
                             improved=False, num_candidates=1, batch_calls=0)
    P = dag.cluster.num_pods
    eu, ev, g0, rem = _genome_view(x0, pairs, P)
    G = _candidate_genomes(dag, g0, rem.sum(axis=1),
                           np.asarray(boosted_limits, dtype=np.int64),
                           eu, ev, rng, num_random=num_random)
    if des is None:
        # reallocation runs inside the fleet's replanning loop: a
        # compile-bucket miss here recompiles XLA per surplus pass, so
        # surface it (the bucketed cache makes it a one-off per shape)
        from repro.core.des_jax import DESOptions, JaxDES
        des = JaxDES(problem, options=DESOptions(warn_on_miss=True))
    # ONE fused genome-scatter + vmap call over the whole portfolio
    ms, feas = des.batch_genome_makespan(G, eu, ev, mask=mask)
    score = np.where(feas, ms, INF)
    # lexicographic tie-break: fewer total ports on ~equal makespan
    ports = 2 * G.sum(axis=1) + int(rem.sum())
    finite = score[np.isfinite(score)]
    ref = float(finite.min()) if len(finite) and finite.min() > 0 else 1.0
    rel = np.where(np.isfinite(score), np.round(score / ref, 6), INF)
    best = int(np.lexsort((ports, rel))[0])

    if base_makespan is None or base_comm_time is None:
        base = _sim(x0)
        base_makespan, base_comm_time = base.makespan, base.comm_time
    makespan, comm_time = base_makespan, base_comm_time
    x_best = _scatter(G[best], eu, ev, P) + rem
    details = {"scores_finite": int(np.isfinite(score).sum())}
    if best != 0:
        cand = _sim(x_best)                       # certify the winner
        accept = cand.feasible \
            and cand.comm_time <= base_comm_time * (1 + 1e-9)
        if accept and dwell_s is not None and reconfig_s_per_circuit > 0:
            # break-even gate: rewiring for the boost must pay for itself
            # within the tenant's expected remaining dwell
            delay = circuit_changes(x_best, x0) * reconfig_s_per_circuit
            if np.isfinite(base_comm_time) and base_comm_time > 0 \
                    and np.isfinite(cand.comm_time):
                saved = dwell_s * (1.0 - cand.comm_time / base_comm_time)
            else:
                saved = INF
            if saved < delay:
                accept = False
                details["rejected"] = "break_even"
                details["delay_s"] = float(delay)
                details["saved_s"] = float(saved)
        if accept:
            makespan, comm_time = cand.makespan, cand.comm_time
        else:
            best = 0                              # never worsen the tenant
            x_best = _scatter(G[0], eu, ev, P) + rem
    nct = comm_time / ideal_comm_time if ideal_comm_time > 0 else INF
    return ReallocResult(
        x=x_best, makespan=makespan, comm_time=comm_time,
        nct=nct, improved=best != 0, num_candidates=len(G),
        details=details)
