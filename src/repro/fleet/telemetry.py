"""Telemetry stream + online estimators for the fleet control plane.

The planner's FastReChain-style break-even (reconfigure only when the
phase dwell amortizes the reconfiguration delay) previously priced every
decision against a hardcoded ``dwell_s = 600.0``.  This module turns that
constant into a *prior* (`DEFAULT_DWELL_S`) behind two measurement-driven
estimators:

  `DwellEstimator`    EWMA over observed phase dwell times, seeded by the
                      prior; ``expected_remaining`` is ``max(ewma,
                      elapsed)`` -- phase dwells are heavy-tailed, so the
                      longer a phase has already run, the longer it is
                      expected to keep running.
  `DriftEstimator`    leaky integrator of the observed per-pair rate
                      matrix (dt-weighted, decay timescale `tau_s`); over
                      a few schedule periods the integral's shape
                      converges to the iteration's *volume* shape, so
                      drift against a planned DAG is the total-variation
                      distance between normalized shapes (0 = traffic
                      matches the plan, 1 = disjoint support).  Window
                      rates alone cannot be compared to the plan: the
                      schedule moves pairs in bursts, so any single
                      window looks nothing like the volume matrix.

`synthesize_telemetry` manufactures the stream the estimators consume --
`TelemetrySample` / `PhaseTransition` events (see `repro.fleet.events`)
derived from the exact DES rate trace of a (dag, topology) pair -- which
is both the test harness and the METTEOR-style trace-replay path: a
recorded journal of these events re-drives a controller bit-identically
(`repro.fleet.control.ControlPlane.replay`).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.dag import CommDAG
from repro.core.des import DESProblem, DESResult, simulate
from repro.fleet.events import PhaseTransition, TelemetrySample

__all__ = ["DEFAULT_DWELL_S", "DwellEstimator", "DriftEstimator",
           "traffic_drift", "synthesize_telemetry"]

INF = float("inf")

# The single source of the phase-dwell prior: how long a tenant is assumed
# to keep its current traffic pattern when no dwell has been measured yet.
# `AdmissionController.repair`/`change` and `FleetPlanner` default to it;
# the control plane replaces it with the per-tenant EWMA estimate.
DEFAULT_DWELL_S = 600.0


# -------------------------------------------------------------- estimators
@dataclass
class DwellEstimator:
    """EWMA of observed phase dwell times for one tenant.

    `observe_transition(t, phase)` closes the currently-open phase (if the
    label changed) and folds its dwell into the EWMA; before any closed
    dwell the estimate is the prior.  The first observation replaces the
    prior outright (the prior carries no evidence worth averaging in).
    """

    prior_s: float = DEFAULT_DWELL_S
    alpha: float = 0.3
    _ewma: float | None = field(default=None, repr=False)
    _count: int = field(default=0, repr=False)
    _phase: str | None = field(default=None, repr=False)
    _since: float | None = field(default=None, repr=False)

    @property
    def phase(self) -> str | None:
        """The currently-open phase label (None before any transition)."""
        return self._phase

    @property
    def count(self) -> int:
        """Closed dwells folded into the EWMA so far."""
        return self._count

    def observe_transition(self, t: float, phase: str) -> float | None:
        """Record a phase marker; returns the dwell it closed (or None)."""
        t = float(t)
        closed = None
        if self._phase is not None and phase != self._phase:
            closed = max(t - float(self._since), 0.0)
            self._ewma = closed if self._ewma is None else \
                (1.0 - self.alpha) * self._ewma + self.alpha * closed
            self._count += 1
        if self._phase != phase:
            self._phase = phase
            self._since = t
        return closed

    def estimate(self) -> float:
        return self.prior_s if self._ewma is None else self._ewma

    def elapsed(self, now: float) -> float:
        if self._since is None:
            return 0.0
        return max(float(now) - self._since, 0.0)

    def expected_remaining(self, now: float) -> float:
        """Expected remaining dwell of the open phase at time `now`."""
        return max(self.estimate(), self.elapsed(now))


def traffic_drift(observed: np.ndarray, expected: np.ndarray) -> float:
    """Total-variation distance between two traffic shapes in [0, 1].

    Both matrices are normalized to unit mass first, so only the *shape*
    of the traffic matters, not its magnitude (observed rates are bytes/s,
    planned volumes are bytes).  Zero-mass inputs carry no signal and
    report zero drift.
    """
    a = np.asarray(observed, dtype=np.float64)
    b = np.asarray(expected, dtype=np.float64)
    sa, sb = float(a.sum()), float(b.sum())
    if sa <= 0.0 or sb <= 0.0:
        return 0.0
    return 0.5 * float(np.abs(a / sa - b / sb).sum())


@dataclass
class DriftEstimator:
    """Leaky time-integral of one tenant's observed rate matrix.

    `observe(rates, dt)` folds one telemetry window in as `rates * dt`
    after decaying the running integral by `exp(-dt / tau_s)`.  With
    `tau_s` spanning a few schedule periods the integral's *shape*
    converges to the per-iteration volume shape (what
    `CommDAG.traffic_matrix` predicts), so within-phase drift sits near
    zero even under heavy rate noise, while a real phase change pulls it
    toward the TV distance between the phases' volume shapes within a
    couple of `tau_s`.  That gap is the signal the controller's
    confirm-ticks hysteresis builds on.
    """

    tau_s: float = 5.0
    _acc: np.ndarray | None = field(default=None, repr=False)

    def observe(self, rates, dt: float = 1.0) -> np.ndarray:
        r = np.asarray(rates, dtype=np.float64) * float(dt)
        self._acc = r.copy() if self._acc is None else \
            self._acc * float(np.exp(-float(dt) / self.tau_s)) + r
        return self._acc

    def drift(self, expected: np.ndarray) -> float:
        """TV drift of the integrated observation vs a planned shape."""
        if self._acc is None:
            return 0.0
        return traffic_drift(self._acc, expected)


# ------------------------------------------------------- stream synthesis
def _freeze(mat: np.ndarray) -> tuple[tuple[float, ...], ...]:
    return tuple(tuple(float(v) for v in row) for row in np.asarray(mat))


def synthesize_telemetry(dag: CommDAG, x: np.ndarray, *, tenant: str,
                         phase: str | None = None, t0: float = 0.0,
                         iterations: int = 1,
                         result: DESResult | None = None,
                         mask: np.ndarray | None = None,
                         noise: float = 0.0,
                         rng: np.random.Generator | None = None) -> list:
    """Manufacture the telemetry a tenant running `dag` on topology `x`
    would emit: one `PhaseTransition` marker at `t0` (when `phase` is
    given) followed by one `TelemetrySample` per DES rate interval, tiled
    over `iterations` training iterations.

    Rates come from the exact fair-share DES rate trace (optionally under
    a fabric `mask`); queue depths are the per-pair bytes still unmoved at
    each window start.  `noise` adds multiplicative Gaussian jitter to the
    *reported* rates (the ground-truth transfer accounting stays exact),
    which is how the hysteresis tests stress the drift estimator.
    """
    from repro.obs.timeline import interval_rate_matrices
    problem = DESProblem(dag)
    if result is None:
        xe = np.asarray(x, dtype=np.float64)
        result = simulate(problem, xe * mask if mask is not None else xe,
                          record_rates=True)
    if not result.feasible or not np.isfinite(result.makespan):
        raise ValueError("cannot synthesize telemetry from an infeasible "
                         "schedule")
    if not result.rate_trace:
        raise ValueError("synthesize_telemetry needs a rate trace; "
                         "simulate with record_rates=True")
    mats = interval_rate_matrices(problem, result)
    vol = dag.traffic_matrix()
    if noise > 0.0 and rng is None:
        rng = np.random.default_rng(0)

    events: list = []
    if phase is not None:
        events.append(PhaseTransition(t=float(t0), tenant=tenant,
                                      phase=phase))
    period = float(result.makespan)
    for it in range(int(iterations)):
        base = float(t0) + it * period
        moved = np.zeros_like(vol)
        for s0, s1, mat in mats:
            dt = s1 - s0
            if dt <= 0.0:
                continue
            queues = np.maximum(vol - moved, 0.0)
            reported = mat
            if noise > 0.0:
                jitter = 1.0 + noise * rng.standard_normal(mat.shape)
                reported = np.maximum(mat * jitter, 0.0)
            events.append(TelemetrySample(
                t=base + s0, tenant=tenant, dt=float(dt),
                rates=_freeze(reported), queues=_freeze(queues),
                phase=phase))
            moved += mat * dt
    return events
