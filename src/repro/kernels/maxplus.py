"""Pallas TPU kernel: tropical (max, +) matrix product.

Longest-path propagation over the dependency DAG (EST/LCT windows of
Algs. 1/4) is a max-plus matrix product; repeated squaring of the adjacency
matrix (diagonal = 0, missing edge = NEG_INF) yields all-pairs longest
paths in ceil(log2 n) products.

The MXU cannot evaluate a (max, +) semiring, so this kernel targets the VPU:
for each (BM, BN) output tile we stream (BM, BK) x (BK, BN) operand tiles
through VMEM and unroll the small K-chunk as rank-1 broadcast max-adds.
BK is kept small (8) so the (BM, BK, BN) broadcast intermediate stays in
registers/VMEM (128*8*128 f32 = 512 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import NEG_INF


def _maxplus_kernel(a_ref, b_ref, out_ref, *, nsteps_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, NEG_INF)

    a = a_ref[...]           # (BM, BK)
    b = b_ref[...]           # (BK, BN)
    cand = jnp.max(a[:, :, None] + b[None, :, :], axis=1)
    out_ref[...] = jnp.maximum(out_ref[...], cand)
    del nsteps_k


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def maxplus(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
            bk: int = 8, interpret: bool = False) -> jax.Array:
    """out[i, j] = max_k (a[i, k] + b[k, j]); NEG_INF encodes 'no path'."""
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb, "inner dimensions must match"
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    mp = max(((m + bm - 1) // bm) * bm, bm)
    np_ = max(((n + bn - 1) // bn) * bn, bn)
    kp = max(((ka + bk - 1) // bk) * bk, bk)
    a = jnp.pad(a, ((0, mp - m), (0, kp - ka)), constant_values=NEG_INF)
    b = jnp.pad(b, ((0, kp - kb), (0, np_ - n)), constant_values=NEG_INF)
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_maxplus_kernel, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a, b)
    return jnp.maximum(out[:m, :n], NEG_INF)
