"""Jitted public wrappers for repro.kernels.

Backend selection: the Pallas kernels target TPU; on CPU the pure-jnp
oracles from ref.py are used (Pallas interpret mode is a correctness tool,
not a performance path).  Pass backend='pallas' to force the kernels
(tests do this with interpret=True on CPU).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import maxplus as _maxplus_k
from repro.kernels import ref as _ref
from repro.kernels import tclosure as _tclosure_k
from repro.kernels import waterfill as _waterfill_k

NEG_INF = _ref.NEG_INF


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick(backend: str | None) -> str:
    if backend in ("pallas", "ref"):
        return backend
    return "pallas" if _on_tpu() else "ref"


def tclosure_step(a, *, backend: str | None = None,
                  interpret: bool | None = None):
    if _pick(backend) == "pallas":
        return _tclosure_k.tclosure_step(
            a, interpret=bool(interpret if interpret is not None
                              else not _on_tpu()))
    return _ref.tclosure_step_ref(jnp.asarray(a))


def transitive_closure(a, *, backend: str | None = None,
                       interpret: bool | None = None):
    """Full boolean transitive closure by repeated squaring (host loop with
    early fixed-point exit -- this is offline planning code)."""
    a = jnp.asarray(a).astype(jnp.bool_)
    n = a.shape[0]
    for _ in range(max(1, math.ceil(math.log2(max(n, 2))))):
        nxt = tclosure_step(a, backend=backend, interpret=interpret)
        if bool((nxt == a).all()):
            return nxt
        a = nxt
    return a


def maxplus(a, b, *, backend: str | None = None,
            interpret: bool | None = None):
    if _pick(backend) == "pallas":
        return _maxplus_k.maxplus(
            a, b, interpret=bool(interpret if interpret is not None
                                 else not _on_tpu()))
    return _ref.maxplus_ref(jnp.asarray(a), jnp.asarray(b))


def longest_paths(adj, *, backend: str | None = None,
                  interpret: bool | None = None):
    """All-pairs longest path of a weighted DAG adjacency matrix.

    adj[i, j] = edge weight, NEG_INF when no edge.  Diagonal is forced to 0
    (empty path).  Repeated max-plus squaring, host loop with fixed point.
    """
    a = jnp.asarray(adj).astype(jnp.float32)
    n = a.shape[0]
    eye = jnp.where(jnp.eye(n, dtype=bool), 0.0, NEG_INF)
    d = jnp.maximum(a, eye)
    for _ in range(max(1, math.ceil(math.log2(max(n, 2))))):
        nxt = maxplus(d, d, backend=backend, interpret=interpret)
        nxt = jnp.maximum(nxt, NEG_INF)
        if bool(jnp.allclose(nxt, d)):
            return nxt
        d = nxt
    return d


def fill_matvec(w, rhs, *, backend: str | None = None,
                interpret: bool | None = None):
    if _pick(backend) == "pallas":
        return _waterfill_k.fill_matvec(
            w, rhs, interpret=bool(interpret if interpret is not None
                                   else not _on_tpu()))
    return _ref.fill_matvec_ref(jnp.asarray(w), jnp.asarray(rhs))


def fill_round(w, level, unfrozen, *, backend: str | None = None,
               interpret: bool | None = None):
    """One DES max-min filling round: per-constraint (used, denom) from one
    fused pass over the incidence matrix (the `repro.core.des_jax._maxmin`
    inner reduction; called once per saturation level of every event)."""
    if _pick(backend) == "pallas":
        return _waterfill_k.fill_round(
            w, level, unfrozen,
            interpret=bool(interpret if interpret is not None
                           else not _on_tpu()))
    return _ref.fill_round_ref(jnp.asarray(w), jnp.asarray(level),
                               jnp.asarray(unfrozen))
