"""Pure-jnp oracles for every Pallas kernel in repro.kernels.

These define the semantics; the Pallas kernels must match them bit-for-bit
(boolean ops) or to float tolerance (max-plus / matvec) across the shape and
dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30  # finite stand-in for -inf in max-plus (keeps f32 MXU-safe)


def tclosure_step_ref(a: jnp.ndarray) -> jnp.ndarray:
    """One squaring step of boolean transitive closure: A | (A @ A > 0)."""
    a = a.astype(jnp.bool_)
    f = a.astype(jnp.float32)
    return a | (f @ f > 0.5)


def transitive_closure_ref(a: jnp.ndarray, max_steps: int | None = None
                           ) -> jnp.ndarray:
    """Full closure by repeated squaring (host loop; offline planning code)."""
    import math
    a = a.astype(jnp.bool_)
    n = a.shape[0]
    steps = max_steps if max_steps is not None else max(
        1, math.ceil(math.log2(max(n, 2))))
    for _ in range(steps):
        nxt = tclosure_step_ref(a)
        if bool((nxt == a).all()):
            return nxt
        a = nxt
    return a


def maxplus_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Tropical (max, +) matrix product: out[i,j] = max_k a[i,k] + b[k,j].

    Entries <= NEG_INF are treated as 'no edge'.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    out = jnp.max(a[:, :, None] + b[None, :, :], axis=1)
    return jnp.maximum(out, NEG_INF)


def fill_matvec_ref(w: jnp.ndarray, rhs: jnp.ndarray) -> jnp.ndarray:
    """Fused water-filling matvec pair: one pass over the incidence matrix.

    w:   (C, N) constraint-task incidence weights
    rhs: (N, R) stacked right-hand sides (R=2: [phi*active, unfrozen_w])
    returns (C, R) = w @ rhs in float32.
    """
    return w.astype(jnp.float32) @ rhs.astype(jnp.float32)


def fill_round_ref(w: jnp.ndarray, level: jnp.ndarray,
                   unfrozen: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One DES fair-share filling round: per-constraint (used, denom)."""
    out = fill_matvec_ref(w, jnp.stack([level, unfrozen], axis=1))
    return out[:, 0], out[:, 1]
