"""Pallas TPU kernel: one squaring step of boolean transitive closure.

Alg. 2 of the paper computes the transitive closure of the dependency set D
"via matrix squaring" -- on TPU that is an MXU-shaped computation: the
OR-AND boolean semiring product A (x) A is a saturating f32 matmul followed
by a threshold, fused here with the final OR against A itself.

Tiling: (BM, BK) x (BK, BN) f32 tiles in VMEM, k innermost in the grid with
a VMEM accumulator in the output block (classic revisiting-matmul pattern).
128x128x128 tiles align with the MXU systolic array; the f32 dot counts
paths exactly for n <= 2^24, far above any DAG we build.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tclosure_kernel(a_ref, b_ref, adiag_ref, out_ref, *, nsteps_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = out_ref[...] + jnp.dot(a_ref[...], b_ref[...],
                                 preferred_element_type=jnp.float32)
    out_ref[...] = acc

    @pl.when(k == nsteps_k - 1)
    def _finish():
        # fuse the OR with A: reach-in-(<=2)-hops = A | (A @ A > 0)
        out_ref[...] = ((out_ref[...] > 0.5) | (adiag_ref[...] > 0.5)
                        ).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def tclosure_step(a: jax.Array, *, bm: int = 128, bn: int = 128,
                  bk: int = 128, interpret: bool = False) -> jax.Array:
    """A | (A @ A > 0) for a square boolean/0-1 matrix A (padded inside)."""
    n = a.shape[0]
    assert a.shape == (n, n), "tclosure_step expects a square matrix"
    f = a.astype(jnp.float32)
    npad = max(((n + 127) // 128) * 128, 128)
    if npad != n:
        f = jnp.pad(f, ((0, npad - n), (0, npad - n)))
    bm, bn, bk = min(bm, npad), min(bn, npad), min(bk, npad)
    grid = (npad // bm, npad // bn, npad // bk)

    out = pl.pallas_call(
        functools.partial(_tclosure_kernel, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, npad), jnp.float32),
        interpret=interpret,
    )(f, f, f)
    return out[:n, :n] > 0.5
