"""Pallas TPU kernel: fused water-filling matvec pair.

One progressive-filling round of the max-min fair-share computation (DES
inner loop) needs, per constraint c:

    used_c  = sum_m W[c, m] * (phi_m * active_m)
    denom_c = sum_m W[c, m] * unfrozen_m

Both are matvecs against the same incidence matrix W.  A matvec on the MXU
wastes 127/128 lanes, so we stack the two right-hand sides into an (N, R)
matrix padded to R=128 lanes: the extra lanes are free (the systolic array
processes 128 lanes regardless), and W -- the bandwidth-dominant operand --
is streamed through VMEM exactly once for both reductions.

`fill_round` is the per-event DES layout of the same kernel: it takes the
two per-task vectors of one filling round (active flow levels, unfrozen
mask) and returns the per-constraint `(used, denom)` pair.  The DES event
loop (`repro.core.des_jax._maxmin`) calls it once per filling round; it is
vmap-safe (batched over GA populations and ensemble members) and runs in
interpret mode off-TPU, where `repro.kernels.ref.fill_round_ref` is the
production fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _fill_kernel(w_ref, rhs_ref, out_ref, *, nsteps_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(w_ref[...], rhs_ref[...],
                            preferred_element_type=jnp.float32)
    del nsteps_k


@functools.partial(jax.jit, static_argnames=("bc", "bk", "interpret"))
def fill_matvec(w: jax.Array, rhs: jax.Array, *, bc: int = 128,
                bk: int = 128, interpret: bool = False) -> jax.Array:
    """(C, N) @ (N, R) -> (C, R) with R padded to the 128-lane MXU width."""
    c, n = w.shape
    n2, r = rhs.shape
    assert n == n2 and r <= LANES
    w = w.astype(jnp.float32)
    rhs = rhs.astype(jnp.float32)
    cp = max(((c + bc - 1) // bc) * bc, bc)
    np_ = max(((n + bk - 1) // bk) * bk, bk)
    w = jnp.pad(w, ((0, cp - c), (0, np_ - n)))
    rhs = jnp.pad(rhs, ((0, np_ - n), (0, LANES - r)))
    grid = (cp // bc, np_ // bk)

    out = pl.pallas_call(
        functools.partial(_fill_kernel, nsteps_k=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, LANES), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bc, LANES), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, LANES), jnp.float32),
        interpret=interpret,
    )(w, rhs)
    return out[:c, :r]


def fill_round(w: jax.Array, level: jax.Array, unfrozen: jax.Array, *,
               interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """One DES fair-share filling round: per-constraint (used, denom).

    w:        (C, N) constraint-task incidence weights
    level:    (N,)   current active flow levels (phi * active)
    unfrozen: (N,)   unfrozen-task mask (float)
    Both reductions share one pass over `w` (stacked 2-lane RHS).
    """
    rhs = jnp.stack([level, unfrozen], axis=1)
    out = fill_matvec(w, rhs, interpret=interpret)
    return out[:, 0], out[:, 1]
