import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell with ShapeDtypeStruct inputs (no allocation) and record the roofline
raw material: memory_analysis(), cost_analysis() and per-kind collective
bytes parsed from the compiled (post-SPMD, per-device) HLO.

The two lines above MUST stay the first statements in this module: jax
locks the device count at first initialization.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
        --shape train_4k --mesh single --quick
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (REGISTRY, SHAPES, ArchSpec, ModelConfig,  # noqa: E402
                           ShapeSpec, shape_applicable)
from repro.distributed import sharding as shd                        # noqa: E402
from repro.launch import hloanalysis                                 # noqa: E402
from repro.launch.mesh import make_production_mesh                   # noqa: E402
from repro.models import model as M                                  # noqa: E402
from repro.training import optimizer as opt                          # noqa: E402
from repro.training import train_step as ts                          # noqa: E402

BIG_PARAMS = 100e9          # >=: bf16 optimizer moments (see DESIGN.md)
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1}


def _xkv_len(cfg: ModelConfig) -> int:
    if cfg.encoder_layers:
        return cfg.enc_tokens
    if cfg.cross_attn_every:
        return cfg.num_image_tokens
    return 0


def input_specs(arch: ArchSpec, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = arch.config
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    xl = _xkv_len(cfg)
    if shape.kind == "train":
        specs = {"tokens": f((B, S), jnp.int32),
                 "labels": f((B, S), jnp.int32)}
        if xl:
            specs["xkv"] = f((B, xl, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": f((B, S), jnp.int32)}
        if xl:
            specs["xkv"] = f((B, xl, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token against a KV cache of length seq_len
    return {"tokens": f((B, 1), jnp.int32)}


def _state_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.total_params() >= BIG_PARAMS else jnp.float32


def _abstract_state(cfg: ModelConfig):
    ocfg = opt.AdamWConfig(state_dtype=_state_dtype(cfg))
    key = jax.random.PRNGKey(0)
    state = jax.eval_shape(
        lambda: ts.init_train_state(cfg, ocfg, key, dtype=jnp.bfloat16))
    return state, ocfg


def _abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(
        lambda: M.init_params(cfg, key, dtype=jnp.bfloat16))


def _abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: M.init_cache(cfg, batch, max_len, dtype=jnp.bfloat16,
                             enc_len=_xkv_len(cfg)))


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum per-device result bytes of every collective op in the HLO."""
    out = {k: 0.0 for k in COLLECTIVES}
    out["count"] = 0
    shape_re = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        for kind in COLLECTIVES:
            if f" {kind}(" not in line and f" {kind}-start(" not in line:
                continue
            lhs = line.split("=", 1)
            if len(lhs) != 2:
                continue
            # result type(s): everything between '=' and the op name
            rhs = lhs[1]
            cut = rhs.find(kind)
            for m in shape_re.finditer(rhs[:cut]):
                dt, dims = m.group(1), m.group(2)
                size = _DTYPE_BYTES.get(dt)
                if size is None:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                out[kind] += n * size
            out["count"] += 1
            break
    return out


def run_cell(arch_name: str, arch: ArchSpec, shape: ShapeSpec,
             mesh, mesh_name: str, accum_steps: int = 0) -> dict:
    cfg = arch.config
    t0 = time.time()
    cell = {"arch": arch_name, "shape": shape.name, "mesh": mesh_name,
            "kind": shape.kind}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        cell.update(status="skipped", reason=why)
        return cell

    specs = input_specs(arch, shape)
    has_xkv = "xkv" in specs
    batch_sh = shd.named(
        jax.tree.map(lambda s: shd.batch_spec(s.shape, mesh), specs), mesh)

    if shape.kind == "train":
        state, ocfg = _abstract_state(cfg)
        state_specs = shd.tree_specs(state, mesh, "state", cfg=cfg)
        state_sh = shd.named(state_specs, mesh)
        if accum_steps == 0:  # auto microbatching: 1 seq/device for the
            # huge archs (activation pressure), 2 otherwise
            dsz = 1
            for a in shd.data_axes(mesh):
                dsz *= mesh.shape[a]
            target = 1 if cfg.total_params() >= BIG_PARAMS else 2
            accum_steps = max(1, shape.global_batch // (dsz * target))
        cell["accum_steps"] = accum_steps
        step_fn = ts.make_train_step(cfg, ocfg, accum_steps=accum_steps,
                                     remat=True, has_xkv=has_xkv,
                                     mesh=mesh,
                                     data_axes=shd.data_axes(mesh))
        jfn = jax.jit(step_fn,
                      in_shardings=(state_sh, batch_sh),
                      out_shardings=(state_sh, None),
                      donate_argnums=(0,))
        args = (state, specs)
    else:
        params = _abstract_params(cfg)
        param_sh = shd.named(shd.tree_specs(params, mesh, "params",
                                            cfg=cfg), mesh)
        cache = _abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cache_sh = shd.named(shd.tree_specs(cache, mesh, "cache"), mesh)
        if shape.kind == "prefill":
            fn = ts.make_prefill_step(cfg, has_xkv=has_xkv)
            jfn = jax.jit(
                fn, in_shardings=(param_sh, cache_sh,
                                  batch_sh["tokens"]) +
                ((batch_sh["xkv"],) if has_xkv else ()),
                donate_argnums=(1,))
            args = (params, cache, specs["tokens"]) + \
                ((specs["xkv"],) if has_xkv else ())
        else:
            fn = ts.make_decode_step(cfg)
            jfn = jax.jit(fn,
                          in_shardings=(param_sh, cache_sh,
                                        batch_sh["tokens"]),
                          donate_argnums=(1,))
            args = (params, cache, specs["tokens"])

    # sharding hints: always pin activations to batch sharding at layer
    # boundaries; additionally sequence-shard attention (Ulysses-style)
    # for archs whose head count does not divide the model axis
    from repro.models.layers import sharding_hints
    msize = mesh.shape["model"]
    seq_shard = bool(cfg.heads % msize) and shape.kind != "decode"
    # sequence-parallel layer boundaries: measured win for large non-SSM
    # archs (grok: memory term halved); regression for SSM/hybrid (the
    # chunked SSD scan fights the seq resharding) and for small dense
    # archs (collective term tripled on yi-6b) -- see EXPERIMENTS.md §Perf
    seq_parallel = shape.kind == "train" and (
        (cfg.family in ("dense", "moe")
         and cfg.total_params() >= BIG_PARAMS)
        or seq_shard)   # pairs well with Ulysses attention (qwen2.5)
    hints = sharding_hints(mesh, shd.data_axes(mesh), seq_shard=seq_shard,
                           seq_parallel=seq_parallel)
    cell["seq_shard_attention"] = seq_shard
    cell["seq_parallel"] = seq_parallel
    try:
        with hints:
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    except Exception as exc:   # noqa: BLE001
        cell.update(status="error", error=f"{type(exc).__name__}: {exc}",
                    trace=traceback.format_exc()[-2000:])
        return cell

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax < 0.5 returns [dict]
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    cost = hloanalysis.analyze(txt)   # trip-count-aware per-device totals
    cell.update(
        status="ok",
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                ma, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
        },
        flops_per_device=cost.flops,
        bytes_per_device=cost.bytes,
        collectives={**cost.collective_bytes,
                     "count": cost.collective_count,
                     "total": cost.total_collective_bytes},
        xla_raw={"flops": ca.get("flops"),
                 "bytes_accessed": ca.get("bytes accessed"),
                 "transcendentals": ca.get("transcendentals")},
        hlo_bytes=len(txt),
        params_total=cfg.total_params(),
        params_active=cfg.total_active_params(),
        tokens=(specs["tokens"].shape[0] * specs["tokens"].shape[1]),
        devices=int(mesh.size),
    )
    return cell


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--accum-steps", type=int, default=0,
                    help="0 = auto (~2 sequences/device/microstep)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="smoke: reduced configs, small shapes")
    args = ap.parse_args()

    archs = list(REGISTRY) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    results = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "multi_pod_2x16x16" if multi else "single_pod_16x16"
        for a in archs:
            arch = REGISTRY[a]
            if args.quick:
                import dataclasses
                arch = dataclasses.replace(arch,
                                           config=arch.config.reduced())
            for s in shapes:
                shape = SHAPES[s]
                if args.quick:
                    import dataclasses
                    shape = dataclasses.replace(
                        shape, seq_len=min(shape.seq_len, 256),
                        global_batch=min(shape.global_batch, 32))
                fname = os.path.join(args.out,
                                     f"{mesh_name}__{a}__{s}.json")
                if args.skip_existing and os.path.exists(fname):
                    print(f"[skip existing] {fname}")
                    continue
                t0 = time.time()
                cell = run_cell(a, arch, shape, mesh, mesh_name,
                                accum_steps=args.accum_steps)
                cell["wall_s"] = round(time.time() - t0, 2)
                with open(fname, "w") as f:
                    json.dump(cell, f, indent=1)
                stat = cell["status"]
                extra = ""
                if stat == "ok":
                    mem = cell["memory"]
                    per_dev = (mem["argument_bytes"] or 0) / mesh.size
                    extra = (f" args={per_dev/2**30:.2f}GiB/dev "
                             f"flops/dev={cell['flops_per_device']:.3g} "
                             f"coll={cell['collectives']['count']}")
                elif stat == "error":
                    extra = " " + cell["error"][:120]
                elif stat == "skipped":
                    extra = " " + cell["reason"]
                print(f"[{stat:7s}] {mesh_name} {a} {s} "
                      f"({cell['wall_s']}s){extra}", flush=True)
                results.append(cell)
    bad = [c for c in results if c["status"] == "error"]
    print(f"\n{len(results)} cells: "
          f"{sum(c['status'] == 'ok' for c in results)} ok, "
          f"{sum(c['status'] == 'skipped' for c in results)} skipped, "
          f"{len(bad)} errors")
    if bad:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
