"""Computation-aware cost analysis of compiled (post-SPMD) HLO text.

`compiled.cost_analysis()` counts every while-loop body exactly once, which
under-counts scanned layer stacks by the trip count (verified empirically:
a 7-trip scan reports 1x body flops).  This module re-derives per-device
totals by parsing the HLO text into computations and multiplying the cost
of every while body by its statically known trip count (jax scans lower to
`iv < constant` loops starting at 0, so the constant in the condition
computation is the trip count).

Accounting model per computation:
  flops  -- 2 * prod(result dims) * prod(contracting dims) per `dot`
            (+ recursion into fusion called computations, nested whiles
            multiplied by their trips).  Elementwise flops are ignored --
            matmuls dominate LM workloads; the XLA raw number is kept
            alongside for reference.
  bytes  -- sum over top-level op lines of (result + operand) bytes,
            treating fusions as single reads of their params and writes of
            their root (a post-fusion HBM traffic model); control ops
            (tuple plumbing, parameters, constants) are skipped.
  coll   -- per-kind collective result bytes (all-gather / all-reduce /
            reduce-scatter / all-to-all / collective-permute), multiplied
            through loop trips like everything else.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2,
               "f8e4m3fn": 1, "f8e5m2": 1,
               "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}
_CONTROL_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "partition-id", "replica-id"}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        sz = DTYPE_BYTES.get(dt)
        if sz is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * sz
    return total


@dataclass
class _Op:
    name: str
    result_type: str
    opcode: str
    rest: str
    operands: list[str] = field(default_factory=list)


@dataclass
class _Comp:
    name: str
    is_entry: bool
    ops: list[_Op] = field(default_factory=list)
    shapes: dict[str, str] = field(default_factory=dict)


def _parse_operands(rest: str) -> tuple[list[str], str]:
    """Operand names inside the first balanced paren group of `rest`."""
    depth = 1
    end = len(rest) - 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args = rest[:end]
    out = re.findall(r"%([\w.\-]+)", args)
    return out, rest[end + 1:]


def parse_computations(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.rstrip().endswith("{"):
                cur = _Comp(name=m.group(2), is_entry=bool(m.group(1)))
                # register header-declared parameter shapes (real as_text
                # repeats them as op lines, but be robust either way)
                for pm in re.finditer(r"(\w[\w.\-]*):\s*([a-z0-9]+\[[0-9,]*\])",
                                      line):
                    cur.shapes.setdefault(pm.group(1), pm.group(2))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OPLINE_RE.match(line)
        if not m:
            continue
        name, rtype, opcode, rest = m.groups()
        operands, tail = _parse_operands(rest)
        op = _Op(name=name, result_type=rtype, opcode=opcode,
                 rest=rest, operands=operands)
        op.tail = tail  # type: ignore[attr-defined]
        cur.ops.append(op)
        cur.shapes[name] = rtype
    return comps


def _dot_flops(op: _Op, shapes: dict[str, str]) -> float:
    result_elems = 0
    for m in _SHAPE_RE.finditer(op.result_type):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        result_elems += n
    lhs = op.operands[0] if op.operands else None
    contract = 1
    mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest + getattr(
        op, "tail", ""))
    if mm and lhs and lhs in shapes:
        lshape = _SHAPE_RE.search(shapes[lhs])
        if lshape:
            dims = [int(d) for d in lshape.group(2).split(",") if d]
            for idx in mm.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * result_elems * contract


def _cond_trip_count(comps: dict[str, _Comp], cond_name: str) -> int:
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    consts = []
    for op in comp.ops:
        if op.opcode == "constant":
            mm = re.search(r"constant\((\-?\d+)\)", "constant(" + op.rest)
            if mm:
                consts.append(int(mm.group(1)))
        mm = re.search(r"constant\((\-?\d+)\)", op.rest)
        if mm:
            consts.append(int(mm.group(1)))
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else 1


@dataclass
class HLOCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = field(default_factory=lambda: {
        k: 0.0 for k in COLLECTIVES})
    collective_count: float = 0.0

    def scaled(self, k: float) -> "HLOCost":
        return HLOCost(self.flops * k, self.bytes * k,
                       {kk: v * k for kk, v in self.collective_bytes.items()},
                       self.collective_count * k)

    def add(self, other: "HLOCost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v
        self.collective_count += other.collective_count

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _analyze_comp(comps: dict[str, _Comp], name: str,
                  memo: dict[str, HLOCost], stack: set[str]) -> HLOCost:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    cost = HLOCost()
    if comp is None or name in stack:
        return cost
    stack = stack | {name}
    for op in comp.ops:
        full = op.rest + getattr(op, "tail", "")
        if op.opcode == "dot":
            cost.flops += _dot_flops(op, comp.shapes)
            cost.bytes += _shape_bytes(op.result_type)
            for o in op.operands:
                cost.bytes += _shape_bytes(comp.shapes.get(o, ""))
        elif op.opcode == "while":
            mb = re.search(r"body=%?([\w.\-]+)", full)
            mc = re.search(r"condition=%?([\w.\-]+)", full)
            trips = _cond_trip_count(comps, mc.group(1)) if mc else 1
            if mb:
                body = _analyze_comp(comps, mb.group(1), memo, stack)
                cost.add(body.scaled(trips))
        elif op.opcode == "fusion":
            mcall = re.search(r"calls=%?([\w.\-]+)", full)
            if mcall:
                inner = _analyze_comp(comps, mcall.group(1), memo, stack)
                # flops/collectives from inside; bytes = fusion boundary
                cost.flops += inner.flops
                for k, v in inner.collective_bytes.items():
                    cost.collective_bytes[k] += v
                cost.collective_count += inner.collective_count
            cost.bytes += _shape_bytes(op.result_type)
            for o in op.operands:
                cost.bytes += _shape_bytes(comp.shapes.get(o, ""))
        elif op.opcode in ("call", "conditional", "async-start"):
            for mcall in re.finditer(
                    r"(?:to_apply|calls|branch_computations=\{?)=?%?"
                    r"([\w.\-]+)", full):
                inner = _analyze_comp(comps, mcall.group(1), memo, stack)
                cost.add(inner)
        else:
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES:
                cost.collective_bytes[base] += _shape_bytes(op.result_type)
                cost.collective_count += 1
                cost.bytes += _shape_bytes(op.result_type)
            elif op.opcode.endswith("-done"):
                pass
            elif op.opcode not in _CONTROL_OPS:
                cost.bytes += _shape_bytes(op.result_type)
                for o in op.operands:
                    cost.bytes += _shape_bytes(comp.shapes.get(o, ""))
    memo[name] = cost
    return cost


def analyze(text: str) -> HLOCost:
    """Per-device cost of a compiled HLO module (trip-count aware)."""
    comps = parse_computations(text)
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:   # pragma: no cover - defensive
        return HLOCost()
    memo: dict[str, HLOCost] = {}
    # fusion-called computations must not double count: analyze from entry
    return _analyze_comp(comps, entry, memo, set())
