"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any import;
everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Best-effort mesh over whatever devices exist (examples / tests)."""
    n = jax.device_count()
    mp = max(1, min(model_parallel, n))
    dp = n // mp
    return jax.make_mesh(
        (dp, mp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
