"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any import;
everything else sees the real device count.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(num_axes: int) -> dict:
    """`axis_types` only exists on newer jax; older versions are implicitly
    Auto everywhere, so omitting it is semantically identical."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Best-effort mesh over whatever devices exist (examples / tests)."""
    n = jax.device_count()
    mp = max(1, min(model_parallel, n))
    dp = n // mp
    return jax.make_mesh((dp, mp), ("data", "model"),
                         **_axis_type_kwargs(2))
