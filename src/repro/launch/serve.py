"""Batched decode serving driver (prefill + autoregressive loop).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduce \
        --batch 4 --prompt-len 64 --decode-steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.training import train_step as ts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = REGISTRY[args.arch].config
    if args.reduce:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.model_parallel)
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key, dtype=jnp.float32)
    params = jax.device_put(
        params, shd.named(shd.tree_specs(params, mesh, "params", cfg=cfg),
                          mesh))
    max_len = args.prompt_len + args.decode_steps
    xl = cfg.enc_tokens if cfg.encoder_layers else cfg.num_image_tokens
    cache = M.init_cache(cfg, args.batch, max_len, dtype=jnp.float32,
                         enc_len=xl)
    cache = jax.device_put(
        cache, shd.named(shd.tree_specs(cache, mesh, "cache"), mesh))

    has_xkv = bool(xl)
    prefill = jax.jit(ts.make_prefill_step(cfg, has_xkv=has_xkv),
                      donate_argnums=(1,))
    decode = jax.jit(ts.make_decode_step(cfg), donate_argnums=(1,))

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    xkv = (jax.random.normal(key, (args.batch, xl, cfg.d_model),
                             jnp.float32) if has_xkv else None)
    t0 = time.time()
    if has_xkv:
        logits, cache = prefill(params, cache, prompt, xkv)
    else:
        logits, cache = prefill(params, cache, prompt)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True).astype(jnp.int32)

    t0 = time.time()
    out = [tok]
    for _ in range(args.decode_steps):
        tok, logits, cache = decode(params, cache, tok)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    total_tok = args.batch * args.decode_steps
    print(f"[serve] {cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.0f}ms; decoded {total_tok} tokens in "
          f"{t_decode*1e3:.0f}ms "
          f"({total_tok/max(t_decode,1e-9):.1f} tok/s)")
    seq = jnp.concatenate(out, axis=1)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    print("[serve] sample token ids:", seq[0, :16].tolist())


if __name__ == "__main__":
    main()
