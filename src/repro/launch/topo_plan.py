"""DELTA topology planning CLI -- the control-plane entry point.

    PYTHONPATH=src python -m repro.launch.topo_plan --arch deepseek-671b \
        --bandwidth 400 --methods prop-alloc,iter-halve,delta-fast \
        --microbatches 32 --port-min --out plan.json

Prints per-method NCT / makespan / port usage and (optionally) writes the
chosen logical topology matrix for the OCS controller.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import ALL_ARCHS, make_job
from repro.core.api import METHODS, PlanRequest, plan
from repro.core.ga import GAOptions
from repro.core.milp import MILPOptions
from repro.core.schedule import build_comm_dag


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gpt-7b", choices=sorted(ALL_ARCHS))
    ap.add_argument("--bandwidth", type=float, default=400.0,
                    help="inter-pod Gb/s per GPU")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = the workload's configured count")
    ap.add_argument("--methods", default="prop-alloc,sqrt-alloc,iter-halve,"
                                         "delta-fast")
    ap.add_argument("--port-min", action="store_true")
    ap.add_argument("--time-limit", type=float, default=300.0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    arch = ALL_ARCHS[args.arch]
    job = make_job(arch, seq_len=args.seq,
                   microbatches=args.microbatches or None)
    dag = build_comm_dag(job, inter_pod_gbps=args.bandwidth)
    s = dag.summary()
    ep_note = (f", {s['ep_volume_fraction']:.0%} EP all-to-all"
               if s["ep_volume_fraction"] > 0 else "")
    print(f"[plan] {args.arch}: tp={job.tp} pp={job.pp} dp={job.dp} "
          f"ep={job.ep} mb={job.num_microbatches} -> {s['num_tasks']} "
          f"inter-pod tasks, {s['num_pods']} pods, "
          f"{s['total_volume_gb']:.1f} GB/iteration{ep_note}")

    methods = [m.strip() for m in args.methods.split(",") if m.strip()]
    bad = set(methods) - set(METHODS)
    if bad:
        raise SystemExit(f"unknown methods: {bad}")
    results = {}
    for m in methods:
        r = plan(PlanRequest(
            dag=dag, method=m, port_min=args.port_min,
            ga_options=GAOptions(time_limit=args.time_limit / 2),
            milp_options=MILPOptions(time_limit=args.time_limit,
                                     port_min=args.port_min)))
        results[m] = r
        print(f"[plan] {m:22s} NCT={r.nct:8.4f} "
              f"makespan={r.makespan*1e3:9.2f}ms ports={r.total_ports:4d} "
              f"t={r.elapsed:6.1f}s")

    best = min((r for r in results.values() if r.feasible),
               key=lambda r: (r.nct, r.total_ports))
    print(f"[plan] selected: {best.method}")
    if args.out:
        payload = {
            "arch": args.arch, "bandwidth_gbps": args.bandwidth,
            "method": best.method, "nct": best.nct,
            "total_ports": best.total_ports,
            "topology": np.asarray(best.x).tolist(),
            "all": {m: {"nct": r.nct, "ports": r.total_ports,
                        "makespan": r.makespan}
                    for m, r in results.items()},
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[plan] wrote {args.out}")


if __name__ == "__main__":
    main()
