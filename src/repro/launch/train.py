"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduce \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised here (all testable on CPU):
  * DELTA topology planning before launch (--plan-topology): builds the
    job's inter-pod DAG from the arch's parallelism plan and prints the
    planned OCS circuits + NCT vs the traffic-matrix baselines.
  * fault tolerance: periodic checkpoints, --simulate-failure N injects a
    crash at step N and the driver restores + replays deterministically.
  * straggler watchdog, gradient-norm logging, optional int8 gradient
    compression demo (--grad-compression, single-process shard_map).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, make_job
from repro.distributed import sharding as shd
from repro.distributed.fault_tolerance import (FailureInjector, StepWatchdog,
                                               run_resilient)
from repro.launch.mesh import make_host_mesh
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training import train_step as ts
from repro.training.data import SyntheticLM


def plan_topology(arch_name: str, seq_len: int) -> None:
    from repro.core.api import compare
    from repro.core.schedule import build_comm_dag
    arch = REGISTRY[arch_name]
    job = make_job(arch, seq_len=seq_len,
                   microbatches=min(arch.plan.num_microbatches, 2 * arch.plan.pp))
    dag = build_comm_dag(job)
    print(f"[topo] job {job.name}: {dag.num_real_tasks} inter-pod tasks, "
          f"{dag.cluster.num_pods} pods")
    res = compare(dag, methods=("prop-alloc", "iter-halve", "delta-fast"))
    for m, r in res.items():
        print(f"[topo] {m:12s} NCT={r.nct:7.4f} ports={r.total_ports:4d} "
              f"({r.elapsed:.1f}s)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduce", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=-1)
    ap.add_argument("--plan-topology", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.plan_topology:
        plan_topology(args.arch, args.seq)

    cfg = REGISTRY[args.arch].config
    if args.reduce:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.model_parallel)
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5))
    key = jax.random.PRNGKey(args.seed)
    dtype = jnp.float32  # CPU-friendly
    state = ts.init_train_state(cfg, ocfg, key, dtype=dtype)
    state_sh = shd.named(shd.tree_specs(state, mesh, "state", cfg=cfg), mesh)
    state = jax.device_put(state, state_sh)
    step_fn = jax.jit(
        ts.make_train_step(cfg, ocfg, accum_steps=args.accum,
                           remat=False,
                           mesh=mesh, data_axes=shd.data_axes(mesh)),
        donate_argnums=(0,))
    data = SyntheticLM(vocab=cfg.vocab, seed=args.seed)

    start_step = 0
    if args.resume and args.ckpt_dir:
        latest = ckpt.latest(args.ckpt_dir)
        if latest:
            state, start_step, _ = ckpt.restore(latest, state)
            print(f"[train] resumed from {latest} at step {start_step}")

    injector = FailureInjector(
        fail_at=(args.simulate_failure,) if args.simulate_failure >= 0
        else ())
    watchdog = StepWatchdog()
    box = {"state": state, "losses": []}

    def do_step(step: int) -> dict:
        injector.maybe_fail(step)
        batch = data.batch(step, args.batch, args.seq)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        box["state"], metrics = step_fn(box["state"], batch)
        loss = float(metrics["loss"])
        box["losses"].append(loss)
        if step % args.log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        return {"loss": loss}

    def save_ckpt(step: int) -> None:
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, step, box["state"])

    def restore_ckpt() -> int:
        latest = ckpt.latest(args.ckpt_dir)
        if not latest:
            return 0
        box["state"], step, _ = ckpt.restore(latest, box["state"])
        print(f"[train] restored {latest} -> step {step}")
        return step

    t0 = time.time()
    summary = run_resilient(args.steps, do_step, save_ckpt, restore_ckpt,
                            ckpt_every=args.ckpt_every,
                            watchdog=watchdog)
    dt = time.time() - t0
    losses = box["losses"]
    first = float(np.mean(losses[:10])) if len(losses) >= 10 else losses[0]
    last = float(np.mean(losses[-10:]))
    print(f"[train] done: {summary['steps']} steps in {dt:.1f}s "
          f"({summary['restarts']} restarts, "
          f"{summary['stragglers']} stragglers) "
          f"loss {first:.4f} -> {last:.4f}")
    if last >= first:
        print("[train] WARNING: loss did not improve")


if __name__ == "__main__":
    main()
