"""Model-zoo primitives: RMSNorm, RoPE, GQA flash attention, SwiGLU,
sort-based MoE dispatch, Mamba-2 SSD (chunked scan + recurrent step).

Everything is a pure function over explicit parameter pytrees so the same
code lowers under pjit for the dry-run meshes and runs eagerly for the CPU
smoke tests.  Softmax/normalization statistics accumulate in float32.

`attention_hints` installs an optional Ulysses-style sequence-sharding
constraint for architectures whose head count does not divide the model
axis (qwen2.5: 40 heads, whisper: 20 heads on a 16-way axis): q/k/v are
constrained to sequence sharding before the score einsums (GSPMD inserts
cheap all-to-alls) so the scores stay device-local instead of being
all-reduced.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = dict[str, Any]

_ATTN_HINTS: contextvars.ContextVar[dict | None] = \
    contextvars.ContextVar("attn_hints", default=None)


@contextlib.contextmanager
def sharding_hints(mesh, data_axes: tuple[str, ...],
                   model_axes: tuple[str, ...] = ("model",),
                   seq_shard: bool = False, seq_parallel: bool = False):
    """Install sharding hints for tracing.

    batch pinning (always): activations keep the batch dim on the data axes
    at layer/MoE boundaries.
    seq_shard: Ulysses-style q/k/v sequence sharding inside attention (for
    head counts that do not divide the model axis).
    seq_parallel: Megatron-SP-style sequence sharding of the *layer
    boundary* activations over the model axis -- shrinks the remat carry
    stack by the model-axis size.
    """
    token = _ATTN_HINTS.set({"mesh": mesh, "data": data_axes,
                             "model": model_axes, "seq_shard": seq_shard,
                             "seq_parallel": seq_parallel})
    try:
        yield
    finally:
        _ATTN_HINTS.reset(token)


# backwards-compatible alias
attention_hints = sharding_hints


def constrain_batch(x: jax.Array, boundary: bool = False) -> jax.Array:
    """Pin (B, ...) activations to batch sharding over the data axes.

    Without this, GSPMD may resolve FSDP weight contractions by
    *replicating* the batch and all-reducing partial sums -- observed on
    jamba/grok as full-microbatch f32 activations per device (16x memory)
    and hundreds of GB of score all-reduces.

    boundary=True additionally sequence-shards dim 1 over the model axes
    when seq_parallel is enabled (layer-boundary activations only).
    """
    hints = _ATTN_HINTS.get()
    if hints is None or x.ndim < 2:
        return x
    mesh = hints["mesh"]
    data = hints["data"]
    dsize = 1
    for a in data:
        dsize *= mesh.shape[a]
    if dsize <= 1 or x.shape[0] % dsize:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    rest: list = [None] * (x.ndim - 1)
    if boundary and hints.get("seq_parallel") and x.ndim >= 3:
        model = hints["model"]
        msize = 1
        for a in model:
            msize *= mesh.shape[a]
        if msize > 1 and x.shape[1] % msize == 0 and x.shape[1] >= msize:
            rest[0] = model if len(model) > 1 else model[0]
    spec = P(data if len(data) > 1 else data[0], *rest)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _seq_shard(x: jax.Array) -> jax.Array:
    """Constrain (B, S, heads, hd) to (data, model, None, None)."""
    hints = _ATTN_HINTS.get()
    if hints is None or not hints["seq_shard"] or x.ndim != 4:
        return x
    mesh = hints["mesh"]
    model = hints["model"]
    msize = 1
    for a in model:
        msize *= mesh.shape[a]
    if x.shape[1] % msize or x.shape[1] < msize:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = P(hints["data"], model if len(model) > 1 else model[0],
             None, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ------------------------------------------------------------------ norms
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention
DENSE_ATTN_MAX_KV = 8192   # use dense masked attention up to this KV length


def _expand_kv(k: jax.Array, heads: int) -> jax.Array:
    """Repeat KV heads up to `heads` (GQA).

    The expanded form keeps every attention einsum free of the
    (B,S,KV,G,hd) reshape, which GSPMD cannot re-shard when the flat head
    dim is model-sharded but neither KV nor G alone is divisible
    (observed: involuntary full rematerialization + per-layer score
    all-reduces).  Under sharding the repeat materializes only the local
    head shard.
    """
    KV = k.shape[2]
    if KV == heads:
        return k
    return jnp.repeat(k, heads // KV, axis=2)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool, q_offset=0) -> jax.Array:
    """Masked-softmax attention (training path).

    Differentiable with O(S^2) transient only -- under the per-group remat
    policy one layer's score matrix lives at a time.  The flash variant is
    used for prefill/long-KV paths, which are forward-only (a scan-based
    flash kernel would otherwise stash its per-chunk probabilities as
    autodiff residuals and negate the memory saving).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    q = _seq_shard(q)
    ke = _seq_shard(_expand_kv(k, H))
    ve = _seq_shard(_expand_kv(v, H))
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqhd,bshd->bhqs", q, ke,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = q_offset + jnp.arange(Sq)
        mask = jnp.arange(Sk)[None, :] <= q_pos[:, None]
        s = jnp.where(mask[None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", p, ve)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool, q_offset: int = 0,
                    kv_block: int = 1024) -> jax.Array:
    """Streaming-softmax attention with GQA.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd).  Scans KV blocks so the
    (Sq x Sk) score matrix never materializes (32k prefill stays in VMEM-
    friendly tiles).  f32 running max/sum.  KV heads are expanded to H
    (see _expand_kv) so the einsums have no sharded contractions.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    q = _seq_shard(q)
    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32) * scale
    nblk = -(-Sk // kv_block)
    pad = nblk * kv_block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, kv_block, KV, hd)
    vb = v.reshape(B, nblk, kv_block, KV, hd)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, start = blk
        kc = _expand_kv(kc, H)        # per-chunk expansion keeps kv small
        vc = _expand_kv(vc, H)
        s = jnp.einsum("bqhd,bchd->bhqc", qf, kc.astype(jnp.float32))
        kv_pos = start + jnp.arange(kv_block)
        mask = kv_pos[None, :] < Sk
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask[None, None, :, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqc,bchd->bhqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    starts = jnp.arange(nblk) * kv_block
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4), starts))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array) -> jax.Array:
    """Single-step attention against a (possibly padded) KV cache.

    q: (B, 1, H, hd); k, v: (B, Smax, KV, hd); kv_len: valid prefix length.
    """
    B, _, H, hd = q.shape
    ke = _expand_kv(k, H)
    ve = _expand_kv(v, H)
    scale = 1.0 / math.sqrt(hd)
    qf = q[:, 0].astype(jnp.float32) * scale          # (B, H, hd)
    s = jnp.einsum("bhd,bshd->bhs", qf, ke.astype(jnp.float32))
    mask = jnp.arange(k.shape[1])[None, :] < kv_len
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, ve.astype(jnp.float32))
    return out[:, None].astype(q.dtype)


# ----------------------------------------------------------- attn wrapper
def init_attention(key, cfg: ModelConfig, cross: bool = False,
                   dtype=jnp.bfloat16) -> Params:
    d, hd = cfg.d_model, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p: Params = {
        "wq": jax.random.normal(k1, (d, cfg.heads, hd), dtype) * std,
        "wk": jax.random.normal(k2, (d, cfg.kv_heads, hd), dtype) * std,
        "wv": jax.random.normal(k3, (d, cfg.kv_heads, hd), dtype) * std,
        "wo": jax.random.normal(k4, (cfg.heads, hd, d), dtype) * std,
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.kv_heads, hd), dtype)
    if cfg.qk_norm and not cross:
        p["qn"] = jnp.ones((hd,), dtype)
        p["kn"] = jnp.ones((hd,), dtype)
    return p


def attention_block(p: Params, cfg: ModelConfig, x: jax.Array,
                    positions: jax.Array, causal: bool = True,
                    cache: Params | None = None,
                    kv_source: jax.Array | None = None,
                    use_rope: bool = True):
    """Self- or cross-attention.  Returns (out, new_cache)."""
    src = kv_source if kv_source is not None else x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if "qn" in p:
        q = rmsnorm(q, p["qn"], cfg.norm_eps)
        k = rmsnorm(k, p["kn"], cfg.norm_eps)
    if use_rope and kv_source is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None and kv_source is None:
        # write this call's K/V at position kv_len
        idx = cache["len"]
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, 1)
        new_cache = {"k": ck, "v": cv, "len": idx + x.shape[1]}
        if x.shape[1] == 1:
            out = decode_attention(q, ck, cv, idx + x.shape[1])
        else:
            # prefill: attend within this call's K/V (cache starts empty)
            out = flash_attention(q, k, v, causal=causal, q_offset=idx)
    elif k.shape[1] <= DENSE_ATTN_MAX_KV:
        out = dense_attention(q, k, v, causal=causal and kv_source is None)
    else:
        out = flash_attention(q, k, v, causal=causal and kv_source is None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ------------------------------------------------------------------ mlps
def init_mlp(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    std = 1.0 / math.sqrt(d)
    return {"wi": jax.random.normal(k1, (d, f), dtype) * std,
            "wg": jax.random.normal(k2, (d, f), dtype) * std,
            "wo": jax.random.normal(k3, (f, d), dtype) * std}


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    return h @ p["wo"]


def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    return {"router": jax.random.normal(k0, (d, e), jnp.float32) * std,
            "wi": jax.random.normal(k1, (e, d, f), dtype) * std,
            "wg": jax.random.normal(k2, (e, d, f), dtype) * std,
            "wo": jax.random.normal(k3, (e, f, d), dtype) * std}


def moe_block(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Top-k MoE with *per-sequence* sort-based dispatch.

    Routing groups = sequences (GShard-style): the argsort/bincount run per
    sequence and therefore stay local to the batch shard under data
    parallelism -- a global token sort would force GSPMD to all-gather the
    whole (T, D) activation (observed: +150 GiB/device temp on jamba).
    Flop-honest: compute is E * C * d * f with
    C = ceil(S * topk / E * cfg.moe_capacity).
    """
    capacity_factor = cfg.moe_capacity
    x = constrain_batch(x)   # sorts/scatters below defeat propagation
    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    C = int(max(1, math.ceil(S * K / E * capacity_factor)))

    def route_one(xs: jax.Array) -> jax.Array:       # (S, D) -> (S, D)
        logits = xs.astype(jnp.float32) @ p["router"]
        gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), K)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        flat_e = idx.reshape(S * K)
        order = jnp.argsort(flat_e)                  # stable, local
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(S * K) - starts[sorted_e]
        keep = rank < C
        buf_slot = jnp.where(keep, sorted_e * C + rank, E * C)  # drop bin
        tok = order // K
        xbuf = jnp.zeros((E * C + 1, D), xs.dtype).at[buf_slot].set(xs[tok])
        xe = xbuf[:-1].reshape(E, C, D)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) * \
            jnp.einsum("ecd,edf->ecf", xe, p["wi"])
        ye = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * C, D)
        ye = jnp.concatenate([ye, jnp.zeros((1, D), ye.dtype)], axis=0)
        contrib = ye[buf_slot] * gates.reshape(S * K)[order][:, None] \
            .astype(ye.dtype) * keep[:, None]
        return jnp.zeros((S, D), xs.dtype).at[tok].add(contrib)

    return constrain_batch(jax.vmap(route_one)(x))


# ----------------------------------------------------------------- mamba2
def init_mamba(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * n
    k1, k2, k3 = jax.random.split(key, 3)
    std = 1.0 / math.sqrt(d)
    return {
        "in_proj": jax.random.normal(
            k1, (d, 2 * d_in + 2 * n + nh), dtype) * std,
        "conv_w": jax.random.normal(k2, (cfg.ssm_conv, conv_dim),
                                    dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": jax.random.normal(k3, (d_in, d), dtype) * std,
    }


def _mamba_split(p: Params, cfg: ModelConfig, x: jax.Array):
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n], axis=-1)
    return z, xbc, dt, d_in, n, nh


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv, kernel K.  state: (B, K-1, C) rolling window."""
    K = w.shape[0]
    if state is not None:
        ctx = jnp.concatenate([state, xbc], axis=1)
        new_state = ctx[:, -(K - 1):, :] if K > 1 else state
    else:
        ctx = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = ctx[:, -(K - 1):, :] if K > 1 else None
    out = sum(ctx[:, i:i + xbc.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b), new_state


def mamba_block(p: Params, cfg: ModelConfig, x: jax.Array,
                cache: Params | None = None, chunk: int = 128):
    """Mamba-2 SSD block.  Train/prefill: chunked scan; decode: recurrence.

    Returns (out, new_cache).
    """
    B, S, _ = x.shape
    z, xbc, dt, d_in, n, nh = _mamba_split(p, cfg, x)
    hd = cfg.ssm_head_dim
    A = -jnp.exp(p["A_log"])                                 # (nh,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)

    if cache is not None and S == 1:
        xbc_conv, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                            cache["conv"])
        xs, Bm, Cm = jnp.split(xbc_conv, [d_in, d_in + n], axis=-1)
        xh = xs.reshape(B, 1, nh, hd).astype(jnp.float32)
        dtb = dt[:, 0]                                       # (B, nh)
        da = jnp.exp(dtb * A)                                # (B, nh)
        bt = Bm[:, 0].astype(jnp.float32)                    # (B, n)
        ct = Cm[:, 0].astype(jnp.float32)
        ssm = cache["ssm"]                                   # (B,nh,hd,n)
        upd = (dtb[..., None] * xh[:, 0])[..., None] * bt[:, None, None, :]
        ssm = ssm * da[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", ssm, ct)[:, None]     # (B,1,nh,hd)
        new_cache = {"conv": conv_state, "ssm": ssm}
    else:
        xbc_conv, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xs, Bm, Cm = jnp.split(xbc_conv, [d_in, d_in + n], axis=-1)
        y, ssm_state = _ssd_chunked(
            xs.reshape(B, S, nh, hd).astype(jnp.float32),
            dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32), chunk)
        new_cache = None
        if cache is not None:  # prefill fills the cache
            new_cache = {"conv": conv_state, "ssm": ssm_state}
    yf = y.reshape(B, S, d_in).astype(x.dtype)
    out = rmsnorm(yf * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return out @ p["out_proj"], new_cache


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """State-space duality (Mamba-2): intra-chunk quadratic attention-like
    term + inter-chunk recurrent state passing.

    xh: (B,S,nh,hd) f32; dt: (B,S,nh); A: (nh,); Bm/Cm: (B,S,n).
    Returns y: (B,S,nh,hd), final_state: (B,nh,hd,n).
    """
    B, S, nh, hd = xh.shape
    n = Bm.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    L = chunk
    xc = xh.reshape(B, nc, L, nh, hd)
    dtc = dt.reshape(B, nc, L, nh)
    Bc = Bm.reshape(B, nc, L, n)
    Cc = Cm.reshape(B, nc, L, n)

    da = dtc * A                                   # log-decay per step
    cum = jnp.cumsum(da, axis=2)                   # (B,nc,L,nh)
    # intra-chunk: y_intra[t] = sum_{s<=t} exp(cum_t - cum_s) dt_s x_s B_s.C_t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,L,L,nh)
    tri = jnp.tril(jnp.ones((L, L), bool))
    # mask *before* exp: above-diagonal seg is positive and overflows, and
    # inf-through-where poisons gradients under fusion
    seg = jnp.where(tri[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)           # (B,nc,L,L)
    w = scores[..., None] * decay * dtc[:, :, None, :, :]    # (B,nc,L,L,nh)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", w, xc)

    # chunk-level states: S_c = sum_s exp(cum_L - cum_s) dt_s x_s B_s^T
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)               # (B,nc,L,nh)
    contrib = jnp.einsum("bclh,bclhp,bcln->bchpn",
                         dtc * dec_end, xc, Bc)              # per chunk
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,nc,nh)

    def scan_fn(s, inp):
        contrib_c, decay_c = inp
        s_new = s * decay_c[..., None, None] + contrib_c
        return s_new, s

    s0 = jnp.zeros((B, nh, hd, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, s0,
        (contrib.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev = prev_states.transpose(1, 0, 2, 3, 4)              # (B,nc,nh,hd,n)
    # inter-chunk: y_inter[t] = C_t . (exp(cum_t) * S_prev)
    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp",
                         Cc, prev, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, nc * L, nh, hd)
    return y[:, :S], final
