"""Generic decoder stack covering all assigned architecture families.

The layer pattern is periodic with period cfg.group_size (e.g. jamba:
7 mamba + 1 attention, MoE every 2nd layer -> period 8).  Parameters are
stored as one pytree per pattern position with leaves stacked over the
n_groups repetitions, and the stack executes as a `lax.scan` over groups --
keeping the lowered HLO compact at 72-layer/400B scale.

Families:
  dense / moe        causal GQA attention (+ optional MoE FFN)
  ssm                Mamba-2 SSD blocks, no attention
  hybrid             attention every cfg.attn_every layers (jamba)
  vlm                cross-attention to stubbed image embeddings
  encdec             bidirectional encoder + causal decoder w/ cross-attn
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = dict[str, Any]


# ------------------------------------------------------------------- init
def init_layer(cfg: ModelConfig, j: int, key, dtype=jnp.bfloat16) -> Params:
    """Parameters of pattern-position j (kind depends only on j)."""
    keys = jax.random.split(key, 4)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), dtype),
                 "ln2": jnp.ones((cfg.d_model,), dtype)}
    if cfg.is_attn_layer(j):
        p["attn"] = L.init_attention(keys[0], cfg, dtype=dtype)
    else:
        p["mamba"] = L.init_mamba(keys[0], cfg, dtype=dtype)
    if cfg.is_moe_layer(j):
        p["moe"] = L.init_moe(keys[1], cfg, dtype=dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = L.init_mlp(keys[1], cfg, dtype=dtype)
    if cfg.is_xattn_layer(j) or (cfg.encoder_layers and
                                 cfg.cross_attn_every == 1):
        p["lnx"] = jnp.ones((cfg.d_model,), dtype)
        p["xattn"] = L.init_attention(keys[2], cfg, cross=True, dtype=dtype)
    return p


def init_encoder_layer(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Params:
    keys = jax.random.split(key, 2)
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": L.init_attention(keys[0], cfg, dtype=dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": L.init_mlp(keys[1], cfg, dtype=dtype)}


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Params:
    g = cfg.group_size
    n_groups = cfg.layers // g
    if cfg.layers % g:
        raise ValueError(f"{cfg.name}: layers={cfg.layers} not divisible by "
                         f"pattern period {g}")
    k_embed, k_layers, k_head, k_enc = jax.random.split(key, 4)
    params: Params = {
        "embed": jax.random.normal(
            k_embed, (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "final_ln": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab), dtype) / math.sqrt(cfg.d_model)
    pos_keys = jax.random.split(k_layers, g)
    groups: list[Params] = []
    for j in range(g):
        gkeys = jax.random.split(pos_keys[j], n_groups)
        stacked = jax.vmap(
            lambda kk, jj=j: init_layer(cfg, jj, kk, dtype))(gkeys)
        groups.append(stacked)
    params["groups"] = tuple(groups)
    if cfg.encoder_layers:
        ekeys = jax.random.split(k_enc, cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda kk: init_encoder_layer(cfg, kk, dtype))(ekeys)
    return params


# ------------------------------------------------------------------ cache
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, enc_len: int = 0) -> Params:
    g = cfg.group_size
    n_groups = cfg.layers // g
    caches: list[Params] = []
    for j in range(g):
        if cfg.is_attn_layer(j):
            shape = (n_groups, batch, max_len, cfg.kv_heads, cfg.hd)
            caches.append({"k": jnp.zeros(shape, dtype),
                           "v": jnp.zeros(shape, dtype)})
        else:
            d_in = cfg.ssm_expand * cfg.d_model
            nh = d_in // cfg.ssm_head_dim
            conv_dim = d_in + 2 * cfg.ssm_state
            caches.append({
                "conv": jnp.zeros((n_groups, batch, cfg.ssm_conv - 1,
                                   conv_dim), dtype),
                "ssm": jnp.zeros((n_groups, batch, nh, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32)})
    cache: Params = {"pos": jnp.zeros((), jnp.int32),
                     "layers": tuple(caches)}
    if enc_len:
        cache["enc"] = jnp.zeros((batch, enc_len, cfg.d_model), dtype)
    return cache


# ---------------------------------------------------------------- forward
def _apply_layer(cfg: ModelConfig, j: int, p: Params, x: jax.Array,
                 positions, cache_j, xkv, pos_scalar):
    new_cache = cache_j
    if cfg.is_attn_layer(j):
        attn_cache = None
        if cache_j is not None:
            attn_cache = {"k": cache_j["k"], "v": cache_j["v"],
                          "len": pos_scalar}
        h, nc = L.attention_block(p["attn"], cfg, L.rmsnorm(x, p["ln1"],
                                                            cfg.norm_eps),
                                  positions, causal=True, cache=attn_cache)
        if nc is not None:
            new_cache = {"k": nc["k"], "v": nc["v"]}
        x = x + h
    else:
        mcache = None
        if cache_j is not None:
            mcache = {"conv": cache_j["conv"], "ssm": cache_j["ssm"]}
        h, nc = L.mamba_block(p["mamba"], cfg,
                              L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                              cache=mcache)
        if nc is not None:
            new_cache = {"conv": nc["conv"], "ssm": nc["ssm"]}
        x = x + h
    if "xattn" in p and xkv is not None:
        h, _ = L.attention_block(p["xattn"], cfg,
                                 L.rmsnorm(x, p["lnx"], cfg.norm_eps),
                                 positions, causal=False, kv_source=xkv)
        x = x + h
    if "moe" in p:
        x = x + L.moe_block(p["moe"], cfg,
                            L.rmsnorm(x, p["ln2"], cfg.norm_eps))
    elif "mlp" in p:
        x = x + L.swiglu(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps))
    return x, new_cache


def encode(cfg: ModelConfig, params: Params, enc_embeds: jax.Array,
           remat: bool = True) -> jax.Array:
    """Bidirectional encoder over stubbed frontend embeddings."""
    positions = jnp.arange(enc_embeds.shape[1])

    def body(x, p):
        h, _ = L.attention_block(p["attn"], cfg,
                                 L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                                 positions, causal=False)
        x = x + h
        x = x + L.swiglu(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, enc_embeds, params["encoder"])
    return x


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            xkv: jax.Array | None = None, cache: Params | None = None,
            remat: bool = False) -> tuple[jax.Array, Params | None]:
    """tokens (B, S) -> logits (B, S, V); updates cache when given.

    xkv: stubbed modality embeddings (image patches / encoder output) for
    vlm / encdec families.
    """
    B, S = tokens.shape
    x = params["embed"][tokens]
    if cache is not None:
        pos_scalar = cache["pos"]
        positions = pos_scalar + jnp.arange(S)
    else:
        pos_scalar = jnp.zeros((), jnp.int32)
        positions = jnp.arange(S)
    # modality source for cross-attention: encoder output (encdec) or raw
    # patch embeddings (vlm); cached at prefill so decode steps reuse it
    enc_cached = None
    if cache is not None and "enc" in cache:
        enc_cached = cache["enc"]
    if xkv is not None and cfg.encoder_layers:
        xkv = encode(cfg, params, xkv)
    if xkv is None:
        xkv = enc_cached

    g = cfg.group_size
    layer_caches = cache["layers"] if cache is not None else \
        tuple([None] * g)

    def group_body(x, xs):
        gparams, gcache = xs
        new_caches = []
        for j in range(g):
            cj = gcache[j] if gcache is not None else None

            def layer_fn(x_, p_, c_, j_=j):
                # layer boundary: batch on data axes (+ optional SP)
                x_ = L.constrain_batch(x_, boundary=True)
                return _apply_layer(cfg, j_, p_, x_, positions, c_, xkv,
                                    pos_scalar)

            if remat and g > 1:
                # per-layer remat inside the group: otherwise one group's
                # backward materializes all `g` layers' intermediates at
                # once (observed: 185 GiB/device on jamba's 8-layer period)
                layer_fn = jax.checkpoint(layer_fn)
            x, nc = layer_fn(x, gparams[j], cj)
            new_caches.append(nc)
        return x, tuple(new_caches)

    body = jax.checkpoint(group_body) if remat else group_body
    if cache is not None:
        x, new_layer_caches = jax.lax.scan(
            body, x, (params["groups"], layer_caches))
        new_cache = {"pos": pos_scalar + S, "layers": new_layer_caches}
        if xkv is not None and (cfg.cross_attn_every or cfg.encoder_layers):
            new_cache["enc"] = xkv
    else:
        x, _ = jax.lax.scan(body, x, (params["groups"],
                                      tuple([None] * g)))
        new_cache = None
    x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = x @ head
    return logits, new_cache


def loss_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
            labels: jax.Array, xkv: jax.Array | None = None,
            remat: bool = False) -> jax.Array:
    logits, _ = forward(cfg, params, tokens, xkv=xkv, remat=remat)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    return jnp.mean(logz - gold)
