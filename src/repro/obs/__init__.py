"""repro.obs -- tracing, metrics, schedule timelines, and the fleet journal.

The observability substrate for the online control plane (ROADMAP:
"planner as a service") and for every perf PR's measurement needs:

  metrics   counters/gauges/histograms with labels, JSON snapshot +
            Prometheus text exposition, planner-scoped deltas
  tracing   nestable spans over the hot seams (GA generations, DES
            compile/simulate, MILP phases, fleet decisions), Chrome-trace
            export, near-zero cost when disabled (the default)
  timeline  DES schedule -> Perfetto-viewable trace with per-link tracks
            + the critical-path / per-task-slack report
  journal   structured JSONL log of fleet events + decisions, replayable
  logs      one ``repro.``-hierarchy logging setup (no bare prints)

Quick start::

    from repro import obs
    obs.TRACER.enable()
    ... run a plan ...
    print(obs.TRACER.summary())            # where did the time go
    print(obs.REGISTRY.render_prometheus())   # scrapeable counters
"""
from repro.obs.journal import FleetJournal, rebuild_event, serialize_event
from repro.obs.logs import get_logger, setup_logging
from repro.obs.metrics import (REGISTRY, Counter, Gauge, Histogram,
                               MetricsRegistry, RegistryScope, get_counter,
                               get_gauge, get_histogram)
from repro.obs.timeline import (plane_rewire_timeline, schedule_timeline,
                                slack_report, task_slack, validate_trace,
                                write_trace)
from repro.obs.tracing import TRACER, SpanRecord, Tracer, enabled, span

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "RegistryScope",
    "REGISTRY", "get_counter", "get_gauge", "get_histogram",
    "Tracer", "TRACER", "SpanRecord", "span", "enabled",
    "plane_rewire_timeline", "schedule_timeline", "slack_report",
    "task_slack", "validate_trace", "write_trace",
    "FleetJournal", "serialize_event", "rebuild_event",
    "get_logger", "setup_logging",
]
