"""Structured, replayable event journal for the fleet control plane.

Every `FleetPlanner.handle()` call appends one entry: the incoming event
(serialized well enough to reconstruct it), the decision record the planner
produced, and a monotonically increasing sequence number.  The journal is

  * **structured**: entries are plain dicts, JSONL on disk (one entry per
    line, append-only -- the persisted-plan-state shape an online planner
    restarts from);
  * **replayable**: `load()` reads entries back and `rebuild_events()`
    turns them into live `FleetEvent` objects (JobSpec round-trips through
    its dataclass fields), so a journal can re-drive a fresh planner;
  * cheap: in-memory by default, file-backed when given a path.

This is deliberately NOT a metrics stream (see `repro.obs.metrics`): the
journal answers "what did the planner decide, in order, and why", metrics
answer "how much / how fast".
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import threading

__all__ = ["FleetJournal", "serialize_event", "rebuild_event",
           "serialize_dag", "rebuild_dag", "serialize_plan", "rebuild_plan"]


def _jobspec_to_dict(job) -> dict:
    return dataclasses.asdict(job)


def _jobspec_from_dict(data: dict):
    from repro.core.traffic import JobSpec
    kw = dict(data)
    for f in dataclasses.fields(JobSpec):
        # JSON round-trips tuples as lists; restore tuple-typed fields
        if f.name in kw and isinstance(kw[f.name], list):
            kw[f.name] = tuple(kw[f.name])
    return JobSpec(**kw)


def serialize_event(event) -> dict:
    """FleetEvent -> JSON-safe dict (kind + reconstruction fields)."""
    from repro.fleet.loop import (LinkFailure, LinkRecovery, JobArrival,
                                  JobDeparture, PlaneFailure, PlaneRecovery,
                                  PortFailure, PortRecovery, TrafficChange)
    if isinstance(event, JobArrival):
        return {"kind": "arrival", "name": event.name,
                "job": _jobspec_to_dict(event.job),
                "reverse_stages": event.reverse_stages,
                "port_min": event.port_min,
                "donate_surplus": event.donate_surplus,
                "base_pod": event.base_pod}
    if isinstance(event, JobDeparture):
        return {"kind": "departure", "name": event.name}
    if isinstance(event, TrafficChange):
        return {"kind": "traffic_change", "name": event.name,
                "job": _jobspec_to_dict(event.job)}
    if isinstance(event, LinkFailure):
        return {"kind": "link_failure", "pair": list(event.pair),
                "fraction": event.fraction}
    if isinstance(event, LinkRecovery):
        return {"kind": "link_recovery", "pair": list(event.pair)}
    if isinstance(event, PortFailure):
        return {"kind": "port_failure", "pod": event.pod,
                "count": event.count}
    if isinstance(event, PortRecovery):
        return {"kind": "port_recovery", "pod": event.pod,
                "count": event.count}
    if isinstance(event, PlaneFailure):
        return {"kind": "plane_failure", "plane": event.plane}
    if isinstance(event, PlaneRecovery):
        return {"kind": "plane_recovery", "plane": event.plane}
    raise TypeError(f"unknown fleet event {event!r}")


def rebuild_event(data: dict):
    """Inverse of `serialize_event`."""
    from repro.fleet.loop import (LinkFailure, LinkRecovery, JobArrival,
                                  JobDeparture, PlaneFailure, PlaneRecovery,
                                  PortFailure, PortRecovery, TrafficChange)
    kind = data.get("kind")
    if kind == "arrival":
        return JobArrival(
            name=data["name"], job=_jobspec_from_dict(data["job"]),
            reverse_stages=bool(data.get("reverse_stages", False)),
            port_min=bool(data.get("port_min", False)),
            donate_surplus=data.get("donate_surplus"),
            base_pod=data.get("base_pod"))
    if kind == "departure":
        return JobDeparture(name=data["name"])
    if kind == "traffic_change":
        return TrafficChange(name=data["name"],
                             job=_jobspec_from_dict(data["job"]))
    if kind == "link_failure":
        return LinkFailure(pair=tuple(data["pair"]),
                           fraction=float(data.get("fraction", 1.0)))
    if kind == "link_recovery":
        return LinkRecovery(pair=tuple(data["pair"]))
    if kind == "port_failure":
        return PortFailure(pod=int(data["pod"]), count=int(data["count"]))
    if kind == "port_recovery":
        return PortRecovery(pod=int(data["pod"]), count=int(data["count"]))
    if kind == "plane_failure":
        return PlaneFailure(plane=int(data["plane"]))
    if kind == "plane_recovery":
        return PlaneRecovery(plane=int(data["plane"]))
    raise ValueError(f"unknown journal event kind {kind!r}")


# ------------------------------------------------- snapshot serialization
def serialize_dag(dag) -> dict:
    """CommDAG -> JSON-safe dict (tasks / deps / cluster / meta)."""
    return {
        "tasks": [dataclasses.asdict(t) for t in dag.tasks],
        "deps": [dataclasses.asdict(d) for d in dag.deps],
        "cluster": dataclasses.asdict(dag.cluster),
        "meta": {k: v for k, v in dag.meta.items()
                 if isinstance(k, str)},
    }


def rebuild_dag(data: dict):
    """Inverse of `serialize_dag` (tuple-typed fields restored)."""
    from repro.core.cluster import ClusterSpec
    from repro.core.dag import CommDAG, CommTask, Dep
    tasks = []
    for t in data["tasks"]:
        kw = dict(t)
        for f in ("src_gpus", "dst_gpus", "tag"):
            kw[f] = tuple(tuple(e) if isinstance(e, list) else e
                          for e in kw.get(f, ()))
        tasks.append(CommTask(**kw))
    deps = [Dep(**d) for d in data["deps"]]
    ckw = dict(data["cluster"])
    for f in dataclasses.fields(ClusterSpec):
        if f.name in ckw and isinstance(ckw[f.name], list):
            ckw[f.name] = tuple(ckw[f.name])
    return CommDAG(tasks=tasks, deps=deps, cluster=ClusterSpec(**ckw),
                   meta=data.get("meta", {}))


def serialize_plan(plan) -> dict | None:
    """CachedPlan -> JSON-safe dict (None passes through)."""
    if plan is None:
        return None
    return {"x": plan.x.tolist(), "makespan": plan.makespan,
            "comm_time": plan.comm_time, "nct": plan.nct,
            "ideal_comm_time": plan.ideal_comm_time,
            "details": json.loads(json.dumps(plan.details,
                                             default=_json_default))}


def rebuild_plan(data: dict | None):
    """Inverse of `serialize_plan`."""
    if data is None:
        return None
    import numpy as np
    from repro.fleet.plancache import CachedPlan
    return CachedPlan(
        x=np.asarray(data["x"], dtype=np.int64),
        makespan=float(data["makespan"]),
        comm_time=float(data["comm_time"]), nct=float(data["nct"]),
        ideal_comm_time=float(data["ideal_comm_time"]),
        details=dict(data.get("details", {})))


class FleetJournal:
    """Append-only planner journal; JSONL-backed when given a path."""

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = os.fspath(path) if path is not None else None
        self.entries: list[dict] = []
        self._lock = threading.Lock()
        self._fh: io.TextIOBase | None = None
        if self.path is not None:
            # long-lived append handle, closed by close()/__exit__
            self._fh = open(self.path, "a")  # noqa: SIM115

    # ------------------------------------------------------------ recording
    def record(self, kind: str, **fields) -> dict:
        """Append one structured entry; returns it (with seq stamped)."""
        with self._lock:
            entry = {"seq": len(self.entries), "kind": kind, **fields}
            self.entries.append(entry)
            if self._fh is not None:
                json.dump(entry, self._fh, default=_json_default)
                self._fh.write("\n")
                self._fh.flush()
        return entry

    def record_event(self, event, record: dict) -> dict:
        """The planner's per-`handle()` entry: event + decision record."""
        return self.record("fleet_event", event=serialize_event(event),
                           record=record)

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __len__(self) -> int:
        return len(self.entries)

    # -------------------------------------------------------------- replay
    @staticmethod
    def load(path: str | os.PathLike) -> list[dict]:
        """Read a JSONL journal back into entry dicts."""
        out = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out

    @classmethod
    def rebuild_events(cls, entries) -> list:
        """Journal entries (or a path) -> ordered live FleetEvents, ready
        to re-drive a fresh `FleetPlanner.process()`."""
        if isinstance(entries, (str, os.PathLike)):
            entries = cls.load(entries)
        return [rebuild_event(e["event"]) for e in entries
                if e.get("kind") == "fleet_event"]


def _json_default(obj):
    """Decision records carry numpy scalars / arrays; keep JSONL valid."""
    import numpy as np
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return str(obj)
