"""Structured, replayable event journal for the fleet control plane.

Every `FleetPlanner.handle()` call appends one entry: the incoming event
(serialized well enough to reconstruct it), the decision record the planner
produced, and a monotonically increasing sequence number.  The journal is

  * **structured**: entries are plain dicts, JSONL on disk (one entry per
    line, append-only -- the persisted-plan-state shape an online planner
    restarts from);
  * **replayable**: `load()` reads entries back and `rebuild_events()`
    turns them into live `FleetEvent` objects (JobSpec round-trips through
    its dataclass fields), so a journal can re-drive a fresh planner;
  * cheap: in-memory by default, file-backed when given a path.

This is deliberately NOT a metrics stream (see `repro.obs.metrics`): the
journal answers "what did the planner decide, in order, and why", metrics
answer "how much / how fast".
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import threading

__all__ = ["FleetJournal", "serialize_event", "rebuild_event",
           "serialize_dag", "rebuild_dag", "serialize_plan", "rebuild_plan"]


# Event (de)serialization is owned by the versioned schema in
# `repro.fleet.events` -- ONE serialize/rebuild path for planner and
# control-plane events alike.  These wrappers stay for compatibility
# (`repro.obs` re-exports them) and import lazily: `repro.obs` must stay
# importable without pulling the fleet package in.
def _jobspec_to_dict(job) -> dict:
    return dataclasses.asdict(job)


def _jobspec_from_dict(data: dict):
    from repro.fleet.events import _jobspec_from_dict as rebuild
    return rebuild(data)


def serialize_event(event) -> dict:
    """FleetEvent -> JSON-safe dict (see `repro.fleet.events`)."""
    from repro.fleet.events import serialize_event as ser
    return ser(event)


def rebuild_event(data: dict):
    """Inverse of `serialize_event` (see `repro.fleet.events`)."""
    from repro.fleet.events import rebuild_event as rebuild
    return rebuild(data)


# ------------------------------------------------- snapshot serialization
def serialize_dag(dag) -> dict:
    """CommDAG -> JSON-safe dict (tasks / deps / cluster / meta)."""
    return {
        "tasks": [dataclasses.asdict(t) for t in dag.tasks],
        "deps": [dataclasses.asdict(d) for d in dag.deps],
        "cluster": dataclasses.asdict(dag.cluster),
        "meta": {k: v for k, v in dag.meta.items()
                 if isinstance(k, str)},
    }


def rebuild_dag(data: dict):
    """Inverse of `serialize_dag` (tuple-typed fields restored)."""
    from repro.core.cluster import ClusterSpec
    from repro.core.dag import CommDAG, CommTask, Dep
    tasks = []
    for t in data["tasks"]:
        kw = dict(t)
        for f in ("src_gpus", "dst_gpus", "tag"):
            kw[f] = tuple(tuple(e) if isinstance(e, list) else e
                          for e in kw.get(f, ()))
        tasks.append(CommTask(**kw))
    deps = [Dep(**d) for d in data["deps"]]
    ckw = dict(data["cluster"])
    for f in dataclasses.fields(ClusterSpec):
        if f.name in ckw and isinstance(ckw[f.name], list):
            ckw[f.name] = tuple(ckw[f.name])
    return CommDAG(tasks=tasks, deps=deps, cluster=ClusterSpec(**ckw),
                   meta=data.get("meta", {}))


def serialize_plan(plan) -> dict | None:
    """CachedPlan -> JSON-safe dict (None passes through)."""
    if plan is None:
        return None
    return {"x": plan.x.tolist(), "makespan": plan.makespan,
            "comm_time": plan.comm_time, "nct": plan.nct,
            "ideal_comm_time": plan.ideal_comm_time,
            "details": json.loads(json.dumps(plan.details,
                                             default=_json_default))}


def rebuild_plan(data: dict | None):
    """Inverse of `serialize_plan`."""
    if data is None:
        return None
    import numpy as np
    from repro.fleet.plancache import CachedPlan
    return CachedPlan(
        x=np.asarray(data["x"], dtype=np.int64),
        makespan=float(data["makespan"]),
        comm_time=float(data["comm_time"]), nct=float(data["nct"]),
        ideal_comm_time=float(data["ideal_comm_time"]),
        details=dict(data.get("details", {})))


class FleetJournal:
    """Append-only planner journal; JSONL-backed when given a path."""

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = os.fspath(path) if path is not None else None
        self.entries: list[dict] = []
        self._lock = threading.Lock()
        self._fh: io.TextIOBase | None = None
        if self.path is not None:
            # long-lived append handle, closed by close()/__exit__
            self._fh = open(self.path, "a")  # noqa: SIM115

    # ------------------------------------------------------------ recording
    def record(self, kind: str, **fields) -> dict:
        """Append one structured entry; returns it (with seq stamped)."""
        with self._lock:
            entry = {"seq": len(self.entries), "kind": kind, **fields}
            self.entries.append(entry)
            if self._fh is not None:
                json.dump(entry, self._fh, default=_json_default)
                self._fh.write("\n")
                self._fh.flush()
        return entry

    def record_event(self, event, record: dict) -> dict:
        """The planner's per-`handle()` entry: event + decision record."""
        return self.record("fleet_event", event=serialize_event(event),
                           record=record)

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __len__(self) -> int:
        return len(self.entries)

    # -------------------------------------------------------------- replay
    @staticmethod
    def load(path: str | os.PathLike) -> list[dict]:
        """Read a JSONL journal back into entry dicts."""
        out = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out

    @classmethod
    def rebuild_events(cls, entries) -> list:
        """Journal entries (or a path) -> ordered live FleetEvents, ready
        to re-drive a fresh `FleetPlanner.process()`."""
        if isinstance(entries, (str, os.PathLike)):
            entries = cls.load(entries)
        return [rebuild_event(e["event"]) for e in entries
                if e.get("kind") == "fleet_event"]


def _json_default(obj):
    """Decision records carry numpy scalars / arrays; keep JSONL valid."""
    import numpy as np
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return str(obj)
