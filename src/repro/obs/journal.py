"""Structured, replayable event journal for the fleet control plane.

Every `FleetPlanner.handle()` call appends one entry: the incoming event
(serialized well enough to reconstruct it), the decision record the planner
produced, and a monotonically increasing sequence number.  The journal is

  * **structured**: entries are plain dicts, JSONL on disk (one entry per
    line, append-only -- the persisted-plan-state shape an online planner
    restarts from);
  * **replayable**: `load()` reads entries back and `rebuild_events()`
    turns them into live `FleetEvent` objects (JobSpec round-trips through
    its dataclass fields), so a journal can re-drive a fresh planner;
  * cheap: in-memory by default, file-backed when given a path.

This is deliberately NOT a metrics stream (see `repro.obs.metrics`): the
journal answers "what did the planner decide, in order, and why", metrics
answer "how much / how fast".
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import threading

__all__ = ["FleetJournal", "serialize_event", "rebuild_event"]


def _jobspec_to_dict(job) -> dict:
    return dataclasses.asdict(job)


def _jobspec_from_dict(data: dict):
    from repro.core.traffic import JobSpec
    kw = dict(data)
    for f in dataclasses.fields(JobSpec):
        # JSON round-trips tuples as lists; restore tuple-typed fields
        if f.name in kw and isinstance(kw[f.name], list):
            kw[f.name] = tuple(kw[f.name])
    return JobSpec(**kw)


def serialize_event(event) -> dict:
    """FleetEvent -> JSON-safe dict (kind + reconstruction fields)."""
    from repro.fleet.loop import JobArrival, JobDeparture, TrafficChange
    if isinstance(event, JobArrival):
        return {"kind": "arrival", "name": event.name,
                "job": _jobspec_to_dict(event.job),
                "reverse_stages": event.reverse_stages,
                "port_min": event.port_min,
                "donate_surplus": event.donate_surplus,
                "base_pod": event.base_pod}
    if isinstance(event, JobDeparture):
        return {"kind": "departure", "name": event.name}
    if isinstance(event, TrafficChange):
        return {"kind": "traffic_change", "name": event.name,
                "job": _jobspec_to_dict(event.job)}
    raise TypeError(f"unknown fleet event {event!r}")


def rebuild_event(data: dict):
    """Inverse of `serialize_event`."""
    from repro.fleet.loop import JobArrival, JobDeparture, TrafficChange
    kind = data.get("kind")
    if kind == "arrival":
        return JobArrival(
            name=data["name"], job=_jobspec_from_dict(data["job"]),
            reverse_stages=bool(data.get("reverse_stages", False)),
            port_min=bool(data.get("port_min", False)),
            donate_surplus=data.get("donate_surplus"),
            base_pod=data.get("base_pod"))
    if kind == "departure":
        return JobDeparture(name=data["name"])
    if kind == "traffic_change":
        return TrafficChange(name=data["name"],
                             job=_jobspec_from_dict(data["job"]))
    raise ValueError(f"unknown journal event kind {kind!r}")


class FleetJournal:
    """Append-only planner journal; JSONL-backed when given a path."""

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = os.fspath(path) if path is not None else None
        self.entries: list[dict] = []
        self._lock = threading.Lock()
        self._fh: io.TextIOBase | None = None
        if self.path is not None:
            self._fh = open(self.path, "a")

    # ------------------------------------------------------------ recording
    def record(self, kind: str, **fields) -> dict:
        """Append one structured entry; returns it (with seq stamped)."""
        with self._lock:
            entry = {"seq": len(self.entries), "kind": kind, **fields}
            self.entries.append(entry)
            if self._fh is not None:
                json.dump(entry, self._fh, default=_json_default)
                self._fh.write("\n")
                self._fh.flush()
        return entry

    def record_event(self, event, record: dict) -> dict:
        """The planner's per-`handle()` entry: event + decision record."""
        return self.record("fleet_event", event=serialize_event(event),
                           record=record)

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __len__(self) -> int:
        return len(self.entries)

    # -------------------------------------------------------------- replay
    @staticmethod
    def load(path: str | os.PathLike) -> list[dict]:
        """Read a JSONL journal back into entry dicts."""
        out = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out

    @classmethod
    def rebuild_events(cls, entries) -> list:
        """Journal entries (or a path) -> ordered live FleetEvents, ready
        to re-drive a fresh `FleetPlanner.process()`."""
        if isinstance(entries, (str, os.PathLike)):
            entries = cls.load(entries)
        return [rebuild_event(e["event"]) for e in entries
                if e.get("kind") == "fleet_event"]


def _json_default(obj):
    """Decision records carry numpy scalars / arrays; keep JSONL valid."""
    import numpy as np
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return str(obj)
