"""One logging setup for the whole system: consistent names, no bare prints.

Every repro module logs under the ``repro.`` hierarchy (``repro.des_jax``,
``repro.fleet``, ``repro.milp``, ...) so one `setup_logging()` call -- or
one dictConfig entry in an embedding service -- controls all of it.
`get_logger` is the single place modules obtain their logger, which keeps
the naming convention mechanical.
"""
from __future__ import annotations

import logging
import os

__all__ = ["get_logger", "setup_logging"]

_FORMAT = "%(asctime)s %(levelname)-7s %(name)s: %(message)s"


def get_logger(name: str) -> logging.Logger:
    """Logger under the ``repro.`` hierarchy (idempotent)."""
    if not name.startswith("repro"):
        name = f"repro.{name}"
    return logging.getLogger(name)


def setup_logging(level: int | str | None = None,
                  fmt: str = _FORMAT) -> logging.Logger:
    """Attach one stream handler to the ``repro`` root logger.

    Idempotent: repeated calls only adjust the level.  The default level
    comes from ``$REPRO_LOG_LEVEL`` (WARNING when unset), so benchmarks
    and services flip verbosity without code changes.
    """
    if level is None:
        level = os.environ.get("REPRO_LOG_LEVEL", "WARNING")
    root = logging.getLogger("repro")
    if not root.handlers:
        handler = logging.StreamHandler()
        handler.setFormatter(logging.Formatter(fmt))
        root.addHandler(handler)
        root.propagate = False
    root.setLevel(level)
    return root
