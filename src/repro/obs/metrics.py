"""Lightweight metrics registry: counters / gauges / histograms with labels.

The fleet control plane (monitor -> decide -> apply) needs exported,
*scopable* measurements instead of ad-hoc process-wide dicts: two
`FleetPlanner`s in one process must not pollute each other's compile-cache
hit rate, and an external scraper must be able to read the same numbers the
planner's own `report()` uses.  This module provides exactly that substrate:

  * `MetricsRegistry` holds named metrics; every metric supports key=value
    labels (one time series per label combination, Prometheus-style);
  * `snapshot()` returns a plain-dict JSON view; `render_prometheus()` the
    text exposition format (``# HELP`` / ``# TYPE`` + one line per series);
  * `RegistryScope` (from `registry.scope()`) captures current counter
    values so callers can read *deltas* -- the planner-local view of shared
    process counters;
  * a disabled registry (``enabled=False`` or ``$REPRO_METRICS=0``) makes
    every mutation a single attribute check and an early return, so
    instrumented hot paths stay effectively free.

One process-wide default registry (`REGISTRY`) is shared by the DES compile
cache, the GA, the MILP phases and the fleet loop; tests and multi-tenant
embeddings can construct private registries.
"""
from __future__ import annotations

import json
import os
import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "RegistryScope", "REGISTRY", "get_counter", "get_gauge",
           "get_histogram"]

# seconds-scale latency buckets: DES calls are ~1e-4..1e0, GA/MILP solves
# 1e-1..1e3 -- a shared log-spaced ladder covers both
DEFAULT_BUCKETS = (1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0, 5.0, 30.0, 120.0, 600.0)

_LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: dict[str, object]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render_labels(key: _LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class _Metric:
    """Common storage: one value slot per label combination."""

    kind = "untyped"

    def __init__(self, name: str, help: str, registry: "MetricsRegistry"):
        self.name = name
        self.help = help
        self._registry = registry
        self._series: dict[_LabelKey, float] = {}

    # the lock lives on the registry so snapshot() sees a consistent view
    @property
    def _lock(self) -> threading.Lock:
        return self._registry._lock

    @property
    def enabled(self) -> bool:
        return self._registry.enabled

    def value(self, **labels) -> float:
        return self._series.get(_label_key(labels), 0.0)

    def series(self) -> dict[_LabelKey, float]:
        with self._lock:
            return dict(self._series)

    def reset(self) -> None:
        with self._lock:
            self._series.clear()

    def _lines(self) -> list[str]:
        with self._lock:
            items = sorted(self._series.items())
        return [f"{self.name}{_render_labels(key)} {_format(v)}"
                for key, v in items]


def _format(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(float(v))


class Counter(_Metric):
    """Monotonically increasing count (resets only via `reset()`)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if not self.enabled:
            return
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount


class Gauge(_Metric):
    """Point-in-time value (pool sizes, cache entries, tenant counts)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._series[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        if not self.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus semantics: each ``le``
    bucket counts observations <= its bound, plus ``+Inf``/sum/count)."""

    kind = "histogram"

    def __init__(self, name: str, help: str, registry: "MetricsRegistry",
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help, registry)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        # per label key: [bucket counts..., +Inf count, sum]
        self._obs: dict[_LabelKey, list[float]] = {}

    def observe(self, value: float, **labels) -> None:
        if not self.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            row = self._obs.get(key)
            if row is None:
                row = self._obs[key] = [0.0] * (len(self.buckets) + 2)
            for i, b in enumerate(self.buckets):
                if value <= b:
                    row[i] += 1.0
            row[-2] += 1.0          # +Inf
            row[-1] += value        # sum

    def value(self, **labels) -> float:
        """Observation count for the label set (the scalar view)."""
        row = self._obs.get(_label_key(labels))
        return row[-2] if row else 0.0

    def sum(self, **labels) -> float:
        row = self._obs.get(_label_key(labels))
        return row[-1] if row else 0.0

    def series(self) -> dict[_LabelKey, float]:
        with self._lock:
            return {key: row[-2] for key, row in self._obs.items()}

    def reset(self) -> None:
        with self._lock:
            self._obs.clear()

    def _lines(self) -> list[str]:
        with self._lock:
            items = sorted((k, list(v)) for k, v in self._obs.items())
        out = []
        for key, row in items:
            for i, b in enumerate(self.buckets):
                lk = _label_key(dict(key, le=_format(b)))
                out.append(f"{self.name}_bucket{_render_labels(lk)} "
                           f"{_format(row[i])}")
            lk = _label_key(dict(key, le="+Inf"))
            out.append(f"{self.name}_bucket{_render_labels(lk)} "
                       f"{_format(row[-2])}")
            out.append(f"{self.name}_sum{_render_labels(key)} "
                       f"{_format(row[-1])}")
            out.append(f"{self.name}_count{_render_labels(key)} "
                       f"{_format(row[-2])}")
        return out

    def snapshot_obs(self) -> dict[_LabelKey, list[float]]:
        with self._lock:
            return {k: list(v) for k, v in self._obs.items()}


class MetricsRegistry:
    """Named metrics + consistent snapshot / exposition / scoping."""

    def __init__(self, enabled: bool | None = None):
        if enabled is None:
            enabled = os.environ.get("REPRO_METRICS", "1") != "0"
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    # ------------------------------------------------------------- factories
    def _get(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, self, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    # ---------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """JSON-friendly view: {metric: {kind, help, series: {labels: v}}}.

        Label keys render as ``k=v,k2=v2`` strings ('' for the bare
        series) so the snapshot survives `json.dumps` untouched.
        """
        out: dict[str, dict] = {}
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            series = {",".join(f"{k}={v}" for k, v in key) or "": val
                      for key, val in m.series().items()}
            out[m.name] = {"kind": m.kind, "help": m.help, "series": series}
        return out

    def to_json(self, **dump_kw) -> str:
        return json.dumps(self.snapshot(), **dump_kw)

    def render_prometheus(self) -> str:
        """Text exposition format (``text/plain; version=0.0.4``)."""
        lines: list[str] = []
        with self._lock:
            metrics = sorted(self._metrics.values(), key=lambda m: m.name)
        for m in metrics:
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            lines.extend(m._lines())
        return "\n".join(lines) + ("\n" if lines else "")

    # --------------------------------------------------------------- scoping
    def scope(self) -> "RegistryScope":
        """Capture current values; `delta()` then reads *scoped* counters.

        This is how a `FleetPlanner` reports its own share of process-wide
        counters (e.g. DES compile-cache hits) without a second planner in
        the same process polluting the numbers.
        """
        return RegistryScope(self)

    def reset(self) -> None:
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m.reset()


class RegistryScope:
    """Value snapshot of a registry; `delta()` returns per-metric change.

    Only scalar series are diffed (counter/gauge values, histogram counts);
    new label combinations appearing after the snapshot count from zero.
    """

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        with registry._lock:
            metrics = list(registry._metrics.items())
        self._base: dict[str, dict[_LabelKey, float]] = {
            name: m.series() for name, m in metrics}

    def delta(self, name: str, **labels) -> float:
        """Change of one series since the scope was captured."""
        m = self.registry._metrics.get(name)
        if m is None:
            return 0.0
        base = self._base.get(name, {}).get(_label_key(labels), 0.0)
        return m.value(**labels) - base

    def deltas(self, name: str) -> dict[str, float]:
        """All of a metric's series deltas, label-rendered keys."""
        m = self.registry._metrics.get(name)
        if m is None:
            return {}
        base = self._base.get(name, {})
        out = {}
        for key, val in m.series().items():
            d = val - base.get(key, 0.0)
            out[",".join(f"{k}={v}" for k, v in key) or ""] = d
        return out


REGISTRY = MetricsRegistry()


def get_counter(name: str, help: str = "") -> Counter:
    return REGISTRY.counter(name, help)


def get_gauge(name: str, help: str = "") -> Gauge:
    return REGISTRY.gauge(name, help)


def get_histogram(name: str, help: str = "",
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, help, buckets)
