"""DES schedule timeline: Chrome-trace export + critical-path/slack report.

The paper's central observation is that non-critical tasks carry *temporal
slack* a topology optimizer can exploit (trim circuits where slack is
plentiful, add them where the critical path lives).  This module makes that
visible: a simulated plan (per-task start/finish times from the numpy DES,
optionally per-interval rates via ``record_rates=True``) becomes

  * a Chrome trace-event JSON (`schedule_timeline`) viewable in Perfetto --
    one track per directed inter-pod link carrying that link's tasks as
    complete (``X``) events, critical-path tasks color-coded, plus one
    counter (``C``) track per link showing its per-interval utilization
    (aggregate task rate / link capacity);
  * a critical-path + slack report (`slack_report`): per task the classic
    backward-pass slack (latest feasible finish minus realized finish under
    the realized durations), the binding critical path, and its identity
    ``max(finish) == makespan`` -- zero-slack chain == the DES makespan.

`validate_trace` is a minimal trace-event schema check used by the tests
and the CI smoke (the emitted file must stay loadable by Perfetto).
"""
from __future__ import annotations

import json

import numpy as np

from repro.core.dag import VIRTUAL, CommDAG
from repro.core.des import DESProblem, DESResult, simulate

__all__ = ["interval_rate_matrices", "plane_rewire_timeline",
           "schedule_timeline", "slack_report", "task_slack",
           "validate_trace", "write_trace"]

INF = float("inf")

# Perfetto color-name palette: critical tasks pop out of the timeline
_COLOR_CRITICAL = "terrible"        # red
_COLOR_BY_KIND = {"pp_fwd": "thread_state_running",
                  "pp_bwd": "thread_state_runnable",
                  "dp": "rail_response",
                  "xattn": "rail_animation"}
_EP_COLOR = "generic_work"


def task_slack(dag: CommDAG, result: DESResult) -> np.ndarray:
    """Backward-pass temporal slack per task, on the *realized* schedule.

    With realized durations ``d_m = finish_m - start_m`` fixed, the latest
    feasible finish is ``LF_m = min over successors s of (LF_s - d_s -
    delta_{m->s})`` with ``LF = makespan`` at the sinks; slack is
    ``LF_m - finish_m``.  Critical tasks have (numerically) zero slack;
    the slack of everything else is exactly the paper's exploitable
    scheduling freedom.  Returns +inf for tasks that never ran.
    """
    n = dag.num_tasks
    finish = result.finish
    start = result.start
    if not result.feasible or not np.isfinite(result.makespan):
        return np.full(n, np.nan)
    dur = np.where(np.isfinite(finish) & np.isfinite(start),
                   finish - start, 0.0)
    LF = np.full(n, result.makespan)
    # reverse topological relaxation: iterate deps until a fixed point
    # (the DAG is small -- hundreds of tasks -- and acyclic, so bounded
    # by the longest chain; one vectorized np.minimum.at pass per round)
    pre, succ, delta = dag.dep_arrays()
    for _ in range(n + 1):
        cand = LF[succ] - dur[succ] - delta
        new = LF.copy()
        np.minimum.at(new, pre, cand)
        if np.allclose(new, LF, rtol=0, atol=1e-12):
            break
        LF = new
    slack = LF - finish
    slack[~np.isfinite(finish)] = INF
    return slack


def slack_report(dag: CommDAG, result: DESResult,
                 slack_tol: float = 1e-6) -> dict:
    """Critical-path + per-task slack summary of one simulated plan."""
    if not result.feasible:
        return {"feasible": False, "makespan": INF, "critical_path": [],
                "tasks": []}
    slack = task_slack(dag, result)
    crit = set(result.critical_path)
    rel = slack_tol * max(result.makespan, 1e-12)
    tasks = []
    for t in dag.real_tasks():
        m = t.tid
        if not np.isfinite(result.finish[m]):
            continue
        tasks.append({
            "tid": int(m), "kind": t.kind,
            "pair": [int(t.pair[0]), int(t.pair[1])],
            "volume_gb": float(t.volume) / 1e9,
            "start": float(result.start[m]),
            "finish": float(result.finish[m]),
            "slack": float(slack[m]),
            "critical": bool(m in crit or slack[m] <= rel)})
    zero_slack = [t["tid"] for t in tasks if t["slack"] <= rel]
    return {
        "feasible": True,
        "makespan": float(result.makespan),
        "comm_time": float(result.comm_time),
        "crit_delta": float(result.crit_delta),
        "critical_path": [int(m) for m in result.critical_path
                          if m != VIRTUAL],
        "zero_slack_tasks": zero_slack,
        "num_tasks": len(tasks),
        "mean_slack": float(np.mean([t["slack"] for t in tasks]))
        if tasks else 0.0,
        "tasks": tasks,
    }


def _link_name(pair: tuple[int, int]) -> str:
    return f"link {pair[0]}->{pair[1]}"


def interval_rate_matrices(problem: DESProblem, result: DESResult
                           ) -> list[tuple[float, float, np.ndarray]]:
    """Per DES interval, the aggregate (P, P) task-rate matrix (bytes/s).

    Requires a rate trace (``simulate(..., record_rates=True)``).  Entry
    ``mat[i, j]`` sums the fair-share rates of every task on directed pod
    pair (i, j) during [t0, t1) -- the ground truth a per-pair telemetry
    stream observes, and the source `repro.fleet.telemetry` synthesizes
    samples from.
    """
    P = problem.dag.cluster.num_pods
    pairs = np.asarray(problem.pairs, dtype=np.int64).reshape(-1, 2)
    active = problem.task_pair >= 0
    out: list[tuple[float, float, np.ndarray]] = []
    for t0, t1, rates in result.rate_trace:
        per_link = np.zeros(len(problem.pairs))
        np.add.at(per_link, problem.task_pair[active],
                  np.asarray(rates)[active])
        mat = np.zeros((P, P))
        mat[pairs[:, 0], pairs[:, 1]] = per_link
        out.append((float(t0), float(t1), mat))
    return out


def schedule_timeline(dag: CommDAG, x: np.ndarray,
                      result: DESResult | None = None,
                      time_scale: float = 1e6) -> dict:
    """Chrome trace-event JSON of one plan's simulated schedule.

    One track (pid/tid pair) per directed inter-pod link; each task on the
    link is a complete event spanning [start, finish) with its kind,
    volume, flow count and slack in ``args``.  When the result carries a
    rate trace (``simulate(..., record_rates=True)``) each link also gets
    a counter track with its per-interval utilization.  ``time_scale``
    maps seconds to trace µs (default 1:1 -- trace µs == schedule µs).
    """
    problem = DESProblem(dag)
    if result is None:
        result = simulate(problem, np.asarray(x), record_rates=True)
    if not result.feasible:
        raise ValueError("cannot export a timeline for an infeasible plan")
    rep = slack_report(dag, result)
    by_tid = {t["tid"]: t for t in rep["tasks"]}

    events: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": f"{dag.cluster.num_pods}-pod schedule "
                          f"(makespan {result.makespan:.6f}s)"}}]
    track_of: dict[tuple[int, int], int] = {}
    for i, pair in enumerate(problem.pairs):
        track_of[pair] = i
        events.append({"name": "thread_name", "ph": "M", "pid": 0,
                       "tid": i, "args": {"name": _link_name(pair)}})
        events.append({"name": "thread_sort_index", "ph": "M", "pid": 0,
                       "tid": i, "args": {"sort_index": i}})

    for t in dag.real_tasks():
        row = by_tid.get(t.tid)
        if row is None:
            continue
        crit = row["critical"]
        cname = _COLOR_CRITICAL if crit else _COLOR_BY_KIND.get(
            t.kind, _EP_COLOR)
        events.append({
            "name": f"{t.kind}#{t.tid}", "ph": "X", "pid": 0,
            "tid": track_of[t.pair],
            "ts": row["start"] * time_scale,
            "dur": max(row["finish"] - row["start"], 0.0) * time_scale,
            "cname": cname,
            "args": {"tid": t.tid, "kind": t.kind,
                     "volume_gb": row["volume_gb"],
                     "flows": int(t.flows),
                     "slack_s": row["slack"],
                     "critical": crit}})

    # per-interval link utilization counters from the rate trace
    B = dag.cluster.nic_bandwidth
    xm = np.asarray(x)
    caps = {pair: float(xm[pair]) * B for pair in problem.pairs}
    for t0, _t1, mat in interval_rate_matrices(problem, result):
        for pair, li in track_of.items():
            cap = caps[pair]
            util = mat[pair] / cap if cap > 0 else 0.0
            events.append({
                "name": f"util {_link_name(pair)}", "ph": "C", "pid": 0,
                "tid": li, "ts": t0 * time_scale,
                "args": {"utilization": round(float(util), 6)}})
    # close the counter tracks at the makespan
    if result.rate_trace:
        for pair, li in track_of.items():
            events.append({
                "name": f"util {_link_name(pair)}", "ph": "C", "pid": 0,
                "tid": li, "ts": result.makespan * time_scale,
                "args": {"utilization": 0.0}})

    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"makespan_s": float(result.makespan),
                          "comm_time_s": float(result.comm_time),
                          "critical_path": rep["critical_path"],
                          "total_ports": int(np.asarray(x).sum())}}


def plane_rewire_timeline(steps, summary=None,
                          time_scale: float = 1e6) -> dict:
    """Chrome trace-event JSON of one staggered plane transition.

    One track per OCS plane; each `PlaneRewireStep` is a complete (``X``)
    event on its plane's track spanning that plane's dark window
    (``ts`` = cumulative reconfiguration delay of the preceding steps,
    ``dur`` = the step's own delay), rollback steps color-coded red.  A
    counter track charts the certified peak inflation the SLO check saw
    at every step.  Pass the transition's `PlaneTransitionSummary` to
    stamp the outcome into ``otherData``.
    """
    steps = list(steps)
    if not steps:
        raise ValueError("cannot export a timeline without steps")
    tname = steps[0].transition
    events: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": f"staggered transition {tname}"}}]
    for plane in sorted({s.plane for s in steps}):
        events.append({"name": "thread_name", "ph": "M", "pid": 0,
                       "tid": plane, "args": {"name": f"plane {plane}"}})
        events.append({"name": "thread_sort_index", "ph": "M", "pid": 0,
                       "tid": plane, "args": {"sort_index": plane}})
    t = 0.0
    for s in steps:
        # a rollback step un-rewires a plane; it pops red in the trace
        cname = _COLOR_CRITICAL if s.direction == "rollback" \
            else "thread_state_running"
        events.append({
            "name": f"{s.direction}#{s.seq}", "ph": "X", "pid": 0,
            "tid": int(s.plane), "ts": t * time_scale,
            "dur": max(float(s.delay_s), 0.0) * time_scale,
            "cname": cname,
            "args": {"seq": int(s.seq), "direction": s.direction,
                     "plane": int(s.plane),
                     "changed_circuits": int(s.changed_circuits),
                     "peak_inflation": float(s.peak_inflation)}})
        events.append({
            "name": "peak_inflation", "ph": "C", "pid": 0, "tid": 0,
            "ts": t * time_scale,
            "args": {"inflation": round(float(s.peak_inflation), 6)}})
        t += float(s.delay_s)
    events.append({"name": "peak_inflation", "ph": "C", "pid": 0,
                   "tid": 0, "ts": t * time_scale,
                   "args": {"inflation": 1.0}})
    other = {"transition": tname, "total_delay_s": float(t),
             "steps": len(steps)}
    if summary is not None:
        other["outcome"] = summary.outcome
        other["peak_inflation"] = float(summary.peak_inflation)
        other["tenants"] = list(summary.tenants)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other}


def validate_trace(trace: dict) -> list[str]:
    """Minimal Chrome trace-event schema check; returns error strings."""
    errors: list[str] = []
    if not isinstance(trace, dict):
        return ["trace must be a JSON object"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents must be a list"]
    if not events:
        errors.append("traceEvents is empty")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            errors.append(f"event {i}: missing name")
        ph = ev.get("ph")
        if ph not in ("X", "B", "E", "C", "M", "i"):
            errors.append(f"event {i}: bad phase {ph!r}")
        if ph in ("X", "B", "E", "C", "i") and \
                not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"event {i}: missing ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i}: bad dur {dur!r}")
        for key in ("pid", "tid"):
            if key in ev and not isinstance(ev[key], int):
                errors.append(f"event {i}: {key} must be an int")
        try:
            json.dumps(ev.get("args", {}))
        except (TypeError, ValueError):
            errors.append(f"event {i}: args not JSON-serializable")
    return errors


def write_trace(trace: dict, path: str) -> str:
    """Validate + write a trace JSON; returns the path (raises on an
    invalid trace so CI never commits an unopenable artifact)."""
    errors = validate_trace(trace)
    if errors:
        raise ValueError("invalid trace: " + "; ".join(errors[:5]))
    with open(path, "w") as f:
        json.dump(trace, f)
    return path
