"""Span-based tracing for the planning/simulation hot seams.

A span is one timed region -- a GA generation, a fused DES fitness batch, a
MILP solve phase, a fleet admission decision.  Spans nest (a per-thread
stack tracks the active parent), survive exceptions (the duration is
recorded and the stack popped either way, with the exception type attached
to the span), and use monotonic clocks, so a span summary is a faithful
"where did the wall clock go" decomposition.

Cost model: tracing is DISABLED by default.  A disabled `span()` returns a
shared no-op context manager -- one attribute check, no allocation -- so
instrumenting per-generation / per-batch paths costs well under the 2%
budget of even the smoke-sized GA runs (see tests/test_obs.py, which bounds
the per-call overhead directly).  Enable via `tracer.enable()`,
``$REPRO_TRACE=1``, or the `enabled(...)` context manager.

Exports:
  * `Tracer.summary()`   -- {span name: {count, total_s, max_s}} rollup (the
    jit-vs-simulate-vs-solve split the benchmark rows attach);
  * `Tracer.to_chrome_trace()` -- Chrome trace-event JSON (Perfetto-ready),
    one track per originating thread, nesting preserved via B/E pairs
    rendered as complete ``X`` events.

One process-wide default tracer (`TRACER`) is shared by all instrumented
modules; `span(name, **attrs)` is the module-level shorthand bound to it.
"""
from __future__ import annotations

import contextlib
import os
import threading
import time

__all__ = ["SpanRecord", "Tracer", "TRACER", "span", "enabled"]


class SpanRecord:
    """One closed span: name, [t0, t0+dur) on the monotonic clock, parent
    span name (or None at the root), nesting depth, originating thread and
    free-form attrs (plus ``error`` when the body raised)."""

    __slots__ = ("name", "t0", "dur", "parent", "depth", "thread", "attrs")

    def __init__(self, name: str, t0: float, dur: float,
                 parent: str | None, depth: int, thread: int, attrs: dict):
        self.name = name
        self.t0 = t0
        self.dur = dur
        self.parent = parent
        self.depth = depth
        self.thread = thread
        self.attrs = attrs

    def as_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "dur": self.dur,
                "parent": self.parent, "depth": self.depth,
                "thread": self.thread, "attrs": self.attrs}

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        return (f"SpanRecord({self.name!r}, dur={self.dur:.6f}, "
                f"parent={self.parent!r})")


class _NullSpan:
    """Shared no-op context manager: the disabled-mode fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        """Attr updates are dropped when tracing is off."""


_NULL_SPAN = _NullSpan()


class _Span:
    """Active span handle; closes into a `SpanRecord` on exit."""

    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> None:
        """Attach attrs mid-span (e.g. a result size known only at the
        end of the body)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "_Span":
        stack = self._tracer._stack()
        stack.append(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur = time.perf_counter() - self._t0
        stack = self._tracer._stack()
        # exception safety: pop our own frame even if the body replaced
        # the stack contents via nested tracer misuse
        if stack and stack[-1] == self.name:
            stack.pop()
        elif self.name in stack:   # pragma: no cover - defensive
            stack.remove(self.name)
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        parent = stack[-1] if stack else None
        self._tracer._record(SpanRecord(
            self.name, self._t0, dur, parent, len(stack),
            threading.get_ident(), self.attrs))
        return False   # never swallow the exception


class Tracer:
    """Thread-safe span collector with a per-thread nesting stack."""

    def __init__(self, enabled: bool | None = None,
                 max_records: int = 1_000_000):
        if enabled is None:
            enabled = os.environ.get("REPRO_TRACE", "0") not in ("0", "")
        self._enabled = bool(enabled)
        self.max_records = int(max_records)
        self.dropped = 0
        self._records: list[SpanRecord] = []
        self._lock = threading.Lock()
        self._local = threading.local()

    # ------------------------------------------------------------ state
    @property
    def is_enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    @contextlib.contextmanager
    def enabled(self, on: bool = True):
        """Temporarily flip tracing on/off (benchmark harness hook)."""
        prev = self._enabled
        self._enabled = bool(on)
        try:
            yield self
        finally:
            self._enabled = prev

    def clear(self) -> None:
        with self._lock:
            self._records.clear()
            self.dropped = 0

    def _stack(self) -> list[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self._records) >= self.max_records:
                self.dropped += 1
                return
            self._records.append(rec)

    # ------------------------------------------------------------- spans
    def span(self, name: str, **attrs):
        """Context manager timing one region.  Near-free when disabled."""
        if not self._enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    @property
    def records(self) -> list[SpanRecord]:
        with self._lock:
            return list(self._records)

    # ----------------------------------------------------------- exports
    def summary(self) -> dict[str, dict[str, float]]:
        """Per-name rollup: {name: {count, total_s, max_s}}."""
        out: dict[str, dict[str, float]] = {}
        for rec in self.records:
            row = out.setdefault(rec.name,
                                 {"count": 0, "total_s": 0.0, "max_s": 0.0})
            row["count"] += 1
            row["total_s"] += rec.dur
            row["max_s"] = max(row["max_s"], rec.dur)
        return out

    def to_chrome_trace(self, process_name: str = "repro") -> dict:
        """Chrome trace-event JSON: complete (``X``) events in µs, one
        track per originating thread, openable in Perfetto / about:tracing.
        """
        events: list[dict] = [{
            "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
            "args": {"name": process_name}}]
        threads = {}
        for rec in self.records:
            tid = threads.setdefault(rec.thread, len(threads))
            events.append({
                "name": rec.name, "ph": "X", "pid": 0, "tid": tid,
                "ts": rec.t0 * 1e6, "dur": rec.dur * 1e6,
                "args": {**rec.attrs, "parent": rec.parent}})
        for ident, tid in threads.items():
            events.append({"name": "thread_name", "ph": "M", "pid": 0,
                           "tid": tid, "args": {"name": f"thread-{ident}"}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}


TRACER = Tracer()


def span(name: str, **attrs):
    """Shorthand for ``TRACER.span(...)`` (the instrumentation call every
    hot seam uses; one attribute check when tracing is off)."""
    if not TRACER._enabled:
        return _NULL_SPAN
    return _Span(TRACER, name, attrs)


def enabled(on: bool = True):
    """Shorthand for ``TRACER.enabled(...)``."""
    return TRACER.enabled(on)
