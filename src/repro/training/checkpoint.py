"""Sharded checkpoint save/restore with elastic re-sharding.

Layout per checkpoint directory:
    manifest.json    tree structure, dtypes, shapes, step
    <leaf-key>.npy   one array per pytree leaf

Restore takes the *current* mesh + PartitionSpecs and `device_put`s each
leaf with its new NamedSharding, so a checkpoint written on one mesh
restores onto a different mesh shape (elastic scaling / failure recovery).
On a multi-host deployment each host would write its addressable shards;
the manifest format already keys leaves by logical path, so only the array
reader changes.
"""
from __future__ import annotations

import json
import os
import re
import time
from typing import Any

import jax
import numpy as np

Params = Any


def _flatten(tree: Params) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def _sanitize(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.\[\]-]", "_", key)


def save(directory: str, step: int, tree: Params,
         extra: dict | None = None) -> str:
    """Write a checkpoint; returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "time": time.time(), "extra": extra or {},
                "leaves": {}}
    for key, arr in flat.items():
        fname = _sanitize(key) + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):   # pragma: no cover - overwrite guard
        raise FileExistsError(path)
    os.rename(tmp, path)       # atomic publish
    return path


def latest(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(directory, ckpts[-1]) if ckpts else None


def restore(path: str, template: Params,
            shardings: Params | None = None) -> tuple[Params, int, dict]:
    """Restore into the structure of `template`.

    shardings: optional pytree of jax.sharding.Sharding matching template;
    when given each leaf is device_put with its sharding (elastic restore
    onto whatever mesh the shardings reference).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]

    flat_t = jax.tree_util.tree_flatten_with_path(template)
    flat_s = (jax.tree_util.tree_flatten(shardings)[0]
              if shardings is not None else [None] * len(flat_t[0]))
    out = []
    for (pathk, leaf), shd in zip(flat_t[0], flat_s):
        key = "/".join(_path_str(p) for p in pathk)
        meta = leaves_meta.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(path, meta["file"]))
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(flat_t[1], out)
    return tree, int(manifest["step"]), manifest.get("extra", {})
