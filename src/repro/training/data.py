"""Deterministic synthetic data pipeline.

Restart-safe by construction: batch(step) is a pure function of
(seed, step), so a resumed job consumes exactly the token stream it would
have seen without the failure (no state to checkpoint beyond the step
counter).  The token process is a noisy affine recurrence, so a real
language model can actually learn it (training-loss decrease is asserted
in tests and demonstrated in examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seed: int = 0
    noise: float = 0.05
    mult: int = 31
    offset: int = 17

    def batch(self, step: int, batch_size: int, seq_len: int,
              xkv_shape: tuple | None = None) -> dict:
        rng = np.random.default_rng((self.seed, step))
        x0 = rng.integers(0, self.vocab, size=batch_size)
        toks = np.empty((batch_size, seq_len + 1), dtype=np.int32)
        toks[:, 0] = x0
        for t in range(seq_len):
            nxt = (toks[:, t] * self.mult + self.offset) % self.vocab
            flip = rng.random(batch_size) < self.noise
            nxt = np.where(flip,
                           rng.integers(0, self.vocab, size=batch_size),
                           nxt)
            toks[:, t + 1] = nxt
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if xkv_shape is not None:
            batch["xkv"] = rng.standard_normal(
                (batch_size, *xkv_shape), dtype=np.float32)
        return batch

    def with_seed(self, seed: int) -> "SyntheticLM":
        return dataclasses.replace(self, seed=seed)
