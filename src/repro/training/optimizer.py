"""Sharded AdamW with configurable state dtype.

For the >=300B architectures the first/second moments are stored in
bfloat16 (8-bit-Adam-style memory trick, see DESIGN.md) so the optimizer
state fits 16 GB/chip on the single-pod mesh; updates always compute in
float32.  Moment tensors inherit the parameter PartitionSpecs (ZeRO-style
when the params are FSDP-sharded).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 20
    state_dtype: Any = jnp.float32   # jnp.bfloat16 for the huge archs


def init_state(params: Params, cfg: AdamWConfig) -> Params:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def global_norm(tree: Params) -> jax.Array:
    sq = jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def apply_updates(params: Params, grads: Params, state: Params,
                  cfg: AdamWConfig) -> tuple[Params, Params]:
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9)) \
        if cfg.grad_clip else jnp.asarray(1.0)
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(cfg.state_dtype), v32.astype(cfg.state_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state
