"""Train / prefill / decode step factories.

`make_train_step` builds a pure (state, batch) -> (state, metrics) function:
gradient accumulation over microbatches via `lax.scan` (f32 accumulators),
remat inside the layer scan, AdamW update -- the function is jit/pjit-ready
and is what the dry-run lowers for the train shapes.

`make_prefill_step` / `make_decode_step` are the serving entry points
(`serve_step` in the assignment's terms lowers the decode step).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training import optimizer as opt

Params = Any


def make_train_step(cfg: ModelConfig, ocfg: opt.AdamWConfig,
                    accum_steps: int = 1, remat: bool = True,
                    has_xkv: bool = False, mesh=None,
                    data_axes: tuple[str, ...] = ()):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt"}; batch = {"tokens", "labels"[, "xkv"]} with
    leading global-batch dim; accum_steps splits it into microbatches.
    mesh/data_axes: when given, the reshaped (accum, micro, ...) batch is
    constrained to keep the *micro* dim on the data axes -- without this
    GSPMD reshards the reshape across (accum x micro) and silently degrades
    data parallelism (8x per-device flops in the 256->(8,32) case).
    """

    def loss_of(params, tokens, labels, xkv):
        return M.loss_fn(cfg, params, tokens, labels, xkv=xkv, remat=remat)

    grad_fn = jax.value_and_grad(loss_of)

    def _constrain_micro(x):
        if mesh is None or not data_axes or x is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec
        spec = PartitionSpec(None, data_axes, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    def train_step(state, batch):
        params = state["params"]
        tokens, labels = batch["tokens"], batch["labels"]
        xkv = batch.get("xkv") if has_xkv else None
        if accum_steps > 1:
            B = tokens.shape[0]
            mb = B // accum_steps
            tok = _constrain_micro(
                tokens.reshape(accum_steps, mb, *tokens.shape[1:]))
            lab = _constrain_micro(
                labels.reshape(accum_steps, mb, *labels.shape[1:]))
            xk = (_constrain_micro(
                xkv.reshape(accum_steps, mb, *xkv.shape[1:]))
                  if xkv is not None else None)

            def acc_body(carry, xs):
                loss_acc, g_acc = carry
                t, l = xs[0], xs[1]
                x = xs[2] if len(xs) > 2 else None
                loss, g = grad_fn(params, t, l, x)
                g32 = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                   g_acc, g)
                return (loss_acc + loss, g32), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            xs = (tok, lab) + ((xk,) if xk is not None else ())
            (loss_sum, grads), _ = jax.lax.scan(acc_body, (0.0, g0), xs)
            loss = loss_sum / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        else:
            loss, grads = grad_fn(params, tokens, labels, xkv)
        new_params, new_opt = opt.apply_updates(params, grads, state["opt"],
                                                ocfg)
        metrics = {"loss": loss, "grad_norm": opt.global_norm(grads),
                   "step": new_opt["step"]}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_forward_loss(cfg: ModelConfig, remat: bool = True,
                      has_xkv: bool = False):
    """Forward-only loss (evaluation)."""

    def eval_step(params, batch):
        xkv = batch.get("xkv") if has_xkv else None
        return M.loss_fn(cfg, params, batch["tokens"], batch["labels"],
                         xkv=xkv, remat=remat)

    return eval_step


def make_prefill_step(cfg: ModelConfig, has_xkv: bool = False):
    def prefill_step(params, cache, tokens, xkv=None):
        logits, cache = M.forward(cfg, params, tokens,
                                  xkv=xkv if has_xkv else None, cache=cache)
        return logits[:, -1:], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """One token for every sequence in the batch against the KV cache --
    the `serve_step` the decode_* dry-run shapes lower."""

    def decode_step(params, cache, tokens):
        logits, cache = M.forward(cfg, params, tokens, cache=cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True)
        return next_tok.astype(jnp.int32), logits, cache

    return decode_step


def init_train_state(cfg: ModelConfig, ocfg: opt.AdamWConfig, key,
                     dtype=jnp.bfloat16) -> Params:
    params = M.init_params(cfg, key, dtype=dtype)
    return {"params": params, "opt": opt.init_state(params, ocfg)}
