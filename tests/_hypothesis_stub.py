"""Deterministic stand-in for the small `hypothesis` API surface used by
this suite (`given`, `settings`, `strategies.integers`,
`strategies.composite`).

The container image does not ship `hypothesis`; rather than skip every
property test we replay each one over a fixed, seeded stream of examples.
This keeps the invariants exercised (and failures reproducible) at the cost
of hypothesis' adaptive shrinking.  When the real package is installed the
stub is never imported (see conftest.py).
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

DEFAULT_MAX_EXAMPLES = 25


class Strategy:
    """A value generator: `sample(rng) -> value`."""

    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def composite(fn):
    """`@st.composite` -- fn(draw, *args) becomes a Strategy factory."""

    @functools.wraps(fn)
    def make(*args, **kwargs) -> Strategy:
        def sample(rng: random.Random):
            return fn(lambda strat: strat.sample(rng), *args, **kwargs)

        return Strategy(sample)

    return make


def given(*strategies: Strategy):
    """Drawn values fill the *rightmost* parameters of the test (hypothesis
    semantics); the leading parameters stay visible to pytest as fixtures."""

    def deco(test):
        params = list(inspect.signature(test).parameters.values())
        fixture_params = params[:len(params) - len(strategies)]

        @functools.wraps(test)
        def runner(*args, **kwargs):
            lead = list(args) + [kwargs.pop(p.name) for p in
                                 fixture_params[len(args):]]
            n = getattr(runner, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = random.Random(0xDE17A + 7919 * i)
                vals = [s.sample(rng) for s in strategies]
                try:
                    test(*lead, *vals, **kwargs)
                except BaseException:
                    print(f"[hypothesis stub] falsifying example #{i}: "
                          f"{vals!r}", file=sys.stderr)
                    raise

        # pytest must only see (and inject) the fixture parameters
        runner.__signature__ = inspect.Signature(fixture_params)
        runner.hypothesis_stub = True
        return runner

    return deco


def settings(**kwargs):
    """Only `max_examples` is honoured; the rest (deadline, ...) is noise
    for the stub's fixed replay loop."""

    def deco(fn):
        fn._stub_max_examples = kwargs.get("max_examples",
                                           DEFAULT_MAX_EXAMPLES)
        return fn

    return deco


def install() -> None:
    """Register the stub as `hypothesis` / `hypothesis.strategies`."""
    h = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.Strategy = Strategy
    st.integers = integers
    st.composite = composite
    h.given = given
    h.settings = settings
    h.strategies = st
    h.__stub__ = True
    sys.modules["hypothesis"] = h
    sys.modules["hypothesis.strategies"] = st
