"""Shared fixtures and hypothesis strategies.

NOTE: no XLA_FLAGS here -- smoke tests and benches must see the real
device count (1 on this container); only the dry-run forces 512.
"""
from __future__ import annotations

import numpy as np
import pytest

try:
    from hypothesis import strategies as st
except ModuleNotFoundError:   # container image without hypothesis
    import _hypothesis_stub

    _hypothesis_stub.install()
    from hypothesis import strategies as st

from repro.core.cluster import ClusterSpec
from repro.core.dag import CommDAG, CommTask, Dep, make_virtual
from repro.core.schedule import build_comm_dag
from repro.core.traffic import JobSpec


def gpt7b_job(mb: int = 4, **kw) -> JobSpec:
    """The paper's Fig.-1 profiling setup (4 pods, 2 stages/pod)."""
    defaults = dict(name="gpt7b", tp=2, pp=4, dp=2, num_microbatches=mb,
                    micro_tokens=4096, d_model=4096,
                    stage_params=(1.75e9,) * 4,
                    gpus_per_pod_per_replica=4)
    defaults.update(kw)
    return JobSpec(**defaults)


@pytest.fixture(scope="session")
def small_dag() -> CommDAG:
    return build_comm_dag(gpt7b_job(4), 400.0)


@pytest.fixture(scope="session")
def tiny_dag() -> CommDAG:
    return build_comm_dag(gpt7b_job(2), 400.0)


# ---------------------------------------------------------------- strategies
@st.composite
def random_comm_dags(draw, max_pods: int = 4, max_tasks: int = 10):
    """Random layered inter-pod DAGs with feasible port budgets."""
    num_pods = draw(st.integers(2, max_pods))
    n = draw(st.integers(1, max_tasks))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    tasks = [make_virtual()]
    gid = 0
    for tid in range(1, n + 1):
        src = int(rng.integers(0, num_pods))
        dst = int((src + 1 + rng.integers(0, num_pods - 1)) % num_pods)
        flows = int(rng.integers(1, 4))
        volume = float(rng.uniform(0.5, 4.0) * 1e9)
        src_g = tuple(range(gid, gid + flows))
        dst_g = tuple(range(gid + 1000, gid + 1000 + flows))
        gid += flows
        tasks.append(CommTask(tid, src, dst, flows, volume, src_g, dst_g,
                              kind="rand"))
    deps = [Dep(0, tid, float(rng.uniform(0, 0.02))) for tid in range(1, n + 1)
            if rng.random() < 0.7 or tid == 1]
    for tid in range(2, n + 1):
        if rng.random() < 0.6:
            pre = int(rng.integers(1, tid))
            deps.append(Dep(pre, tid, float(rng.uniform(0, 0.05))))
    # ensure every task is reachable from the virtual source
    reached = {0} | {d.succ for d in deps if d.pre == 0}
    for tid in range(1, n + 1):
        if tid not in reached and not any(d.succ == tid for d in deps):
            deps.append(Dep(0, tid, 0.0))
    # port budget: enough for one circuit per incident pair + slack
    pairs_at = [set() for _ in range(num_pods)]
    for t in tasks[1:]:
        key = tuple(sorted((t.src_pod, t.dst_pod)))
        pairs_at[t.src_pod].add(key)
        pairs_at[t.dst_pod].add(key)
    ports = tuple(max(2, len(p) + int(rng.integers(0, 3)))
                  for p in pairs_at)
    cluster = ClusterSpec(num_pods=num_pods, port_limits=ports,
                          nic_bandwidth=50e9)
    return CommDAG(tasks=tasks, deps=deps, cluster=cluster)


def one_circuit_topology(dag: CommDAG) -> np.ndarray:
    P = dag.cluster.num_pods
    x = np.zeros((P, P), dtype=np.int64)
    for i, j in dag.undirected_pairs():
        x[i, j] = x[j, i] = 1
    return x
