"""RPR001 fixture: one unread field, one read field, one swept class."""
from dataclasses import dataclass
from typing import NamedTuple


@dataclass(frozen=True)
class Spec:
    used: int
    ghost: int  # TP: written at construction, read nowhere


def consume(s: Spec) -> int:
    return s.used  # near miss: `used` is read


class Swept(NamedTuple):
    a: int
    b: int


# near miss: a `_fields` sweep makes Swept's reads untrackable by name,
# so the rule must skip the whole class
_ALL_FIELDS = Swept._fields
