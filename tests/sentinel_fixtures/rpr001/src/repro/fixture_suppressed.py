"""RPR001 fixture: inline suppression silences the finding."""
from dataclasses import dataclass


@dataclass(frozen=True)
class Annotated:
    kept: int = 0  # sentinel: ignore[RPR001]  (provenance-only field)
