"""RPR002 fixture: caller-passed options mutation vs. the safe idioms."""
import dataclasses
from dataclasses import dataclass


@dataclass
class RetryOptions:
    limit: int = 3


def peek(opts: RetryOptions) -> int:
    return opts.limit  # keeps the field read (out of RPR001's scope)


def bad(opts: RetryOptions) -> None:
    opts.limit = 5  # TP: caller's object mutated


def bad_fallback(opts=None) -> None:
    opts = opts or RetryOptions()
    opts.limit = 7  # TP: `or` fallback still aliases the caller's object


def good(opts: RetryOptions) -> None:
    opts = dataclasses.replace(opts, limit=5)
    opts.limit = 9  # near miss: mutation of a local copy


def _private(opts: RetryOptions) -> None:
    opts.limit = 11  # near miss: private helpers own their arguments
