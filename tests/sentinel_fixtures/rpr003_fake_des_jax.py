"""RPR003 fixture (hot-path pathname): explicit float64 under jnp."""
import jax.numpy as jnp


def build_caps(n):
    caps = jnp.zeros((n,), dtype=jnp.float64)  # TP: silent downcast
    rates = jnp.zeros((n,), dtype=jnp.float32)  # near miss: explicit f32
    ids = jnp.arange(n, dtype=jnp.int32)  # near miss: integer dtype
    return caps, rates, ids
