"""RPR004 fixture (hot-path pathname): bare float64-default np arrays."""
import numpy as np


def stage(vals):
    buf = np.zeros((8,))  # TP: float64 default crosses the device seam
    payload = np.array([1.0, 2.0])  # TP: float payload, no dtype
    typed = np.zeros((8,), dtype=np.float32)  # near miss: explicit dtype
    cast = np.array([3.0, 4.0]).astype(np.float32)  # near miss: .astype
    idx = np.array([1, 2])  # near miss: integer payload
    return buf, payload, typed, cast, idx
