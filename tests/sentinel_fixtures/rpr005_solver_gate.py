"""RPR005 fixture: solver payloads used with and without status gates."""


def bad_unpack(md):
    status, x, info = md.solve()
    return x.sum()  # TP: no `x is None` gate


def good_unpack(md):
    status, x, info = md.solve()
    if x is None:  # near miss: gated
        return None
    return x.sum()


def bad_result(dag):
    res = solve_delta_milp(dag)  # noqa: F821 -- fixture, never executed
    return res.x  # TP: payload read, feasible/status never consulted


def good_result(dag):
    res = solve_delta_milp(dag)  # noqa: F821
    if not res.feasible:  # near miss: gated
        return None
    return res.x
