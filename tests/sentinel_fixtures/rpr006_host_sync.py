"""RPR006 fixture: host syncs / Python control flow on traced values."""
import jax
import jax.numpy as jnp


@jax.jit
def bad(a):
    s = jnp.cumsum(a)
    if s[0] > 0:  # TP: branch folded at trace time
        s = s + 1
    return float(s[0])  # TP: host sync on a traced value


@jax.jit
def bad_item(a):
    return jnp.sum(a).item()  # TP: device round-trip inside jit


@jax.jit
def good(a, mode: str = "fast"):
    s = jnp.cumsum(a)
    n = s.shape[0]
    if n > 1:  # near miss: shape is static under trace
        s = s * 2
    if mode == "fast":  # near miss: plain parameter, not traced
        s = s + 1
    return jnp.where(s > 0, s, 0.0)  # near miss: traced branch done right
