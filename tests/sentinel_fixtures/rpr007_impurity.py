"""RPR007 fixture: impure host APIs inside (transitively) jitted code."""
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import span


@jax.jit
def bad(a):
    t0 = time.time()  # TP: runs once at trace time
    b = jnp.sum(a)
    c = np.asarray(b)  # TP: host numpy on a traced operand
    return _helper(b), t0, c


def _helper(b):
    return b * random.random()  # TP: transitively jit-reachable


@jax.jit
def bad_span(a):
    with span("fixture.trace"):  # TP: span fires once at trace time
        return jnp.sum(a)


def host(a):
    t0 = time.time()  # near miss: plain host function, not jit-reachable
    with span("fixture.host"):  # near miss
        return np.asarray(a), t0
