"""RPR008 fixture: cache keys that are not hashable statics."""
import functools
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np

_CACHE = {}


class Cfg(NamedTuple):
    d: int
    e: int


class ArrBox(NamedTuple):
    a: np.ndarray


@dataclass
class MutableBox:
    v: int


def bad_param(arrs: list):
    _CACHE[(arrs, 3)] = 1  # TP: list-annotated parameter in the key


def bad_local():
    k = [1, 2]
    _CACHE[(k, 0)] = 1  # TP: local list in the key


def bad_dataclass():
    b = MutableBox(1)
    _CACHE[(b,)] = 1  # TP: non-frozen dataclass is unhashable


def bad_arraybox(a):
    box = ArrBox(a)
    _CACHE[(box, 2)] = 1  # TP: hash recurses into the ndarray field


@functools.lru_cache
def bad_lru(xs: list):  # TP: unhashable lru_cache parameter
    return sum(xs)


def good(cfg: Cfg, d: int):
    _CACHE[(cfg, d)] = 2  # near miss: scalar NamedTuple + int


@functools.lru_cache
def good_lru(n: int):  # near miss
    return n * 2
