"""RPR009 fixture: deprecated facade calls vs the plan() entry point.

True positives: `bad_direct` (name imported from repro.core.api),
`bad_alias` (attribute call through a module alias).  Near misses: the
unified `plan` call, a same-named helper imported from elsewhere, and an
attribute call on an object that is not the api module.
"""
from repro.core import api
from repro.core.api import PlanRequest, optimize, plan
from repro.other.tools import optimize as tune  # not the facade


def bad_direct(dag):
    return optimize(dag, "delta-fast")          # flagged


def bad_alias(requests):
    return api.fleet_optimize(requests)         # flagged


def good_plan(dag):
    return plan(PlanRequest(dag=dag))           # the replacement


def good_other_import(params):
    return tune(params)                         # different `optimize`


def good_method_call(runner, dag):
    return runner.optimize(dag)                 # not the api module
