"""Traffic-matrix baselines: feasibility + allocation shape."""
import numpy as np
import pytest
from hypothesis import given, settings

from conftest import gpt7b_job, random_comm_dags
from repro.core.baselines import BASELINES, iter_halve, prop_alloc, \
    sqrt_alloc
from repro.core.des import DESProblem, simulate
from repro.core.schedule import build_comm_dag


@pytest.fixture(scope="module")
def dag():
    return build_comm_dag(gpt7b_job(4))


@pytest.mark.parametrize("name", list(BASELINES))
def test_baseline_feasible(dag, name):
    x = BASELINES[name](dag)
    U = dag.cluster.port_limits
    assert (x == x.T).all()
    for p in range(dag.cluster.num_pods):
        assert x[p].sum() <= U[p]
    for i, j in dag.undirected_pairs():
        assert x[i, j] >= 1
    res = simulate(DESProblem(dag), x)
    assert res.feasible


@settings(max_examples=20, deadline=None)
@given(random_comm_dags())
def test_property_baselines_always_feasible(dag):
    for fn in BASELINES.values():
        x = fn(dag)
        U = dag.cluster.port_limits
        for p in range(dag.cluster.num_pods):
            assert x[p].sum() <= U[p]
        assert simulate(DESProblem(dag), x).feasible


def test_prop_alloc_tracks_volume():
    """Heavier pairs never get fewer circuits under Prop-Alloc."""
    dag = build_comm_dag(gpt7b_job(6))
    x = prop_alloc(dag)
    tm = dag.traffic_matrix()
    w = tm + tm.T
    pairs = dag.undirected_pairs()
    for a in pairs:
        for b in pairs:
            if w[a] > 2 * w[b]:
                assert x[a] >= x[b]


def test_variants_differ_on_skewed_traffic():
    dag = build_comm_dag(gpt7b_job(8))
    xs = {n: f(dag) for n, f in BASELINES.items()}
    del xs  # allocations may coincide on tiny instances; smoke only
    assert sqrt_alloc(dag).sum() > 0 and iter_halve(dag).sum() > 0
