"""Traffic-matrix baselines: feasibility + allocation shape."""
import pytest
from hypothesis import given, settings

from conftest import gpt7b_job, random_comm_dags
from repro.core.baselines import BASELINES, iter_halve, prop_alloc, \
    sqrt_alloc
from repro.core.des import DESProblem, simulate
from repro.core.schedule import build_comm_dag


@pytest.fixture(scope="module")
def dag():
    return build_comm_dag(gpt7b_job(4))


@pytest.mark.parametrize("name", list(BASELINES))
def test_baseline_feasible(dag, name):
    x = BASELINES[name](dag)
    U = dag.cluster.port_limits
    assert (x == x.T).all()
    for p in range(dag.cluster.num_pods):
        assert x[p].sum() <= U[p]
    for i, j in dag.undirected_pairs():
        assert x[i, j] >= 1
    res = simulate(DESProblem(dag), x)
    assert res.feasible


@settings(max_examples=20, deadline=None)
@given(random_comm_dags())
def test_property_baselines_always_feasible(dag):
    for fn in BASELINES.values():
        x = fn(dag)
        U = dag.cluster.port_limits
        for p in range(dag.cluster.num_pods):
            assert x[p].sum() <= U[p]
        assert simulate(DESProblem(dag), x).feasible


@settings(max_examples=30, deadline=None)
@given(random_comm_dags(max_pods=5, max_tasks=14))
def test_property_budget_symmetry_connectivity(dag):
    """Structural invariants every TM baseline must uphold on arbitrary
    DAGs: per-pod port budgets are never exceeded, the allocation is a
    symmetric matrix with an empty diagonal, and every active pair gets at
    least one circuit (connectivity before any weighting rule spends the
    remaining budget).  Runs under tests/_hypothesis_stub.py too."""
    U = dag.cluster.port_limits
    pairs = dag.undirected_pairs()
    for name, fn in BASELINES.items():
        x = fn(dag)
        assert (x == x.T).all(), f"{name}: allocation must be symmetric"
        assert (x.diagonal() == 0).all(), f"{name}: self-circuits"
        assert (x >= 0).all(), f"{name}: negative circuits"
        for p in range(dag.cluster.num_pods):
            assert x[p].sum() <= U[p], \
                f"{name}: pod {p} over budget ({x[p].sum()} > {U[p]})"
        for i, j in pairs:
            assert x[i, j] >= 1, f"{name}: active pair ({i},{j}) dark"


def test_prop_alloc_tracks_volume():
    """Heavier pairs never get fewer circuits under Prop-Alloc."""
    dag = build_comm_dag(gpt7b_job(6))
    x = prop_alloc(dag)
    tm = dag.traffic_matrix()
    w = tm + tm.T
    pairs = dag.undirected_pairs()
    for a in pairs:
        for b in pairs:
            if w[a] > 2 * w[b]:
                assert x[a] >= x[b]


def test_variants_differ_on_skewed_traffic():
    dag = build_comm_dag(gpt7b_job(8))
    xs = {n: f(dag) for n, f in BASELINES.items()}
    del xs  # allocations may coincide on tiny instances; smoke only
    assert sqrt_alloc(dag).sum() > 0 and iter_halve(dag).sum() > 0
