"""Control plane: estimators, telemetry synthesis, break-even steering,
hysteresis, journal replay, and the unified plan() facade identity."""
from __future__ import annotations

import json

import numpy as np
import pytest

from conftest import gpt7b_job
from repro.core.api import (FailureModel, FleetOptions, PlanRequest,
                            fleet_optimize, optimize, optimize_ensemble,
                            optimize_failsafe, plan)
from repro.core.dag import DagEnsemble
from repro.core.des import DESProblem, simulate
from repro.core.ga import GAOptions
from repro.core.schedule import build_comm_dag
from repro.core.traffic import JobSpec
from repro.fleet import (ControllerConfig, ControlPlane, FleetPlanner,
                         FleetSpec, JobArrival, PhaseTransition,
                         TelemetrySample, circuit_changes, reallocate,
                         synthesize_telemetry, traffic_drift)
from repro.fleet.events import rebuild_event, serialize_event
from repro.fleet.telemetry import (DEFAULT_DWELL_S, DriftEstimator,
                                   DwellEstimator)
from repro.obs.journal import FleetJournal

GA = GAOptions(pop_size=12, max_generations=25, patience=8, time_limit=5.0,
               seed=0)


def phase_job(mb: int, d_model: int, params: float) -> JobSpec:
    """Same placement footprint (tp/pp/dp fixed), different traffic shape:
    high mb + wide activations = PP-heavy, big stages = DP-heavy."""
    return JobSpec(name="t", tp=2, pp=4, dp=2, num_microbatches=mb,
                   micro_tokens=4096, d_model=d_model,
                   stage_params=(params,) * 4, gpus_per_pod_per_replica=4)


JOB_A = phase_job(8, 4096, 0.2e9)     # PP-heavy phase
JOB_B = phase_job(2, 1024, 3e9)       # DP-heavy phase


def make_planner(**kw) -> FleetPlanner:
    kw.setdefault("reconfig_s_per_circuit", 0.05)
    return FleetPlanner(FleetSpec(num_pods=4, ports_per_pod=8,
                                  nic_gbps=100.0), ga_options=GA, seed=0,
                        **kw)


def drive(cp: ControlPlane, dag, x, *, phase, t0, iterations, **kw):
    for ev in synthesize_telemetry(dag, x, tenant="t", phase=phase, t0=t0,
                                   iterations=iterations, **kw):
        cp.observe(ev)


# ------------------------------------------------------------- estimators
def test_dwell_estimator_convergence():
    est = DwellEstimator(prior_s=600.0, alpha=0.3)
    assert est.estimate() == 600.0
    t = 0.0
    for i in range(40):                   # true dwell 50s, phases alternate
        est.observe_transition(t, "A" if i % 2 == 0 else "B")
        t += 50.0
    assert est.estimate() == pytest.approx(50.0)
    assert est.count == 39
    # heavy-tail correction: a phase already longer than the EWMA is
    # expected to keep running
    last = t - 50.0                       # time of the final transition
    assert est.expected_remaining(last + 500.0) == pytest.approx(500.0)
    assert est.expected_remaining(last + 1.0) == pytest.approx(50.0)


def test_dwell_estimator_first_observation_replaces_prior():
    est = DwellEstimator(prior_s=600.0, alpha=0.3)
    est.observe_transition(0.0, "A")
    est.observe_transition(30.0, "B")     # first closed dwell: 30s
    assert est.estimate() == pytest.approx(30.0)   # not 0.7*600 + 0.3*30
    # a repeated marker for the open phase closes nothing
    assert est.observe_transition(40.0, "B") is None
    assert est.count == 1


def test_traffic_drift_bounds():
    a = np.array([[0.0, 2.0], [0.0, 0.0]])
    b = np.array([[0.0, 0.0], [3.0, 0.0]])
    assert traffic_drift(a, a) == 0.0
    assert traffic_drift(a, 10 * a) == 0.0        # shape, not magnitude
    assert traffic_drift(a, b) == pytest.approx(1.0)
    assert traffic_drift(np.zeros((2, 2)), a) == 0.0


def test_drift_estimator_integrates_windows():
    planned = np.array([[0.0, 1.0], [0.0, 0.0]])
    est = DriftEstimator(tau_s=10.0)
    assert est.drift(planned) == 0.0          # no observations yet
    for _ in range(20):
        est.observe(planned, dt=1.0)
    assert est.drift(planned) == pytest.approx(0.0)
    # one short rogue window barely moves the dt-weighted integral
    est.observe(np.array([[0.0, 0.0], [1.0, 0.0]]), dt=0.1)
    assert est.drift(planned) < 0.05          # raw window TV would be 1.0


def test_drift_estimator_shape_converges_to_volume():
    """Bursty per-window rates (disjoint pair per window) integrate to the
    iteration's volume shape, so within-phase drift ends near zero."""
    vol = np.array([[0.0, 3.0], [1.0, 0.0]])
    w1 = np.array([[0.0, 6.0], [0.0, 0.0]])  # first half: pair (0,1) only
    w2 = np.array([[0.0, 0.0], [2.0, 0.0]])  # second half: pair (1,0) only
    est = DriftEstimator(tau_s=50.0)
    for _ in range(40):
        est.observe(w1, dt=0.5)
        est.observe(w2, dt=0.5)
    assert est.drift(vol) < 0.02
    # each window alone is maximally off-shape
    assert traffic_drift(w1, vol) == pytest.approx(0.25)


# ----------------------------------------------------- telemetry synthesis
def test_synthesized_telemetry_conserves_volume(tiny_dag):
    prob = DESProblem(tiny_dag)
    P = tiny_dag.cluster.num_pods
    x = np.full((P, P), 2); np.fill_diagonal(x, 0)
    events = synthesize_telemetry(tiny_dag, x, tenant="t", phase="A",
                                  iterations=2)
    assert isinstance(events[0], PhaseTransition)
    samples = [e for e in events if isinstance(e, TelemetrySample)]
    n = len(samples) // 2
    moved = sum(np.asarray(s.rates) * s.dt for s in samples[:n])
    vol = tiny_dag.traffic_matrix()
    np.testing.assert_allclose(moved, vol, rtol=1e-6, atol=1e-6)
    # queues drain monotonically within an iteration and restart at the
    # full per-pair volume each iteration
    q0 = np.asarray(samples[0].queues)
    np.testing.assert_allclose(q0, vol)
    totals = [float(np.asarray(s.queues).sum()) for s in samples[:n]]
    assert all(a >= b - 1e-9 for a, b in zip(totals, totals[1:]))
    np.testing.assert_allclose(np.asarray(samples[n].queues), vol)
    del prob


def test_synthesized_telemetry_rejects_infeasible(tiny_dag):
    P = tiny_dag.cluster.num_pods
    with pytest.raises(ValueError):
        synthesize_telemetry(tiny_dag, np.zeros((P, P)), tenant="t")


def test_telemetry_events_round_trip_json():
    s = TelemetrySample(t=1.5, tenant="t", dt=0.25,
                        rates=((0.0, 2.5), (1.0, 0.0)),
                        queues=((0.0, 9.0), (3.0, 0.0)), phase="A")
    p = PhaseTransition(t=2.0, tenant="t", phase="B")
    for ev in (s, p):
        data = json.loads(json.dumps(serialize_event(ev)))
        assert data["v"] == 3
        assert rebuild_event(data) == ev
        # v2 journals (pre-planes) must still rebuild unchanged
        assert rebuild_event({**data, "v": 2}) == ev


# --------------------------------------------------------------- steering
@pytest.fixture(scope="module")
def steered_session():
    """One full monitored session: admit on phase A, drive phase-A then
    phase-B telemetry through a journaling controller until it steers."""
    planner = make_planner(journal=FleetJournal())
    planner.handle(JobArrival(name="t", job=JOB_A))
    x0 = planner.tenants["t"].plan.x.copy()
    # surplus grants are revoked before any event is priced, so the
    # incumbent the steer competes against is the *base* plan
    base_x = planner.tenants["t"].base_plan.x.copy()
    dag_a = build_comm_dag(JOB_A, 100.0)
    dag_b = build_comm_dag(JOB_B, 100.0)
    cfg = ControllerConfig(cadence_s=1.0, confirm_ticks=2, cooldown_s=0.0,
                           drift_threshold=0.05)
    cp = ControlPlane(planner, cfg, phase_book={"t": {"A": JOB_A,
                                                      "B": JOB_B}})
    drive(cp, dag_a, x0, phase="A", t0=0.0, iterations=10)
    drive(cp, dag_b, x0, phase="B", t0=300.0, iterations=40)
    return planner, cp, base_x, cfg


def test_steered_change_clears_break_even(steered_session):
    planner, cp, base_x, _ = steered_session
    applied = [d for d in cp.decisions if "decision" in d]
    assert applied, "controller never steered"
    decision = applied[0]["decision"]
    assert decision["option"] == "replan"
    # the measured dwell (300s of phase A), not the 600s prior, priced it
    assert decision["dwell_s"] == pytest.approx(300.0)
    assert decision["cost_replan_s"] < decision["cost_keep_s"]
    # certified against the exact DES oracle: keeping the incumbent (base
    # plan; grants are revoked before pricing) on the new phase's DAG
    prob = DESProblem(planner.tenants["t"].dag)
    ms_keep = simulate(prob, np.asarray(base_x, dtype=np.float64)).makespan
    assert decision["ms_keep"] == pytest.approx(ms_keep)
    inflation = max(ms_keep / decision["ms_replan"] - 1.0, 0.0)
    assert decision["inflation"] == pytest.approx(inflation)
    assert decision["cost_keep_s"] == pytest.approx(
        decision["dwell_s"] * inflation)
    delay = decision["changed_circuits"] * planner.reconfig_s_per_circuit
    assert decision["delay_s"] == pytest.approx(delay)
    assert decision["dwell_s"] * inflation > delay


def test_steered_dwell_estimate_reaches_planner(steered_session):
    planner, cp, _, _ = steered_session
    assert planner.dwell_for("t") == pytest.approx(300.0)
    assert planner.dwell_for("ghost") == DEFAULT_DWELL_S
    rep = cp.report()
    assert rep["tenants"]["t"]["planned_phase"] == "B"
    assert rep["actions"].get("replan", 0) >= 1


def test_keep_wins_when_dwell_cannot_amortize():
    """Same phase shift, but reconfiguration so expensive (and measured
    dwell so short) that the priced decision keeps the incumbent."""
    planner = make_planner(reconfig_s_per_circuit=1e4)
    planner.handle(JobArrival(name="t", job=JOB_A))
    x0 = planner.tenants["t"].plan.x.copy()
    base_x = planner.tenants["t"].base_plan.x.copy()
    dag_a = build_comm_dag(JOB_A, 100.0)
    dag_b = build_comm_dag(JOB_B, 100.0)
    cfg = ControllerConfig(cadence_s=1.0, confirm_ticks=2, cooldown_s=0.0,
                           drift_threshold=0.05)
    cp = ControlPlane(planner, cfg,
                      phase_book={"t": {"A": JOB_A, "B": JOB_B}})
    drive(cp, dag_a, x0, phase="A", t0=0.0, iterations=10)
    drive(cp, dag_b, x0, phase="B", t0=60.0, iterations=40)
    applied = [d for d in cp.decisions if "decision" in d]
    assert applied and applied[0]["decision"]["option"] == "keep"
    # the incumbent base topology survives (the surplus pass may still
    # boost the working plan on top of it)
    assert np.array_equal(planner.tenants["t"].base_plan.x, base_x)
    assert applied[0]["decision"]["cost_keep_s"] <= \
        applied[0]["decision"]["cost_replan_s"]


def test_hysteresis_short_flap_never_reaches_planner():
    """A phase marker that reverts within the confirm window must produce
    zero steered events (and zero replans)."""
    planner = make_planner()
    planner.handle(JobArrival(name="t", job=JOB_A))
    x0 = planner.tenants["t"].plan.x.copy()
    dag_a = build_comm_dag(JOB_A, 100.0)
    dag_b = build_comm_dag(JOB_B, 100.0)
    cfg = ControllerConfig(cadence_s=5.0, confirm_ticks=3, cooldown_s=0.0,
                           drift_threshold=0.05)
    cp = ControlPlane(planner, cfg,
                      phase_book={"t": {"A": JOB_A, "B": JOB_B}})
    history_before = len(planner.history)
    drive(cp, dag_a, x0, phase="A", t0=0.0, iterations=10)
    # flap: one short burst of B (far shorter than 3 x 5s), then back to A
    drive(cp, dag_b, x0, phase="B", t0=100.0, iterations=2)
    drive(cp, dag_a, x0, phase="A", t0=104.0, iterations=30)
    assert len(planner.history) == history_before   # no TrafficChange
    assert all("decision" not in d for d in cp.decisions)
    assert cp.report()["tenants"]["t"]["planned_phase"] == "A"
    assert np.array_equal(planner.tenants["t"].plan.x, x0)


def test_hysteresis_noisy_rates_do_not_flap():
    """Noisy within-phase rates plus a *stale* B marker: drift vs the
    planned matrix stays put only when B's traffic actually shows up, so
    noise alone (still phase-A-shaped traffic) must not confirm."""
    planner = make_planner()
    planner.handle(JobArrival(name="t", job=JOB_A))
    x0 = planner.tenants["t"].plan.x.copy()
    dag_a = build_comm_dag(JOB_A, 100.0)
    cfg = ControllerConfig(cadence_s=1.0, confirm_ticks=2, cooldown_s=0.0,
                           drift_threshold=0.05)
    cp = ControlPlane(planner, cfg,
                      phase_book={"t": {"A": JOB_A, "B": JOB_B}})
    drive(cp, dag_a, x0, phase="A", t0=0.0, iterations=5)
    # the marker claims B but the (noisy) traffic is still phase A
    cp.observe(PhaseTransition(t=200.0, tenant="t", phase="B"))
    drive(cp, dag_a, x0, phase=None, t0=200.0, iterations=40, noise=0.3,
          rng=np.random.default_rng(7))
    evaluated = [d for d in cp.decisions if d["tenant"] == "t"]
    assert evaluated, "cadence never fired"
    assert all("decision" not in d for d in evaluated)
    assert np.array_equal(planner.tenants["t"].plan.x, x0)


def test_cooldown_limits_steer_rate():
    planner = make_planner()
    planner.handle(JobArrival(name="t", job=JOB_A))
    x0 = planner.tenants["t"].plan.x.copy()
    dag_b = build_comm_dag(JOB_B, 100.0)
    cfg = ControllerConfig(cadence_s=1.0, confirm_ticks=1, cooldown_s=1e9,
                           drift_threshold=0.05)
    cp = ControlPlane(planner, cfg,
                      phase_book={"t": {"A": JOB_A, "B": JOB_B}})
    cp.observe(PhaseTransition(t=0.0, tenant="t", phase="A"))
    cp._last_change["t"] = 0.0          # freshly steered, still cooling
    drive(cp, dag_b, x0, phase="B", t0=10.0, iterations=40)
    assert {d["action"] for d in cp.decisions} == {"cooldown"}
    assert np.array_equal(planner.tenants["t"].plan.x, x0)


# ----------------------------------------------------------------- replay
def test_journal_replay_reproduces_decisions(steered_session, tmp_path):
    planner, cp, _, cfg = steered_session
    path = tmp_path / "session.jsonl"
    with open(path, "w") as f:
        for entry in planner.journal.entries:
            json.dump(entry, f, default=str)
            f.write("\n")
    fresh = make_planner(journal=FleetJournal())
    cp2 = ControlPlane.replay(str(path), fresh, config=cfg,
                              phase_book={"t": {"A": JOB_A, "B": JOB_B}})
    def strip(decisions):
        return [{k: v for k, v in d.items() if k != "decision"}
                for d in decisions]
    assert strip(cp2.decisions) == strip(cp.decisions)
    applied = [d["decision"] for d in cp.decisions if "decision" in d]
    replayed = [d["decision"] for d in cp2.decisions if "decision" in d]
    assert [d["option"] for d in replayed] == \
        [d["option"] for d in applied]
    for a, b in zip(applied, replayed):
        assert a["cost_keep_s"] == pytest.approx(b["cost_keep_s"])
        assert a["cost_replan_s"] == pytest.approx(b["cost_replan_s"])
    np.testing.assert_array_equal(fresh.tenants["t"].plan.x,
                                  planner.tenants["t"].plan.x)
    assert fresh.dwell_for("t") == pytest.approx(planner.dwell_for("t"))


# --------------------------------------------------- realloc break-even
def test_realloc_break_even_gate(tiny_dag):
    """A surplus boost whose rewiring cost exceeds the dwell-weighted
    saving is rejected (details flag the break-even), and accepted again
    when the dwell amortizes it."""
    P = tiny_dag.cluster.num_pods
    x0 = np.full((P, P), 1); np.fill_diagonal(x0, 0)
    prob = DESProblem(tiny_dag)
    ideal = simulate(prob, np.zeros((P, P)), ideal=True)
    boosted = np.full(P, 8)
    kw = dict(ideal_comm_time=ideal.comm_time, num_random=4,
              rng=np.random.default_rng(0))
    res_free = reallocate(tiny_dag, x0, boosted, **kw)
    assert res_free.improved          # boost helps when rewiring is free
    res_gated = reallocate(tiny_dag, x0, boosted, dwell_s=1e-6,
                           reconfig_s_per_circuit=1e3, **kw)
    assert not res_gated.improved
    assert res_gated.details.get("rejected") == "break_even"
    np.testing.assert_array_equal(res_gated.x, x0)
    res_long = reallocate(tiny_dag, x0, boosted, dwell_s=1e12,
                          reconfig_s_per_circuit=1e-9, **kw)
    assert res_long.improved
    np.testing.assert_array_equal(res_long.x, res_free.x)


# ------------------------------------------------------- plan() facade
def test_plan_request_kind_validation(tiny_dag):
    with pytest.raises(ValueError):
        PlanRequest().kind
    with pytest.raises(ValueError):
        PlanRequest(dag=tiny_dag, fleet_requests=[("a", JOB_A)]).kind
    assert PlanRequest(dag=tiny_dag).kind == "dag"
    assert PlanRequest(dag=tiny_dag, failure=FailureModel()).kind \
        == "failsafe"
    assert PlanRequest(dag=tiny_dag,
                       failure=FailureModel(resilient=True)).kind \
        == "resilient"
    assert PlanRequest(fleet_requests=[("a", JOB_A)]).kind == "fleet"


def test_plan_matches_optimize_bit_identical(tiny_dag):
    legacy = optimize(tiny_dag, "delta-fast", ga_options=GA)
    unified = plan(PlanRequest(dag=tiny_dag, ga_options=GA))
    np.testing.assert_array_equal(legacy.x, unified.x)
    assert legacy.makespan == unified.makespan
    assert legacy.nct == unified.nct
    assert legacy.total_ports == unified.total_ports


def test_plan_matches_ensemble_and_failsafe_bit_identical(tiny_dag):
    ens = DagEnsemble([tiny_dag, build_comm_dag(gpt7b_job(4), 400.0)])
    a = optimize_ensemble(ens, objective="max-regret", ga_options=GA)
    b = plan(PlanRequest(ensemble=ens, objective="max-regret",
                         ga_options=GA))
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.makespans, b.makespans)
    assert a.worst_regret == b.worst_regret
    fa = optimize_failsafe(tiny_dag, num_planes=2, k=1, ga_options=GA)
    fb = plan(PlanRequest(dag=tiny_dag, ga_options=GA,
                          failure=FailureModel(num_planes=2, k=1)))
    np.testing.assert_array_equal(fa.x, fb.x)
    assert fa.makespan == fb.makespan


def test_plan_matches_fleet_bit_identical():
    a_planner, a_report = fleet_optimize([("a", JOB_A)], ga_options=GA)
    res = plan(PlanRequest(fleet_requests=[("a", JOB_A)], ga_options=GA,
                           fleet=FleetOptions()))
    b_planner, b_report = res           # FleetPlanResult unpacks
    np.testing.assert_array_equal(a_planner.tenants["a"].plan.x,
                                  b_planner.tenants["a"].plan.x)
    assert a_report["tenants"].keys() == b_report["tenants"].keys()
