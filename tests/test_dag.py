"""Structure tests for the 1F1B schedule -> reduced inter-pod DAG."""
import collections

import numpy as np
import pytest

from conftest import gpt7b_job
from repro.core.des import DESProblem, simulate
from repro.core.schedule import build_comm_dag, order_1f1b


def test_1f1b_order_first_and_last_stage():
    assert order_1f1b(0, 4, 4) == [("F", 1), ("F", 2), ("F", 3), ("F", 4),
                                   ("B", 1), ("B", 2), ("B", 3), ("B", 4)]
    assert order_1f1b(3, 4, 4) == [("F", 1), ("B", 1), ("F", 2), ("B", 2),
                                   ("F", 3), ("B", 3), ("F", 4), ("B", 4)]


@pytest.mark.parametrize("mb", [1, 2, 4, 8])
def test_1f1b_order_complete(mb):
    for s in range(4):
        order = order_1f1b(s, 4, mb)
        fwd = [b for k, b in order if k == "F"]
        bwd = [b for k, b in order if k == "B"]
        assert fwd == list(range(1, mb + 1))
        assert bwd == list(range(1, mb + 1))
        # every backward b comes after forward b
        pos = {op: i for i, op in enumerate(order)}
        for b in range(1, mb + 1):
            assert pos[("F", b)] < pos[("B", b)]


def test_task_counts_match_paper_footnote():
    # one stage per pod: PP tasks = 2*(PP-1)*MB per replica, DP tasks = PP
    # per ring link; reduced single-replica projection models 2 links.
    job = gpt7b_job(mb=8, tp=2, gpus_per_pod_per_replica=2)
    dag = build_comm_dag(job)
    kinds = collections.Counter(t.kind for t in dag.real_tasks())
    assert kinds["pp_fwd"] == (job.pp - 1) * 8
    assert kinds["pp_bwd"] == (job.pp - 1) * 8
    assert kinds["dp"] == 2 * job.pp


def test_pp_tasks_aggregate_tp_flows(small_dag):
    for t in small_dag.real_tasks():
        if t.kind.startswith("pp"):
            assert t.flows == 2  # tp = 2
            assert t.volume == 4096 * 4096 * 2  # micro_tokens*d_model*bytes


def test_intra_pod_boundaries_excluded():
    # 2 stages per pod -> boundary 0-1 and 2-3 intra-pod, only 1-2 crosses
    job = gpt7b_job(mb=4)  # gppr=4, tp=2 -> 2 stages/pod
    dag = build_comm_dag(job)
    kinds = collections.Counter(t.kind for t in dag.real_tasks())
    assert kinds["pp_fwd"] == 4  # one crossing boundary x 4 microbatches


def test_reversed_placement_maps_stages_backwards():
    job = gpt7b_job(4)
    p = job.placement()
    pr = job.placement(reverse_stages=True)
    assert p.pod_of(0, 0) == pr.pod_of(0, job.pp - 1)
    assert p.pod_of(0, job.pp - 1) == pr.pod_of(0, 0)
    assert p.num_pods == pr.num_pods


def test_virtual_task_precedes_everything(small_dag):
    reach = {0}
    order = small_dag.topo_order()
    preds = small_dag.preds()
    for v in order:
        if v == 0:
            continue
        assert any(d.pre in reach for d in preds.get(v, [])), \
            f"task {v} unreachable from virtual source"
        reach.add(v)


def test_dag_deltas_nonnegative(small_dag):
    assert all(d.delta >= 0 for d in small_dag.deps)


def test_dominance_pruning_preserves_makespan():
    from conftest import one_circuit_topology
    job = gpt7b_job(4)
    d1 = build_comm_dag(job, prune_dominated=True)
    d0 = build_comm_dag(job, prune_dominated=False)
    assert len(d1.deps) <= len(d0.deps)
    x = one_circuit_topology(d0)
    m1 = simulate(DESProblem(d1), x).makespan
    m0 = simulate(DESProblem(d0), x).makespan
    assert m1 == pytest.approx(m0, rel=1e-9)


def test_full_instance_vs_reduced_replica_consistency():
    """dp=2 with symmetric placement: reduced projection == full instance."""
    from conftest import one_circuit_topology
    job = gpt7b_job(3)
    d_red = build_comm_dag(job, reduce_replicas=True)
    d_full = build_comm_dag(job, reduce_replicas=False)
    m_red = simulate(DESProblem(d_red), one_circuit_topology(d_red)).makespan
    m_full = simulate(DESProblem(d_full),
                      one_circuit_topology(d_full)).makespan
    assert m_red == pytest.approx(m_full, rel=1e-6)


def test_whisper_encdec_dag_has_xattn_tasks():
    from repro.configs import REGISTRY, make_job
    from repro.core.schedule import build_comm_dag as bcd
    job = make_job(REGISTRY["whisper-large-v3"], microbatches=4)
    dag = bcd(job)
    kinds = collections.Counter(t.kind for t in dag.real_tasks())
    assert kinds.get("xattn", 0) > 0
    assert kinds.get("dp", 0) > 0


def test_traffic_matrix_symmetric_volumes(small_dag):
    tm = small_dag.traffic_matrix()
    # PP fwd one way == PP bwd other way; DP is ring-symmetric here
    assert tm.sum() > 0
    np.testing.assert_allclose(tm, tm.T, rtol=1e-6)
