"""DES engine: hand-checkable cases + hypothesis invariants."""
import numpy as np
import pytest
from hypothesis import given, settings

from conftest import one_circuit_topology, random_comm_dags
from repro.core.cluster import ClusterSpec
from repro.core.dag import CommDAG, CommTask, Dep, make_virtual
from repro.core.des import DESProblem, evaluate_nct, maxmin_fair_rates, \
    simulate


def _two_pod_cluster(B=1.0):
    return ClusterSpec(num_pods=2, port_limits=(8, 8), nic_bandwidth=B)


def _dag(tasks, deps, cluster=None):
    return CommDAG([make_virtual()] + tasks, deps,
                   cluster or _two_pod_cluster())


def test_two_tasks_share_link_fairly():
    dag = _dag(
        [CommTask(1, 0, 1, 1, 1.0, (0,), (10,)),
         CommTask(2, 0, 1, 1, 1.0, (1,), (11,))],
        [Dep(0, 1, 0.0), Dep(0, 2, 0.0)])
    res = simulate(DESProblem(dag), np.array([[0, 1], [1, 0]]))
    assert res.makespan == pytest.approx(2.0)
    assert res.finish[1] == pytest.approx(2.0)


def test_staggered_third_task():
    dag = _dag(
        [CommTask(1, 0, 1, 1, 1.0, (0,), (10,)),
         CommTask(2, 0, 1, 1, 1.0, (1,), (11,)),
         CommTask(3, 0, 1, 1, 1.0, (2,), (12,))],
        [Dep(0, 1, 0.0), Dep(0, 2, 0.0), Dep(0, 3, 0.5)])
    res = simulate(DESProblem(dag), np.array([[0, 1], [1, 0]]))
    # 0.5s at rate 1/2 each, then 1/3 each until 1&2 done, then 3 alone
    assert res.makespan == pytest.approx(3.0)
    assert res.start[3] == pytest.approx(0.5)


def test_chain_critical_path():
    dag = _dag(
        [CommTask(1, 0, 1, 1, 1.0, (0,), (10,)),
         CommTask(2, 1, 0, 1, 1.0, (10,), (0,))],
        [Dep(0, 1, 0.0), Dep(1, 2, 0.5)])
    res = simulate(DESProblem(dag), np.array([[0, 1], [1, 0]]))
    assert res.makespan == pytest.approx(2.5)
    assert res.critical_path == [0, 1, 2]
    assert res.crit_delta == pytest.approx(0.5)
    assert res.comm_time == pytest.approx(2.0)


def test_nic_constraint_binds():
    # one GPU sources both tasks to different pods: NIC halves each rate
    cluster = ClusterSpec(num_pods=3, port_limits=(4, 4, 4),
                          nic_bandwidth=1.0)
    dag = _dag([CommTask(1, 0, 1, 1, 1.0, (0,), (10,)),
                CommTask(2, 0, 2, 1, 1.0, (0,), (20,))],
               [Dep(0, 1, 0.0), Dep(0, 2, 0.0)], cluster)
    x = np.zeros((3, 3), dtype=int)
    x[0, 1] = x[1, 0] = x[0, 2] = x[2, 0] = 2  # links not the bottleneck
    res = simulate(DESProblem(dag), x)
    assert res.makespan == pytest.approx(2.0)


def test_weighted_flows_share():
    # task1 has 3 flows, task2 has 1; per-flow fairness -> 3:1 rate split
    dag = _dag(
        [CommTask(1, 0, 1, 3, 3.0, (0, 1, 2), (10, 11, 12)),
         CommTask(2, 0, 1, 1, 1.0, (3,), (13,))],
        [Dep(0, 1, 0.0), Dep(0, 2, 0.0)])
    prob = DESProblem(dag)
    caps = prob.link_caps(np.array([[0, 4], [4, 0]]))
    active = np.array([False, True, True])
    rates = maxmin_fair_rates(prob, active, caps)
    assert rates[1] == pytest.approx(3.0)
    assert rates[2] == pytest.approx(1.0)


def test_infeasible_topology():
    dag = _dag([CommTask(1, 0, 1, 1, 1.0, (0,), (10,))], [Dep(0, 1, 0.0)])
    res = simulate(DESProblem(dag), np.zeros((2, 2)))
    assert not res.feasible and res.makespan == np.inf


@settings(max_examples=20, deadline=None)
@given(random_comm_dags())
def test_link_caps_matches_loop_reference(dag):
    """Vectorized capacity gather == the per-pair loop it replaced."""
    prob = DESProblem(dag)
    x = one_circuit_topology(dag) * 3
    for ideal in (False, True):
        caps = prob.link_caps(x, ideal=ideal)
        ref = np.empty(prob.num_cons)
        for i, (a, b) in enumerate(prob.pairs):
            ref[i] = np.inf if ideal else float(x[a, b]) * prob.B
        ref[prob.num_link_cons:] = prob.B
        assert np.array_equal(caps, ref)


@settings(max_examples=40, deadline=None)
@given(random_comm_dags())
def test_property_invariants(dag):
    prob = DESProblem(dag)
    x = one_circuit_topology(dag)
    res = simulate(prob, x)
    assert res.feasible
    n = dag.num_tasks
    # precedence respected
    for d in dag.deps:
        assert res.start[d.succ] >= res.finish[d.pre] + d.delta - 1e-9
    # finish after start, makespan is max finish
    real = slice(1, n)
    assert (res.finish[real] >= res.start[real] - 1e-12).all()
    assert res.makespan == pytest.approx(np.max(res.finish[real]))
    # tasks can never beat their minimum physical duration
    for t in dag.real_tasks():
        tau_min = t.volume / (t.flows * dag.cluster.nic_bandwidth)
        assert res.finish[t.tid] - res.start[t.tid] >= tau_min * (1 - 1e-9)
    # critical path decomposition: makespan == sum(tau) + sum(delta)
    assert 0 <= res.crit_delta <= res.makespan + 1e-12


@settings(max_examples=25, deadline=None)
@given(random_comm_dags())
def test_property_more_circuits_never_hurt(dag):
    prob = DESProblem(dag)
    x1 = one_circuit_topology(dag)
    m1 = simulate(prob, x1).makespan
    m2 = simulate(prob, x1 * 2).makespan
    ideal = simulate(prob, x1, ideal=True).makespan
    assert m2 <= m1 * (1 + 1e-9)
    assert ideal <= m2 * (1 + 1e-9)


@settings(max_examples=25, deadline=None)
@given(random_comm_dags())
def test_property_nct_at_least_one(dag):
    rep = evaluate_nct(DESProblem(dag), one_circuit_topology(dag))
    assert rep.nct >= 1 - 1e-6
    # contention can only slow the end-to-end makespan down, too (RPR001:
    # stretch is the consumer of NCTReport.ideal_makespan)
    assert rep.stretch >= 1 - 1e-6


def test_rate_trace_conserves_volume(small_dag):
    prob = DESProblem(small_dag)
    x = one_circuit_topology(small_dag)
    res = simulate(prob, x, record_rates=True)
    sent = np.zeros(small_dag.num_tasks)
    for t0, t1, rates in res.rate_trace:
        sent += rates * (t1 - t0)
    for t in small_dag.real_tasks():
        assert sent[t.tid] == pytest.approx(t.volume, rel=1e-6)
