"""Kernel-fused DES: waterfill-backend parity (segment / ref / pallas
interpret) against a pure-numpy max-min reference, bucket-padding
equivalence, the module-level compile cache, and batched ensemble
trimming."""
import logging

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp
from conftest import gpt7b_job, one_circuit_topology
from repro.core.cluster import ClusterSpec
from repro.core.dag import CommDAG, CommTask, DagEnsemble, Dep, make_virtual
from repro.core.des import DESProblem, simulate
from repro.core.des_jax import (DESArrays, DESOptions, EnsembleJaxDES,
                                JaxDES, PadSpec, _maxmin, des_cache_clear,
                                des_cache_stats)
from repro.core.ga import trim_ports_ensemble
from repro.core.schedule import build_comm_dag

RTOL = 5e-5  # jax runs in f32 by default


# ------------------------------------------------- numpy max-min reference
def maxmin_numpy_ref(n, C, con_task, con_id, con_w, flows, active, caps):
    """Pure-numpy weighted max-min fair-share oracle (progressive filling,
    float64): the semantics every `_maxmin` backend must reproduce."""
    phi = np.zeros(n)
    unfrozen = active.copy()
    for _ in range(C + 1):
        if not unfrozen.any():
            break
        used = np.zeros(C)
        denom = np.zeros(C)
        np.add.at(used, con_id,
                  np.where(active[con_task], con_w, 0.0) * phi[con_task])
        np.add.at(denom, con_id, np.where(unfrozen[con_task], con_w, 0.0))
        slack = caps - used
        with np.errstate(divide="ignore", invalid="ignore"):
            alpha_c = np.where(denom > 0,
                               slack / np.maximum(denom, 1e-300), np.inf)
        alpha = max(float(alpha_c.min()), 0.0)
        if not np.isfinite(alpha):
            break
        phi[unfrozen] += alpha
        sat = np.isfinite(alpha_c) & (alpha_c <= alpha * (1 + 1e-9) + 1e-18)
        task_sat = np.zeros(n, dtype=bool)
        task_sat[con_task[sat[con_id]]] = True
        unfrozen = unfrozen & ~task_sat
    return flows * phi * active


def _synthetic_arrays(n, C, con_task, con_id, con_w, flows) -> DESArrays:
    """DESArrays carrying only the fields `_maxmin` consumes."""
    z = np.zeros(1, dtype=np.int32)
    return DESArrays(
        volume=jnp.ones(n), flows=jnp.asarray(flows),
        dep_pre=jnp.asarray(z), dep_succ=jnp.asarray(z),
        dep_delta=jnp.zeros(1), indegree=jnp.zeros(n, dtype=jnp.int32),
        con_task=jnp.asarray(con_task, dtype=jnp.int32),
        con_id=jnp.asarray(con_id, dtype=jnp.int32),
        con_w=jnp.asarray(con_w), link_pair_a=jnp.asarray(z),
        link_pair_b=jnp.asarray(z), task_valid=jnp.ones(n, dtype=bool),
        num_cons=C, num_link_cons=0, nic_bandwidth=1.0, n=n)


@st.composite
def maxmin_instances(draw):
    """Random active-flow / capacity instances where every task belongs to
    at least one finite-capacity constraint (so filling always saturates)."""
    n = draw(st.integers(1, 12))
    C = draw(st.integers(1, 6))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    # guarantee coverage: task m is a member of constraint m % C, plus
    # random extra memberships
    pairs = {(m % C, m) for m in range(n)}
    for _ in range(int(rng.integers(0, 2 * n + 1))):
        pairs.add((int(rng.integers(0, C)), int(rng.integers(0, n))))
    con_id, con_task = map(np.asarray, zip(*sorted(pairs)))
    con_w = rng.uniform(0.1, 3.0, size=len(con_id))
    flows = rng.uniform(1.0, 4.0, size=n)
    caps = rng.uniform(0.1, 5.0, size=C)
    active = rng.random(n) < 0.8
    return n, C, con_task, con_id, con_w, flows, active, caps


@pytest.mark.parametrize("backend", ["segment", "ref", "pallas"])
@settings(max_examples=25, deadline=None)
@given(maxmin_instances())
def test_property_maxmin_matches_numpy(backend, instance):
    n, C, con_task, con_id, con_w, flows, active, caps = instance
    arr = _synthetic_arrays(n, C, con_task, con_id, con_w, flows)
    got = np.asarray(_maxmin(arr, jnp.asarray(active), jnp.asarray(caps),
                             backend=backend, interpret=True))
    want = maxmin_numpy_ref(n, C, con_task, con_id, con_w, flows, active,
                            caps)
    # f32 vs f64 can flip a freeze decision on a near-tie, so compare with
    # a tolerance wide enough for one filling level of drift...
    assert np.allclose(got, want, rtol=5e-3, atol=1e-4)
    # ...and check the defining invariants exactly: no rate on inactive
    # tasks, non-negative rates, and no constraint over capacity
    assert (got[~active] == 0).all()
    assert (got >= 0).all()
    used = np.zeros(C)
    np.add.at(used, con_id, con_w * (got / flows)[con_task])
    assert (used <= caps * (1 + 1e-3) + 1e-4).all()


def test_maxmin_single_link_fair_share():
    """Three 1-flow tasks on one cap-2 link: each gets 2/3."""
    arr = _synthetic_arrays(3, 1, np.arange(3), np.zeros(3, dtype=int),
                            np.ones(3), np.ones(3))
    for backend in ("segment", "ref", "pallas"):
        got = np.asarray(_maxmin(arr, jnp.ones(3, dtype=bool),
                                 jnp.asarray([2.0]), backend=backend,
                                 interpret=True))
        assert np.allclose(got, 2.0 / 3.0, rtol=1e-6)


# ------------------------------------------------ engine parity on real DAGs
@pytest.fixture(scope="module")
def dag():
    return build_comm_dag(gpt7b_job(2))


def test_backends_match_numpy_end_to_end(dag):
    """Every kernel backend reproduces the numpy DES makespan through the
    full event loop (the pallas path runs in interpret mode off-TPU, so CI
    exercises the kernel body on every run)."""
    prob = DESProblem(dag)
    x = one_circuit_topology(dag)
    want = simulate(prob, x)
    x2 = x * 2
    want2 = simulate(prob, x2)
    for backend in ("segment", "ref", "pallas"):
        jd = JaxDES(prob, options=DESOptions(backend=backend,
                                             interpret=True))
        ms, feas, *_ = jd.simulate(x)
        assert feas == want.feasible
        assert ms == pytest.approx(want.makespan, rel=RTOL), backend
        # the batched (vmap) path wraps the same kernel loop
        ms_b, feas_b = jd.batch_makespan(np.stack([x, x2]))
        assert feas_b.all() == (want.feasible and want2.feasible)
        assert ms_b[0] == pytest.approx(want.makespan, rel=RTOL), backend
        assert ms_b[1] == pytest.approx(want2.makespan, rel=RTOL), backend


def test_bucket_padding_is_exact(dag):
    """Bucket-padded simulation equals the exact-shape one bit-for-bit
    (ghost tasks contribute zero to every reduction) and strips the ghost
    tasks from start/finish."""
    prob = DESProblem(dag)
    x = one_circuit_topology(dag)
    opts = dict(backend="ref")
    jd_b = JaxDES(prob, options=DESOptions(bucket=True, **opts))
    jd_e = JaxDES(prob, options=DESOptions(bucket=False, **opts))
    assert jd_b.pad.n > prob.n >= jd_e.pad.n
    ms_b, feas_b, start_b, finish_b = jd_b.simulate(x)
    ms_e, feas_e, start_e, finish_e = jd_e.simulate(x)
    assert ms_b == ms_e and feas_b == feas_e
    assert start_b.shape == (prob.n,) and finish_b.shape == (prob.n,)
    np.testing.assert_array_equal(start_b, start_e)
    np.testing.assert_array_equal(finish_b, finish_e)


def test_pad_spec_quantization():
    spec = PadSpec(n=17, d=40, e=48, links=6, cons=22)
    b = spec.bucketed(DESOptions(bucket_quantum=64,
                                 bucket_quantum_cons=8).resolve())
    assert b == PadSpec(n=64, d=64, e=64, links=8, cons=24)
    # already-aligned sizes stay put
    assert b.bucketed(DESOptions(bucket_quantum=64,
                                 bucket_quantum_cons=8).resolve()) == b


# --------------------------------------------------------- compile cache
def test_compile_cache_shared_across_instances(dag):
    des_cache_clear()
    prob = DESProblem(dag)
    opts = DESOptions(backend="ref", bucket=True)
    JaxDES(prob, options=opts)
    stats = des_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 0
    JaxDES(prob, options=opts)           # same bucket: no recompile
    JaxDES(DESProblem(dag), options=opts)
    stats = des_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 2
    assert stats["entries"] == 1


def test_compile_cache_miss_warns(dag, caplog):
    des_cache_clear()
    prob = DESProblem(dag)
    with caplog.at_level(logging.WARNING, logger="repro.des_jax"):
        JaxDES(prob, options=DESOptions(backend="ref",
                                        warn_on_miss=True))
    assert any("compile-cache miss" in r.message for r in caplog.records)
    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.des_jax"):
        JaxDES(prob, options=DESOptions(backend="ref",
                                        warn_on_miss=True))
    assert not caplog.records           # hit: silent


def test_ensemble_bucket_shares_member_shapes(dag):
    """Two ensembles whose members land in the same bucket share one
    compiled entry."""
    des_cache_clear()
    p2 = DESProblem(dag)
    p3 = DESProblem(build_comm_dag(gpt7b_job(3)))
    opts = DESOptions(backend="ref", bucket=True)
    EnsembleJaxDES([p2, p3], options=opts)
    EnsembleJaxDES([p3, p2], options=opts)
    stats = des_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 1


# ------------------------------------------------- batched ensemble trimming
def _wide_member(cluster, volumes) -> CommDAG:
    tasks = [make_virtual()]
    deps = []
    tid = 0
    gid = 0
    P = cluster.num_pods
    for i in range(P):
        for j in range(i + 1, P):
            tid += 1
            v = float(volumes[(i * P + j) % len(volumes)])
            tasks.append(CommTask(tid, i, j, 2, v, (gid, gid + 1),
                                  (gid + 500, gid + 501), kind="wide"))
            gid += 2
            deps.append(Dep(0, tid, 0.0))
    return CommDAG(tasks=tasks, deps=deps, cluster=cluster)


@pytest.fixture(scope="module")
def wide_ensemble():
    P = 7                                # 21 undirected pairs (>= 16)
    cluster = ClusterSpec(num_pods=P, port_limits=(40,) * P,
                          nic_bandwidth=50e9)
    rng = np.random.default_rng(7)
    a = _wide_member(cluster, rng.uniform(0.5, 2.0, 21) * 1e9)
    b = _wide_member(cluster, rng.uniform(0.5, 2.0, 21) * 1e9)
    return DagEnsemble([a, b], names=["a", "b"])


def test_trim_ports_ensemble_batched_matches_serial(wide_ensemble):
    """The batched candidates-x-members sweep reproduces the serial
    member-by-member sweep exactly on a wide fabric."""
    pairs = wide_ensemble.undirected_pairs()
    P = wide_ensemble.cluster.num_pods
    x = np.zeros((P, P), dtype=np.int64)
    for i, j in pairs:
        x[i, j] = x[j, i] = 3
    got = trim_ports_ensemble(wide_ensemble, x, backend="jax")
    want = trim_ports_ensemble(wide_ensemble, x, backend="numpy")
    assert (got == want).all()
    assert got.sum() < x.sum()           # the sweep had real work to do
    # budgets hold for every member
    base = [simulate(DESProblem(m), x).makespan
            for m in wide_ensemble.members]
    for m, b in zip(wide_ensemble.members, base):
        assert simulate(DESProblem(m), got).makespan <= b * (1 + 1e-6)


def test_trim_ports_ensemble_off_pair_circuits_stay_serial():
    """Circuits outside the union pairs are invisible to the genome
    scatter: the batched path must refuse and fall back to the serial
    sweep (identical result, off-pair circuits preserved)."""
    P = 7
    cluster = ClusterSpec(num_pods=P, port_limits=(40,) * P,
                          nic_bandwidth=50e9)
    # members only touch pods 1..6, so pair (0, 1) is outside the union
    rng = np.random.default_rng(3)

    def member(volumes):
        tasks, deps = [make_virtual()], []
        tid = gid = 0
        for i in range(1, P):
            for j in range(i + 1, P):
                tid += 1
                v = float(volumes[tid % len(volumes)])
                tasks.append(CommTask(tid, i, j, 2, v, (gid, gid + 1),
                                      (gid + 500, gid + 501), kind="wide"))
                gid += 2
                deps.append(Dep(0, tid, 0.0))
        return CommDAG(tasks=tasks, deps=deps, cluster=cluster)

    ens = DagEnsemble([member(rng.uniform(0.5, 2.0, 15) * 1e9),
                       member(rng.uniform(0.5, 2.0, 15) * 1e9)])
    x = np.zeros((P, P), dtype=np.int64)
    for i, j in ens.undirected_pairs():
        x[i, j] = x[j, i] = 3
    x[0, 1] = x[1, 0] = 2                # off-union circuits
    got = trim_ports_ensemble(ens, x, backend="jax")
    want = trim_ports_ensemble(ens, x, backend="numpy")
    assert (got == want).all()
    assert got[0, 1] == 2 and got[1, 0] == 2


# --------------------------------------------------------- fleet ref cache
def test_fleet_robust_refs_come_from_plan_cache():
    """plan_robust's max-regret reference runs are the members' single-DAG
    plans: they must be served by the fleet PlanCache, not re-solved."""
    from repro.core.ga import GAOptions
    from repro.fleet import FleetPlanner, FleetSpec, JobArrival, TrafficChange

    opts = GAOptions(seed=0, pop_size=12, max_generations=4, patience=10**9,
                     time_limit=30.0)
    fp = FleetPlanner(FleetSpec(num_pods=4, ports_per_pod=8),
                      ga_options=opts, robust_replan=True)
    fp.handle(JobArrival(name="j", job=gpt7b_job(2)))
    rec = fp.handle(TrafficChange(name="j",
                                  job=gpt7b_job(2, micro_tokens=16384)))
    assert rec["robust"] and rec["robust_members"] == 2
    details = fp.tenants["j"].plan.details
    # the incumbent phase's ref was already in the cache from admission
    assert details["ref_cache_hits"] >= 1
    # flipping back re-solves only the robust plan (the primary DAG hash
    # changed) -- BOTH member refs come from the cache
    misses_before = fp.cache.misses
    rec2 = fp.handle(TrafficChange(name="j", job=gpt7b_job(2)))
    assert rec2["robust"]
    assert fp.cache.misses == misses_before + 1
    assert fp.tenants["j"].plan.details["ref_cache_hits"] == 2
