"""JAX DES must match the numpy engine (f32 tolerance)."""
import numpy as np
import pytest
from hypothesis import given, settings

from conftest import gpt7b_job, one_circuit_topology, random_comm_dags
from repro.core.des import DESProblem, simulate
from repro.core.des_jax import JaxDES
from repro.core.schedule import build_comm_dag

RTOL = 5e-5  # jax runs in f32 by default


@settings(max_examples=15, deadline=None)
@given(random_comm_dags(max_pods=3, max_tasks=8))
def test_property_matches_numpy(dag):
    prob = DESProblem(dag)
    jd = JaxDES(prob)
    x = one_circuit_topology(dag)
    r = simulate(prob, x)
    ms, feas, start, finish = jd.simulate(x)
    assert feas == r.feasible
    if r.feasible:
        assert ms == pytest.approx(r.makespan, rel=RTOL)


def test_gpt7b_grid_matches_numpy():
    dag = build_comm_dag(gpt7b_job(4))
    prob = DESProblem(dag)
    jd = JaxDES(prob)
    rng = np.random.default_rng(0)
    P = dag.cluster.num_pods
    for _ in range(6):
        x = np.zeros((P, P), dtype=int)
        for i, j in dag.undirected_pairs():
            x[i, j] = x[j, i] = rng.integers(1, 3)
        r = simulate(prob, x)
        ms, feas, *_ = jd.simulate(x)
        assert feas == r.feasible
        assert ms == pytest.approx(r.makespan, rel=RTOL)


def test_batched_equals_single():
    dag = build_comm_dag(gpt7b_job(3))
    prob = DESProblem(dag)
    jd = JaxDES(prob)
    rng = np.random.default_rng(1)
    P = dag.cluster.num_pods
    xs = []
    for _ in range(8):
        x = np.zeros((P, P), dtype=int)
        for i, j in dag.undirected_pairs():
            x[i, j] = x[j, i] = rng.integers(1, 4)
        xs.append(x)
    xs = np.stack(xs)
    ms_b, feas_b = jd.batch_makespan(xs)
    for i in range(len(xs)):
        ms, feas, *_ = jd.simulate(xs[i])
        assert feas == bool(feas_b[i])
        assert ms == pytest.approx(float(ms_b[i]), rel=1e-6)


def test_ideal_mode():
    dag = build_comm_dag(gpt7b_job(3))
    prob = DESProblem(dag)
    jd = JaxDES(prob)
    x = one_circuit_topology(dag)
    ideal_np = simulate(prob, x, ideal=True).makespan
    ideal_jx = jd.makespan(x, ideal=True)
    assert ideal_jx == pytest.approx(ideal_np, rel=RTOL)
