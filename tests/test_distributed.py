"""Sharding rules, int8 ring all-reduce (subprocess with fake devices),
and API-level plan comparison."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import model as M


def test_param_specs_cover_all_archs_1device():
    mesh = make_host_mesh(1)
    for arch in sorted(REGISTRY):
        cfg = REGISTRY[arch].config.reduced()
        params = jax.eval_shape(
            lambda c=cfg: M.init_params(c, jax.random.PRNGKey(0)))
        specs = shd.tree_specs(params, mesh, "params", cfg=cfg)
        assert len(jax.tree.leaves(params)) == len(
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec)))


def test_assign_divisibility_fallback():
    from jax.sharding import PartitionSpec as P
    mesh = make_host_mesh(1)
    spec = shd.assign((7, 13), mesh, [(("model",), [0, 1])])
    assert spec == P(None, None)  # size-1 axis -> nothing to shard


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import ring_allreduce_int8

    # no axis_types: implicit Auto on old jax, explicit default on new
    mesh = jax.make_mesh((4,), ("data",))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 1000)).astype(np.float32)

    def body(v):
        v = v.reshape(-1)
        total, res = ring_allreduce_int8(v, "data")
        exact = jax.lax.psum(v, "data")
        return total[None], res[None], exact[None]

    try:
        shard_map = jax.shard_map
    except AttributeError:               # jax < 0.5: experimental namespace
        from jax.experimental.shard_map import shard_map
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                           out_specs=P("data")))
    total, res, exact = fn(jnp.asarray(x))
    total, res, exact = map(np.asarray, (total, res, exact))
    scale = np.abs(x).max() * 4 / 127
    err = np.abs(total - exact).max()
    assert err <= 4 * scale + 1e-5, (err, scale)
    # all devices agree
    assert np.allclose(total[0], total[1]) and np.allclose(total[0],
                                                           total[3])
    # residual bounded by one quantization step
    assert np.abs(res).max() <= scale + 1e-6
    print("RING_OK", err / max(np.abs(exact).max(), 1e-9))
""")


def test_int8_ring_allreduce_subprocess():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=300)
    assert "RING_OK" in out.stdout, out.stdout + out.stderr


def test_api_compare_orders_methods():
    from conftest import gpt7b_job
    from repro.core.api import compare
    from repro.core.ga import GAOptions
    from repro.core.schedule import build_comm_dag
    dag = build_comm_dag(gpt7b_job(3))
    res = compare(dag, methods=("prop-alloc", "iter-halve", "delta-fast"),
                  ga_options=GAOptions(time_limit=20, patience=10, seed=0))
    assert all(r.feasible for r in res.values())
    best_baseline = min(res["prop-alloc"].nct, res["iter-halve"].nct)
    assert res["delta-fast"].nct <= best_baseline + 1e-6
