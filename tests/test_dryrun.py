"""Dry-run machinery: HLO analyzer exactness + quick-mode subprocess
(full-mesh lower/compile for representative cells; the complete 40-cell
matrix runs via `python -m repro.launch.dryrun` and is reported in
EXPERIMENTS.md)."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.launch.hloanalysis import analyze


def test_hlo_analyzer_counts_scan_trips():
    probe = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hloanalysis import analyze

        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y.sum()

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                     NamedSharding(mesh, P(None, "model")))
                    ).lower(jax.ShapeDtypeStruct((16, 64), jnp.float32),
                            jax.ShapeDtypeStruct((64, 64), jnp.float32)
                            ).compile()
        cost = analyze(c.as_text())
        assert cost.flops == 7 * 2 * 8 * 16 * 64, cost.flops
        assert cost.collective_bytes["all-gather"] == 7 * 8 * 64 * 4
        print("ANALYZER_OK")
    """)
    out = subprocess.run([sys.executable, "-c", probe],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=300)
    assert "ANALYZER_OK" in out.stdout, out.stdout + out.stderr


def test_parser_handles_tuples_and_fusions():
    txt = """
%helper (p: f32[4,4]) -> f32[4,4] {
  ROOT %d = f32[4,4]{1,0} dot(%p, %p), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  ROOT %fus = f32[4,4]{1,0} fusion(%a), kind=kLoop, calls=%helper
}
"""
    cost = analyze(txt)
    assert cost.flops == 2 * 4 * 4 * 4


@pytest.mark.slow
def test_quick_dryrun_subprocess(tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--quick",
           "--arch", "qwen3-0.6b,granite-moe-1b-a400m,mamba2-130m",
           "--shape", "train_4k,decode_32k", "--mesh", "multi",
           "--out", str(tmp_path)]
    out = subprocess.run(cmd, capture_output=True, text=True,
                         cwd="/root/repo", timeout=900,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert "0 errors" in out.stdout, out.stdout[-3000:] + out.stderr[-2000:]
    cells = list(tmp_path.glob("*.json"))
    assert len(cells) == 6
    for c in cells:
        data = json.loads(c.read_text())
        assert data["status"] == "ok", data
        assert data["flops_per_device"] > 0
        assert data["devices"] == 512
