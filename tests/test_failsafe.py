"""DELTA-Failsafe: degraded-mode DES masks, ledger port failures, priced
repair decisions, the solver fallback chain, and journal crash recovery."""
from __future__ import annotations

import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:   # container image without hypothesis
    import _hypothesis_stub

    _hypothesis_stub.install()
    from hypothesis import given, settings
    from hypothesis import strategies as st

from conftest import gpt7b_job, one_circuit_topology
from repro.core.des import DESProblem, simulate
from repro.core.ga import GAOptions, delta_failsafe, failure_scenarios
from repro.core.milp import (MILPOptions, result_from_topology,
                             solve_delta_milp, solve_resilient,
                             validate_solution)
from repro.fleet import (FabricHealth, FaultInjector, FleetPlanner,
                         FleetSpec, JobArrival, LedgerError, LinkFailure,
                         LinkRecovery, PlanCache, PlaneFailure,
                         PlaneRecovery, PortFailure, PortLedger,
                         PortRecovery, fault_events_from_trace,
                         shrink_to_limits, step_failure_trace)
from repro.obs import FleetJournal
from repro.obs.journal import _json_default

GA = GAOptions(pop_size=12, max_generations=25, patience=8, time_limit=5.0,
               seed=0)

# one cache across planners: chaos traces re-solve the same tenant DAGs
_SHARED_CACHE = PlanCache()


def _job(name="j", pp=4, mb=4):
    return gpt7b_job(mb, name=name, pp=pp, stage_params=(1.75e9,) * pp)


def make_planner(pods=6, ports=16, **kw) -> FleetPlanner:
    kw.setdefault("cache", _SHARED_CACHE)
    return FleetPlanner(FleetSpec(num_pods=pods, ports_per_pod=ports),
                        ga_options=GA, seed=0, **kw)


def _history_json(planner: FleetPlanner) -> str:
    return json.dumps(planner.history, default=_json_default)


# ---------------------------------------------------------- degraded DES
def test_jax_mask_matches_numpy_oracle(small_dag):
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.core.des_jax import JaxDES
    prob = DESProblem(small_dag)
    des = JaxDES(prob)
    P = small_dag.cluster.num_pods
    x = 2 * one_circuit_topology(small_dag)
    rng = np.random.default_rng(0)
    for _ in range(4):
        mask = np.ones((P, P))
        for (i, j) in small_dag.undirected_pairs():
            if rng.random() < 0.6:
                f = float(rng.uniform(0.25, 1.0))
                mask[i, j] = mask[j, i] = f
        got = des.makespan(x, mask=mask)
        want = simulate(prob, x.astype(np.float64) * mask).makespan
        assert got == pytest.approx(want, rel=1e-4)


def test_jax_dead_link_is_inf_in_both_engines(small_dag):
    pytest.importorskip("jax")
    from repro.core.des_jax import JaxDES
    prob = DESProblem(small_dag)
    des = JaxDES(prob)
    P = small_dag.cluster.num_pods
    x = one_circuit_topology(small_dag)
    i, j = small_dag.undirected_pairs()[0]
    mask = np.ones((P, P))
    mask[i, j] = mask[j, i] = 0.0
    assert not np.isfinite(des.makespan(x, mask=mask))
    assert not np.isfinite(
        simulate(prob, x.astype(np.float64) * mask).makespan)


def test_mask_is_traced_not_recompiled(small_dag):
    pytest.importorskip("jax")
    from repro.core.des_jax import JaxDES, des_cache_stats
    prob = DESProblem(small_dag)
    des = JaxDES(prob)
    P = small_dag.cluster.num_pods
    x = one_circuit_topology(small_dag)
    des.makespan(x)                      # warm the compile bucket
    before = des_cache_stats()["misses"]
    rng = np.random.default_rng(1)
    for _ in range(5):
        mask = rng.uniform(0.3, 1.0, size=(P, P))
        mask = (mask + mask.T) / 2
        des.makespan(x, mask=mask)
    assert des_cache_stats()["misses"] == before


def test_ensemble_per_member_masks(small_dag, tiny_dag):
    pytest.importorskip("jax")
    from repro.core.des_jax import EnsembleJaxDES
    members = [small_dag, tiny_dag]
    des = EnsembleJaxDES([DESProblem(d) for d in members])
    P = small_dag.cluster.num_pods
    x = 2 * one_circuit_topology(small_dag)
    masks = np.stack([np.ones((P, P)), np.full((P, P), 0.5)])
    ms, feas = des.makespans(x, masks=masks)
    assert feas.all()
    for m, (dag, mask) in zip(ms, zip(members, masks)):
        want = simulate(DESProblem(dag),
                        x.astype(np.float64) * mask).makespan
        assert m == pytest.approx(want, rel=1e-4)


# ------------------------------------------------------- ledger failures
def test_ledger_fail_ports_escalation_and_conservation():
    led = PortLedger([8, 8])
    led.admit("a", [4, 0])
    led.commit("a", [3, 0])
    led.admit("b", [2, 2])
    led.commit("b", [2, 2])
    # pool at pod 0 is 2; failing 3 eats the pool then seizes a's surplus
    assert led.fail_ports(0, 3) == []
    led.check()
    assert led.failed[0] == 3
    assert led.account("a").seized[0] == 1
    # failing 3 more must strand someone (only allocated ports remain)
    stranded = led.fail_ports(0, 3)
    assert stranded
    # stranded tenants wire more than their reduced limits: check() fails
    # until the caller re-commits a smaller plan (what replan_reduced does)
    with pytest.raises(LedgerError):
        led.check()
    for name in stranded:
        acct = led.account(name)
        assert (acct.allocated > acct.limits).any()
        led.commit(name, np.minimum(acct.allocated, acct.limits))
    led.check()
    # restoration makes seized accounts whole first, then refills the pool
    led.restore_ports(0, 6)
    led.check()
    assert led.failed[0] == 0
    assert led.account("a").seized.sum() == 0
    assert led.account("b").seized.sum() == 0


def test_ledger_fail_ports_clamps_and_snapshot_roundtrip():
    led = PortLedger([4, 4])
    led.admit("a", [2, 1])
    led.commit("a", [1, 1])
    stranded = led.fail_ports(0, 99)   # clamped to capacity
    assert led.failed[0] == 4
    assert stranded == ["a"]
    acct = led.account("a")
    led.commit("a", np.minimum(acct.allocated, acct.limits))
    led.check()
    clone = PortLedger.from_snapshot(led.snapshot())
    assert (clone.failed == led.failed).all()
    acct, acct2 = led.account("a"), clone.account("a")
    for f in ("entitled", "donated", "granted", "allocated", "seized"):
        assert (getattr(acct, f) == getattr(acct2, f)).all()
    with pytest.raises(LedgerError):
        led.fail_ports(0, -1)


def test_shrink_to_limits_fits_and_is_deterministic():
    x = np.array([[0, 3, 2], [3, 0, 1], [2, 1, 0]], dtype=np.int64)
    limits = np.array([3, 2, 2])
    y = shrink_to_limits(x, limits)
    assert (y.sum(axis=1) <= limits).all()
    assert (y == y.T).all() and (y >= 0).all()
    assert (shrink_to_limits(x, limits) == y).all()


# -------------------------------------------------------- fault modeling
def test_fabric_health_masks_and_snapshot():
    h = FabricHealth(num_pods=3, num_planes=4)
    assert h.healthy and h.mask().min() == 1.0
    h.fail_link((0, 1), 0.5)
    h.fail_link((0, 1), 0.25)          # cumulative
    assert h.mask()[0, 1] == pytest.approx(0.25)
    h.fail_plane(2)
    assert h.plane_factor == pytest.approx(0.75)
    assert h.mask()[1, 2] == pytest.approx(0.75)
    assert h.degraded_pairs() == [(0, 1), (0, 2), (1, 2)]
    assert h.affects([1, 2])
    h2 = FabricHealth.from_snapshot(h.snapshot())
    assert np.allclose(h2.mask(), h.mask())
    h.recover_plane(2)
    h.recover_link((0, 1))
    assert h.healthy


def test_fault_injector_is_seeded_and_shared_format():
    t1 = FaultInjector(num_pods=4, seed=7).trace(20)
    t2 = FaultInjector(num_pods=4, seed=7).trace(20)
    assert t1 == t2
    assert t1 != FaultInjector(num_pods=4, seed=8).trace(20)
    steps = [ev["step"] for ev in t1]
    assert steps == sorted(steps)
    events = fault_events_from_trace(t1)
    assert len(events) == len(t1)
    # step failures ride the same trace format but go to the training loop
    from repro.distributed.fault_tolerance import FailureInjector
    mixed = t1 + step_failure_trace([3, 9])
    inj = FailureInjector.from_trace(mixed)
    assert inj.fail_at == (3, 9)
    assert len(fault_events_from_trace(mixed)) == len(t1)
    assert inj.to_trace() == step_failure_trace([3, 9])
    with pytest.raises(ValueError):
        fault_events_from_trace([{"step": 0, "kind": "nope"}])


# ------------------------------------------------------- delta_failsafe
def test_delta_failsafe_worst_case(tiny_dag):
    scen = failure_scenarios(tiny_dag, num_planes=4, k=1)
    assert len(scen) == len(tiny_dag.undirected_pairs()) + 1
    res = delta_failsafe(tiny_dag, GA, scenarios=scen)
    assert res.feasible
    assert len(res.makespans) == len(scen)
    # scenario 0 is the healthy fabric; every degraded scenario is at
    # least as slow, and the reported makespans are exact (numpy) values
    prob = DESProblem(tiny_dag)
    for m, ms in zip(scen, res.makespans):
        assert ms == pytest.approx(
            simulate(prob, res.x.astype(np.float64) * m).makespan, rel=1e-9)
        assert ms >= res.makespans[0] - 1e-9
    with pytest.raises(ValueError):
        delta_failsafe(tiny_dag, GA, objective="nope")


# ------------------------------------------------- solver fallback chain
def _force_milp_timeout(monkeypatch):
    """scipy.optimize.milp returning time-limit with NO incumbent."""
    class FakeRes:
        status = 1
        x = None
        mip_gap = None
        message = "time limit reached (no incumbent)"

    monkeypatch.setattr("repro.core.milp.milp",
                        lambda *a, **kw: FakeRes())


def test_milp_time_limit_without_incumbent_is_infeasible(tiny_dag,
                                                         monkeypatch):
    _force_milp_timeout(monkeypatch)
    res = solve_delta_milp(tiny_dag, MILPOptions(time_limit=1.0))
    assert res.status == "time_limit"
    assert not np.isfinite(res.makespan)
    assert not res.feasible          # the clean fallback trigger


def test_solve_resilient_milp_timeout_falls_back_to_ga(tiny_dag,
                                                       monkeypatch):
    _force_milp_timeout(monkeypatch)
    res = solve_resilient(tiny_dag, MILPOptions(time_limit=1.0),
                          budget_s=5.0, ga_options=GA)
    assert res.feasible and res.degraded and res.fallback_stage == "ga"
    assert validate_solution(tiny_dag, res) == []


def test_solve_resilient_solver_exception_falls_back(tiny_dag, monkeypatch):
    calls = {"n": 0}

    def boom(*a, **kw):
        calls["n"] += 1
        raise RuntimeError("solver crashed")

    monkeypatch.setattr("repro.core.milp.milp", boom)
    res = solve_resilient(tiny_dag, MILPOptions(time_limit=1.0),
                          budget_s=5.0, retries=1, backoff_s=0.0,
                          ga_options=GA)
    assert calls["n"] >= 2           # retried before falling back
    assert res.feasible and res.degraded and res.fallback_stage == "ga"
    assert validate_solution(tiny_dag, res) == []


def test_solve_resilient_last_resort_current_plan(tiny_dag, monkeypatch):
    _force_milp_timeout(monkeypatch)

    def ga_down(*a, **kw):
        raise RuntimeError("ga unavailable")

    monkeypatch.setattr("repro.core.ga.delta_fast", ga_down)
    P = tiny_dag.cluster.num_pods
    mask = np.full((P, P), 0.5)
    cur = 2 * one_circuit_topology(tiny_dag)
    res = solve_resilient(tiny_dag, MILPOptions(time_limit=1.0),
                          budget_s=5.0, current_x=cur, mask=mask)
    assert res.feasible and res.degraded and res.fallback_stage == "current"
    assert (res.x == cur).all()
    # masked capacities only shrink, so the DES schedule still satisfies
    # the nominal Eq. 9 link caps of the integer topology
    assert validate_solution(tiny_dag, res) == []
    # and the masked makespan really is the degraded one
    want = simulate(DESProblem(tiny_dag),
                    cur.astype(np.float64) * mask).makespan
    assert res.makespan == pytest.approx(want, rel=1e-9)


def test_result_from_topology_is_validate_clean(tiny_dag):
    x = one_circuit_topology(tiny_dag)
    res = result_from_topology(tiny_dag, x)
    assert res.feasible
    assert validate_solution(tiny_dag, res) == []
    # an all-dead mask partitions the job: priced honestly as infeasible
    P = tiny_dag.cluster.num_pods
    dead = result_from_topology(tiny_dag, x, mask=np.zeros((P, P)))
    assert dead.status == "infeasible" and not dead.feasible


# --------------------------------------------------------- fleet repairs
def test_plane_failure_keeps_topology_uniform_haircut():
    pl = make_planner()
    pl.handle(JobArrival(name="a", job=_job("ja")))
    ms0 = pl.tenants["a"].plan.makespan
    rec = pl.handle(PlaneFailure(plane=0))
    (dec,) = rec["repairs"]
    # a dark plane scales every pair by 3/4: no rewiring can help, so the
    # priced decision keeps the topology and inflates the makespan ~4/3
    assert dec["option"] in ("keep", "rewire")
    assert pl.tenants["a"].plan.makespan >= ms0
    assert "a" in pl._degraded
    rec = pl.handle(PlaneRecovery(plane=0))
    (dec,) = rec["repairs"]
    assert dec["option"] == "healthy"
    assert pl._degraded == set()
    assert pl.tenants["a"].plan.makespan == pytest.approx(ms0, rel=1e-9)
    pl.ledger.check()


def test_dead_pair_is_priced_as_partition():
    pl = make_planner()
    pl.handle(JobArrival(name="a", job=_job("ja")))
    pair = tuple(pl.tenants["a"].dag.undirected_pairs()[0])
    rec = pl.handle(LinkFailure(pair=pair, fraction=1.0))
    (dec,) = rec["repairs"]
    # every option routes pair traffic over zero surviving capacity
    assert not np.isfinite(dec["makespan"])
    assert not np.isfinite(pl.tenants["a"].plan.makespan)
    rec = pl.handle(LinkRecovery(pair=pair))
    assert rec["repairs"][0]["option"] == "healthy"
    assert np.isfinite(pl.tenants["a"].plan.makespan)


def test_partial_link_failure_prices_all_options():
    pl = make_planner(replan_threshold=0.0)   # always price the full replan
    pl.handle(JobArrival(name="a", job=_job("ja")))
    dag = pl.tenants["a"].dag
    vol = dag.traffic_matrix()
    pair = max(dag.undirected_pairs(),
               key=lambda e: vol[e[0], e[1]] + vol[e[1], e[0]])
    rec = pl.handle(LinkFailure(pair=pair, fraction=0.75))
    (dec,) = rec["repairs"]
    assert set(dec["options"]) >= {"keep", "rewire", "replan"}
    assert dec["options"]["keep"]["delay_s"] == 0.0
    costs = {n: o["cost_s"] for n, o in dec["options"].items()}
    assert dec["cost_s"] == min(costs.values())
    # the committed plan carries the winner's exact masked pricing
    mask = pl.health.local_mask(pl.tenants["a"].pods)
    want = simulate(DESProblem(pl.tenants["a"].dag),
                    pl.tenants["a"].plan.x.astype(np.float64) * mask)
    assert pl.tenants["a"].plan.makespan == pytest.approx(want.makespan,
                                                          rel=1e-9)
    pl.ledger.check()


def test_port_failure_strands_and_recovers_through_replan():
    pl = make_planner(pods=4, ports=8)
    pl.handle(JobArrival(name="a", job=_job("ja")))
    x_before = pl.tenants["a"].plan.x.copy()
    pod = int(pl.tenants["a"].pods[0])
    rec = pl.handle(PortFailure(pod=pod, count=8))
    assert rec["stranded"] == ["a"]
    assert rec["replans"] and rec["replans"][0]["tenant"] == "a"
    limits = pl.ledger.limits("a")
    assert (pl.tenants["a"].fleet_usage(pl.fleet.num_pods) <= limits).all()
    assert "a" in pl._shrunk
    pl.ledger.check()
    rec = pl.handle(PortRecovery(pod=pod, count=8))
    assert pl.ledger.account("a").seized.sum() == 0
    assert "a" not in pl._shrunk
    # full budget back -> the cached original plan returns
    assert (pl.tenants["a"].plan.x == x_before).all()
    pl.ledger.check()


# -------------------------------------------------------- crash recovery
def _scripted_events():
    return [
        JobArrival(name="a", job=_job("ja")),
        JobArrival(name="b", job=_job("jb", pp=2), port_min=True),
        LinkFailure(pair=(0, 1), fraction=0.5),
        PlaneFailure(plane=0),
        PortFailure(pod=0, count=10),
        PortRecovery(pod=0, count=10),
        LinkRecovery(pair=(0, 1)),
        PlaneRecovery(plane=0),
    ]


def test_snapshot_journal_recovery_is_bit_identical(tmp_path):
    path = tmp_path / "journal.jsonl"
    pl = make_planner(snapshot_every=3, journal=FleetJournal(path))
    for ev in _scripted_events():
        pl.handle(ev)
    pl.journal.close()
    assert sum(1 for e in FleetJournal.load(path)
               if e["kind"] == "fleet_snapshot") >= 2

    pl2 = FleetPlanner.recover(str(path), pl.fleet, ga_options=GA, seed=0,
                               cache=PlanCache(), snapshot_every=3)
    assert _history_json(pl) == _history_json(pl2)
    assert pl.rng.bit_generator.state == pl2.rng.bit_generator.state
    assert pl.ledger.snapshot() == pl2.ledger.snapshot()
    for name, t in pl.tenants.items():
        t2 = pl2.tenants[name]
        assert (t.plan.x == t2.plan.x).all()
        assert t.plan.makespan == t2.plan.makespan
        assert t.plan.nct == t2.plan.nct


def test_recovery_without_snapshot_replays_whole_journal(tmp_path):
    path = tmp_path / "journal.jsonl"
    # both sides must start with a cold cache: a full replay re-plans the
    # arrivals, and a warm cache on one side would skip the planning work
    # (and its rng draws) that the other side performs
    pl = make_planner(journal=FleetJournal(path), cache=PlanCache())
    for ev in _scripted_events()[:4]:
        pl.handle(ev)
    pl.journal.close()
    pl2 = FleetPlanner.recover(str(path), pl.fleet, ga_options=GA, seed=0,
                               cache=PlanCache())
    assert _history_json(pl) == _history_json(pl2)


# ------------------------------------------------------------ chaos test
@settings(max_examples=5)
@given(st.integers(0, 2**31 - 1))
def test_chaos_traces_preserve_invariants(seed):
    """Property: any seeded failure trace through a loaded planner keeps
    ledger conservation after every event, raises nothing, and replays
    from the journal to identical decisions."""
    pl = make_planner(snapshot_every=4)
    pl.handle(JobArrival(name="a", job=_job("ja")))
    pl.handle(JobArrival(name="b", job=_job("jb", pp=2), port_min=True))
    inj = FaultInjector(num_pods=pl.fleet.num_pods, seed=seed,
                        max_fraction=0.9)
    for ev in fault_events_from_trace(inj.trace(8)):
        pl.handle(ev)            # handle() runs ledger.check() each event
        for name in pl.tenants:
            acct = pl.ledger.account(name)
            assert (acct.allocated + acct.surplus == acct.limits).all()
    pl2 = FleetPlanner.recover(pl.journal.entries, pl.fleet, ga_options=GA,
                               seed=0, cache=_SHARED_CACHE,
                               snapshot_every=4)
    assert _history_json(pl) == _history_json(pl2)
